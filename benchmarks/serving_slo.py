"""Serving-level SLO benchmark (beyond paper): CoCaR-quality caching vs
naive residency under a Poisson load sweep, measured as p95 latency / SLO
attainment / delivered precision through the queueing simulator.

This closes the loop between the paper's control plane (which submodels are
resident) and serving-infrastructure metrics (latency percentiles).
"""
from __future__ import annotations


from benchmarks import common
from repro import configs
from repro.models import partition
from repro.serving.simulator import QueueSim, poisson_arrivals

MODELS = {"qwen": configs.get_smoke("qwen1.5-0.5b"),
          "glm": configs.get_smoke("chatglm3-6b"),
          "mix": configs.get_smoke("mixtral-8x7b")}
POP = [0.6, 0.3, 0.1]
N_PODS = 3


def _residency(policy: str):
    """Three hand-constructed residency profiles standing in for control-
    plane outputs of decreasing quality."""
    names = list(MODELS)
    if policy == "cocar":      # demand-weighted depths + full coverage
        return {0: {"qwen": 2, "glm": 0},
                1: {"qwen": 2, "mix": 0},
                2: {"glm": 2, "qwen": 0, "mix": 0}}
    if policy == "greedy":     # biggest submodels of the popular model only
        return {p: {"qwen": 2} for p in range(N_PODS)}
    return {p: {names[p % 3]: 1} for p in range(N_PODS)}   # "random"


def main():
    cfg = list(MODELS.values())[0]
    c = partition.submodel_flops_per_token(cfg, cfg.n_exits - 1, ctx=64)
    compute = 64 * c / 0.05                      # full request ~50 ms
    out = {}
    for rate in (5.0, 40.0, 120.0):
        out[rate] = {}
        for policy in ("cocar", "greedy", "random"):
            sim = QueueSim(MODELS, _residency(policy), compute, seed=1)
            arr = poisson_arrivals(rate, 30.0, list(MODELS), POP,
                                   tokens=64, slo_s=2.0, seed=1)
            m = sim.run(arr)
            out[rate][policy] = m
            common.csv_row(
                f"serving_slo_r{rate:.0f}_{policy}", 0,
                f"slo={m['slo_attainment']:.3f};p95={m['p95_latency']:.3f};"
                f"prec={m['avg_precision']:.3f}")
    common.save("serving_slo", out)
    return out


if __name__ == "__main__":
    main()
