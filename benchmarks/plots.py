"""Render the paper's figures from saved benchmark JSONs -> results/plots/.

  PYTHONPATH=src python -m benchmarks.plots
"""
from __future__ import annotations

import json

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

from benchmarks.common import RESULTS  # noqa: E402

PLOTS = RESULTS.parent / "plots"

STYLE = {"cocar": ("CoCaR", "o-"), "cocar-ol": ("CoCaR-OL", "o-"),
         "greedy": ("Greedy", "s--"), "spr3": ("SPR³", "^--"),
         "random": ("Random", "x:"), "lfu": ("LFU", "v--"),
         "lfu-mad": ("LFU-MAD", "d--"), "gatmarl": ("GatMARL", "*--"),
         "lr": ("LR", "k-.")}


def _sweep_plot(name, metric, xlabel, ylabel, title, fname):
    path = RESULTS / f"{name}.json"
    if not path.exists():
        return None
    data = json.loads(path.read_text())
    fig, ax = plt.subplots(figsize=(5, 3.4))
    algos = sorted({a for v in data.values() for a in v})
    for a in algos:
        xs, ys = [], []
        for x, block in sorted(data.items(), key=lambda kv: float(kv[0])):
            if a in block and metric in block[a]:
                xs.append(float(x))
                ys.append(block[a][metric])
        if xs:
            label, fmt = STYLE.get(a, (a, "-"))
            ax.plot(xs, ys, fmt, label=label, markersize=4)
    ax.set_xlabel(xlabel)
    ax.set_ylabel(ylabel)
    ax.set_title(title, fontsize=10)
    ax.grid(alpha=0.3)
    ax.legend(fontsize=7)
    fig.tight_layout()
    out = PLOTS / fname
    fig.savefig(out, dpi=130)
    plt.close(fig)
    return out


def roofline_plot(mesh="16x16"):
    md = RESULTS.parent / f"roofline_{mesh}.md"
    if not md.exists():
        return None
    rows = []
    for line in md.read_text().splitlines()[2:]:
        cells = [c.strip() for c in line.strip("|").split("|")]
        if len(cells) < 8 or cells[2] == "—":
            continue
        rows.append((f"{cells[0]}\n{cells[1]}", float(cells[2]),
                     float(cells[3]), float(cells[4])))
    rows.sort(key=lambda r: -(r[1] + r[2] + r[3]))
    rows = rows[:14]
    fig, ax = plt.subplots(figsize=(9, 4))
    xs = range(len(rows))
    ax.bar(xs, [r[1] for r in rows], label="compute", color="#4c72b0")
    ax.bar(xs, [r[2] for r in rows], bottom=[r[1] for r in rows],
           label="memory", color="#dd8452")
    ax.bar(xs, [r[3] for r in rows],
           bottom=[r[1] + r[2] for r in rows], label="collective",
           color="#55a868")
    ax.set_xticks(list(xs))
    ax.set_xticklabels([r[0] for r in rows], fontsize=6, rotation=45,
                       ha="right")
    ax.set_ylabel("roofline terms (s/step/device)")
    ax.set_title(f"Roofline terms per cell — {mesh}", fontsize=10)
    ax.legend(fontsize=8)
    fig.tight_layout()
    out = PLOTS / f"roofline_{mesh}.png"
    fig.savefig(out, dpi=130)
    plt.close(fig)
    return out


def policy_comparison_plot():
    """Sec. VII-B headline bars from the fused policy grid: grid-mean
    served precision + QoE per policy (``BENCH_baselines.json``)."""
    path = RESULTS / "BENCH_baselines.json"
    if not path.exists():
        return None
    comp = json.loads(path.read_text()).get("comparison")
    if not comp:
        return None
    order = sorted(comp["means"], key=lambda p: -comp["means"][p])
    fig, ax = plt.subplots(figsize=(5.5, 3.4))
    xs = range(len(order))
    ax.bar([x - 0.2 for x in xs], [comp["means"][p] for p in order],
           width=0.4, label="avg precision", color="#4c72b0")
    if "avg_qoe" in comp:
        ax.bar([x + 0.2 for x in xs], [comp["avg_qoe"][p] for p in order],
               width=0.4, label="avg QoE", color="#dd8452")
    ax.set_xticks(list(xs))
    ax.set_xticklabels([STYLE.get(p, (p,))[0] for p in order], fontsize=8)
    ax.set_ylabel("grid mean")
    ax.set_title(f"Sec. VII-B policy comparison — CoCaR "
                 f"{comp['improvement_ratio']:.2f}x best baseline",
                 fontsize=10)
    ax.grid(alpha=0.3, axis="y")
    ax.legend(fontsize=8)
    fig.tight_layout()
    out = PLOTS / "policy_comparison.png"
    fig.savefig(out, dpi=130)
    plt.close(fig)
    return out


def main():
    PLOTS.mkdir(parents=True, exist_ok=True)
    made = [
        _sweep_plot("fig6_memory", "avg_precision", "BS memory (MB)",
                    "avg precision", "Fig 6a — memory capacity (offline)",
                    "fig6_precision.png"),
        _sweep_plot("fig6_memory", "hit_rate", "BS memory (MB)", "hit rate",
                    "Fig 6b — memory capacity (offline)", "fig6_hitrate.png"),
        _sweep_plot("fig8_zipf", "avg_precision", "Zipf skewness",
                    "avg precision", "Fig 8a — Zipf skew (offline)",
                    "fig8_precision.png"),
        _sweep_plot("fig12_memory_online", "avg_qoe", "BS memory (MB)",
                    "avg QoE", "Fig 12a — memory capacity (online)",
                    "fig12_qoe.png"),
        _sweep_plot("fig13_popfreq_online", "avg_qoe",
                    "popularity change period (slots)", "avg QoE",
                    "Fig 13a — popularity change (online)", "fig13_qoe.png"),
        _sweep_plot("fig14_zipf_online", "avg_qoe", "Zipf skewness",
                    "avg QoE", "Fig 14a — Zipf skew (online)",
                    "fig14_qoe.png"),
        policy_comparison_plot(),
        roofline_plot("16x16"),
        roofline_plot("2x16x16"),
    ]
    for m in made:
        if m:
            print("wrote", m)


if __name__ == "__main__":
    main()
