"""Paper Sec. III motivating example: static (complete models) vs. dynamic
(submodel switching) caching at one BS over two observation windows.

Uses the paper's own metric definitions:
  P_avg = Σ_h ⌊u_h/|τ| · (|τ| − l_h)⌋ · p_h / U_total
  H_avg = Σ_h ⌊u_h/|τ| · (|τ| − l_h)⌋ / U_total
"""
from __future__ import annotations

import math

from repro.configs.vit_edge import MOTIVATING

WINDOW = 5.0
CAP_GB = 2.0


def _served(users, load_s):
    return math.floor(users / WINDOW * (WINDOW - load_s))


def run_example():
    A, B = MOTIVATING["A"], MOTIVATING["B"]
    demand = [(60, 40), (20, 80)]              # (A, B) users per window
    total = sum(a + b for a, b in demand)

    # ---- static: complete models only ------------------------------------
    sP = sH = 0.0
    # w1: cache full A (both full models exceed 2 GB); B dropped
    n = _served(demand[0][0], A[2]["load_s"])
    sP += n * A[2]["precision"]
    sH += n
    # w2: evict A, cold-load full B
    n = _served(demand[1][1], B[2]["load_s"])
    sP += n * B[2]["precision"]
    sH += n
    static = {"avg_precision": sP / total, "hit_rate": sH / total}

    # ---- dynamic: submodel switching --------------------------------------
    dP = dH = 0.0
    # w1: A sub2 + B sub2 (0.8 + 1.0 GB <= 2 GB)
    for users, sub in ((demand[0][0], A[1]), (demand[0][1], B[1])):
        n = _served(users, sub["load_s"])
        dP += n * sub["precision"]
        dH += n
    # w2: upgrade B 2->3 (Δ-switch), downgrade A 2->1 (cheap prune)
    n = _served(demand[1][1], MOTIVATING["switch_B2_to_B3_s"])
    dP += n * B[2]["precision"]
    dH += n
    n = _served(demand[1][0], A[0]["load_s"])
    dP += n * A[0]["precision"]
    dH += n
    dynamic = {"avg_precision": dP / total, "hit_rate": dH / total}
    return static, dynamic


def main():
    static, dynamic = run_example()
    print(f"static : P_avg={static['avg_precision']:.3f} "
          f"H_avg={static['hit_rate']:.3f}")
    print(f"dynamic: P_avg={dynamic['avg_precision']:.3f} "
          f"H_avg={dynamic['hit_rate']:.3f}")
    print("paper reports 0.51 vs 0.87 precision (dynamic wins by +0.36)")
    return static, dynamic


if __name__ == "__main__":
    main()
