"""Closed-loop serving bench: control-plane decisions drive the queue
simulator under *measured* loading times.

This is the decision bridge end-to-end — no hand-constructed residency
profiles anywhere.  Four blocks, persisted as
``results/bench/BENCH_serving.json`` and gated by
``scripts/check_bench.py``:

  * **offline** — a measured catalog (loading latencies from the actual
    parameter-tree bytes each submodel transition transfers, over a
    Table III-cross-checked bandwidth) is optimized by all five offline
    policies (``policy_grid_device``), each policy's integral caching
    arrays are exported (``export_cache_plans``) into
    :class:`~repro.serving.plan.ServingPlan`\\ s, and every plan runs
    through ``QueueSim`` twice per Poisson rate — idealised instant
    loading vs the plan's measured loading delay.  The headline flag
    ``ranking_preserved`` records whether CoCaR still beats every
    baseline on delivered precision once loading delay is simulated.
    Every run is tapped by the request-level telemetry (``repro.obs``):
    a shared event log (conservation-checked: each arrival terminates
    exactly once) and per-policy merged streaming histograms, from which
    each policy gets an ``attribution`` block — the fraction of
    delivered latency spent queueing vs loading-stalled vs in service,
    with phase percentiles — and the per-request identity
    ``queue_s + stall_s + service_s == latency`` is asserted exact to
    1e-9 over the whole bench;
  * **agreement** — the catalog's D_m seconds == the seconds
    ``serving.loader.PodCache`` actually takes for the same transitions
    (same ``delta_bytes`` math, byte-for-byte; lazy weight store, so the
    multi-GB checkpoints never materialize);
  * **online** — a CoCaR-OL run over the same measured-catalog scenario
    with ``record_states=True``: per-slot cache/download states become
    per-slot serving plans, the ``mid_download_never_serves`` invariant
    is checked non-vacuously (Eq. 37: a submodel mid-download must not
    serve), numpy and scan engines must record identical states, and
    sampled slot plans execute through the queue simulator;
  * **cluster** — one online plan applied to the real-generation
    ``EdgeCluster`` (``apply_caching`` + load ticks + actual
    prefill/decode), proving the bridge reaches running weights.

Every block runs at one fixed scale (independent of REPRO_BENCH_FULL),
so the flags and the ``cocar_over_best_baseline`` drift gate engage on
CI smoke, local, and nightly runs alike.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_serving
Quick CI smoke:  PYTHONPATH=src python -m benchmarks.bench_serving --smoke
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro import configs
from repro.core import cocar as CC
from repro.core.online import OnlineConfig, run_online
from repro.mec.catalog import crosscheck_table3, make_catalog
from repro.mec.scenario import MECConfig, Scenario, stack_instances
from repro.obs import TRACER, EventLog, MetricsRegistry, observe_online_diag
from repro.serving.loader import PodCache, WeightStore
from repro.serving.plan import (catalog_precisions,
                                check_mid_download_never_serves,
                                execute_plan, plan_from_offline,
                                plans_from_online_states)
from repro.serving.simulator import poisson_arrivals

# offline block: real (full-config) checkpoints, GB-scale — loading
# delay is seconds, not noise.  Byte math is eval_shape-only, so no
# weights ever materialize here.
ARCHS = ("qwen1.5-0.5b", "zamba2-1.2b", "xlstm-125m", "whisper-small")
N_PODS, N_USERS, N_WINDOWS = 4, 150, 2
PDHG_ITERS, BEST_OF, EPISODES = 600, 4, 30
RATES = (4.0, 20.0, 60.0)
DURATION_S, TOKENS, SLO_S = 20.0, 64, 0.5
CAPACITY_MB = 3000.0

# online/cluster block: smoke-scale configs (weights are actually run in
# the cluster block), same measured-catalog construction.
ONLINE_ARCHS = ("qwen1.5-0.5b", "chatglm3-6b", "stablelm-12b")
ONLINE_SLOTS = 40


def _offline_scenario():
    cfgs = {a: configs.get_config(a) for a in ARCHS}
    cat = make_catalog("measured", cfgs=cfgs, tokens=TOKENS)
    # compute sized so a mean full-depth request takes ~50 ms — the SAME
    # figure enters the LP's inference latency (flops_req / C) and the
    # queue simulator's service_time, so the two planes agree
    compute_gflops = float(cat.flops[:, -1].mean() / 0.05)
    mcfg = MECConfig(n_bs=N_PODS, n_users=N_USERS, n_models=len(ARCHS),
                     n_windows=N_WINDOWS, mem_capacity_mb=CAPACITY_MB,
                     compute_gflops=compute_gflops, zipf=0.8, seed=0)
    return cfgs, cat, Scenario(mcfg, catalog=cat)


def _mean(rows, key):
    return float(np.mean([r[key] for r in rows]))


#: per-request latency attribution must telescope exactly (Eq. 40 terms)
ATTRIBUTION_TOL = 1e-9
_PHASES = ("queue", "stall", "service")
_ROW_KEYS = ("slo_attainment", "p50_latency", "p95_latency",
             "p99_latency", "avg_precision", "served", "deadline_misses")


def bench_offline(events: EventLog = None,
                  registry: MetricsRegistry = None):
    """All five policies' actual decisions, executed with vs without
    their measured loading delay, across a Poisson rate sweep.

    ``events``/``registry`` attach the request-level telemetry taps
    (decision-inert; the numbers below are identical without them): one
    lifecycle event per request phase into the shared log, and one
    metrics registry per (policy, window, rate) run, merged per policy —
    the merge order never matters (fixed-bucket histograms) — to pool
    phase percentiles across the rate sweep."""
    cfgs, cat, sc = _offline_scenario()
    names = list(ARCHS)
    compute_flops = sc.cfg.compute_gflops * 1e9

    with TRACER.span("serving:control_plane", windows=N_WINDOWS,
                     policies=len(CC.OFFLINE_POLICIES)):
        insts = [sc.instance(w, sc.empty_cache()) for w in range(N_WINDOWS)]
        stacked = stack_instances(insts)
        grid = CC.policy_grid_device(stacked, seed=0,
                                     pdhg_iters=PDHG_ITERS,
                                     best_of=BEST_OF, n_seeds=1,
                                     episodes=EPISODES)
        plans = CC.export_cache_plans(grid, stacked)

    per_policy = {}
    max_att_err = 0.0
    with TRACER.span("serving:data_plane", rates=len(RATES)):
        for p in CC.OFFLINE_POLICIES:
            ideal_rows, delayed_rows = [], []
            max_load = 0.0
            reg_p = MetricsRegistry()
            for w in range(N_WINDOWS):
                # window 0 is a cold start; window 1 loads only the Δ
                # from the same policy's previous decision
                prev = plans[p][w - 1]["x"] if w else None
                plan = plan_from_offline(plans[p][w]["x"], names,
                                         catalog=cat, x_prev=prev,
                                         policy=p,
                                         routing=plans[p][w]["A"])
                max_load = max(max_load, plan.max_load_s())
                for k, rate in enumerate(RATES):
                    arr = lambda: poisson_arrivals(  # noqa: E731
                        rate, DURATION_S, names, sc.pop, tokens=TOKENS,
                        slo_s=SLO_S, seed=100 * w + k)
                    reg_run = MetricsRegistry()
                    ideal_rows.append(execute_plan(
                        plan, cfgs, compute_flops, arr(), catalog=cat,
                        names=names, with_load_delay=False,
                        events=events))
                    delayed_rows.append(execute_plan(
                        plan, cfgs, compute_flops, arr(), catalog=cat,
                        names=names, with_load_delay=True,
                        events=events, registry=reg_run))
                    reg_p.merge(reg_run)
            max_att_err = max(
                max_att_err,
                max(r["attribution_max_err"]
                    for r in ideal_rows + delayed_rows))
            # pooled attribution: exact phase fractions from per-run
            # sums, percentiles from the merged streaming histograms
            sums = {ph: sum(r["attribution"][ph]["sum"]
                            for r in delayed_rows) for ph in _PHASES}
            lat_total = sum(sums.values())
            hists = {ph: reg_p.histogram(f"request_{ph}_seconds")
                     for ph in _PHASES}
            attribution = {
                ph: {"frac": sums[ph] / lat_total if lat_total else 0.0,
                     "p50": hists[ph].percentile(50),
                     "p95": hists[ph].percentile(95),
                     "p99": hists[ph].percentile(99)}
                for ph in _PHASES}
            per_policy[p] = {
                "lp_avg_precision": float(np.mean(
                    [plans[p][w]["metrics"]["avg_precision"]
                     for w in range(N_WINDOWS)])),
                "max_load_s": max_load,
                "ideal": {k: _mean(ideal_rows, k) for k in _ROW_KEYS},
                "delayed": {k: _mean(delayed_rows, k) for k in _ROW_KEYS},
                "attribution": attribution,
            }
            if registry is not None:
                registry.merge(reg_p)
            common.csv_row(
                f"serving_{p}", 0,
                f"slo={per_policy[p]['delayed']['slo_attainment']:.3f};"
                f"p95={per_policy[p]['delayed']['p95_latency']:.3f};"
                f"prec={per_policy[p]['delayed']['avg_precision']:.3f}")

    delayed_prec = {p: per_policy[p]["delayed"]["avg_precision"]
                    for p in CC.OFFLINE_POLICIES}
    best_base = max(v for p, v in delayed_prec.items() if p != "cocar")
    return {
        "n_pods": N_PODS, "n_models": len(ARCHS), "n_users": N_USERS,
        "n_windows": N_WINDOWS, "pdhg_iters": PDHG_ITERS,
        "best_of": BEST_OF, "episodes": EPISODES, "rates": list(RATES),
        "duration_s": DURATION_S, "tokens": TOKENS, "slo_s": SLO_S,
        "capacity_mb": CAPACITY_MB,
        "compute_gflops": sc.cfg.compute_gflops,
        "catalog": {"source": cat.source,
                    "bandwidth_MBps": cat.bandwidth_MBps,
                    "full_sizes_mb": cat.sizes[:, -1].tolist(),
                    "max_cold_load_s": float(cat.loadD[:, 0, -1].max()),
                    "crosscheck": crosscheck_table3(cat)},
        "lp_obj": np.asarray(grid["lp_obj"]).tolist(),
        # residencies came from policy_grid_device arrays, not by hand
        "decisions_from_control_plane": True,
        "per_policy": per_policy,
        "attribution_max_err": max_att_err,
        "attribution_exact": bool(max_att_err <= ATTRIBUTION_TOL),
        "ranking_preserved": bool(
            delayed_prec["cocar"] >= best_base - 1e-12),
        "cocar_over_best_baseline": delayed_prec["cocar"]
        / max(best_base, 1e-12),
    }


def bench_agreement(cat=None, cfgs=None):
    """Catalog D_m seconds == PodCache transfer seconds, transition by
    transition, on the *full* GB-scale configs (lazy store: byte
    accounting only, no weights)."""
    if cat is None:
        cfgs = {a: configs.get_config(a) for a in ARCHS}
        cat = make_catalog("measured", cfgs=cfgs, tokens=TOKENS)
    store = WeightStore(cfgs, lazy=True)
    bw = cat.bandwidth_MBps * 1e6
    gap, pairs = 0.0, 0
    H = cat.H
    for m, name in enumerate(cfgs):
        for prev in range(0, H + 1):
            for tgt in range(prev + 1, H + 1):
                pod = PodCache(store, capacity_bytes=10**14,
                               bandwidth_Bps=bw)
                if prev > 0:
                    pod.resident[name] = prev - 1
                ev = pod.request_load(name, tgt - 1, now=0.0)
                gap = max(gap, abs(ev.seconds
                                   - cat.load_seconds(m, prev, tgt)))
                pairs += 1
    return {"max_transfer_gap_s": gap, "pairs_checked": pairs,
            "bandwidth_MBps": cat.bandwidth_MBps}


def _online_scenario():
    cfgs = {a: configs.get_smoke(a) for a in ONLINE_ARCHS}
    cat = make_catalog("measured", cfgs=cfgs, tokens=32)
    # cloud link tuned so one Δ download spans several slots — the
    # in-flight state the mid-download invariant is about must occur
    mcfg = MECConfig(n_bs=3, n_users=60, n_models=len(ONLINE_ARCHS),
                     cloud_mbps=1.6, mem_capacity_mb=2.0, seed=0)
    return cfgs, cat, Scenario(mcfg, catalog=cat)


def bench_online(events: EventLog = None,
                 registry: MetricsRegistry = None):
    """CoCaR-OL per-slot cache states -> per-slot serving plans, checked
    and executed.  The scan run's per-slot telemetry (hit rate,
    downloads in flight, evictions) feeds the same histogram schema the
    offline serving runs use — one textfile for both planes."""
    cfgs, cat, sc = _online_scenario()
    names = list(ONLINE_ARCHS)
    ocfg = OnlineConfig(n_slots=ONLINE_SLOTS, rounds=2)
    from repro.traces.registry import default_workload
    wl = default_workload(sc.cfg, ocfg)

    with TRACER.span("serving:online", slots=ONLINE_SLOTS):
        scan = run_online(wl, "cocar-ol", cfg=sc.cfg, ocfg=ocfg,
                          engine="scan", record_states=True, scenario=sc,
                          diagnostics=registry is not None)
        ref = run_online(wl, "cocar-ol", cfg=sc.cfg, ocfg=ocfg,
                         engine="numpy", record_states=True, scenario=sc)
    if registry is not None and "diagnostics" in scan:
        observe_online_diag(registry, scan["diagnostics"])
    states_equal = all(
        np.array_equal(np.asarray(scan["states"][k], np.int32),
                       np.asarray(ref["states"][k], np.int32))
        for k in ("lvl", "dl", "target"))
    verdict = check_mid_download_never_serves(scan["states"])

    # execute sampled slot plans: residency is the current level only —
    # the state machine already charges the download delay, so a slot
    # plan needs no availability times
    plans = plans_from_online_states(scan["states"], names,
                                     algo="cocar-ol")
    compute_flops = float(cat.flops[:, -1].mean() / 0.05) * 1e9
    rows = []
    for t in range(0, ONLINE_SLOTS, 8):
        arr = poisson_arrivals(20.0, 2.0, names, sc.pop, tokens=32,
                               slo_s=0.5, seed=t)
        rows.append(execute_plan(plans[t], cfgs, compute_flops, arr,
                                 catalog=cat, names=names, events=events,
                                 registry=registry))
    exec_out = {"slots_executed": len(rows),
                "served": int(sum(r["served"] for r in rows)),
                "slo_attainment": _mean(rows, "slo_attainment"),
                "avg_precision": _mean(rows, "avg_precision")}
    return {
        "n_bs": sc.cfg.n_bs, "n_models": sc.cfg.n_models,
        "n_slots": ONLINE_SLOTS, "cloud_mbps": sc.cfg.cloud_mbps,
        "catalog_bandwidth_MBps": cat.bandwidth_MBps,
        "states_equal_numpy_scan": bool(states_equal),
        "mid_download_never_serves": verdict["ok"],
        "in_flight_pairs": verdict["in_flight_pairs"],
        "vacuous": verdict["vacuous"],
        "in_flight_nonvacuous": not verdict["vacuous"],
        "exec": exec_out,
        "avg_qoe": scan["avg_qoe"],
    }, plans


def bench_cluster(plans):
    """One online plan through the real-generation cluster: the decision
    bridge reaches actual running weights."""
    cfgs = {a: configs.get_smoke(a) for a in ONLINE_ARCHS}
    cat = make_catalog("measured", cfgs=cfgs, tokens=32)
    names = list(ONLINE_ARCHS)
    # the last slot with a non-empty residency (the settled cache state)
    plan = next(p for p in reversed(plans)
                if any(p.residency[n] for n in p.residency))
    from repro.serving.engine import EdgeCluster, Request

    with TRACER.span("serving:cluster", source=plan.source):
        store = WeightStore(cfgs, seed=0)
        cluster = EdgeCluster(
            store, n_pods=plan.n_pods, capacity_bytes=10**10,
            bandwidth_Bps=cat.bandwidth_MBps * 1e6,
            compute_flops=197e12,
            precisions=catalog_precisions(cat, names))
        cluster.apply_caching(plan.residency)
        cluster.tick(60.0)                       # let every load land
        model = next(m for res in plan.residency.values() for m in res)
        reqs = [Request(rid=i, model=model, tokens=[2, 3, 4], max_new=3,
                        home=i % plan.n_pods, deadline=cluster.now + 30.0)
                for i in range(3)]
        served = cluster.submit(reqs)
    return {"plan_source": plan.source, "served": served,
            "real_generation": bool(served and all(
                len(r.output) == 3 for r in reqs if r.done))}


def run(subdir=None):
    events, registry = EventLog(), MetricsRegistry()
    with TRACER.span("bench_serving"):
        offline = bench_offline(events, registry)
        agreement = bench_agreement()
        online, plans = bench_online(events, registry)
        cluster = bench_cluster(plans)
    conservation = events.conservation()
    out = {"offline": offline, "agreement": agreement, "online": online,
           "cluster": cluster, "events": conservation,
           "events_conserved": conservation["ok"]}
    path = common.save("BENCH_serving", out, subdir=subdir)
    TRACER.export_jsonl(path.with_name(path.stem + ".trace.jsonl"))
    events.export_jsonl(path.with_name(path.stem + ".events.jsonl"))
    registry.export_prometheus(path.with_name(path.stem + ".metrics.prom"))
    registry.export_json(path.with_name(path.stem + ".metrics.json"))

    assert offline["decisions_from_control_plane"]
    assert offline["catalog"]["crosscheck"]["ok"], offline["catalog"]
    assert offline["ranking_preserved"], offline["per_policy"]
    assert offline["attribution_exact"], offline["attribution_max_err"]
    assert conservation["ok"], conservation
    assert agreement["max_transfer_gap_s"] < 1e-9, agreement
    assert online["states_equal_numpy_scan"], online
    assert online["mid_download_never_serves"], online
    assert not online["vacuous"], online
    assert cluster["real_generation"], cluster
    att = offline["per_policy"]["cocar"]["attribution"]
    print(f"serving: CoCaR delivered precision "
          f"{offline['per_policy']['cocar']['delayed']['avg_precision']:.3f}"
          f" under measured loading delay "
          f"({offline['cocar_over_best_baseline']:.2f}x best baseline; "
          f"ranking preserved: {offline['ranking_preserved']}); "
          f"latency attribution queue/stall/service = "
          f"{att['queue']['frac']:.1%}/{att['stall']['frac']:.1%}/"
          f"{att['service']['frac']:.1%} "
          f"(exact to {ATTRIBUTION_TOL:g}; events conserved over "
          f"{conservation['n_arrivals']} arrivals); "
          f"max cold load "
          f"{offline['catalog']['max_cold_load_s']:.1f}s at "
          f"{offline['catalog']['bandwidth_MBps']:.0f} MB/s "
          f"(Table III cross-check ok); online in-flight pairs "
          f"{online['in_flight_pairs']}, mid-download never serves; "
          f"cluster served {cluster['served']} real requests")
    return out


def main():
    return run()


def smoke():
    """CI smoke: the same fixed-scale closed loop, persisted to the
    ``ci/`` scratch dir so check_bench gates flags + the ranking drift
    against the committed baseline."""
    return run(subdir="ci")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
