"""Online engine benchmark: NumPy ``OnlineSim`` vs the ``lax.scan`` engine.

Both sides route through the unified ``run_online(workload, policy,
cfg=..., ocfg=..., engine=...)`` API (demand is aggregated per-(BS,
model) counts; only the per-user reference replay touches dense
tensors).  Two measurements, persisted as
``results/bench/BENCH_online.json``:

  * **equivalence** — on a fixed stationary-Zipf workload, every policy's
    per-slot QoE and final cache state must match between the per-user
    reference replay and the scan engine (the scan engine mirrors the
    NumPy state machine op-for-op, f64);
  * **throughput** — a >=16-scenario online grid (config variants x
    workload families, all cocar-ol) through (a) the per-scenario NumPy
    slot loop and (b) ONE vmapped scan dispatch.  Compile time is
    reported separately: the steady-state number is what a sweep pays per
    additional grid, the compile is paid once per process/shape.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_online
Quick CI smoke:  PYTHONPATH=src python -m benchmarks.bench_online --smoke
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.online import OnlineConfig, run_online
from repro.mec.scenario import MECConfig, config_grid
from repro.traces import as_workload, draw_decision_stream, make_trace, make_workload
from repro.traces import engine as E

ALGOS = ("cocar-ol", "lfu", "lfu-mad", "random")


def bench_equivalence(n_users=100, n_slots=30):
    """Per-policy parity on one stationary workload: per-user reference
    replay vs the aggregated scan engine."""
    from repro.core.online import run_online_trace

    cfg = MECConfig(n_users=n_users)
    ocfg = OnlineConfig(n_slots=n_slots)
    trace = make_trace("stationary", cfg, n_slots, seed=cfg.seed)
    wl = as_workload(trace, cfg=cfg)
    stream = draw_decision_stream(n_slots, ocfg.rounds, cfg.n_bs,
                                  cfg.n_models, cfg.seed + 99)
    rows = {}
    for algo in ALGOS:
        qs, _, sim = run_online_trace(cfg, ocfg, algo, trace, stream)
        lvl = np.argmax(sim.X, -1)
        res = run_online(wl, algo, cfg=cfg, ocfg=ocfg, engine="scan",
                         stream=stream)
        gap = float(np.abs(qs - res["slot_qoe"]).max() / max(qs.max(), 1e-9))
        state_eq = bool((res["final_state"].lvl == lvl).all())
        rows[algo] = {"max_slot_qoe_relgap": gap, "final_state_equal": state_eq}
        common.csv_row(f"online_equiv_{algo}", 0,
                       f"relgap={gap:.2e};state_equal={state_eq}")
    return rows


def _grid_jobs(ocfg, n_users):
    cfgs = config_grid(MECConfig(n_users=n_users),
                       {"zipf": (0.4, 0.8),
                        "mem_capacity_mb": (300.0, 500.0)})
    families = ("stationary", "drift", "flash_crowd", "mobility")
    return [dict(cfg=c, algo="cocar-ol",
                 workload=make_workload(t, c, ocfg.n_slots, seed=c.seed))
            for c in cfgs for t in families]


def bench_throughput(n_users=None, n_slots=None):
    """>=16-scenario cocar-ol grid: NumPy loop vs one vmapped dispatch."""
    n_users = n_users or (300 if common.FULL else 150)
    n_slots = n_slots or (100 if common.FULL else 40)
    ocfg = OnlineConfig(n_slots=n_slots)
    jobs = _grid_jobs(ocfg, n_users)
    B = len(jobs)
    sslots = B * n_slots                          # scenario-slots total

    t0 = time.time()
    E.run_online_grid(jobs, ocfg)
    t_first = time.time() - t0
    t0 = time.time()
    scan_res = E.run_online_grid(jobs, ocfg)
    t_scan = time.time() - t0

    t0 = time.time()
    np_res = [run_online(j["workload"], j["algo"], cfg=j["cfg"],
                         ocfg=ocfg, engine="numpy")
              for j in jobs]
    t_np = time.time() - t0

    gap = max(abs(a["avg_qoe"] - b["avg_qoe"])
              for a, b in zip(np_res, scan_res))
    out = {
        "scenarios": B,
        "n_slots": n_slots,
        "n_users": n_users,
        "numpy_s": t_np,
        "scan_s": t_scan,
        "scan_first_call_s": t_first,
        "numpy_slots_per_s": sslots / t_np,
        "scan_slots_per_s": sslots / t_scan,
        "speedup": t_np / t_scan,
        "max_avg_qoe_gap": gap,
    }
    common.csv_row(f"online_grid_B{B}", t_scan / sslots * 1e6,
                   f"speedup={out['speedup']:.1f}x;"
                   f"numpy_slots_s={out['numpy_slots_per_s']:.0f};"
                   f"scan_slots_s={out['scan_slots_per_s']:.0f};"
                   f"gap={gap:.2e}")
    return out


def main():
    out = {"equivalence": bench_equivalence(), "throughput": bench_throughput()}
    common.save("BENCH_online", out)
    th = out["throughput"]
    print(f"online grid ({th['scenarios']} scenarios x {th['n_slots']} "
          f"slots): scan {th['scan_slots_per_s']:.0f} slots/s vs numpy "
          f"{th['numpy_slots_per_s']:.0f} slots/s "
          f"({th['speedup']:.1f}x, compile {th['scan_first_call_s']:.1f}s, "
          f"max avg-QoE gap {th['max_avg_qoe_gap']:.2e})")
    return out


def smoke():
    """CI smoke: tiny equivalence + one tiny grid dispatch.

    Persists the equivalence block (no throughput at this scale) to the
    ``ci/`` scratch subdir — never over the committed baseline — so
    ``scripts/check_bench.py`` can gate the correctness gaps in CI."""
    eq = bench_equivalence(n_users=40, n_slots=12)
    common.save("BENCH_online", {"equivalence": eq}, subdir="ci")
    assert all(r["final_state_equal"] for r in eq.values()), eq
    assert all(r["max_slot_qoe_relgap"] < 1e-9 for r in eq.values()), eq
    ocfg = OnlineConfig(n_slots=12)
    res = E.run_online_grid(_grid_jobs(ocfg, 40)[:4], ocfg)
    assert len(res) == 4 and all(0 <= r["avg_qoe"] <= 1 for r in res)
    print("online smoke OK: numpy==scan on all policies, grid dispatch ran")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
