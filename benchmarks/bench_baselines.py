"""Baseline-suite benchmark: the fused one-dispatch policy grid (CoCaR +
SPR³/Greedy/Random/GatMARL) vs the per-instance host loop.

Three measurements, persisted as ``results/bench/BENCH_baselines.json``:

  * **equivalence** — on the default 16-variant offline grid, every
    policy's device kernel must reproduce the NumPy reference *decisions*
    exactly when both consume the same fractional LP solutions, pre-drawn
    uniforms, and trained GatMARL params: identical cache/routing arrays,
    objectives (post-enforcement precision sums) and window metrics within
    1e-9;
  * **throughput** — a (16 variants × seeds × 5 policies) grid through
    (a) the pre-refactor per-instance host loop (scipy-LP SPR³, per-user
    Python routing loops, per-window CoCaR) and (b) ONE fused
    jitted/vmapped device dispatch.  GatMARL training is host-side and
    shared by both paths, so it is timed separately;
  * **comparison** — the paper's Sec. VII-B headline: the CoCaR-vs-best-
    baseline improvement ratio of grid-mean served precision, computed
    from the one-dispatch grid (and drift-gated by
    ``scripts/check_bench.py``).

Speedup ratios (not absolute times) are what the CI gate holds on — they
are stable across machines.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_baselines
Quick CI smoke:  PYTHONPATH=src python -m benchmarks.bench_baselines --smoke
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import baselines as BL
from repro.core import cocar as CC
from repro.experiments.sweep import DEFAULT_AXES
from repro.mec import metrics as MET
from repro.mec.scenario import (MECConfig, Scenario, config_grid,
                                stack_instances)


def _grid_stack(n_users):
    cfgs = config_grid(MECConfig(n_users=n_users), DEFAULT_AXES)
    insts = []
    for c in cfgs:
        sc = Scenario(c)
        insts.append(sc.instance(0, sc.empty_cache()))
    return stack_instances(insts)


def _run_both(stacked, n_seeds, best_of, iters, episodes, seed=0):
    uniforms = CC.policy_uniforms(stacked, seed, n_seeds, best_of)
    gat = CC.gat_grid_policies(stacked, seed, episodes)
    dev = CC.policy_grid_device(stacked, seed=seed, pdhg_iters=iters,
                                best_of=best_of, n_seeds=n_seeds,
                                uniforms=uniforms, gat=gat)
    host = CC.policy_grid_host(stacked, uniforms, gat,
                               dev["cocar_frac"]["x"],
                               dev["cocar_frac"]["A"],
                               dev["spr3_frac"], n_seeds=n_seeds)
    return dev, host


def _compare(stacked, dev, host, n_seeds):
    """Per-policy decision identity + objective/metric gaps."""
    per_policy = {}
    for p in CC.OFFLINE_POLICIES:
        identical = True
        obj_gap = 0.0
        met_gap = 0.0
        for i, inst in enumerate(stacked.insts):
            for s in range(n_seeds):
                xd = dev[p]["x"][i, s, :inst.N]
                Ad = dev[p]["A"][i, s, :inst.N, :inst.U]
                xh, Ah, mh = host[p][i][s]
                identical &= bool(np.array_equal(xd, xh))
                identical &= bool(np.array_equal(Ad, Ah))
                obj_gap = max(obj_gap, abs(
                    float(dev[p]["metrics"]["precision_sum"][i, s])
                    - mh["precision_sum"]))
                met_gap = max(met_gap, max(
                    abs(float(dev[p]["metrics"][k][i, s]) - mh[k])
                    for k in mh))
        per_policy[p] = {"decisions_identical": identical,
                        "obj_gap": obj_gap, "metric_gap": met_gap}
    return per_policy


def bench_equivalence(n_users=40, n_seeds=2, best_of=4, iters=800,
                      episodes=30):
    """Default 16-variant grid: every policy's device kernel vs its NumPy
    oracle on the same fractional solutions, uniforms, and params.

    This config is deliberately independent of ``REPRO_BENCH_FULL``: the
    CI smoke, the local full bench, and the nightly full-scale job all
    run it at the *same* scale, so the improvement-ratio drift gate
    derived from this grid engages on every one of them.
    """
    stacked = _grid_stack(n_users)
    dev, host = _run_both(stacked, n_seeds, best_of, iters, episodes)
    per_policy = _compare(stacked, dev, host, n_seeds)
    out = {"variants": len(stacked), "n_seeds": n_seeds, "n_users": n_users,
           "best_of": best_of, "pdhg_iters": iters, "episodes": episodes,
           "decisions_identical": all(v["decisions_identical"]
                                      for v in per_policy.values()),
           "max_obj_gap": max(v["obj_gap"] for v in per_policy.values()),
           "max_metric_gap": max(v["metric_gap"]
                                 for v in per_policy.values()),
           "per_policy": per_policy}
    common.csv_row("baselines_equiv", 0,
                   f"identical={out['decisions_identical']};"
                   f"obj_gap={out['max_obj_gap']:.2e};"
                   f"metric_gap={out['max_metric_gap']:.2e}")
    return out, dev


def _comparison(eq, dev):
    """The Sec. VII-B headline block, computed from the equivalence grid
    (fixed scale — see ``bench_equivalence``) and stamped with that scale
    so ``check_bench.py`` can drift-gate the ratio on every CI run."""
    comp = CC.improvement_ratio(
        {p: dev[p]["metrics"]["avg_precision"]
         for p in CC.OFFLINE_POLICIES})
    out = {k: eq[k] for k in ("variants", "n_seeds", "n_users", "best_of",
                              "pdhg_iters", "episodes")}
    out.update(improvement_ratio=comp["ratio"],
               best_baseline=comp["best_baseline"], means=comp["means"],
               avg_qoe={p: float(np.mean(dev[p]["metrics"]["avg_qoe"]))
                        for p in CC.OFFLINE_POLICIES})
    return out


def _host_policy_loop(insts, n_seeds, best_of, iters, gat_params):
    """The pre-refactor path: every (window, seed) runs each policy as a
    per-instance host call — per-user Python routing loops, a scipy LP
    per SPR³ solve, NumPy round/repair for CoCaR — then host metrics."""
    from repro.core.cocar import cocar_window

    rows = {p: [] for p in CC.OFFLINE_POLICIES}
    for i, inst in enumerate(insts):
        params_i = {k: v[i] for k, v in gat_params.items()}
        for s in range(n_seeds):
            x, A, _ = cocar_window(inst, seed=s, solver="pdhg",
                                   pdhg_iters=iters, best_of=best_of)
            rows["cocar"].append(MET.window_metrics(inst, x, A))
            x, A = BL.spr3(inst, seed=s)
            rows["spr3"].append(MET.window_metrics(inst, x, A))
            x, A = BL.greedy(inst)
            rows["greedy"].append(MET.window_metrics(inst, x, A))
            x, A = BL.random_policy(inst, seed=s)
            rows["random"].append(MET.window_metrics(inst, x, A))
            x, A = BL.gat_rollout_host(inst, params_i)
            rows["gatmarl"].append(MET.window_metrics(inst, x, A))
    return rows


def bench_throughput(n_users=None, n_seeds=None, best_of=8, iters=1500,
                     episodes=None):
    """(16 variants × seeds × 5 policies): one fused dispatch vs the
    per-instance host loop.  GatMARL training (host, shared) is timed
    separately."""
    n_users = n_users or (300 if common.FULL else 150)
    n_seeds = n_seeds or (16 if common.FULL else 8)
    episodes = episodes or (80 if common.FULL else 40)
    stacked = _grid_stack(n_users)
    B = len(stacked)
    uniforms = CC.policy_uniforms(stacked, 0, n_seeds, best_of)

    t0 = time.time()
    gat = CC.gat_grid_policies(stacked, 0, episodes)
    t_train = time.time() - t0

    t0 = time.time()
    CC.policy_grid_device(stacked, pdhg_iters=iters, best_of=best_of,
                          n_seeds=n_seeds, uniforms=uniforms, gat=gat)
    t_first = time.time() - t0
    t0 = time.time()
    dev = CC.policy_grid_device(stacked, pdhg_iters=iters, best_of=best_of,
                                n_seeds=n_seeds, uniforms=uniforms, gat=gat)
    t_dev = time.time() - t0

    t0 = time.time()
    host_rows = _host_policy_loop(stacked.insts, n_seeds, best_of, iters,
                                  gat[0])
    t_host = time.time() - t0

    ratio_dev = CC.improvement_ratio(
        {p: dev[p]["metrics"]["avg_precision"]
         for p in CC.OFFLINE_POLICIES})
    host_means = {p: float(np.mean([r["avg_precision"]
                                    for r in host_rows[p]]))
                  for p in CC.OFFLINE_POLICIES}
    evals = B * n_seeds * len(CC.OFFLINE_POLICIES)
    out = {
        "variants": B, "n_seeds": n_seeds, "best_of": best_of,
        "pdhg_iters": iters, "n_users": n_users, "episodes": episodes,
        "device_s": t_dev, "device_first_call_s": t_first,
        "host_loop_s": t_host, "gat_train_s": t_train,
        "policy_windows_per_s_device": evals / t_dev,
        "policy_windows_per_s_host": evals / t_host,
        "speedup_vs_host_loop": t_host / t_dev,
        "avg_precision_host_loop": host_means,
    }
    common.csv_row(
        f"policy_grid_B{B}x{n_seeds}x{len(CC.OFFLINE_POLICIES)}",
        t_dev / evals * 1e6,
        f"speedup={out['speedup_vs_host_loop']:.1f}x;"
        f"ratio={ratio_dev['ratio']:.2f}x_vs_{ratio_dev['best_baseline']}")
    return out


def main():
    eq, dev = bench_equivalence()
    comparison = _comparison(eq, dev)
    th = bench_throughput()
    out = {"equivalence": eq, "throughput": th, "comparison": comparison}
    assert eq["decisions_identical"], eq
    common.save("BENCH_baselines", out)
    print(f"policy grid ({th['variants']} variants x {th['n_seeds']} seeds "
          f"x {len(CC.OFFLINE_POLICIES)} policies): one dispatch "
          f"{th['device_s']:.1f}s vs host loop {th['host_loop_s']:.1f}s "
          f"({th['speedup_vs_host_loop']:.1f}x; compile "
          f"{th['device_first_call_s']:.1f}s, GAT training "
          f"{th['gat_train_s']:.1f}s); CoCaR "
          f"{comparison['improvement_ratio']:.2f}x best baseline "
          f"({comparison['best_baseline']})")
    return out


def smoke():
    """CI smoke: per-policy device==reference decisions + the headline
    ratio, at the SAME equivalence-grid scale as the committed baseline —
    so the drift gate on ``comparison.improvement_ratio`` engages on
    every CI run, not only on full bench runs.

    Persists to the ``ci/`` scratch subdir (no throughput block at smoke
    time) — never over the committed baseline."""
    eq, dev = bench_equivalence()
    comparison = _comparison(eq, dev)
    common.save("BENCH_baselines",
                {"equivalence": eq, "comparison": comparison},
                subdir="ci")
    assert eq["decisions_identical"], eq
    assert eq["max_obj_gap"] < 1e-9, eq
    assert eq["max_metric_gap"] < 1e-9, eq
    print("baselines smoke OK: all device policies == numpy references "
          f"on {eq['variants']} variants "
          f"(CoCaR {comparison['improvement_ratio']:.2f}x "
          f"{comparison['best_baseline']})")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
