"""Shared benchmark plumbing: paper-default scenario configs, scaling knobs,
and result persistence."""
from __future__ import annotations

import json
import os
import pathlib
import time

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "bench"

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def paper_offline_cfg(**kw):
    """Paper Sec. VII-A defaults (reduced unless REPRO_BENCH_FULL=1)."""
    from repro.mec.scenario import MECConfig
    base = dict(n_bs=5, n_users=600 if FULL else 300,
                n_models=8, n_windows=10 if FULL else 6,
                window_s=3.0, zipf=0.8, mem_capacity_mb=500.0,
                compute_gflops=70.0, seed=0)
    base.update(kw)
    return MECConfig(**base)


def paper_online_cfg(**kw):
    from repro.core.online import OnlineConfig
    base = dict(n_slots=100 if FULL else 60, slot_s=0.5, rounds=3,
                dT_past=10, dT_future=5, alpha=0.9, gamma=0.9)
    base.update(kw)
    return OnlineConfig(**base)


def save(name: str, payload, subdir: str = None):
    """Persist a result payload; ``subdir`` keeps scratch outputs (e.g.
    the CI smoke runs) out of the committed baseline files.  A sibling
    ``<name>.manifest.json`` (git SHA, jax/device info, config hash —
    ``repro.obs.manifest``) records the provenance of every run;
    scratch-run manifests are gitignored, but every committed
    ``results/bench/BENCH_*.json`` baseline ships with its manifest
    sibling."""
    from repro.obs import write_manifest

    root = RESULTS / subdir if subdir else RESULTS
    root.mkdir(parents=True, exist_ok=True)
    path = root / f"{name}.json"
    path.write_text(json.dumps(payload, indent=1, default=float))
    write_manifest(path, config={"bench": name, "subdir": subdir,
                                 "full": FULL})
    return path


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
