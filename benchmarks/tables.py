"""Paper tables & figures as benchmark functions (Table IV/V, Figs 6-9,
12-14).  Each returns a dict and persists JSON under results/bench/."""
from __future__ import annotations


from benchmarks import common
from repro.core.cocar import run_offline
from repro.core.online import run_online

OFFLINE_ALGOS = ("lr", "cocar", "gatmarl", "greedy", "spr3", "random")
ONLINE_ALGOS = ("cocar-ol", "lfu-mad", "lfu", "random")


def _timed(fn, *args, **kw):
    """Run one algo and thread its wall-clock into the result row — every
    table/figure cell carries real ``seconds`` for the benchmark CSV."""
    res, secs = common.timed(fn, *args, **kw)
    res["seconds"] = round(secs, 3)
    return res


def sweep_table(**sweep_kw):
    """Scenario-grid sweep (repro.experiments.sweep) as a persisted table:
    every variant's window is LP-solved in one vmapped PDHG dispatch."""
    from repro.experiments.sweep import run_sweep
    rows, secs = common.timed(run_sweep, **sweep_kw)
    out = {"seconds": secs, "rows": rows}
    common.save("sweep_grid", out)
    return out


def table4_offline(algos=OFFLINE_ALGOS, **cfg_kw):
    cfg = common.paper_offline_cfg(**cfg_kw)
    out = {}
    for a in algos:
        out[a] = _timed(run_offline, cfg, a)
    common.save("table4_offline", out)
    return out


def table5_online(algos=ONLINE_ALGOS, **cfg_kw):
    cfg = common.paper_offline_cfg(**cfg_kw)
    out = {}
    for part in (True, False):
        ocfg = common.paper_online_cfg(partition=part)
        key = "w_partition" if part else "wo_partition"
        out[key] = {}
        for a in algos:
            out[key][a] = _timed(run_online, cfg, ocfg, a)
    common.save("table5_online", out)
    return out


def fig6_memory(caps=(100, 200, 300, 400, 500),
                algos=("cocar", "greedy", "spr3", "random")):
    out = {}
    for cap in caps:
        cfg = common.paper_offline_cfg(mem_capacity_mb=float(cap))
        out[cap] = {a: _timed(run_offline, cfg, a) for a in algos}
    common.save("fig6_memory", out)
    return out


def fig7_popularity(change_every=(1, 2, 5, 10),
                    algos=("cocar", "greedy", "spr3", "random")):
    out = {}
    for ce in change_every:
        cfg = common.paper_offline_cfg(
            popularity_change_every=ce,
            n_windows=20 if common.FULL else 10)
        out[ce] = {a: _timed(run_offline, cfg, a) for a in algos}
    common.save("fig7_popularity", out)
    return out


def fig8_zipf(zipfs=(0.0, 0.2, 0.4, 0.6, 0.8, 1.0),
              algos=("cocar", "greedy", "spr3", "random")):
    out = {}
    for z in zipfs:
        cfg = common.paper_offline_cfg(zipf=z)
        out[z] = {a: _timed(run_offline, cfg, a) for a in algos}
    common.save("fig8_zipf", out)
    return out


def fig9_window(durations=(1.0, 2.0, 3.0, 4.0, 5.0),
                algos=("cocar", "spr3", "greedy")):
    """Total time fixed at 30 s: |Γ| = 30/Δτ windows, U = 200·Δτ users."""
    out = {}
    total_s, users_per_s = 30.0, 200 if common.FULL else 100
    for d in durations:
        cfg = common.paper_offline_cfg(
            window_s=d, n_windows=int(total_s / d),
            n_users=int(users_per_s * d))
        out[d] = {a: _timed(run_offline, cfg, a) for a in algos}
    common.save("fig9_window", out)
    return out


def fig12_memory_online(caps=(100, 300, 500, 700, 900),
                        algos=("cocar-ol", "lfu-mad", "lfu", "random")):
    out = {}
    for cap in caps:
        cfg = common.paper_offline_cfg(mem_capacity_mb=float(cap))
        ocfg = common.paper_online_cfg()
        out[cap] = {a: _timed(run_online, cfg, ocfg, a) for a in algos}
    common.save("fig12_memory_online", out)
    return out


def fig13_popfreq_online(change_every=(10, 20, 50, 100),
                         algos=("cocar-ol", "lfu-mad", "lfu", "random")):
    out = {}
    for ce in change_every:
        cfg = common.paper_offline_cfg()
        ocfg = common.paper_online_cfg(pop_change_every=ce)
        out[ce] = {a: _timed(run_online, cfg, ocfg, a) for a in algos}
    common.save("fig13_popfreq_online", out)
    return out


def fig14_zipf_online(zipfs=(0.0, 0.4, 0.8),
                      algos=("cocar-ol", "lfu-mad", "lfu", "random")):
    out = {}
    for z in zipfs:
        cfg = common.paper_offline_cfg(zipf=z)
        ocfg = common.paper_online_cfg()
        out[z] = {a: _timed(run_online, cfg, ocfg, a) for a in algos}
    common.save("fig14_zipf_online", out)
    return out
