"""Million-user workload benchmark for the aggregated online engine.

The point of the Workload API: online demand enters the engines as
per-slot ``(n_bs, n_models)`` request-count tensors, so cost and memory
are independent of the user population U.  Two blocks, persisted as
``results/bench/BENCH_users.json``:

  * **identity** — at small U, where the dense per-user replay is still
    affordable, the aggregated scan engine must make bit-identical cache
    decisions and per-slot QoE within 1e-9 of the per-user reference
    (``run_online_trace``), and chunk-streamed execution must be
    bit-identical to the one-shot scan (a scan is a strict fold — the
    chunk layout cannot change anything);
  * **scale** — a ``poisson_zipf`` streaming workload with one MILLION
    users per slot runs through the chunked scan engine while
    ``tracemalloc`` watches host allocations: peak traced memory must
    stay bounded (``memory_bounded``) and far below what a dense (T, U)
    per-user tensor would cost (``no_dense_tensor``).

``scripts/check_bench.py`` gates the flags and gaps against the
committed baseline.  The smoke run keeps U at 1e6 — per-slot cost does
not depend on U, that is the point — and only shrinks the horizon.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_users
Quick CI smoke:  PYTHONPATH=src python -m benchmarks.bench_users --smoke
"""
from __future__ import annotations

import time
import tracemalloc

import numpy as np

from benchmarks import common
from repro.core.online import OnlineConfig, run_online, run_online_trace
from repro.mec.scenario import MECConfig
from repro.traces import as_workload, draw_decision_stream, make_trace, make_workload

MEM_CAP_MB = 256.0         # absolute host-allocation ceiling for the scale run
DENSE_BYTES_PER_REQ = 16   # what a (T, U) per-user trace costs per user-slot


def _state_equal(a, b):
    return bool(np.array_equal(np.asarray(a.lvl), np.asarray(b.lvl))
                and np.array_equal(np.asarray(a.target), np.asarray(b.target)))


def bench_identity(n_users=120, n_slots=40, chunk_slots=7):
    """Small-U certificate: per-user replay vs aggregated engines."""
    cfg = MECConfig(n_users=n_users)
    ocfg = OnlineConfig(n_slots=n_slots)
    trace = make_trace("stationary", cfg, n_slots, seed=cfg.seed)
    wl = as_workload(trace, cfg=cfg)
    stream = draw_decision_stream(n_slots, ocfg.rounds, cfg.n_bs,
                                  cfg.n_models, cfg.seed + 99)

    # per-user reference: routes every user individually (Eq. 41)
    qs, _, sim = run_online_trace(cfg, ocfg, "cocar-ol", trace, stream)
    ref = sim.state()

    scan = run_online(wl, "cocar-ol", cfg=cfg, ocfg=ocfg, engine="scan",
                      stream=stream)
    chunked = run_online(wl, "cocar-ol", cfg=cfg, ocfg=ocfg, engine="scan",
                         stream=stream, chunk_slots=chunk_slots)
    agg_np = run_online(wl, "cocar-ol", cfg=cfg, ocfg=ocfg, engine="numpy",
                        stream=stream)

    scale = max(float(qs.max()), 1e-9)
    out = {
        "n_users": n_users,
        "n_slots": n_slots,
        "chunk_slots": chunk_slots,
        "decisions_identical": _state_equal(ref, scan["final_state"]),
        "numpy_state_equal": _state_equal(ref, agg_np["final_state"]),
        "chunked_identical": bool(
            np.array_equal(scan["slot_qoe"], chunked["slot_qoe"])
            and _state_equal(scan["final_state"], chunked["final_state"])),
        "max_slot_qoe_relgap": float(
            np.abs(qs - scan["slot_qoe"]).max() / scale),
        "numpy_max_slot_qoe_relgap": float(
            np.abs(qs - agg_np["slot_qoe"]).max() / scale),
    }
    common.csv_row(
        "users_identity", 0,
        f"decisions={out['decisions_identical']};"
        f"chunked={out['chunked_identical']};"
        f"relgap={out['max_slot_qoe_relgap']:.2e}")
    return out


def bench_scale(users_per_slot=1_000_000, n_slots=None, chunk_slots=25):
    """Stream U=1e6 per slot through the chunked scan engine, watching
    host allocations.  The first (untimed) pass pays the chunk-shape
    compile; the measured pass is the steady-state streaming cost."""
    n_slots = n_slots or (200 if common.FULL else 25)
    cfg = MECConfig()      # engine params only; demand comes from the workload
    ocfg = OnlineConfig(n_slots=n_slots)
    wl = make_workload("poisson_zipf", cfg, n_slots, seed=1,
                       users_per_slot=users_per_slot,
                       chunk_slots=chunk_slots)
    run = lambda: run_online(wl, "cocar-ol", cfg=cfg, ocfg=ocfg,  # noqa: E731
                             engine="scan", chunk_slots=chunk_slots)
    run()                                   # warm the chunk-shape compile
    tracemalloc.start()
    t0 = time.perf_counter()
    res = run()
    wall = time.perf_counter() - t0
    peak_mb = tracemalloc.get_traced_memory()[1] / 1e6
    tracemalloc.stop()

    total = wl.total()
    dense_mb = n_slots * users_per_slot * DENSE_BYTES_PER_REQ / 1e6
    out = {
        "users_per_slot": users_per_slot,
        "n_slots": n_slots,
        "chunk_slots": chunk_slots,
        "total_requests": total,
        "avg_qoe": res["avg_qoe"],
        "hit_rate": res["hit_rate"],
        "wall_s": wall,
        "slots_per_s": n_slots / wall,
        "requests_per_s": total / wall,
        "peak_host_mb": peak_mb,
        "dense_equivalent_mb": dense_mb,
        "memory_bounded": bool(peak_mb < MEM_CAP_MB),
        "no_dense_tensor": bool(peak_mb < dense_mb / 10),
    }
    common.csv_row(
        f"users_scale_U{users_per_slot:.0e}", wall / n_slots * 1e6,
        f"reqs_s={out['requests_per_s']:.2e};peak_mb={peak_mb:.1f};"
        f"dense_mb={dense_mb:.0f};qoe={res['avg_qoe']:.3f}")
    return out


def main():
    out = {"identity": bench_identity(), "scale": bench_scale()}
    common.save("BENCH_users", out)
    sc = out["scale"]
    print(f"users bench: U={sc['users_per_slot']:.0e}/slot x "
          f"{sc['n_slots']} slots ({sc['total_requests']:.2e} requests) "
          f"in {sc['wall_s']:.2f}s, peak host {sc['peak_host_mb']:.1f} MB "
          f"(dense per-user would be {sc['dense_equivalent_mb']:.0f} MB); "
          f"small-U decisions identical: "
          f"{out['identity']['decisions_identical']}")
    return out


def smoke():
    """CI smoke: same U=1e6 (cost is U-independent), shorter horizon.

    Saved to the ``ci/`` scratch subdir so ``check_bench.py`` gates the
    identity flags + gaps without touching the committed baseline."""
    out = {"identity": bench_identity(n_users=60, n_slots=16, chunk_slots=5),
           "scale": bench_scale(n_slots=15, chunk_slots=5)}
    common.save("BENCH_users", out, subdir="ci")
    ident = out["identity"]
    assert ident["decisions_identical"] and ident["chunked_identical"], ident
    assert ident["max_slot_qoe_relgap"] < 1e-9, ident
    assert out["scale"]["memory_bounded"], out["scale"]
    print(f"users smoke OK: decisions identical at U={ident['n_users']}, "
          f"U=1e6 stream peaked at {out['scale']['peak_host_mb']:.1f} MB")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
