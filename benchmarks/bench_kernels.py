"""Kernel micro-benchmarks: wall time (interpret mode on CPU — structural
only; real timing requires TPU) + analytic FLOPs and arithmetic intensity
per kernel, vs the pure-jnp reference."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    jax.tree.leaves(fn(*args))[0].block_until_ready()      # warm-up / compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / reps * 1e6


def main():
    rows = {}
    key = jax.random.key(0)
    # flash attention tile
    B, H, K, S, E = 1, 8, 4, 1024, 64
    q = jax.random.normal(key, (B, H, S, E), jnp.float32)
    k = jax.random.normal(key, (B, K, S, E), jnp.float32)
    v = jax.random.normal(key, (B, K, S, E), jnp.float32)
    us_k = _time(lambda *a: ops.flash_attention(*a), q, k, v)
    us_r = _time(lambda *a: ref.flash_attention_ref(*a), q, k, v)
    flops = 2 * 2 * B * H * S * S * E
    rows["flash_attention"] = {"us_kernel_interp": us_k, "us_ref": us_r,
                               "gflops": flops / 1e9}
    common.csv_row("kernel_flash_attention", us_k,
                   f"ref_us={us_r:.0f};gflop={flops/1e9:.2f}")

    B, H, K, T, E = 4, 16, 8, 4096, 128
    q = jax.random.normal(key, (B, H, E), jnp.float32)
    kk = jax.random.normal(key, (B, T, K, E), jnp.float32)
    vv = jax.random.normal(key, (B, T, K, E), jnp.float32)
    us_k = _time(lambda *a: ops.decode_attention(*a), q, kk, vv,
                 jnp.int32(T))
    bytes_moved = 2 * B * T * K * E * 4
    rows["decode_attention"] = {"us_kernel_interp": us_k,
                                "mb_kv": bytes_moved / 1e6}
    common.csv_row("kernel_decode_attention", us_k,
                   f"kv_mb={bytes_moved/1e6:.1f}")

    B, Hh, NC, c, P, N = 1, 8, 16, 128, 64, 64
    xb = jax.random.normal(key, (B, Hh, NC, c, P))
    Bc = jax.random.normal(key, (B, NC, c, N))
    Cc = jax.random.normal(key, (B, NC, c, N))
    cum = -jnp.cumsum(jnp.abs(jax.random.normal(key, (B, Hh, NC, c))), -1) * .1
    us_k = _time(lambda *a: ops.ssm_chunk_scan(*a), xb, Bc, Cc, cum)
    rows["ssm_chunk_scan"] = {"us_kernel_interp": us_k}
    common.csv_row("kernel_ssm_chunk_scan", us_k, f"chunks={NC}")

    T, D, V = 512, 1024, 32768
    h = jax.random.normal(key, (T, D))
    nw = jnp.ones((D,))
    W = jax.random.normal(key, (D, V)) * 0.02
    us_k = _time(lambda *a: ops.early_exit_head(*a), h, nw, W)
    saved = T * V * 4
    rows["early_exit_head"] = {"us_kernel_interp": us_k,
                               "hbm_saved_mb": saved / 1e6}
    common.csv_row("kernel_early_exit_head", us_k,
                   f"logits_hbm_saved_mb={saved/1e6:.0f}")
    common.save("kernels", rows)
    return rows


if __name__ == "__main__":
    main()
