"""Benchmark entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Default scale finishes on a
laptop-class CPU; set REPRO_BENCH_FULL=1 for the paper-scale settings
(N=5, U=600, 10 windows / 100 slots).
"""
from __future__ import annotations

import time

from benchmarks import (bench_baselines, bench_kernels, bench_lp,
                        bench_offline, bench_online, bench_serving, common,
                        motivating_example, roofline, tables)


def _emit_offline(name, res):
    for a, r in res.items():
        extra = f"prec={r.get('avg_precision', r.get('lr_bound', 0)):.3f}"
        if "hit_rate" in r:
            extra += f";hr={r['hit_rate']:.3f};mem={r.get('mem_util', 0):.3f}"
        common.csv_row(f"{name}_{a}", r.get("seconds", 0) * 1e6, extra)


def main() -> None:
    t0 = time.time()
    print("name,us_per_call,derived")

    st, dy = motivating_example.run_example()
    common.csv_row("motivating_static", 0,
                   f"prec={st['avg_precision']:.3f};hr={st['hit_rate']:.3f}")
    common.csv_row("motivating_dynamic", 0,
                   f"prec={dy['avg_precision']:.3f};hr={dy['hit_rate']:.3f}")

    res4 = tables.table4_offline()
    _emit_offline("table4", res4)

    res5 = tables.table5_online()
    for key, block in res5.items():
        for a, r in block.items():
            common.csv_row(f"table5_{key}_{a}", r.get("seconds", 0) * 1e6,
                           f"qoe={r['avg_qoe']:.3f};hr={r['hit_rate']:.3f}")

    for fn, name in ((tables.fig6_memory, "fig6"),
                     (tables.fig8_zipf, "fig8")):
        res = fn()
        for xval, algos in res.items():
            for a, r in algos.items():
                common.csv_row(f"{name}_{xval}_{a}",
                               r.get("seconds", 0) * 1e6,
                               f"prec={r['avg_precision']:.3f};"
                               f"hr={r['hit_rate']:.3f}")

    res = tables.fig12_memory_online(caps=(100, 500, 900))
    for cap, algos in res.items():
        for a, r in algos.items():
            common.csv_row(f"fig12_{cap}_{a}", r.get("seconds", 0) * 1e6,
                           f"qoe={r['avg_qoe']:.3f};hr={r['hit_rate']:.3f}")

    sw = tables.sweep_table()
    common.csv_row("sweep_grid", sw["seconds"] / len(sw["rows"]) * 1e6,
                   f"variants={len(sw['rows'])};"
                   f"total_s={sw['seconds']:.2f}")

    bench_serving.main()
    bench_lp.main()
    bench_online.main()
    bench_offline.main()
    bench_baselines.main()
    bench_kernels.main()

    for mesh in ("16x16", "2x16x16"):
        rows = roofline.load_cells(mesh)
        ok = [r for r in rows if "skipped" not in r]
        if ok:
            worst = min(ok, key=lambda r: r["roofline_fraction"] or 1)
            best = max(ok, key=lambda r: r["roofline_fraction"] or 0)
            common.csv_row(
                f"roofline_{mesh}", 0,
                f"cells={len(ok)};best={best['arch']}/{best['shape']}="
                f"{best['roofline_fraction']};worst={worst['arch']}/"
                f"{worst['shape']}={worst['roofline_fraction']}")

    common.csv_row("total_bench", (time.time() - t0) * 1e6, "done")


if __name__ == "__main__":
    main()
