"""LP solver benchmark: the fused PDHG kernel vs the reference kernel.

Persisted as ``results/bench/BENCH_lp.json``, three blocks:

  * **step** — single-window sweep step time at U ∈ {300, 600, 1000},
    the reference ``LP._pdhg_kernel`` vs the fused sweep (``pdhg_fused``
    with ``polish=0``), both under ``enable_x64`` — the configuration
    every production path solves in.  The reference therefore pays its
    all-f64 step while the fused kernel pays the f32 sweep step, which
    is exactly the per-iteration cost each backend charges the offline
    pipeline; the fused layout alone is worth ~2x of the ratio and the
    precision schedule the rest (the f64-vs-f64 layout ratio is the
    ``solve`` block's polish tail).  The headline ``fused_speedup_u1000``
    carries the PR's >= 3x target (asserted here, regression-gated by
    ``scripts/check_bench.py``).
  * **solve** — the production mixed-precision solve (f32 sweep + f64
    polish tail) vs the all-f64 reference, end to end at U = 1000:
    wall time, speedup, and the fractional gap between the solutions.
  * **grid** — the conformance contract on the full offline grid: the
    ``lp_backend="pallas"`` pipeline must reproduce the reference
    backend's integral cache/routing decisions and winning trials
    BIT-IDENTICALLY (``decisions_identical``), with the fractional gap
    certified below a tenth of every rounding uniform's distance to its
    threshold (``margin_certified`` — the margin machinery is shared
    with the test suite, ``tests/harness.decision_margin``).

Timing protocol: the contenders are interleaved rep by rep and the
MINIMUM per contender is kept.  Back-to-back block timing on a shared
box is distorted by machine noise (±50% observed between consecutive
identical runs); interleaving exposes both contenders to the same noise
and min-of-N discards it.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_lp
Quick CI smoke:  PYTHONPATH=src python -m benchmarks.bench_lp --smoke
"""
from __future__ import annotations

import functools
import pathlib
import sys
import time

import numpy as np

from benchmarks import common
from repro.core import cocar as CC
from repro.core import lp as LP
from repro.experiments.sweep import DEFAULT_AXES
from repro.mec.scenario import MECConfig, Scenario, config_grid, stack_instances

SPEEDUP_TARGET = 3.0      # fused sweep vs reference step time at U=1000

_TESTS = pathlib.Path(__file__).resolve().parent.parent / "tests"


def _certificates():
    """The rounding certificates live with the test harness (they are
    the same contract the suite asserts); import them from there."""
    if str(_TESTS) not in sys.path:
        sys.path.insert(0, str(_TESTS))
    from harness import decision_margin, threshold_shift_certificate
    return decision_margin, threshold_shift_certificate


def _single_inst(n_users: int, seed: int = 2):
    sc = Scenario(MECConfig(n_users=n_users, seed=seed))
    return sc.instance(0, sc.empty_cache())


def _single_data(n_users: int, seed: int = 2):
    import jax
    import jax.numpy as jnp

    return jax.tree_util.tree_map(jnp.asarray,
                                  LP.pdhg_data(_single_inst(n_users, seed)))


def _min_interleaved(contenders: dict, reps: int) -> dict:
    """Alternate the (pre-warmed) contenders rep by rep; keep the min."""
    best = {k: float("inf") for k in contenders}
    for _ in range(reps):
        for name, fn in contenders.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def bench_step(sizes=(300, 600, 1000), iters: int = 400, reps: int = 5):
    """Per-iteration sweep cost under the production ``enable_x64``
    config: the reference's f64 step vs the fused kernel's f32 sweep
    step (``polish=0``) — what each backend charges the pipeline per
    iteration."""
    import jax
    from jax.experimental import enable_x64

    from repro.kernels.pdhg_fused import pdhg_fused

    per_size = {}
    with enable_x64():
        ref = LP._jitted_kernel(False, "reference")
        fused = jax.jit(functools.partial(pdhg_fused, polish=0),
                        static_argnums=(1,))
        for U in sizes:
            data = _single_data(U)
            thunks = {
                "reference": lambda: jax.block_until_ready(ref(data, iters)),
                "fused": lambda: jax.block_until_ready(fused(data, iters)),
            }
            for fn in thunks.values():      # warm the compile caches
                fn()
            best = _min_interleaved(thunks, reps)
            row = {"ref_step_us": best["reference"] / iters * 1e6,
                   "fused_step_us": best["fused"] / iters * 1e6,
                   "speedup": best["reference"] / best["fused"]}
            per_size[f"u{U}"] = row
            common.csv_row(f"lp_step_U{U}", row["fused_step_us"],
                           f"ref_us={row['ref_step_us']:.1f};"
                           f"speedup={row['speedup']:.2f}x")
    out = {"iters": iters, "reps": reps, "n_users_max": max(sizes),
           "per_size": per_size}
    if 1000 in sizes:
        sp = per_size["u1000"]["speedup"]
        out["fused_speedup_u1000"] = sp
        out["target_3x_met"] = bool(sp >= SPEEDUP_TARGET)
    return out


def bench_solve(n_users: int = 1000, iters: int = 1000, reps: int = 3):
    """Production solve: mixed-precision fused vs all-f64 reference."""
    import jax
    from jax.experimental import enable_x64

    from repro.kernels.pdhg_fused import POLISH_TAIL

    inst = _single_inst(n_users)
    with enable_x64():
        import jax.numpy as jnp

        data = jax.tree_util.tree_map(jnp.asarray, LP.pdhg_data(inst))
        ref = LP._jitted_kernel(False, "reference")
        fused = LP._jitted_kernel(False, "pallas")
        thunks = {
            "reference": lambda: jax.block_until_ready(ref(data, iters)),
            "fused": lambda: jax.block_until_ready(fused(data, iters)),
        }
        for fn in thunks.values():
            fn()
        best = _min_interleaved(thunks, reps)
        xr, Ar = (np.asarray(v) for v in ref(data, iters))
        xf, Af = (np.asarray(v) for v in fused(data, iters))
    gap = max(float(np.abs(xr - xf).max()), float(np.abs(Ar - Af).max()))
    # convergence telemetry at this truncated budget — drift-gated by
    # check_bench.py, NOT flag-gated (the budget is below DEFAULT_TOL's
    # calibration point on purpose; only regressions matter here)
    residual = max(LP.pdhg_primal_residual(inst, xr, Ar),
                   LP.pdhg_primal_residual(inst, xf, Af))
    out = {"n_users": n_users, "iters": iters, "reps": reps,
           "polish": POLISH_TAIL,
           "ref_s": best["reference"], "fused_s": best["fused"],
           "fused_speedup": best["reference"] / best["fused"],
           "frac_gap": gap,
           "pdhg_final_residual": residual,
           "pdhg_converged": bool(residual <= LP.PDHG_TOL),
           "pdhg_tol": LP.PDHG_TOL}
    common.csv_row(f"lp_solve_U{n_users}", best["fused"] * 1e6,
                   f"ref_s={best['reference']:.2f};"
                   f"speedup={out['fused_speedup']:.2f}x;gap={gap:.2e}")
    return out


def _grid_stack(n_users: int):
    cfgs = config_grid(MECConfig(n_users=n_users), DEFAULT_AXES)
    insts = []
    for c in cfgs:
        sc = Scenario(c)
        insts.append(sc.instance(0, sc.empty_cache()))
    return stack_instances(insts)


def bench_grid(n_users: int = 100, iters: int = 500, n_seeds: int = 2,
               best_of: int = 2, reps: int = 2, uniform_seed: int = 1):
    """Full offline grid through both LP backends: time + conformance.

    ``uniform_seed`` fixes the rounding draw, which fixes the margin side
    of the certificate — the gate then monitors the fused perturbation
    against a constant, so a flipped ``margin_certified`` flag means the
    threshold shifts GREW, not that the draw got unlucky.  The default
    seed maximizes the certificate headroom across the smoke and full
    scales (~50x and ~6x at the defaults) so version-to-version float
    noise cannot flip the flag without a real regression."""
    decision_margin, threshold_shift_certificate = _certificates()
    stacked = _grid_stack(n_users)
    u_cat, u_phi = CC.offline_uniforms(stacked, uniform_seed, n_seeds,
                                       best_of)

    def run(backend):
        return CC.offline_pipeline_device(stacked, u_cat, u_phi,
                                          pdhg_iters=iters, n_seeds=n_seeds,
                                          lp_backend=backend)

    ref, pal = run("reference"), run("pallas")      # warm + keep results
    best = _min_interleaved({"reference": lambda: run("reference"),
                             "pallas": lambda: run("pallas")}, reps)

    identical = (np.array_equal(ref["x"], pal["x"])
                 and np.array_equal(ref["A"], pal["A"])
                 and np.array_equal(ref["best_t"], pal["best_t"]))
    decision_gap = 0.0 if identical else max(
        float(np.abs(ref["x"] - pal["x"]).max()),
        float(np.abs(ref["A"] - pal["A"]).max()))

    # per-comparison certificate: every uniform must clear the reference
    # threshold by more than that threshold moved under the fused
    # solution — decision identity is then *implied*, not observed.
    # (decision_margin's global min is also recorded for context; at
    # bench scale it collapses below the global gap while the sharp
    # certificate still holds with wide headroom.)
    frac_gap, min_margin, certified, headroom = 0.0, float("inf"), True, \
        float("inf")
    residuals = []
    for i, inst in enumerate(stacked.insts):
        N, U = inst.N, inst.U
        args = (ref["x_frac"][i, :N], ref["A_frac"][i, :N, :U],
                pal["x_frac"][i, :N], pal["A_frac"][i, :N, :U],
                inst.onehot_mu(), u_cat[i, :, :N], u_phi[i, :, :N, :U])
        frac_gap = max(
            frac_gap,
            float(np.abs(ref["x_frac"][i, :N] - pal["x_frac"][i, :N]).max()),
            float(np.abs(ref["A_frac"][i, :N, :U]
                         - pal["A_frac"][i, :N, :U]).max()))
        m = decision_margin(args[0], args[1], args[4], args[5], args[6])
        min_margin = min(min_margin, m["min"])
        cert = threshold_shift_certificate(*args)
        certified &= cert["certified"]
        headroom = min(headroom, cert["headroom"])
        residuals.append(max(
            LP.pdhg_primal_residual(inst, args[0], args[1]),
            LP.pdhg_primal_residual(inst, args[2], args[3])))

    out = {"variants": len(stacked), "n_users": n_users,
           "pdhg_iters": iters, "n_seeds": n_seeds, "best_of": best_of,
           "reference_s": best["reference"], "pallas_s": best["pallas"],
           "grid_speedup": best["reference"] / best["pallas"],
           "decisions_identical": bool(identical),
           "decision_gap": decision_gap,
           "max_frac_gap": frac_gap,
           "min_margin": min_margin,
           "margin_headroom": headroom,
           "margin_certified": bool(certified),
           # truncated-budget convergence telemetry (drift-gated, see
           # bench_solve)
           "pdhg_final_residual": max(residuals),
           "n_windows_not_converged": sum(
               1 for r in residuals if r > LP.PDHG_TOL),
           "pdhg_tol": LP.PDHG_TOL}
    common.csv_row(
        f"lp_grid_B{out['variants']}", best["pallas"] * 1e6,
        f"speedup={out['grid_speedup']:.2f}x;identical={identical};"
        f"frac_gap={frac_gap:.2e};headroom={headroom:.1f}x")
    return out


def main():
    out = {"step": bench_step(), "solve": bench_solve(),
           "grid": bench_grid()}
    assert out["grid"]["decisions_identical"], out["grid"]
    assert out["grid"]["margin_certified"], out["grid"]
    assert out["step"]["fused_speedup_u1000"] >= SPEEDUP_TARGET, out["step"]
    common.save("BENCH_lp", out)
    st, so, gr = out["step"], out["solve"], out["grid"]
    print(f"lp bench: fused sweep {st['fused_speedup_u1000']:.2f}x "
          f"reference step time at U=1000 "
          f"(target {SPEEDUP_TARGET:.0f}x) | mixed solve "
          f"{so['fused_speedup']:.2f}x, frac gap {so['frac_gap']:.1e} | "
          f"grid {gr['grid_speedup']:.2f}x with identical decisions "
          f"(certified, {gr['margin_headroom']:.1f}x threshold headroom)")
    return out


def smoke():
    """CI smoke: the conformance contract only (perf is too noisy on
    shared CI boxes) on a tiny grid, persisted to the ``ci/`` scratch
    subdir for ``scripts/check_bench.py`` to gate."""
    g = bench_grid(n_users=25, iters=200, n_seeds=2, best_of=2, reps=1)
    common.save("BENCH_lp", {"grid": g}, subdir="ci")
    assert g["decisions_identical"], g
    assert g["margin_certified"], g
    print(f"lp smoke OK: fused backend == reference decisions on "
          f"{g['variants']} windows (certified, "
          f"{g['margin_headroom']:.1f}x threshold headroom)")


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
