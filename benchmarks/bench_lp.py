"""LP solver benchmark.

1. HiGHS (oracle) vs JAX PDHG across instance sizes — objective parity and
   wall time (the PDHG path is the accelerator-native production solver).
2. Batched vs scalar PDHG on the sweep grid.  Each contender is timed in
   its own fresh subprocess: compilation cost is part of what is being
   compared (the pre-refactor loop recompiles every window, the cached
   kernel once per shape, the batched dispatch once), and in-process
   sequential timing lets earlier contenders warm XLA's caches for later
   ones, silently distorting the comparison either way.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks import common
from repro.core import lp as LP
from repro.experiments.sweep import DEFAULT_AXES
from repro.mec.scenario import MECConfig, Scenario, config_grid, stack_instances


def bench_solvers():
    """Scipy vs scalar PDHG parity/time across instance sizes."""
    rows = {}
    for U in (100, 300, 600):
        cfg = MECConfig(n_users=U, seed=2)
        sc = Scenario(cfg)
        inst = sc.instance(0, sc.empty_cache())
        t0 = time.time()
        _, _, obj_s = LP.solve_lp_scipy(inst)
        t_s = time.time() - t0
        t0 = time.time()
        res = LP.solve_lp_pdhg(inst, iters=3000)
        t_p = time.time() - t0
        rows[U] = {"scipy_s": t_s, "pdhg_s": t_p, "scipy_obj": obj_s,
                   "pdhg_obj": res.obj, "gap": abs(res.obj - obj_s) / obj_s}
        common.csv_row(f"lp_U{U}", t_s * 1e6,
                       f"pdhg_us={t_p*1e6:.0f};gap={rows[U]['gap']:.4f}")
    common.save("lp_solvers", rows)
    return rows


def _closure_jit_solve(inst, iters):
    """The pre-refactor scalar path, reproduced exactly: the instance
    arrays are captured by the jitted closure, so they are baked into the
    HLO as constants — every window re-traces AND recompiles (different
    constants -> XLA executable-cache miss).  This is what ``solve_lp_pdhg``
    did before the kernel took the instance as an argument, and it is the
    per-window cost the batched path eliminates.
    """
    import jax
    import jax.numpy as jnp

    data = jax.tree_util.tree_map(jnp.asarray, LP.pdhg_data(inst))
    run = jax.jit(lambda _: LP._pdhg_kernel(data, iters))
    x, A = run(0)
    return inst.objective(np.asarray(A))


def _grid_instances(n_users: int):
    cfgs = config_grid(MECConfig(n_users=n_users), DEFAULT_AXES)
    scenarios = [Scenario(c) for c in cfgs]
    return [sc.instance(0, sc.empty_cache()) for sc in scenarios]


def _bench_mode(mode: str, iters: int, n_users: int):
    """One contender, timed in THIS process (meant to run in a fresh one).
    Prints a JSON line with the solve-phase seconds and per-window
    objectives."""
    insts = _grid_instances(n_users)
    if mode == "loop":
        t0 = time.time()
        objs = [_closure_jit_solve(inst, iters) for inst in insts]
        secs = time.time() - t0
    elif mode == "cached":
        t0 = time.time()
        objs = [LP.solve_lp_pdhg(inst, iters=iters).obj for inst in insts]
        secs = time.time() - t0
    elif mode == "batched":
        # stacking is part of the batched path's cost, so it is timed
        # (the scalar contenders pay their per-window pdhg_data inside
        # the loop too)
        t0 = time.time()
        stacked = stack_instances(insts)
        res = LP.solve_lp_pdhg_batched(stacked.data, iters=iters)
        sols = stacked.unstack(res.x, res.A)
        objs = [inst.objective(A) for inst, (_, A) in zip(insts, sols)]
        secs = time.time() - t0
    else:
        raise ValueError(mode)
    print(json.dumps({"seconds": secs, "objs": objs}))


def _bench_subprocess(mode: str, iters: int, n_users: int):
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_lp", "--mode", mode,
         "--iters", str(iters), "--n-users", str(n_users)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(f"bench mode {mode} failed:\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def bench_batched(iters: int = 3000, n_users: int = 40):
    """Batched (one vmapped dispatch) vs scalar-loop PDHG over the sweep
    grid.  Three contenders, each in a fresh subprocess (cold jit caches —
    the true cost of running the sweep that way in a fresh process):

      * ``scalar_loop``  — per-window closure-jit, the pre-refactor
        ``solve_lp_pdhg`` behavior (recompiles every window);
      * ``scalar_cached`` — per-window solve through the refactored
        shape-cached kernel (compiles once per distinct (N, U) shape);
      * ``batched``      — all windows in one vmapped dispatch (compiles
        once for the padded stack).
    """
    res = {m: _bench_subprocess(m, iters, n_users)
           for m in ("loop", "cached", "batched")}
    B = len(res["batched"]["objs"])
    t_loop = res["loop"]["seconds"]
    t_scalar = res["cached"]["seconds"]
    t_batched = res["batched"]["seconds"]
    gap = max(abs(b - s) / max(abs(s), 1e-9)
              for b, s in zip(res["batched"]["objs"], res["cached"]["objs"]))
    out = {
        "windows": B,
        "iters": iters,
        "scalar_loop_s": t_loop,
        "scalar_cached_s": t_scalar,
        "batched_s": t_batched,
        "scalar_loop_windows_per_s": B / t_loop,
        "scalar_cached_windows_per_s": B / t_scalar,
        "batched_windows_per_s": B / t_batched,
        "speedup_vs_loop": t_loop / t_batched,
        "speedup_vs_cached": t_scalar / t_batched,
        "max_obj_gap": gap,
    }
    common.csv_row(f"lp_batched_B{B}", t_batched / B * 1e6,
                   f"speedup_vs_loop={out['speedup_vs_loop']:.2f}x;"
                   f"speedup_vs_cached={out['speedup_vs_cached']:.2f}x;"
                   f"gap={gap:.4f}")
    common.save("lp_batched", out)
    print(f"batched {out['batched_windows_per_s']:.2f} windows/s | "
          f"scalar loop (pre-refactor, per-window jit) "
          f"{out['scalar_loop_windows_per_s']:.2f} windows/s "
          f"({out['speedup_vs_loop']:.2f}x) | cached-kernel scalar "
          f"{out['scalar_cached_windows_per_s']:.2f} windows/s "
          f"({out['speedup_vs_cached']:.2f}x) | max obj gap {gap:.4f}")
    return out


def main():
    return {"batched": bench_batched(), "solvers": bench_solvers()}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("loop", "cached", "batched"))
    ap.add_argument("--iters", type=int, default=3000)
    ap.add_argument("--n-users", type=int, default=40)
    args = ap.parse_args()
    if args.mode:
        _bench_mode(args.mode, args.iters, args.n_users)
    else:
        main()
