"""LP solver benchmark: HiGHS (oracle) vs JAX PDHG across instance sizes —
objective parity and wall time (the PDHG path is the accelerator-native
production solver; on CPU its advantage is jit-compiled batch windows)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import lp as LP
from repro.mec.scenario import MECConfig, Scenario


def main():
    rows = {}
    for U in (100, 300, 600):
        cfg = MECConfig(n_users=U, seed=2)
        sc = Scenario(cfg)
        inst = sc.instance(0, sc.empty_cache())
        t0 = time.time()
        _, _, obj_s = LP.solve_lp_scipy(inst)
        t_s = time.time() - t0
        t0 = time.time()
        res = LP.solve_lp_pdhg(inst, iters=3000)
        t_p = time.time() - t0
        rows[U] = {"scipy_s": t_s, "pdhg_s": t_p, "scipy_obj": obj_s,
                   "pdhg_obj": res.obj, "gap": abs(res.obj - obj_s) / obj_s}
        common.csv_row(f"lp_U{U}", t_s * 1e6,
                       f"pdhg_us={t_p*1e6:.0f};gap={rows[U]['gap']:.4f}")
    common.save("lp_solvers", rows)
    return rows


if __name__ == "__main__":
    main()
