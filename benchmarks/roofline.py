"""Roofline analysis (§Roofline): three terms per (arch × shape × mesh)
derived from the compiled dry-run artifacts under results/dryrun/.

  compute    = HLO_FLOPs(loop-aware) / peak_FLOP/s      (per chip)
  memory     = HLO_bytes(traffic proxy) / HBM_bw        (per chip)
  collective = collective_bytes / link_bw               (per chip)

plus MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (serve) and the
MODEL/HLO ratio (remat + padding + dispatch waste), and the roofline
fraction = compute / max(all three) — the §Perf score.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks import common
from repro import configs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import SHAPES
from repro.models import partition

DRYRUN = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"


def analyse_cell(rec: dict) -> dict:
    arch, shape = rec["arch"], rec["shape"]
    cfg = configs.get_config(arch)
    seq, batch, mode = SHAPES[shape]
    flops = rec.get("flops_per_device") or 0.0
    hbm = rec.get("hbm_bytes_per_device") or 0.0
    coll = rec.get("collective_bytes_per_device") or 0.0
    chips = 512 if rec["mesh"] == "2x16x16" else 256

    t_c = flops / PEAK_FLOPS_BF16
    t_m = hbm / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    model_flops = partition.model_flops(cfg, batch, seq, mode) / chips
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh"], "mode": mode,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_chip": model_flops,
        "model_over_hlo": round(model_flops / flops, 3) if flops else None,
        "roofline_fraction": round(t_c / max(t_c, t_m, t_x), 4)
        if max(t_c, t_m, t_x) > 0 else None,
        "peak_bytes_per_device": rec.get("peak_bytes_per_device"),
        "compile_s": rec.get("compile_s"),
    }


def load_cells(mesh="16x16"):
    rows = []
    d = DRYRUN / mesh
    if not d.exists():
        return rows
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("ok"):
            rows.append(analyse_cell(rec))
        elif rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "skipped": rec["skipped"]})
    return rows


def markdown_table(rows):
    hdr = ("| arch | shape | compute s | memory s | coll s | dominant | "
           "MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['model_over_hlo']} | "
            f"{r['roofline_fraction']} |")
    return "\n".join(lines)


def main(mesh="16x16"):
    rows = load_cells(mesh)
    common.save(f"roofline_{mesh}", rows)
    md = markdown_table(rows)
    out = DRYRUN.parent / f"roofline_{mesh}.md"
    out.write_text(md + "\n")
    print(md)
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "16x16")
