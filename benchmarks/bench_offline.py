"""Offline pipeline benchmark: the fused one-dispatch CoCaR grid vs the
host-loop path.

Two measurements, persisted as ``results/bench/BENCH_offline.json``:

  * **equivalence** — on the default 16-variant offline grid, the device
    round+repair must reproduce the NumPy reference *decisions* exactly
    when both consume the same fractional LP solution and the same
    pre-drawn rounding uniforms: identical cache/routing arrays, the same
    winning ``best_of`` trial per seed, objectives and window metrics
    within 1e-9;
  * **throughput** — a (16 variants × rounding seeds) grid through
    (a) the pre-refactor host-loop path (each rounding seed re-runs the
    batched LP dispatch + per-window NumPy round/repair — what a
    multi-seed sweep cost before the fused pipeline), (b) the LP-sharing
    host loop (one LP dispatch, NumPy round/repair over all seeds), and
    (c) ONE fused jitted/vmapped device dispatch.  Compile time is
    reported separately: the steady-state number is what a sweep pays per
    additional grid.

Speedup ratios (not absolute times) are what ``scripts/check_bench.py``
gates on — they are stable across machines.

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_offline
Quick CI smoke:  PYTHONPATH=src python -m benchmarks.bench_offline --smoke
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import cocar as CC
from repro.core import lp as LP
from repro.experiments.sweep import DEFAULT_AXES
from repro.mec.scenario import MECConfig, Scenario, config_grid, stack_instances


def _grid_stack(n_users):
    cfgs = config_grid(MECConfig(n_users=n_users), DEFAULT_AXES)
    insts = []
    for c in cfgs:
        sc = Scenario(c)
        insts.append(sc.instance(0, sc.empty_cache()))
    return stack_instances(insts)


def _compare(stacked, dev, host, n_seeds):
    """Device vs host-reference results: decision identity + value gaps."""
    devu = CC._unstack_device(stacked, dev, n_seeds)
    identical = True
    obj_gap = 0.0
    met_gap = 0.0
    for per_dev, per_host in zip(devu, host):
        for (xd, Ad, idv), (xh, Ah, ih) in zip(per_dev, per_host):
            identical &= bool(np.array_equal(xd, xh))
            identical &= bool(np.array_equal(Ad, Ah))
            identical &= idv["best_t"] == ih["best_t"]
            obj_gap = max(obj_gap, abs(idv["obj"] - ih["obj"]))
            met_gap = max(met_gap, max(
                abs(idv["metrics"][k] - ih["metrics"][k])
                for k in ih["metrics"]))
    return identical, obj_gap, met_gap


def bench_equivalence(n_users=40, n_seeds=2, best_of=4, iters=800):
    """Default 16-variant grid: device round+repair vs the NumPy oracle on
    the same fractional solution and uniforms."""
    stacked = _grid_stack(n_users)
    u_cat, u_phi = CC.offline_uniforms(stacked, 0, n_seeds, best_of)
    dev = CC.offline_pipeline_device(stacked, u_cat, u_phi,
                                     pdhg_iters=iters, n_seeds=n_seeds)
    host = CC.offline_pipeline_host(stacked, dev["x_frac"], dev["A_frac"],
                                    u_cat, u_phi, n_seeds=n_seeds)
    identical, obj_gap, met_gap = _compare(stacked, dev, host, n_seeds)
    out = {"variants": len(stacked), "n_seeds": n_seeds,
           "best_of": best_of, "pdhg_iters": iters,
           "decisions_identical": identical,
           "max_obj_gap": obj_gap, "max_metric_gap": met_gap}
    common.csv_row("offline_equiv", 0,
                   f"identical={identical};obj_gap={obj_gap:.2e};"
                   f"metric_gap={met_gap:.2e}")
    return out


def bench_throughput(n_users=None, n_seeds=None, best_of=8, iters=1500):
    """(16 variants × seeds) grid: one fused dispatch vs the host loops."""
    n_users = n_users or (300 if common.FULL else 150)
    n_seeds = n_seeds or (16 if common.FULL else 8)
    stacked = _grid_stack(n_users)
    B = len(stacked)
    T = max(best_of, 1)
    u_cat, u_phi = CC.offline_uniforms(stacked, 0, n_seeds, best_of)

    t0 = time.time()
    CC.offline_pipeline_device(stacked, u_cat, u_phi, pdhg_iters=iters,
                               n_seeds=n_seeds)
    t_first = time.time() - t0
    t0 = time.time()
    dev = CC.offline_pipeline_device(stacked, u_cat, u_phi,
                                     pdhg_iters=iters, n_seeds=n_seeds)
    t_dev = time.time() - t0

    # (b) LP-sharing host loop: one LP dispatch + NumPy round/repair
    LP.solve_lp_pdhg_batched(stacked.data, iters=iters)       # warm compile
    t0 = time.time()
    res = LP.solve_lp_pdhg_batched(stacked.data, iters=iters)
    host = CC.offline_pipeline_host(stacked, res.x, res.A, u_cat, u_phi,
                                    n_seeds=n_seeds)
    t_host_rr = time.time() - t0

    # (a) pre-refactor host-loop path: every rounding seed re-runs the LP
    # dispatch (rounding+repair were welded to the solve, so a multi-seed
    # sweep had no way to share it)
    t0 = time.time()
    for s in range(n_seeds):
        sl = slice(s * T, (s + 1) * T)
        res_s = LP.solve_lp_pdhg_batched(stacked.data, iters=iters)
        CC.offline_pipeline_host(stacked, res_s.x, res_s.A,
                                 u_cat[:, sl], u_phi[:, sl], n_seeds=1)
    t_host_loop = time.time() - t0

    # quality: same algorithm either way; LP backends differ only in the
    # fused kernel's f64 vs the batched solver's f32 iterates
    prec_dev = np.asarray(dev["metrics"]["avg_precision"]).mean()
    prec_host = np.mean([[ih["metrics"]["avg_precision"]
                          for _, _, ih in per] for per in host])
    grids = B * n_seeds                       # windows solved end to end
    out = {
        "variants": B, "n_seeds": n_seeds, "best_of": best_of,
        "pdhg_iters": iters, "n_users": n_users,
        "device_s": t_dev, "device_first_call_s": t_first,
        "host_rr_s": t_host_rr, "host_loop_s": t_host_loop,
        "windows_per_s_device": grids / t_dev,
        "windows_per_s_host_loop": grids / t_host_loop,
        "speedup_vs_host_loop": t_host_loop / t_dev,
        "speedup_vs_host_rr": t_host_rr / t_dev,
        "avg_precision_device": float(prec_dev),
        "avg_precision_host": float(prec_host),
        "avg_precision_gap": float(abs(prec_dev - prec_host)),
    }
    common.csv_row(
        f"offline_grid_B{B}x{n_seeds}", t_dev / grids * 1e6,
        f"speedup={out['speedup_vs_host_loop']:.1f}x;"
        f"vs_shared_lp={out['speedup_vs_host_rr']:.2f}x;"
        f"prec_gap={out['avg_precision_gap']:.2e}")
    return out


def main():
    out = {"equivalence": bench_equivalence(),
           "throughput": bench_throughput()}
    assert out["equivalence"]["decisions_identical"], out["equivalence"]
    common.save("BENCH_offline", out)
    th = out["throughput"]
    print(f"offline grid ({th['variants']} variants x {th['n_seeds']} "
          f"seeds x best_of {th['best_of']}): one dispatch {th['device_s']:.1f}s "
          f"vs host-loop {th['host_loop_s']:.1f}s "
          f"({th['speedup_vs_host_loop']:.1f}x; "
          f"{th['speedup_vs_host_rr']:.2f}x vs LP-sharing host, "
          f"compile {th['device_first_call_s']:.1f}s, "
          f"prec gap {th['avg_precision_gap']:.2e})")
    return out


def smoke():
    """CI smoke: tiny grid, device==reference decisions + a fused dispatch.

    Persists the equivalence block (no throughput at this scale) to the
    ``ci/`` scratch subdir — never over the committed baseline — so
    ``scripts/check_bench.py`` can gate the correctness gaps in CI."""
    eq = bench_equivalence(n_users=25, n_seeds=2, best_of=2, iters=200)
    common.save("BENCH_offline", {"equivalence": eq}, subdir="ci")
    assert eq["decisions_identical"], eq
    assert eq["max_obj_gap"] < 1e-9, eq
    assert eq["max_metric_gap"] < 1e-9, eq
    print("offline smoke OK: device round+repair == numpy reference "
          f"on {eq['variants']} variants x {eq['n_seeds']} seeds")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
