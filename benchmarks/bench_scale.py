"""Sharded grid executor benchmark: ``repro.scale.run_grid`` across a
forced 8-device host mesh vs the one-device vmap dispatch.

Two measurements, persisted as ``results/bench/BENCH_scale.json``:

  * **equivalence** — on a heterogeneous grid, the sharded + bucketed +
    chunked executor must reproduce the one-device, max-padded vmap
    dispatch's *decisions* exactly: identical cache/routing arrays and
    winning ``best_of`` trials for the offline pipeline, and bit-equal
    per-slot QoE for the online scan engine; objective/metric value
    gaps stay at float-reduction noise;
  * **throughput** — the same (variants × seeds) offline grid through
    (a) ONE one-device vmapped dispatch (the PR-3 path) and (b) the
    executor sharding chunks across all 8 host devices
    (``shard_map`` over the batch axis, chunk streaming with donated
    buffers).  ``sharded_speedup = t_one_device / t_sharded`` is the
    machine-portable ratio ``scripts/check_bench.py`` gates; the
    chunked run's ``peak_chunk_in_bytes`` vs the one-shot grid bytes is
    the recorded evidence that streaming bounds peak live memory.

The module forces ``--xla_force_host_platform_device_count=8`` before
the first jax import, so it exercises the real multi-device shard_map
path even on a single-CPU box (the same trick ``launch/dryrun.py`` uses
for the 512-chip production meshes).

Run standalone:  PYTHONPATH=src python -m benchmarks.bench_scale
Quick CI smoke:  PYTHONPATH=src python -m benchmarks.bench_scale --smoke
"""
from __future__ import annotations

# before ANY jax-importing module: the device count locks on first init
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import resource                                             # noqa: E402
from dataclasses import replace                             # noqa: E402

import numpy as np                                          # noqa: E402

from benchmarks import common                               # noqa: E402
from repro.experiments.sweep import DEFAULT_AXES            # noqa: E402
from repro.mec.scenario import (MECConfig, Scenario,        # noqa: E402
                                config_grid)
from repro.obs import TRACER                                # noqa: E402
from repro.scale import GridSpec, run_grid                  # noqa: E402

N_DEVICES = 8


def _grid_insts(n_variants, n_users=40, hetero=True):
    """``n_variants`` scenario windows cycling over the default sweep
    axes with distinct seeds; ``hetero`` alternates user counts so the
    grid actually has multiple (N, U) shapes to bucket."""
    cfgs = config_grid(MECConfig(n_users=n_users), DEFAULT_AXES)
    insts = []
    for i in range(n_variants):
        cfg = replace(cfgs[i % len(cfgs)], seed=i,
                      n_users=n_users - (10 if hetero and i % 2 else 0))
        sc = Scenario(cfg)
        insts.append(sc.instance(0, sc.empty_cache()))
    return insts


def _compare_offline(ref, out):
    """Decision identity + value gaps between two offline grid results."""
    identical, obj_gap, met_gap = True, 0.0, 0.0
    for per_r, per_o in zip(ref, out):
        for (xr, Ar, ir), (xo, Ao, io) in zip(per_r, per_o):
            identical &= bool(np.array_equal(xr, xo))
            identical &= bool(np.array_equal(Ar, Ao))
            identical &= ir["best_t"] == io["best_t"]
            obj_gap = max(obj_gap, abs(ir["obj"] - io["obj"]))
            met_gap = max(met_gap, max(
                abs(ir["metrics"][k] - io["metrics"][k])
                for k in ir["metrics"]))
    return identical, obj_gap, met_gap


def _online_jobs(n_slots=12):
    # twin of tests/test_scale.py::_online_jobs — pytest asserts the same
    # mixed-shape grid this bench gates; keep them in sync
    from repro.traces.registry import make_trace

    cfg_a = MECConfig(n_bs=3, n_users=40, n_models=4, seed=0)
    cfg_b = MECConfig(n_bs=4, n_users=30, n_models=4, seed=1)
    tr_a = make_trace("stationary", cfg_a, n_slots, seed=0)
    tr_b = make_trace("flash_crowd", cfg_b, n_slots, seed=1)
    return ([dict(cfg=cfg_a, algo=a, trace=tr_a)
             for a in ("cocar-ol", "lfu", "random")]
            + [dict(cfg=cfg_b, algo=a, trace=tr_b, seed=1)
               for a in ("cocar-ol", "lfu-mad")])


def bench_equivalence(n_variants=16, n_users=40, n_seeds=2, best_of=4,
                      iters=800):
    """Sharded+bucketed+chunked executor vs the one-device max-padded
    vmap dispatch, plus the online scan engine across the mesh."""
    import jax

    insts = _grid_insts(n_variants, n_users)
    kw = dict(kind="offline", insts=insts, seed=0, n_seeds=n_seeds,
              best_of=best_of, pdhg_iters=iters)
    ref = run_grid(GridSpec(**kw, backend="vmap", max_buckets=1))
    bkt = run_grid(GridSpec(**kw, backend="vmap", max_buckets=3))
    shd = run_grid(GridSpec(**kw, backend="sharded", max_buckets=3,
                            chunk_size=max(n_variants // 2, N_DEVICES)))
    identical_b, obj_b, met_b = _compare_offline(ref.results, bkt.results)
    identical_s, obj_s, met_s = _compare_offline(ref.results, shd.results)

    from repro.core.online import OnlineConfig
    from repro.traces.engine import run_online_grid

    jobs = _online_jobs()
    ocfg = OnlineConfig(n_slots=12, rounds=2)
    on_ref = run_online_grid(jobs, ocfg, backend="vmap")
    on_shd = run_online_grid(jobs, ocfg, backend="sharded")
    online_identical = all(
        np.array_equal(a["slot_qoe"], b["slot_qoe"])
        and np.array_equal(a["final_state"].lvl, b["final_state"].lvl)
        for a, b in zip(on_ref, on_shd))

    out = {"variants": n_variants, "n_seeds": n_seeds, "best_of": best_of,
           "pdhg_iters": iters, "n_users": n_users,
           "devices": len(jax.devices()),
           "plan": [list(p) for p in shd.stats["plan"]],
           "decisions_identical": bool(identical_s),
           "bucketed_identical": bool(identical_b),
           "online_identical": bool(online_identical),
           "max_obj_gap": float(max(obj_b, obj_s)),
           "max_metric_gap": float(max(met_b, met_s))}
    common.csv_row("scale_equiv", 0,
                   f"sharded={identical_s};bucketed={identical_b};"
                   f"online={online_identical};"
                   f"obj_gap={out['max_obj_gap']:.2e}")
    return out


def bench_throughput(n_variants=None, n_users=40, n_seeds=2, best_of=8,
                     iters=1500):
    """(variants × seeds) homogeneous grid: one-device vmap dispatch vs
    the executor streaming chunks across the 8-device mesh."""
    import jax

    n_variants = n_variants or (96 if common.FULL else 64)
    insts = _grid_insts(n_variants, n_users, hetero=False)
    kw = dict(kind="offline", insts=insts, seed=0, n_seeds=n_seeds,
              best_of=best_of, pdhg_iters=iters, max_buckets=1)
    chunk = max(n_variants // 4, N_DEVICES)

    # warm both compile caches, then measure steady state
    run_grid(GridSpec(**kw, backend="vmap"))
    with TRACER.span("bench:one_device", variants=n_variants) as sp:
        one_dev = run_grid(GridSpec(**kw, backend="vmap"))
    t_vmap = sp.seconds

    run_grid(GridSpec(**kw, backend="sharded", chunk_size=chunk))
    with TRACER.span("bench:sharded", variants=n_variants,
                     chunk=chunk) as sp:
        shd = run_grid(GridSpec(**kw, backend="sharded", chunk_size=chunk))
    t_shard = sp.seconds

    identical, obj_gap, met_gap = _compare_offline(one_dev.results,
                                                   shd.results)
    grids = n_variants * n_seeds
    one_shot_bytes = one_dev.stats["peak_chunk_in_bytes"]
    out = {
        "variants": n_variants, "n_seeds": n_seeds, "best_of": best_of,
        "pdhg_iters": iters, "n_users": n_users,
        "devices": len(jax.devices()), "chunk_size": chunk,
        "one_device_s": t_vmap, "sharded_s": t_shard,
        "windows_per_s_one_device": grids / t_vmap,
        "windows_per_s_sharded": grids / t_shard,
        "sharded_speedup": t_vmap / t_shard,
        "decisions_identical": bool(identical),
        "decision_obj_gap": float(obj_gap),
        "decision_metric_gap": float(met_gap),
        # streaming keeps live input bytes at one chunk, not the grid
        "grid_in_bytes": int(one_shot_bytes),
        "peak_chunk_in_bytes": int(shd.stats["peak_chunk_in_bytes"]),
        "memory_bounded": bool(
            shd.stats["peak_chunk_in_bytes"] * 2 <= one_shot_bytes),
        "ru_maxrss_kb": int(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss),
    }
    common.csv_row(
        f"scale_grid_B{n_variants}x{n_seeds}", t_shard / grids * 1e6,
        f"speedup={out['sharded_speedup']:.2f}x;"
        f"chunk_bytes={out['peak_chunk_in_bytes']};"
        f"grid_bytes={out['grid_in_bytes']}")
    return out


def main():
    out = {"equivalence": bench_equivalence(),
           "throughput": bench_throughput()}
    eq, th = out["equivalence"], out["throughput"]
    assert eq["decisions_identical"] and eq["bucketed_identical"], eq
    assert th["decisions_identical"], th
    common.save("BENCH_scale", out)
    print(f"scale grid ({th['variants']} variants x {th['n_seeds']} seeds, "
          f"{th['devices']} host devices): sharded {th['sharded_s']:.1f}s "
          f"vs one-device {th['one_device_s']:.1f}s "
          f"({th['sharded_speedup']:.2f}x), chunk bytes "
          f"{th['peak_chunk_in_bytes'] / 1e6:.1f}MB vs one-shot "
          f"{th['grid_in_bytes'] / 1e6:.1f}MB, decisions identical")
    return out


def smoke():
    """CI smoke under the forced 8-device mesh: sharded == one-device
    decisions on a small heterogeneous grid + the online engine.
    Persists the equivalence block to the ``ci/`` scratch dir so
    ``scripts/check_bench.py`` gates the flags and gaps."""
    eq = bench_equivalence(n_variants=8, n_users=25, n_seeds=1, best_of=2,
                           iters=200)
    common.save("BENCH_scale", {"equivalence": eq}, subdir="ci")
    assert eq["decisions_identical"], eq
    assert eq["bucketed_identical"], eq
    assert eq["online_identical"], eq
    assert eq["max_obj_gap"] < 1e-9, eq
    assert eq["max_metric_gap"] < 1e-9, eq
    print(f"scale smoke OK: sharded executor == one-device vmap on "
          f"{eq['variants']} variants across {eq['devices']} host devices "
          f"(plan {eq['plan']})")


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
