#!/usr/bin/env bash
# CI / newcomer entry point: install deps, run the tier-1 suite.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${CI_SKIP_INSTALL:-0}" != "1" ]; then
    python -m pip install -r requirements.txt
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# quick online smoke: NumPy OnlineSim == scan engine on every policy
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_online --smoke
