#!/usr/bin/env bash
# CI / newcomer entry point: install deps, lint, run the tier-1 suite,
# then the engine-equivalence bench smokes + the bench regression gate.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${CI_SKIP_INSTALL:-0}" != "1" ]; then
    python -m pip install -r requirements.txt
fi

# lint (rules live in pyproject.toml); skipped quietly where ruff is not
# installed — the CI workflow always installs and runs it
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "ci.sh: ruff not installed, skipping lint"
fi

# --durations surfaces the slowest tests in the job log; REPRO_TEST_TIMEOUT
# (set by the CI workflow, see tests/conftest.py) hard-kills a hung device
# dispatch after N seconds instead of eating the whole job budget
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q --durations=15 "$@"

# bench smokes: NumPy OnlineSim == scan engine on every policy, the
# NumPy round+repair == fused offline pipeline, and every offline
# baseline's device kernel == its NumPy oracle, all on small grids.
# Fresh results land in the results/bench/ci/ scratch dir — never over
# the committed baselines — and check_bench compares the two (correctness
# gaps always; perf ratios and drift checks only for same-scale runs).
# JAX_ENABLE_X64 is scoped to these steps: the equivalence engines want
# f64 defaults, while the Pallas kernel tests above pin float32.
JAX_ENABLE_X64=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.bench_online --smoke
JAX_ENABLE_X64=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.bench_offline --smoke
# the Workload API's aggregation certificate: count-tensor engines make
# the per-user replay's decisions bit-exactly at small U, and a U=1e6/slot
# poisson_zipf stream runs chunk-by-chunk at bounded host memory (the
# smoke keeps U at 1e6 — per-slot cost is U-independent, that is the
# point — and only shortens the horizon)
JAX_ENABLE_X64=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.bench_users --smoke
JAX_ENABLE_X64=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.bench_baselines --smoke
# the fused LP backend's conformance smoke: lp_backend="pallas" must
# reproduce the reference backend's offline-grid decisions bit-exactly,
# with the per-comparison threshold-shift certificate holding.  No
# JAX_ENABLE_X64 here: the bench scopes x64 internally per block, the
# same way the production pipeline does.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.bench_lp --smoke
# the sharded grid executor under a forced 8-device host mesh: shard_map
# + bucketed batching + chunk streaming must reproduce the one-device
# dispatch's decisions exactly (the flag is also set inside bench_scale
# before its first jax import; exporting it here keeps the subprocess
# honest even if that import order ever changes)
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    JAX_ENABLE_X64=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.bench_scale --smoke
# closed-loop serving smoke: control-plane decisions -> ServingPlan ->
# queue simulator under measured loading times, with the request-level
# telemetry always on (event log + streaming metrics).  Runs at the
# SAME fixed scale as the committed baseline, so check_bench's flags
# (ranking survives loading delay, exact latency attribution, event
# conservation, Eq. 37 mid-download invariant, Table III cross-check)
# and the attribution/percentile drifts all engage here
JAX_ENABLE_X64=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.bench_serving --smoke
# the Prometheus textfile the smoke just exported must parse and carry
# the serving schema (cumulative buckets, _sum/_count consistency)
python scripts/check_metrics.py results/bench/ci/BENCH_serving.metrics.prom
# observability smoke (repro.obs): a tiny sharded offline sweep with the
# jit-safe diagnostics taps ON, then report.py over its artifacts —
# manifests, span traces, and the one uniform gate: PDHG convergence
# (every smoke window must clear DEFAULT_TOL) plus the deadline-miss
# regression check against the committed BENCH_serving baseline (the
# serving smoke above writes the fresh copy into results/bench/ci)
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.experiments.sweep --smoke --shard
python scripts/report.py results/sweep/ci results/bench/ci \
    --check-converged | tee /tmp/obs_report.txt
grep -q "== Convergence" /tmp/obs_report.txt \
    || { echo "ci.sh: report.py produced no convergence section"; exit 1; }
grep -q "== Deadline misses" /tmp/obs_report.txt \
    || { echo "ci.sh: report.py produced no deadline-miss section"; exit 1; }
python scripts/check_bench.py --fresh results/bench/ci
