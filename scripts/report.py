#!/usr/bin/env python
"""Render the observability artifacts a run leaves behind.

Scans a results directory (default ``results/sweep``) for

  * ``*.manifest.json``   — run provenance (``repro.obs.manifest``):
    git SHA, jax/device info, seeds, config hash;
  * ``*.trace.jsonl``     — span exports (``repro.obs.tracing``):
    per-phase wall times, retrace counts, per-chunk bytes/throughput;
  * ``grid.json`` / ``policy_grid.json`` / ``online_grid.json`` — sweep
    tables with the jit-safe solver/scan diagnostics columns;
  * ``BENCH_*.json``      — bench payloads (convergence keys only).

and prints a compact report: slowest spans, per-jit retrace counts,
per-chunk throughput (bytes / span seconds) and padding waste, the PDHG
convergence table, online cache telemetry, and the request-level
telemetry of any ``BENCH_serving.json`` it finds — the per-policy
latency-attribution table (fraction of delivered latency spent
queueing vs loading-stalled vs in service, p50/p95/p99 per phase: the
Eq. 40 decomposition made visible).  Pure stdlib — no jax, no numpy —
so it runs anywhere the JSON landed (CI artifact dirs, laptops,
containers).

Usage:
    python scripts/report.py [DIR ...] [--top N] [--check-converged]

``--check-converged`` is the one uniform CI gate: it exits 1 if any
sweep window's final PDHG residual missed its tolerance OR if a scanned
``BENCH_serving.json`` shows a per-policy ``deadline_misses`` regression
against the committed ``results/bench/BENCH_serving.json`` baseline
(bench speed/drift budgets stay with ``check_bench.py``).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _fmt_bytes(n):
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024 or unit == "GB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024.0


def _load_json(path):
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"  [warn] unreadable {path}: {e}")
        return None


def report_manifests(root):
    paths = sorted(root.glob("*.manifest.json"))
    if not paths:
        return
    print("\n== Manifests ==")
    for p in paths:
        m = _load_json(p)
        if m is None:
            continue
        git = m.get("git") or {}
        jx = m.get("jax") or {}
        sha = (git.get("sha") or "?")[:12] + ("*" if git.get("dirty") else "")
        dev = (f"{jx.get('backend', '?')}x{jx.get('device_count', '?')}"
               if jx.get("imported") else "jax-not-imported")
        print(f"  {p.name}: {m.get('created', '?')}  git {sha}  {dev}  "
              f"x64={jx.get('x64')}  cfg {str(m.get('config_hash'))[:12]}")


def _spans(root):
    out = []
    for p in sorted(root.glob("*.trace.jsonl")):
        for line in p.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                print(f"  [warn] bad span line in {p.name}")
    return out


def report_spans(spans, top):
    if not spans:
        return
    print("\n== Spans ==")
    by_name = {}
    for s in spans:
        d = by_name.setdefault(s["name"], dict(count=0, total=0.0,
                                               retraces=0))
        d["count"] += 1
        d["total"] += s.get("seconds", 0.0)
        d["retraces"] += s.get("retraces", 0)
    for name, d in sorted(by_name.items(), key=lambda kv: -kv[1]["total"]):
        print(f"  {name:24s} n={d['count']:<4d} total={d['total']:8.3f}s  "
              f"retraces={d['retraces']}")
    print(f"  total retraces across spans: "
          f"{sum(d['retraces'] for d in by_name.values())}")
    slowest = sorted(spans, key=lambda s: -s.get("seconds", 0.0))[:top]
    print("  slowest:")
    for s in slowest:
        pad = "  " * s.get("depth", 0)
        print(f"    {pad}{s['name']:20s} {s.get('seconds', 0.0):8.3f}s  "
              f"{s.get('attrs', {})}")


def report_chunks(spans):
    chunks = [s for s in spans if s["name"] == "chunk"]
    if not chunks:
        return
    print("\n== Chunks ==")
    for s in chunks:
        a = s.get("attrs", {})
        sec = s.get("seconds", 0.0) or 1e-12
        bps = a.get("in_bytes", 0) / sec
        print(f"  {a.get('kind', '?'):8s} bucket={a.get('bucket', '?'):12s} "
              f"chunk {a.get('chunk', '?')}/{a.get('n_chunks', '?')}  "
              f"batch={a.get('batch', '?'):<4} "
              f"pad={a.get('pad_rows', '?'):<3} "
              f"{_fmt_bytes(a.get('in_bytes', 0)):>9s}  "
              f"{sec:7.3f}s  {_fmt_bytes(bps)}/s")


def _iter_rows(payload):
    """grid.json is a row list; policy_grid.json is {rows, summary}."""
    if isinstance(payload, dict):
        return payload.get("rows", []), payload.get("summary", {})
    return payload or [], {}


def report_convergence(root):
    """Aggregate sweep-side PDHG convergence; returns the number of
    non-converged windows (``--check-converged`` gates on it)."""
    bad = 0
    seen = False
    for name in ("grid.json", "policy_grid.json"):
        p = root / name
        if not p.exists():
            continue
        payload = _load_json(p)
        if payload is None:
            continue
        rows, summary = _iter_rows(payload)
        conv = summary.get("convergence")
        if conv:
            seen = True
            bad += int(conv["n_not_converged"])
            print(f"\n== Convergence ({name}) ==")
            print(f"  {conv['n_windows']} windows, "
                  f"{conv['n_not_converged']} not converged, "
                  f"max final residual {conv['max_final_residual']:.3e} "
                  f"(tol {conv['tol']:g})")
            continue
        res = [r["pdhg_final_residual"] for r in rows
               if "pdhg_final_residual" in r]
        if not res:
            continue
        seen = True
        n_bad = sum(1 for r in rows if not r.get("pdhg_converged", True))
        bad += n_bad
        print(f"\n== Convergence ({name}) ==")
        print(f"  {len(res)} windows, {n_bad} not converged, "
              f"max final residual {max(res):.3e}")
    if not seen:
        return None
    return bad


def report_online(root):
    p = root / "online_grid.json"
    if not p.exists():
        return
    rows = _load_json(p)
    if not rows:
        return
    print("\n== Online telemetry ==")
    for r in rows:
        extra = ""
        if "mean_dl_in_flight" in r:
            extra = (f"  dl_in_flight={r['mean_dl_in_flight']:.2f}  "
                     f"evictions={r['evictions']:.0f}  "
                     f"cache={r['final_cache_mb']:.0f}MB")
        # rows carry "workload" (+ optional "family") since the Workload
        # API; older artifacts carry "trace" — render both identically,
        # aggregated or per-user
        wl = r.get("workload", r.get("trace", "?"))
        fam = r.get("family")
        if fam and fam != wl:
            wl = f"{wl}[{fam}]"
        print(f"  {wl:12s} {r.get('algo', '?'):10s} "
              f"qoe={r.get('avg_qoe', float('nan')):.3f} "
              f"hit={r.get('hit_rate', float('nan')):.3f}{extra}")


def report_bench(root):
    keys = (("grid.pdhg_final_residual", "grid residual"),
            ("grid.n_windows_not_converged", "grid not conv"),
            ("solve.pdhg_final_residual", "solve residual"),
            ("solve.pdhg_converged", "solve converged"),
            ("identity.decisions_identical", "aggregated==per-user"),
            ("scale.peak_host_mb", "U=1e6 peak host MB"),
            ("offline.ranking_preserved", "serving ranking preserved"),
            ("offline.cocar_over_best_baseline", "serving cocar/best"),
            ("online.mid_download_never_serves", "mid-download never serves"),
            ("agreement.max_transfer_gap_s", "catalog vs loader gap s"))
    lines = []
    for p in sorted(root.glob("BENCH_*.json")):
        payload = _load_json(p)
        if payload is None:
            continue
        for dotted, label in keys:
            cur = payload
            for part in dotted.split("."):
                cur = cur.get(part) if isinstance(cur, dict) else None
                if cur is None:
                    break
            if cur is not None:
                lines.append(f"  {p.name}: {label} = {cur}")
    if lines:
        print("\n== Bench convergence keys ==")
        print("\n".join(lines))


def report_attribution(root):
    """Per-policy latency attribution from BENCH_serving payloads: the
    fraction of delivered latency from queueing vs loading vs service,
    with per-phase percentiles (pooled streaming histograms)."""
    printed = False
    for p in sorted(root.glob("BENCH_*.json")):
        payload = _load_json(p)
        per_policy = ((payload or {}).get("offline") or {}).get("per_policy")
        if not isinstance(per_policy, dict):
            continue
        rows = [(pol, d["attribution"]) for pol, d in per_policy.items()
                if isinstance(d, dict) and "attribution" in d]
        if not rows:
            continue
        if not printed:
            print("\n== Latency attribution (delayed serving runs) ==")
            printed = True
        print(f"  {p.name}:")
        print(f"    {'policy':10s} {'phase':8s} {'frac':>7s} "
              f"{'p50':>9s} {'p95':>9s} {'p99':>9s}")
        for pol, att in rows:
            for ph in ("queue", "stall", "service"):
                a = att.get(ph)
                if a:
                    print(f"    {pol:10s} {ph:8s} {a['frac']:7.1%} "
                          f"{a['p50']:9.4f} {a['p95']:9.4f} "
                          f"{a['p99']:9.4f}")


def _repo_root():
    return pathlib.Path(__file__).resolve().parent.parent


def _baseline_serving():
    """The committed BENCH_serving baseline: HEAD's copy via git,
    falling back to the checked-out file (artifact dirs without git)."""
    root = _repo_root()
    rel = "results/bench/BENCH_serving.json"
    try:
        import subprocess
        out = subprocess.run(["git", "-C", str(root), "show",
                              f"HEAD:{rel}"], capture_output=True,
                             text=True, timeout=30)
        if out.returncode == 0:
            return json.loads(out.stdout)
    except Exception:
        pass
    p = root / rel
    return _load_json(p) if p.exists() else None


def check_deadline_misses(root, baseline=None, eps=1e-9):
    """Deadline-miss regression gate: every policy's mean delayed
    ``deadline_misses`` in a fresh BENCH_serving.json must not exceed
    the committed baseline's.  Returns None when ``root`` carries no
    BENCH_serving.json (gate not applicable), else the number of
    regressing policies."""
    p = root / "BENCH_serving.json"
    fresh = _load_json(p) if p.exists() else None
    if fresh is None:
        return None
    if baseline is None:
        baseline = _baseline_serving()
    if baseline is None:
        print("\n== Deadline misses ==\n  [warn] no committed "
              "BENCH_serving baseline; regression gate skipped")
        return 0
    print("\n== Deadline misses (delayed, vs committed baseline) ==")
    bad = 0
    per = ((fresh.get("offline") or {}).get("per_policy") or {})
    base_per = ((baseline.get("offline") or {}).get("per_policy") or {})
    for pol, d in per.items():
        cur = (d.get("delayed") or {}).get("deadline_misses")
        ref = ((base_per.get(pol) or {}).get("delayed")
               or {}).get("deadline_misses")
        if cur is None or ref is None:
            continue
        tag = "ok"
        if cur > ref + eps:
            bad += 1
            tag = "REGRESSION"
        print(f"  {pol:10s} {ref:8.3f} -> {cur:8.3f}  {tag}")
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dirs", nargs="*", default=None,
                    help="results directories (default: results/sweep)")
    ap.add_argument("--top", type=int, default=10,
                    help="slowest spans to show (default 10)")
    ap.add_argument("--check-converged", action="store_true",
                    help="exit 1 if any sweep window missed its PDHG "
                         "tolerance")
    args = ap.parse_args(argv)
    dirs = [pathlib.Path(d) for d in (args.dirs or ["results/sweep"])]

    total_bad, any_conv = 0, False
    miss_bad = 0
    for root in dirs:
        print(f"=== {root} ===")
        if not root.is_dir():
            print("  (missing)")
            continue
        report_manifests(root)
        spans = _spans(root)
        report_spans(spans, args.top)
        report_chunks(spans)
        bad = report_convergence(root)
        if bad is not None:
            any_conv = True
            total_bad += bad
        report_online(root)
        report_bench(root)
        report_attribution(root)
        misses = check_deadline_misses(root)
        if misses is not None:
            miss_bad += misses
        print()
    if args.check_converged:
        if not any_conv:
            print("check-converged: FAIL (no convergence data found)")
            return 1
        if total_bad:
            print(f"check-converged: FAIL ({total_bad} window(s) above "
                  f"tolerance)")
            return 1
        if miss_bad:
            print(f"check-converged: FAIL ({miss_bad} policy(ies) "
                  f"regressed on deadline misses)")
            return 1
        print("check-converged: OK (converged; no deadline-miss "
              "regressions)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
