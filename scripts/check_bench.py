#!/usr/bin/env python
"""Bench regression gate: compare freshly produced ``results/bench/
BENCH_*.json`` against the committed baselines.

Three kinds of checks, driven by the manifest below:

  * **perf ratios** (speedups, higher is better): machine-portable because
    both sides of each ratio ran on the same box; fail when a fresh ratio
    drops below ``(1 - RATIO_TOL)`` of the baseline (>25% slowdown);
  * **correctness gaps** (lower is better) and **flags** (must stay
    truthy): fail on ANY growth beyond the absolute floor — an
    equivalence gap that widens is a correctness regression, not noise;
  * **drifts** (must stay put, either direction): reproduced paper
    quantities like the CoCaR-vs-best-baseline improvement ratio; fail
    when a fresh value moves more than the per-key relative tolerance
    from the baseline — in either direction, since a quality *jump* is as
    suspicious as a drop when the algorithms did not change.

Perf ratios are only compared when the fresh run used the same scale
knobs (scale fields below) as the baseline; a CI smoke run at a smaller
scale skips them with a notice instead of failing spuriously.

Usage:
    python scripts/check_bench.py [--baseline DIR] [--fresh DIR]

Defaults: baseline = the committed copy (via ``git show HEAD:...``),
fresh = ``results/bench``.  Exit code 1 on any failure.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
RATIO_TOL = 0.25          # fail on >25% slowdown of a perf ratio
GAP_FLOOR = 1e-9          # correctness gaps may float below this freely

#: per-file manifest: dotted paths into the JSON payload
MANIFEST = {
    "BENCH_online.json": {
        "scale": ["throughput.scenarios", "throughput.n_slots",
                  "throughput.n_users"],
        "ratios": ["throughput.speedup"],
        "gaps": ["throughput.max_avg_qoe_gap",
                 "equivalence.cocar-ol.max_slot_qoe_relgap",
                 "equivalence.lfu.max_slot_qoe_relgap",
                 "equivalence.lfu-mad.max_slot_qoe_relgap",
                 "equivalence.random.max_slot_qoe_relgap"],
        "flags": ["equivalence.cocar-ol.final_state_equal",
                  "equivalence.lfu.final_state_equal",
                  "equivalence.lfu-mad.final_state_equal",
                  "equivalence.random.final_state_equal"],
    },
    "BENCH_users.json": {
        "scale": ["scale.users_per_slot", "scale.n_slots",
                  "scale.chunk_slots"],
        "ratios": [],
        "gaps": ["identity.max_slot_qoe_relgap",
                 "identity.numpy_max_slot_qoe_relgap"],
        # the Workload API's contract: the aggregated count-tensor engine
        # makes the SAME cache decisions as the per-user replay at small
        # U, chunk streaming changes nothing (a scan is a strict fold),
        # and the U=1e6 stream never materializes a dense (T, U) tensor
        # (peak host memory bounded and << the dense-equivalent bytes)
        "flags": ["identity.decisions_identical",
                  "identity.numpy_state_equal",
                  "identity.chunked_identical",
                  "scale.memory_bounded", "scale.no_dense_tensor"],
    },
    "BENCH_offline.json": {
        "scale": ["throughput.variants", "throughput.n_seeds",
                  "throughput.n_users", "throughput.pdhg_iters"],
        "ratios": ["throughput.speedup_vs_host_loop",
                   "throughput.speedup_vs_host_rr"],
        "gaps": ["equivalence.max_obj_gap", "equivalence.max_metric_gap",
                 "throughput.avg_precision_gap"],
        "flags": ["equivalence.decisions_identical"],
    },
    "BENCH_baselines.json": {
        "scale": ["throughput.variants", "throughput.n_seeds",
                  "throughput.n_users", "throughput.pdhg_iters"],
        "ratios": ["throughput.speedup_vs_host_loop"],
        "gaps": ["equivalence.max_obj_gap", "equivalence.max_metric_gap"]
        + [f"equivalence.per_policy.{p}.metric_gap"
           for p in ("cocar", "spr3", "greedy", "random", "gatmarl")],
        "flags": ["equivalence.decisions_identical"],
        # the reproduced Sec. VII-B headline: CoCaR over the best
        # baseline.  Scale-keyed on the comparison block itself (the
        # equivalence grid), which every CI path runs at the same config
        # — so this gate engages on smoke, full, and nightly runs alike.
        "drifts": [("comparison.improvement_ratio", 0.15)],
        "drift_scale": ["comparison.variants", "comparison.n_seeds",
                        "comparison.n_users", "comparison.best_of",
                        "comparison.pdhg_iters", "comparison.episodes"],
    },
    "BENCH_serving.json": {
        # closed-loop serving runs at ONE fixed scale on every CI path
        # (smoke == full), so all gates engage everywhere
        "scale": ["offline.n_pods", "offline.n_models", "offline.n_users",
                  "offline.n_windows", "offline.pdhg_iters",
                  "offline.duration_s"],
        "ratios": [],
        # catalog D_m seconds vs the loader's actual transfer seconds:
        # the same byte math, so the gap must stay at zero
        "gaps": ["agreement.max_transfer_gap_s"],
        # the decision bridge's contract: residencies come from the
        # control plane (never hand-constructed), the measured catalog's
        # bandwidth sits in the Table III band, CoCaR's ranking survives
        # simulated loading delay, Eq. 37's mid-download invariant holds
        # non-vacuously with numpy/scan state parity, and a plan reaches
        # real running weights in the cluster
        "flags": ["offline.decisions_from_control_plane",
                  "offline.ranking_preserved",
                  "offline.catalog.crosscheck.ok",
                  "offline.attribution_exact",
                  "events_conserved",
                  "online.states_equal_numpy_scan",
                  "online.mid_download_never_serves",
                  "online.in_flight_nonvacuous",
                  "cluster.real_generation"],
        # the headline margin (CoCaR's delivered precision under loading
        # delay over the best baseline's) plus the request-level latency
        # attribution: phase fractions and percentiles of CoCaR's
        # delayed runs — deterministic simulation at a fixed scale, so a
        # move beyond tolerance means the serving behaviour changed
        "drifts": [("offline.cocar_over_best_baseline", 0.2),
                   ("offline.per_policy.cocar.attribution.stall.frac",
                    0.25),
                   ("offline.per_policy.cocar.attribution.queue.frac",
                    0.25),
                   ("offline.per_policy.cocar.attribution.service.frac",
                    0.25),
                   ("offline.per_policy.cocar.attribution.stall.p95",
                    0.25),
                   ("offline.per_policy.cocar.attribution.service.p95",
                    0.25),
                   ("offline.per_policy.cocar.delayed.p95_latency", 0.25),
                   ("offline.per_policy.cocar.delayed.p99_latency",
                    0.25)],
        "drift_scale": ["offline.n_pods", "offline.n_models",
                        "offline.n_users", "offline.n_windows",
                        "offline.pdhg_iters", "offline.duration_s"],
    },
    "BENCH_lp.json": {
        "scale": ["step.iters", "step.n_users_max", "grid.variants",
                  "grid.n_users", "grid.pdhg_iters"],
        "ratios": ["step.fused_speedup_u1000", "solve.fused_speedup",
                   "grid.grid_speedup"],
        "gaps": ["grid.decision_gap"],
        # the fused LP backend's contract: >= 3x reference step time at
        # U=1000 (target_3x_met; the bench itself asserts it), identical
        # offline-grid decisions, and the per-comparison threshold-shift
        # certificate that *implies* the identity (margin_certified) —
        # the CI smoke produces the grid flags; the step flag exists on
        # full-scale runs
        "flags": ["step.target_3x_met", "grid.decisions_identical",
                  "grid.margin_certified"],
        # PDHG convergence telemetry (repro.obs): the truncated bench
        # budgets legitimately stop above DEFAULT_TOL, so the final
        # residuals are drift-gated against the baseline instead of
        # flag-gated — a residual that moves >50% at an identical budget
        # means the solver's convergence behaviour changed
        "drifts": [("grid.pdhg_final_residual", 0.5),
                   ("solve.pdhg_final_residual", 0.5)],
        "drift_scale": ["grid.variants", "grid.n_users",
                        "grid.pdhg_iters", "solve.n_users",
                        "solve.iters"],
    },
    "BENCH_scale.json": {
        "scale": ["throughput.variants", "throughput.n_seeds",
                  "throughput.n_users", "throughput.pdhg_iters",
                  "throughput.devices"],
        "ratios": ["throughput.sharded_speedup"],
        "gaps": ["equivalence.max_obj_gap", "equivalence.max_metric_gap",
                 "throughput.decision_obj_gap",
                 "throughput.decision_metric_gap"],
        # the executor's contract: sharded/bucketed/chunked dispatch makes
        # the SAME decisions as the one-device vmap path, and chunked
        # streaming keeps peak live input bytes under half the one-shot
        # grid's (the CI smoke produces the equivalence flags; the
        # throughput flags exist on full-scale runs)
        "flags": ["equivalence.decisions_identical",
                  "equivalence.bucketed_identical",
                  "equivalence.online_identical",
                  "throughput.decisions_identical",
                  "throughput.memory_bounded"],
    },
}


def _get(payload, dotted):
    cur = payload
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _load(root, name, git_ref=None):
    if git_ref is not None:
        try:
            out = subprocess.run(
                ["git", "show", f"{git_ref}:results/bench/{name}"],
                cwd=REPO, capture_output=True, text=True, check=True)
        except subprocess.CalledProcessError:
            return None
        return json.loads(out.stdout)
    path = pathlib.Path(root) / name
    if not path.exists():
        return None
    return json.loads(path.read_text())


def check_file(name, spec, base, fresh):
    """Returns a list of (level, message); level in {fail, warn, ok}.

    A fresh field that was not produced at this scale (e.g. a CI smoke run
    writes only the equivalence block) is skipped with a notice — the
    full-scale local/bench runs are where every field exists.  A file where
    *nothing* could be compared fails: that is a schema break, not a
    smaller scale.
    """
    msgs = []
    same_scale = all(_get(base, k) == _get(fresh, k) for k in spec["scale"])
    for key in spec["ratios"]:
        b, f = _get(base, key), _get(fresh, key)
        if f is None:
            msgs.append(("warn", f"{name}:{key} not produced by this run"))
        elif b is None:
            msgs.append(("warn", f"{name}:{key} has no baseline yet"))
        elif not same_scale:
            msgs.append(("warn", f"{name}:{key} perf check skipped "
                         "(scale mismatch vs baseline)"))
        elif f < b * (1.0 - RATIO_TOL):
            msgs.append(("fail", f"{name}:{key} regressed: "
                         f"{f:.2f} < {b:.2f} - {RATIO_TOL:.0%}"))
        else:
            msgs.append(("ok", f"{name}:{key} {f:.2f} (baseline {b:.2f})"))
    for key in spec["gaps"]:
        b, f = _get(base, key), _get(fresh, key)
        if f is None:
            msgs.append(("warn", f"{name}:{key} not produced by this run"))
        elif b is None:
            msgs.append(("warn", f"{name}:{key} has no baseline yet"))
        elif f > max(b, GAP_FLOOR):
            msgs.append(("fail", f"{name}:{key} correctness gap grew: "
                         f"{f:.3e} > {max(b, GAP_FLOOR):.3e}"))
        else:
            msgs.append(("ok", f"{name}:{key} {f:.2e} "
                         f"(baseline {b:.2e})"))
    for key in spec["flags"]:
        f = _get(fresh, key)
        if f is None:
            msgs.append(("warn", f"{name}:{key} not produced by this run"))
        elif not f:
            msgs.append(("fail", f"{name}:{key} is {f!r}, must be true"))
        else:
            msgs.append(("ok", f"{name}:{key} true"))
    drift_scale_keys = spec.get("drift_scale", spec["scale"])
    drift_same_scale = all(_get(base, k) == _get(fresh, k)
                           for k in drift_scale_keys)
    for key, rtol in spec.get("drifts", ()):
        b, f = _get(base, key), _get(fresh, key)
        if f is None:
            msgs.append(("warn", f"{name}:{key} not produced by this run"))
        elif b is None:
            msgs.append(("warn", f"{name}:{key} has no baseline yet"))
        elif not drift_same_scale:
            msgs.append(("warn", f"{name}:{key} drift check skipped "
                         "(scale mismatch vs baseline)"))
        elif abs(f - b) > rtol * abs(b):
            msgs.append(("fail", f"{name}:{key} drifted beyond {rtol:.0%}: "
                         f"{f:.3f} vs baseline {b:.3f}"))
        else:
            msgs.append(("ok", f"{name}:{key} {f:.3f} "
                         f"(baseline {b:.3f}, tol {rtol:.0%})"))
    if not any(level == "ok" for level, _ in msgs):
        msgs.append(("fail", f"{name}: nothing comparable was produced "
                     "(schema break?)"))
    return msgs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=None,
                    help="directory with baseline BENCH_*.json "
                         "(default: committed copy at --git-ref)")
    ap.add_argument("--fresh", default=str(REPO / "results" / "bench"),
                    help="directory with freshly produced BENCH_*.json")
    ap.add_argument("--git-ref", default="HEAD",
                    help="ref for the committed baseline (default HEAD)")
    args = ap.parse_args(argv)

    failures = 0
    checked = 0
    for name, spec in MANIFEST.items():
        base = _load(args.baseline, name,
                     git_ref=None if args.baseline else args.git_ref)
        fresh = _load(args.fresh, name)
        if base is None:
            print(f"[skip] {name}: no committed baseline")
            continue
        if fresh is None:
            print(f"[FAIL] {name}: baseline exists but no fresh result "
                  f"under {args.fresh}")
            failures += 1
            continue
        checked += 1
        for level, msg in check_file(name, spec, base, fresh):
            tag = {"fail": "[FAIL]", "warn": "[skip]", "ok": "[ ok ]"}[level]
            print(f"{tag} {msg}")
            failures += level == "fail"
    if checked == 0:
        print("[FAIL] no bench files checked — baselines missing?")
        failures += 1
    print(f"check_bench: {failures} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
