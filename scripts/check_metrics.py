#!/usr/bin/env python3
"""Schema check for the Prometheus textfiles the metrics layer exports.

Validates structure, not values (values are check_bench.py's job):

  * every metric sample is preceded by matching ``# TYPE`` metadata;
  * histogram families carry cumulative ``_bucket{le=...}`` series with
    non-decreasing counts, a terminal ``le="+Inf"`` bucket equal to
    ``_count``, and a ``_sum`` sample;
  * counters are finite and non-negative;
  * with ``--require``, the named metric families must be present
    (e.g. the serving schema's ``repro_request_latency_seconds``).

stdlib-only (the CI gate must run with no deps), importable for tests:

    python scripts/check_metrics.py FILE [FILE ...] \
        [--require repro_request_latency_seconds ...]

Exit 0 = schema ok, 1 = violation (listed on stdout).
"""
from __future__ import annotations

import argparse
import math
import pathlib
import re
import sys

_SAMPLE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$')

#: the serving-plane families BENCH_serving textfiles must carry
SERVING_REQUIRED = (
    "repro_request_latency_seconds",
    "repro_request_queue_seconds",
    "repro_request_stall_seconds",
    "repro_request_service_seconds",
    "repro_requests_served_total",
)


def parse_textfile(text: str) -> dict:
    """{family: {"type": str, "samples": [(name, labels, value)]}} —
    raises ValueError on lines that are neither comments nor samples."""
    families: dict = {}
    types: dict = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, name, mtype = line.split(None, 3)
            types[name] = mtype
            families.setdefault(name, {"type": mtype, "samples": []})
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"line {ln}: unparseable sample {line!r}")
        name = m.group("name")
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        fam = base if base in types else name
        families.setdefault(fam, {"type": types.get(fam, "untyped"),
                                  "samples": []})
        families[fam]["samples"].append(
            (name, m.group("labels") or "", float(m.group("value"))))
    return families


def check_family(fam: str, info: dict) -> list:
    """Schema violations for one metric family (empty list = ok)."""
    errs = []
    mtype, samples = info["type"], info["samples"]
    if mtype == "untyped":
        errs.append(f"{fam}: sample without # TYPE metadata")
    if not samples:
        errs.append(f"{fam}: # TYPE with no samples")
        return errs
    if mtype == "histogram":
        buckets = [(lb, v) for n, lb, v in samples
                   if n == f"{fam}_bucket"]
        count = [v for n, _, v in samples if n == f"{fam}_count"]
        total = [v for n, _, v in samples if n == f"{fam}_sum"]
        if not buckets:
            errs.append(f"{fam}: histogram with no _bucket series")
            return errs
        if len(count) != 1 or len(total) != 1:
            errs.append(f"{fam}: expected exactly one _count and _sum")
            return errs
        les, last = [], -math.inf
        for lb, v in buckets:
            m = re.search(r'le="([^"]+)"', lb)
            if not m:
                errs.append(f"{fam}: bucket without le label ({lb!r})")
                continue
            le = math.inf if m.group(1) == "+Inf" else float(m.group(1))
            les.append(le)
            if v < last:
                errs.append(f"{fam}: bucket counts not cumulative at "
                            f'le="{m.group(1)}" ({v} < {last})')
            last = v
        if les != sorted(les):
            errs.append(f"{fam}: le edges not sorted")
        if les and les[-1] != math.inf:
            errs.append(f'{fam}: missing le="+Inf" bucket')
        elif buckets and buckets[-1][1] != count[0]:
            errs.append(f"{fam}: +Inf bucket {buckets[-1][1]} != _count "
                        f"{count[0]}")
    elif mtype == "counter":
        for n, _, v in samples:
            if v < 0 or not math.isfinite(v):
                errs.append(f"{fam}: counter value {v} invalid")
    elif mtype == "gauge":
        for n, _, v in samples:
            if not math.isfinite(v):
                errs.append(f"{fam}: gauge value {v} not finite")
    else:
        errs.append(f"{fam}: unknown type {mtype!r}")
    return errs


def check_file(path, require=()) -> list:
    text = pathlib.Path(path).read_text()
    try:
        families = parse_textfile(text)
    except ValueError as e:
        return [str(e)]
    errs = []
    for fam, info in sorted(families.items()):
        errs += check_family(fam, info)
    for fam in require:
        if fam not in families:
            errs.append(f"required metric family missing: {fam}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Prometheus textfile schema gate")
    ap.add_argument("files", nargs="+")
    ap.add_argument("--require", nargs="*", default=None,
                    help="metric families that must be present "
                         "(default: the serving schema)")
    args = ap.parse_args(argv)
    require = (SERVING_REQUIRED if args.require is None
               else tuple(args.require))
    bad = 0
    for f in args.files:
        errs = check_file(f, require=require)
        if errs:
            bad += 1
            print(f"FAIL {f}")
            for e in errs:
                print(f"  - {e}")
        else:
            print(f"ok   {f}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
