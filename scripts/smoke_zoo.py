"""Ad-hoc: forward every smoke config (train + prefill + decode)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M, partition
from repro.models.config import build_plan

B, S = 2, 32

for arch in configs.ARCH_IDS:
    cfg = configs.get_smoke(arch)
    plan = build_plan(cfg)
    key = jax.random.key(0)
    params = M.init(cfg, key)
    npar = partition.submodel_param_count(cfg)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, : S - cfg.frontend_len]
        batch["patches"] = jax.random.normal(key, (B, cfg.frontend_len, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_len, cfg.d_model))
    logits, aux = M.apply_train(cfg, params, batch, plan)
    assert len(logits) == cfg.n_exits, (arch, len(logits))
    for lg in logits:
        assert lg.shape == (B, S, cfg.padded_vocab), (arch, lg.shape)
        assert not np.any(np.isnan(lg)), f"{arch}: NaN in train logits"

    cache = M.cache_init(cfg, B, S, plan)
    lg, cache = M.prefill(cfg, params, batch, cache, exit_idx=-1, plan=plan)
    assert lg.shape == (B, cfg.padded_vocab)
    assert not np.any(np.isnan(lg)), f"{arch}: NaN in prefill logits"

    tok = jnp.zeros((B, 1), jnp.int32)
    lg2, cache = M.decode(cfg, params, tok, jnp.int32(S), cache, plan=plan)
    assert lg2.shape == (B, cfg.padded_vocab)
    assert not np.any(np.isnan(lg2)), f"{arch}: NaN in decode logits"

    print(f"OK {arch:16s} params={npar:>10,} segs={len(plan.segments)} "
          f"exits={plan.exit_after}")

print("zoo smoke OK")
