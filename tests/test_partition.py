"""Dynamic-DNN partitioning invariants (hypothesis property tests included):
submodel sizes are monotone, Δ-chains telescope, catalogs are consistent."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - single-example fallback
    from _hypothesis_fallback import given, settings, st

from repro import configs
from repro.models import partition
from repro.models.config import build_plan, submodel_plan


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_sizes_monotone(arch):
    cfg = configs.get_config(arch)
    sizes = [partition.submodel_bytes(cfg, j) for j in range(cfg.n_exits)]
    assert all(a < b for a, b in zip(sizes, sizes[1:]))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_delta_chain_telescopes(arch):
    """Σ Δ(i->i+1) + cold(h1) == full size: the paper's incremental
    download chain covers exactly the whole model."""
    cfg = configs.get_config(arch)
    total = partition.delta_bytes(cfg, -1, 0)
    for j in range(1, cfg.n_exits):
        total += partition.delta_bytes(cfg, j - 1, j)
    assert total == partition.submodel_bytes(cfg, cfg.n_exits - 1)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_flops_monotone(arch):
    cfg = configs.get_config(arch)
    f = [partition.submodel_flops_per_token(cfg, j) for j in range(cfg.n_exits)]
    assert all(a < b for a, b in zip(f, f[1:]))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_submodel_plan_prefix(arch):
    cfg = configs.get_config(arch)
    plan = build_plan(cfg)
    for j in range(cfg.n_exits):
        sub = submodel_plan(plan, j)
        assert sub.segments == plan.segments[: plan.exit_after[j] + 1]
        # backbone depth at the cut matches the configured exit layer
        assert sub.segments[-1].depth_end == cfg.exit_layers[j]


def test_shrink_is_free():
    cfg = configs.get_config("qwen1.5-0.5b")
    assert partition.delta_bytes(cfg, 2, 1) == 0
    assert partition.delta_bytes(cfg, 2, 2) == 0


@settings(max_examples=20, deadline=None)
@given(n_layers=st.integers(3, 24),
       cuts=st.lists(st.integers(1, 24), min_size=1, max_size=5))
def test_plan_exits_any_cut_set(n_layers, cuts):
    """Property: any valid exit set produces a plan whose exits land at the
    requested depths and whose segments partition the backbone."""
    from repro.models.config import ModelConfig
    cuts = sorted({min(c, n_layers) for c in cuts} | {n_layers})
    cfg = ModelConfig(name="t", family="dense", n_layers=n_layers,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64, exit_layers=tuple(cuts))
    plan = build_plan(cfg)
    assert sum(s.n_layers for s in plan.segments) == n_layers
    for j, seg_idx in enumerate(plan.exit_after):
        assert plan.segments[seg_idx].depth_end == cuts[j]


def test_zoo_catalog_consistent():
    from repro.mec.catalog import make_catalog
    archs = ["qwen1.5-0.5b", "xlstm-125m"]
    cat = make_catalog("zoo", arch_ids=archs)
    assert cat.source == "zoo" and cat.n_models == len(archs)
    assert cat.names == tuple(archs)
    assert np.all(cat.sizes[:, 0] == 0) and np.all(cat.prec[:, 0] == 0)
    assert np.all(np.diff(cat.sizes[:, 1:], axis=1) > 0)
    assert np.all(np.diff(cat.prec[:, 1:], axis=1) > 0)
    # upgrades cost time, downgrades are cheap
    assert cat.loadD[0, 0, 1] > cat.loadD[0, 2, 1]
    assert cat.load_seconds(0, 0, 1) == cat.loadD[0, 0, 1]
