"""End-to-end behaviour tests for the paper's system: the full offline
pipeline at reduced scale, ordering of algorithms, metric plumbing, and the
control-plane -> data-plane integration (CoCaR decisions driving a real
serving cluster)."""
import numpy as np
import pytest

from repro.core.cocar import run_offline
from repro.core.online import OnlineConfig, run_online
from repro.mec.scenario import MECConfig, Scenario


@pytest.fixture(scope="module")
def small_cfg():
    return MECConfig(n_users=150, n_windows=4, seed=3)


def test_offline_ordering(small_cfg):
    """CoCaR must dominate the non-LP baselines (paper Table IV order)."""
    res = {a: run_offline(small_cfg, a)
           for a in ("cocar", "greedy", "random", "spr3")}
    for a in ("greedy", "random", "spr3"):
        assert res["cocar"]["avg_precision"] > res[a]["avg_precision"], res
    assert res["cocar"]["hit_rate"] > 0.5
    assert 0 < res["cocar"]["mem_util"] <= 1.0


def test_lr_is_upper_bound(small_cfg):
    res = run_offline(small_cfg, "lr")
    coc = run_offline(small_cfg, "cocar")
    assert res["lr_bound"] >= coc["avg_precision"] - 1e-6


def test_dynamic_beats_static_motivating_example():
    """Sec. III: with warm caches, submodel switching serves strictly more
    precision than complete-model reloading under the same memory."""
    from benchmarks.motivating_example import run_example
    static, dynamic = run_example()
    assert dynamic["avg_precision"] > static["avg_precision"] + 0.2
    assert dynamic["hit_rate"] > static["hit_rate"] + 0.2


def test_online_end_to_end():
    from repro.traces.registry import default_workload
    cfg = MECConfig(n_users=120)
    ocfg = OnlineConfig(n_slots=40)
    r = run_online(default_workload(cfg, ocfg), "cocar-ol", cfg=cfg,
                   ocfg=ocfg)
    assert 0 < r["avg_qoe"] <= 1.0
    assert 0 < r["hit_rate"] <= 1.0


def test_control_plane_drives_data_plane():
    """CoCaR caching decisions applied to a real EdgeCluster: cached
    submodels serve actual tokens; evicted ones do not."""
    from repro import configs
    from repro.serving import EdgeCluster, Request, WeightStore
    cfgs = {"m0": configs.get_smoke("qwen1.5-0.5b"),
            "m1": configs.get_smoke("stablelm-12b")}
    store = WeightStore(cfgs, seed=1)
    cl = EdgeCluster(store, n_pods=2, capacity_bytes=10_000_000,
                     bandwidth_Bps=1e9)
    # a CoCaR-style decision: pod0 serves m0 at full depth, pod1 m1 small
    cl.apply_caching({0: {"m0": 2}, 1: {"m1": 0}})
    cl.tick(1.0)
    reqs = [Request(rid=i, model="m0", tokens=[1 + i], max_new=2, home=0,
                    deadline=cl.now + 50) for i in range(4)]
    reqs.append(Request(rid=9, model="m1", tokens=[2], max_new=2, home=1,
                        deadline=cl.now + 50))
    served = cl.submit(reqs)
    assert served == 5
    assert all(r.done for r in reqs)
    # precision ladder: deeper submodel => higher precision
    assert reqs[0].precision > reqs[-1].precision


def test_scenario_reproducible():
    a = Scenario(MECConfig(seed=5))
    b = Scenario(MECConfig(seed=5))
    ia = a.instance(0, a.empty_cache())
    ib = b.instance(0, b.empty_cache())
    np.testing.assert_array_equal(ia.m_u, ib.m_u)
    np.testing.assert_array_equal(ia.s_u, ib.s_u)
