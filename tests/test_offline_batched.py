"""The fused offline pipeline (LP → round → repair → metrics, one device
dispatch) vs the NumPy reference: decision-identical equivalence on whole
grids, repair edge cases asserted on BOTH paths, and the deterministic
reduction (`tree_sum`) invariants the equivalence rides on."""
import numpy as np
from harness import make_instance, tiny_instance

from repro.core import cocar as CC
from repro.core import lp as LP
from repro.core.jdcr import check_feasible, objective_sel, tree_sum
from repro.core.rounding import repair, repair_device, round_from_uniforms
from repro.mec import metrics as MET
from repro.mec.scenario import MECConfig, stack_instances


def both_repairs(inst, x, A):
    """Run the NumPy reference and the device kernel on the same rounded
    input; assert they make identical decisions, then return them."""
    from jax.experimental import enable_x64

    xh, Ah = repair(inst, np.array(x), np.array(A))
    data = LP.pdhg_data(inst)
    with enable_x64():
        xd, Ad = repair_device(data, np.array(x), np.array(A))
    xd, Ad = np.asarray(xd), np.asarray(Ad)
    assert np.array_equal(xh, xd), (xh, xd)
    assert np.array_equal(Ah, Ad), (Ah, Ad)
    # post-repair, metric-time enforcement must be an identity (the fused
    # pipeline computes metrics without re-running enforce)
    assert np.array_equal(MET.enforce(inst, xh, Ah), Ah)
    assert check_feasible(inst, xh, Ah)["ok"]
    return xh, Ah


# ---------------------------------------------------------------------------
# tree_sum: the deterministic reduction equivalence rides on
# ---------------------------------------------------------------------------

def test_tree_sum_matches_numpy_and_is_padding_invariant():
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 17, 64, 150):
        v = rng.standard_normal((5, n))
        ref = tree_sum(v, -1)
        np.testing.assert_allclose(ref, v.sum(-1), rtol=1e-12)
        # appending zeros must not change a single bit
        padded = np.concatenate([v, np.zeros((5, 37))], axis=-1)
        assert np.array_equal(tree_sum(padded, -1), ref)
        # the jnp path folds the same adds -> bit-identical to numpy
        with enable_x64():
            dev = np.asarray(tree_sum(jnp.asarray(v), -1))
        assert np.array_equal(dev, ref)


def test_round_from_uniforms_np_jnp_identical():
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    inst = make_instance(n_users=30)
    x_f, A_f, _ = LP.solve_lp_scipy(inst)
    onehot = np.zeros((inst.U, inst.M))
    onehot[np.arange(inst.U), inst.m_u] = 1.0
    from repro.core.rounding import draw_rounding_uniforms
    u_cat, u_phi = draw_rounding_uniforms(3, 4, inst.N, inst.M, inst.U,
                                          inst.H)
    xh, Ah = round_from_uniforms(np.asarray(x_f), np.asarray(A_f), onehot,
                                 u_cat, u_phi)
    with enable_x64():
        xd, Ad = round_from_uniforms(jnp.asarray(x_f), jnp.asarray(A_f),
                                     jnp.asarray(onehot),
                                     jnp.asarray(u_cat),
                                     jnp.asarray(u_phi))
    assert np.array_equal(xh, np.asarray(xd))
    assert np.array_equal(Ah, np.asarray(Ad))


# ---------------------------------------------------------------------------
# repair edge cases, identical on both paths
# ---------------------------------------------------------------------------

def _route(inst, entries):
    """A (N, U, H) routing matrix with 1.0 at each (n, u, h) entry."""
    A = np.zeros((inst.N, inst.U, inst.H))
    for n, u, h in entries:
        A[n, u, h] = 1.0
    return A


def _cache(inst, levels):
    """A one-hot x from per-(n, m) cached levels."""
    x = np.zeros((inst.N, inst.M, inst.H + 1))
    for (n, m), h in levels.items():
        x[n, m, h] = 1.0
    return x


def test_memory_overflow_downgrade_to_smaller_submodel():
    """Slack fits the next-smaller submodel: the evicted model downgrades
    (h2 -> h1) and its users follow to the downgraded route."""
    inst = tiny_instance(R=32.0)                # 40 used, slack fits h1
    x = _cache(inst, {(0, 0): 2, (0, 1): 2})
    A = _route(inst, [(0, 0, 1), (0, 1, 1)])    # both users at h2
    xh, Ah = both_repairs(inst, x, A)
    # model 1 has the smaller routed precision -> downgraded to h1
    assert np.argmax(xh[0, 1]) == 1
    assert np.argmax(xh[0, 0]) == 2
    assert Ah[0, 1, 0] == 1.0 and Ah[0, 1, 1] == 0.0   # user moved h2->h1
    assert Ah[0, 0, 1] == 1.0                          # untouched


def test_memory_overflow_evicts_to_h0():
    """No smaller submodel fits: evict to h0 and drop the orphaned user."""
    inst = tiny_instance(R=25.0)                # slack 5 < h1 size 10
    x = _cache(inst, {(0, 0): 2, (0, 1): 2})
    A = _route(inst, [(0, 0, 1), (0, 1, 1)])
    xh, Ah = both_repairs(inst, x, A)
    assert np.argmax(xh[0, 1]) == 0             # evicted outright
    assert Ah[0, 1].sum() == 0.0                # its user goes to the cloud
    assert Ah[0, 0, 1] == 1.0


def test_downgrade_chain_over_multiple_evictions():
    """Tight memory forces a chain: one model steps down, then the other,
    until the budget fits — the bounded while_loop must reach the same
    fixpoint as the reference's open-ended loop."""
    inst = tiny_instance(R=21.0, m_u=(0, 1), prec2=(0.9, 0.8))
    x = _cache(inst, {(0, 0): 2, (0, 1): 2})    # 40 used vs R=21
    A = _route(inst, [(0, 0, 1), (0, 1, 1)])
    xh, Ah = both_repairs(inst, x, A)
    used = float(np.sum(xh[0] * inst.sizes))
    assert used <= 21.0 + 1e-9


def test_dedupe_exact_precision_tie_keeps_smallest_bs():
    """Two routes to the SAME submodel level at different BSs are an exact
    precision tie — both engines must keep the smaller (n, h)."""
    inst = tiny_instance(n_bs=2, m_u=(0,), R=100.0)
    x = _cache(inst, {(0, 0): 2, (1, 0): 2, (0, 1): 0, (1, 1): 0})
    A = _route(inst, [(0, 0, 1), (1, 0, 1)])    # duplicate routes, tied
    xh, Ah = both_repairs(inst, x, A)
    assert Ah[0, 0, 1] == 1.0 and Ah[1, 0, 1] == 0.0


def test_users_infeasible_at_every_bs_stay_unserved():
    """A deadline below every achievable latency: the kick-out stage drops
    the routes and the re-route stage must NOT bring them back."""
    inst = tiny_instance(ddl=1e-6, R=100.0)
    x = _cache(inst, {(0, 0): 2, (0, 1): 2})
    A = _route(inst, [(0, 0, 1), (0, 1, 1)])
    xh, Ah = both_repairs(inst, x, A)
    assert Ah.sum() == 0.0
    m = MET.window_metrics(inst, xh, Ah)
    assert m["hits"] == 0 and m["hit_rate"] == 0.0


def test_reroute_recovers_unserved_user_at_feasible_bs():
    """A user whose rounded route was dropped gets re-routed to a cached
    feasible replica (the routing-only step beyond Sec. V-D)."""
    inst = tiny_instance(n_bs=2, m_u=(0,), R=100.0)
    x = _cache(inst, {(0, 0): 0, (1, 0): 2, (0, 1): 0, (1, 1): 0})
    A = _route(inst, [])                        # unserved after rounding
    xh, Ah = both_repairs(inst, x, A)
    assert Ah[1, 0, 1] == 1.0                   # picked up at BS 1, h2


# ---------------------------------------------------------------------------
# the fused pipeline end to end
# ---------------------------------------------------------------------------

HETERO = [(0, 40, 3), (1, 50, 4), (2, 35, 3)]


def _device_vs_reference(n_seeds, best_of, iters=500):
    insts = [make_instance(seed=s, n_users=u, n_bs=n) for s, u, n in HETERO]
    stacked = stack_instances(insts)
    u_cat, u_phi = CC.offline_uniforms(stacked, 7, n_seeds, best_of)
    dev = CC.offline_pipeline_device(stacked, u_cat, u_phi,
                                     pdhg_iters=iters, n_seeds=n_seeds)
    host = CC.offline_pipeline_host(stacked, dev["x_frac"], dev["A_frac"],
                                    u_cat, u_phi, n_seeds=n_seeds)
    devu = CC._unstack_device(stacked, dev, n_seeds)
    return insts, devu, host


def test_device_pipeline_matches_reference_on_hetero_grid():
    """Identical cache/routing decisions on a padded heterogeneous stack,
    objectives and window metrics within 1e-9, all outputs feasible."""
    insts, devu, host = _device_vs_reference(n_seeds=2, best_of=4)
    for inst, per_dev, per_host in zip(insts, devu, host):
        for (xd, Ad, idv), (xh, Ah, ih) in zip(per_dev, per_host):
            assert np.array_equal(xd, xh)
            assert np.array_equal(Ad, Ah)
            assert check_feasible(inst, xd, Ad)["ok"]
            assert abs(idv["obj"] - ih["obj"]) < 1e-9
            assert abs(idv["lp_obj"] - ih["lp_obj"]) < 1e-9
            for k, v in ih["metrics"].items():
                assert abs(idv["metrics"][k] - v) < 1e-9, k


def test_best_of_trial_argmax_agreement():
    """The device argmax over trials must pick the same winner as the host
    strictly-greater loop — per (window, seed), with bit-equal per-trial
    objectives (ties included)."""
    _, devu, host = _device_vs_reference(n_seeds=3, best_of=8)
    for per_dev, per_host in zip(devu, host):
        for (_, _, idv), (_, _, ih) in zip(per_dev, per_host):
            assert idv["best_t"] == ih["best_t"]
            assert np.array_equal(idv["trial_objs"],
                                  np.asarray(ih["trial_objs"]))


def test_check_feasible_device_on_pipeline_outputs():
    """The jnp feasibility residuals, evaluated on the padded pipeline
    outputs, must report every repaired window as feasible."""
    from jax.experimental import enable_x64

    from repro.core.jdcr import check_feasible_device

    insts = [make_instance(seed=s, n_users=u, n_bs=n) for s, u, n in HETERO]
    stacked = stack_instances(insts)
    u_cat, u_phi = CC.offline_uniforms(stacked, 1, 2, 2)
    dev = CC.offline_pipeline_device(stacked, u_cat, u_phi,
                                     pdhg_iters=400, n_seeds=2)
    for i in range(len(stacked)):
        data_i = type(stacked.data)(*(v[i] for v in stacked.data))
        for s in range(2):
            with enable_x64():
                res = check_feasible_device(data_i, dev["x"][i, s],
                                            dev["A"][i, s])
            for k, v in res.items():
                assert float(v) <= 1e-6, (k, float(v))


def test_objective_sel_matches_objective():
    inst = make_instance(n_users=30)
    x_f, A_f, _ = LP.solve_lp_scipy(inst)
    from repro.core.rounding import round_solution
    x, A = round_solution(inst, x_f, A_f, key=0)
    x, A = repair(inst, x, A)
    prec_u = inst.prec[inst.m_u, 1:]
    assert abs(objective_sel(prec_u, A) - inst.objective(A)) < 1e-9


def test_sweep_seeds_axis():
    """run_sweep(n_seeds=2) emits one row per (variant, rounding seed)."""
    from repro.experiments.sweep import run_sweep
    rows = run_sweep(base=MECConfig(n_users=20),
                     axes={"zipf": (0.4, 0.8)}, pdhg_iters=300,
                     best_of=2, n_seeds=2)
    assert len(rows) == 4
    assert {r["rounding_seed"] for r in rows} == {0, 1}
    for r in rows:
        assert 0.0 <= r["hit_rate"] <= 1.0


def test_cocar_grid_host_backend_matches_shapes():
    """The host backend returns the same result structure (it is the same
    algorithm, looped on the host against its own LP solve)."""
    insts = [make_instance(seed=s, n_users=u, n_bs=n)
             for s, u, n in HETERO[:2]]
    grid = CC.cocar_grid(insts, seed=0, pdhg_iters=300, best_of=2,
                         n_seeds=2, backend="host")
    assert len(grid) == 2 and len(grid[0]) == 2
    for inst, per_seed in zip(insts, grid):
        for x, A, info in per_seed:
            assert x.shape == (inst.N, inst.M, inst.H + 1)
            assert check_feasible(inst, x, A)["ok"]
            assert info["lp_obj"] > 0
