"""Trace workload subsystem: generator determinism/shape/semantics, and
NumPy-vs-scan engine equivalence (per-slot QoE, final cache state, and the
download state machine edge cases, Eqs. 35-37)."""
import numpy as np
import pytest

from repro.core.online import OnlineConfig, OnlineSim, run_online
from repro.mec.scenario import MECConfig
from repro.traces import available, draw_decision_stream, make_trace
from repro.traces import engine as E

# one shared shape so every jitted variant compiles once per test session
CFG = MECConfig(n_users=60)
OCFG = OnlineConfig(n_slots=20)
T, U, N, M = OCFG.n_slots, CFG.n_users, CFG.n_bs, CFG.n_models


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", available())
def test_trace_shapes_and_determinism(name):
    tr1 = make_trace(name, CFG, T, seed=3)
    tr2 = make_trace(name, CFG, T, seed=3)
    tr3 = make_trace(name, CFG, T, seed=4)
    assert tr1.model.shape == tr1.home.shape == tr1.mask.shape == (T, U)
    assert tr1.model.min() >= 0 and tr1.model.max() < M
    assert tr1.home.min() >= 0 and tr1.home.max() < N
    # pure function of the key
    np.testing.assert_array_equal(tr1.model, tr2.model)
    np.testing.assert_array_equal(tr1.home, tr2.home)
    np.testing.assert_array_equal(tr1.mask, tr2.mask)
    assert not (np.array_equal(tr1.model, tr3.model)
                and np.array_equal(tr1.home, tr3.home))


def test_counts_match_requests():
    tr = make_trace("diurnal", CFG, T, seed=1, min_load=0.3)
    counts = tr.counts(N, M)
    assert counts.shape == (T, N, M)
    for t in (0, T // 2, T - 1):
        m_u, home = tr.requests(t)
        ref = np.zeros((N, M))
        np.add.at(ref, (home, m_u), 1.0)
        np.testing.assert_array_equal(counts[t], ref)
    assert counts.sum() == tr.mask.sum()


def test_drift_changes_popularity():
    tr = make_trace("drift", CFG, 80, seed=0, change_every=40, warmup=0)
    h1 = np.bincount(tr.model[:35].ravel(), minlength=M)
    h2 = np.bincount(tr.model[45:].ravel(), minlength=M)
    # distributions across periods differ substantially
    tv = 0.5 * np.abs(h1 / h1.sum() - h2 / h2.sum()).sum()
    assert tv > 0.1


def test_flash_crowd_concentrates_demand():
    tr = make_trace("flash_crowd", CFG, T, seed=2, n_events=1,
                    duration=10, intensity=0.9)
    ev = tr.meta["events"][0]
    spike = tr.model[ev["start"]:ev["end"]]
    share = (spike == ev["model"]).mean()
    assert share > 0.6                      # ~0.9 by construction
    calm = np.concatenate([tr.model[:ev["start"]], tr.model[ev["end"]:]])
    if calm.size:
        assert (calm == ev["model"]).mean() < share


def test_diurnal_load_oscillates():
    tr = make_trace("diurnal", CFG, 50, seed=0, period=50, min_load=0.1)
    load = tr.mask.mean(1)
    assert load.max() > 0.7 and load.min() < 0.4


def test_mobility_handover():
    tr = make_trace("mobility", CFG, T, seed=0, p_move=0.2)
    assert tr.meta["handovers"] > 0
    # homes persist between moves: consecutive-slot agreement far above iid
    agree = (tr.home[1:] == tr.home[:-1]).mean()
    assert agree > 0.5


def test_mmpp_burst_metadata():
    tr = make_trace("mmpp", CFG, 100, seed=1)
    assert 0 < tr.meta["burst_slots"] < 100
    assert tr.mask.any() and not tr.mask.all()


def test_flash_crowd_overlapping_events_compose():
    from repro.traces.generators import flash_crowd
    tr = flash_crowd(0, 20, U, N, M, n_events=2, duration=15,
                     intensity=0.8)
    e1, e2 = tr.meta["events"]
    lo, hi = max(e1["start"], e2["start"]), min(e1["end"], e2["end"])
    if hi > lo and e1["model"] != e2["model"]:       # overlap happened
        overlap = tr.model[lo:hi]
        # both hot models elevated above the 1/M baseline in the overlap
        assert (overlap == e1["model"]).mean() > 1.2 / M
        assert (overlap == e2["model"]).mean() > 1.2 / M


def test_registry_unknown_name():
    with pytest.raises(KeyError):
        make_trace("nope", CFG, T)


def test_scenario_trace_hook():
    from repro.mec.scenario import Scenario
    sc = Scenario(CFG)
    tr = sc.trace("stationary", T)
    ref = make_trace("stationary", CFG, T, seed=CFG.seed)
    np.testing.assert_array_equal(tr.model, ref.model)
    np.testing.assert_array_equal(tr.home, ref.home)


def test_decision_stream_deterministic():
    s1 = draw_decision_stream(T, 3, N, M, seed=7)
    s2 = draw_decision_stream(T, 3, N, M, seed=7)
    np.testing.assert_array_equal(s1.adjust_ns, s2.adjust_ns)
    np.testing.assert_array_equal(s1.u_shrink, s2.u_shrink)
    assert s1.adjust_ns.shape == (T, 3)
    assert s1.perms.shape == (T, 3, M)
    assert sorted(s1.perms[0, 0]) == list(range(M))


# ---------------------------------------------------------------------------
# engine equivalence (the acceptance bar: per-slot QoE + final cache state
# match OnlineSim for all four policies on a fixed stationary trace)
# ---------------------------------------------------------------------------

def _numpy_reference(cfg, ocfg, algo, trace, stream):
    from repro.core.online import run_online_trace

    return run_online_trace(cfg, ocfg, algo, trace, stream)


STAT_TRACE = make_trace("stationary", CFG, T, seed=CFG.seed)
STREAM = draw_decision_stream(T, OCFG.rounds, N, M, CFG.seed + 99)


@pytest.mark.parametrize("algo", E.POLICIES)
def test_scan_matches_numpy(algo):
    qs, hs, sim = _numpy_reference(CFG, OCFG, algo, STAT_TRACE, STREAM)
    res = run_online(STAT_TRACE, algo, cfg=CFG, ocfg=OCFG,
                     engine="scan", stream=STREAM)
    np.testing.assert_allclose(res["slot_qoe"], qs, rtol=1e-9, atol=1e-9)
    np.testing.assert_array_equal(res["slot_hits"], hs)
    fs = res["final_state"]
    np.testing.assert_array_equal(fs.lvl, np.argmax(sim.X, -1))
    np.testing.assert_allclose(fs.O, sim.O, rtol=1e-9, atol=1e-9)
    np.testing.assert_array_equal(fs.target, sim.target)


def test_scan_matches_numpy_no_partition():
    ocfg = OnlineConfig(n_slots=T, partition=False)
    qs, _, sim = _numpy_reference(CFG, ocfg, "cocar-ol", STAT_TRACE, STREAM)
    res = run_online(STAT_TRACE, "cocar-ol", cfg=CFG, ocfg=ocfg,
                     engine="scan", stream=STREAM)
    np.testing.assert_allclose(res["slot_qoe"], qs, rtol=1e-9, atol=1e-9)
    np.testing.assert_array_equal(res["final_state"].lvl,
                                  np.argmax(sim.X, -1))


def test_grid_matches_single_runs():
    """vmapped grid (mixed traces x policies via lax.switch) == per-job
    NumPy runs, including jobs with a non-default seed (the grid's
    default-seed/stream derivation must match run_online's)."""
    drift_tr = make_trace("drift", CFG, T, seed=CFG.seed, change_every=8)
    jobs = [dict(cfg=CFG, algo=a, trace=STAT_TRACE, stream=STREAM)
            for a in ("cocar-ol", "lfu", "lfu-mad", "random")]
    # seed=5 jobs, no explicit stream: the grid must draw it from seed+99
    jobs += [dict(cfg=CFG, algo=a, trace=drift_tr, seed=5)
             for a in ("cocar-ol", "lfu", "lfu-mad", "random")]
    stream5 = draw_decision_stream(T, OCFG.rounds, N, M, 5 + 99)
    grid = E.run_online_grid(jobs, OCFG)
    assert len(grid) == 8
    from dataclasses import replace
    for job, g in zip(jobs, grid):
        cfg = replace(CFG, seed=job.get("seed", 0))   # as run_online does
        qs, _, sim = _numpy_reference(cfg, OCFG, job["algo"], job["trace"],
                                      job.get("stream", stream5))
        np.testing.assert_allclose(g["slot_qoe"], qs, rtol=1e-9, atol=1e-9)
        np.testing.assert_array_equal(g["final_state"].lvl,
                                      np.argmax(sim.X, -1))


def test_grid_mixed_shapes_bucketed():
    """Mixed (n_bs, n_models) grids — rejected before the scale executor
    — are now bucketed by shape, and every job still reproduces its solo
    scan run bit-exactly."""
    cfg2 = MECConfig(n_bs=4, n_users=60, n_models=M, seed=3)
    jobs = [dict(cfg=CFG, algo="lfu", trace=STAT_TRACE, stream=STREAM),
            dict(cfg=cfg2, algo="lfu", seed=3)]
    grid = E.run_online_grid(jobs, OCFG)
    assert len(grid) == 2
    solo0 = run_online(STAT_TRACE, "lfu", cfg=CFG, ocfg=OCFG,
                       engine="scan", stream=STREAM)
    from repro.traces.registry import default_trace
    solo1 = run_online(default_trace(cfg2, OCFG), "lfu", cfg=cfg2,
                       ocfg=OCFG, engine="scan", seed=3)
    np.testing.assert_array_equal(grid[0]["slot_qoe"], solo0["slot_qoe"])
    np.testing.assert_array_equal(grid[1]["slot_qoe"], solo1["slot_qoe"])
    np.testing.assert_array_equal(grid[1]["final_state"].lvl,
                                  solo1["final_state"].lvl)


def test_online_sweep_rows():
    from repro.experiments.sweep import run_online_sweep

    rows = run_online_sweep(
        base=CFG, axes={"mem_capacity_mb": (300.0, 500.0)},
        workloads=("stationary", "drift"), policies=("cocar-ol", "lfu"),
        ocfg=OCFG)
    assert len(rows) == 8
    for r in rows:
        assert set(r) == {"mem_capacity_mb", "workload", "family", "algo",
                          "avg_qoe", "hit_rate"}
        assert 0.0 <= r["avg_qoe"] <= 1.0


# ---------------------------------------------------------------------------
# download state machine edge cases — asserted identically on both engines
# ---------------------------------------------------------------------------

def _both_engines(sim):
    """Mirror a NumPy sim's download state into engine pytrees."""
    params = E.make_params(sim.cfg, sim.ocfg, sc=sim.sc)
    st = E.init_state(params, sim.ocfg.dT_past)
    st = st._replace(lvl=np.argmax(sim.X, -1).astype(np.int32),
                     O=sim.O.copy(),
                     target=sim.target.astype(np.int32))
    return params, st


def _routine_jax(params, st):
    from jax.experimental import enable_x64

    with enable_x64():
        out = E._routine_update(params, st)
        return E.OnlineState(*(np.asarray(x) for x in out))


def test_one_slot_finishes_multiple_deltas_both_engines():
    """A slot budget large enough for several queued Δ components finishes
    them all; the cache jumps to the LAST finished submodel (Eq. 37)."""
    sim = OnlineSim(CFG, OCFG)
    s = sim.sc.sizes
    budget = sim.W[0] * OCFG.slot_s
    n, m = 0, 0
    # two tiny deltas well inside one budget + a third partial one
    d1, d2 = 0.2 * budget, 0.3 * budget
    sim.O[n, m, 0], sim.O[n, m, 1], sim.O[n, m, 2] = d1, d2, 2.0 * budget
    sim.target[n, m] = 3
    params, st = _both_engines(sim)
    sim.routine_update()
    out = _routine_jax(params, st)
    assert np.argmax(sim.X[n, m]) == 2          # h2 live, h3 still in flight
    np.testing.assert_array_equal(out.lvl, np.argmax(sim.X, -1))
    np.testing.assert_allclose(out.O, sim.O, rtol=1e-12, atol=1e-12)
    assert sim.O[n, m, 2] > 0                   # partial remains queued


def test_partial_cross_slot_download_both_engines():
    """A Δ bigger than one slot budget survives across slots, decremented
    exactly by the budget; no cache switch until it completes."""
    sim = OnlineSim(CFG, OCFG)
    budget = sim.W[0] * OCFG.slot_s
    n, m = 1, 2
    sim.O[n, m, 0] = 2.5 * budget
    sim.target[n, m] = 1
    params, st = _both_engines(sim)
    for _ in range(2):
        sim.routine_update()
        st = _routine_jax(params, st)
        np.testing.assert_array_equal(st.lvl, np.argmax(sim.X, -1))
        np.testing.assert_allclose(st.O, sim.O, rtol=1e-12, atol=1e-12)
        assert np.argmax(sim.X[n, m]) == 0      # still not servable
    sim.routine_update()
    st = _routine_jax(params, st)
    assert np.argmax(sim.X[n, m]) == 1          # third slot completes it
    np.testing.assert_array_equal(st.lvl, np.argmax(sim.X, -1))


def test_eviction_mid_download_both_engines():
    """LFU-style eviction can shrink a model while its download is in
    flight (Eq. 49 is immediate); when the download lands the cache jumps
    to the downloaded target on both engines."""
    sim = OnlineSim(CFG, OCFG)
    budget = sim.W[0] * OCFG.slot_s
    n, m = 0, 1
    sim.X[n, m, :] = 0
    sim.X[n, m, 2] = 1                          # cached at h2
    sim.O[n, m, 2] = 0.5 * budget               # upgrading h2 -> h3
    sim.target[n, m] = 3
    # mid-download eviction: cache shrunk to h0 while O is in flight
    sim.X[n, m, :] = 0
    sim.X[n, m, 0] = 1
    params, st = _both_engines(sim)
    sim.routine_update()
    out = _routine_jax(params, st)
    assert np.argmax(sim.X[n, m]) == 3          # landed download wins
    np.testing.assert_array_equal(out.lvl, np.argmax(sim.X, -1))
    np.testing.assert_allclose(out.O, sim.O, rtol=1e-12, atol=1e-12)
