"""Queueing simulator: SLO attainment vs load, caching quality effects."""

from repro import configs
from repro.serving.simulator import QueueSim, SimRequest, poisson_arrivals

from repro.models import partition

CFGS = {"a": configs.get_smoke("qwen1.5-0.5b"),
        "b": configs.get_smoke("stablelm-12b")}
# calibrate pod compute so one full-depth 64-token request takes ~50 ms
_c = partition.submodel_flops_per_token(CFGS["a"], CFGS["a"].n_exits - 1,
                                        ctx=64)
COMPUTE = 64 * _c / 0.05


def _sim(residency, rate, seed=0, duration=30.0):
    sim = QueueSim(CFGS, residency, COMPUTE, seed=seed)
    arr = poisson_arrivals(rate, duration, list(CFGS), [0.7, 0.3],
                           tokens=64, slo_s=2.0, seed=seed)
    return sim.run(arr), len(arr)


def test_slo_degrades_with_load():
    residency = {0: {"a": 2, "b": 2}, 1: {"a": 2, "b": 2}}
    low, _ = _sim(residency, rate=2.0)
    high, _ = _sim(residency, rate=200.0)
    assert low["slo_attainment"] > high["slo_attainment"]
    assert low["p95_latency"] <= high["p95_latency"] + 1e-9


def test_smaller_submodels_carry_more_load():
    """Under overload, caching small submodels (lower precision, faster)
    serves more requests within SLO — the precision/latency trade the
    paper's QoE objective navigates."""
    big = {0: {"a": 2, "b": 2}, 1: {"a": 2, "b": 2}}
    small = {0: {"a": 0, "b": 0}, 1: {"a": 0, "b": 0}}
    m_big, n = _sim(big, rate=100.0)
    m_small, _ = _sim(small, rate=100.0)
    assert m_small["served"] > m_big["served"]
    assert m_small["slo_attainment"] > m_big["slo_attainment"]
    # per-served precision is lower for small submodels...
    per_big = m_big["avg_precision"] * n / m_big["served"]
    per_small = m_small["avg_precision"] * n / m_small["served"]
    assert per_small < per_big
    # ...but TOTAL delivered precision is higher — the paper's Sec. III
    # motivation, reproduced at the queueing level
    assert m_small["avg_precision"] > m_big["avg_precision"]


def test_uncached_model_dropped():
    residency = {0: {"a": 1}}
    sim = QueueSim(CFGS, residency, COMPUTE)
    reqs = [SimRequest(rid=0, model="b", tokens=16, arrival=0.0,
                       deadline=10.0)]
    m = sim.run(reqs)
    assert m["dropped"] == 1 and m["served"] == 0


def test_routing_prefers_precision_with_slack():
    residency = {0: {"a": 0}, 1: {"a": 2}}
    sim = QueueSim(CFGS, residency, COMPUTE)
    reqs = [SimRequest(rid=i, model="a", tokens=16, arrival=float(i),
                       deadline=float(i) + 5.0) for i in range(4)]
    m = sim.run(reqs)
    assert all(r.pod == 1 for r in sim.done)       # deep submodel wins


def test_deadline_miss_accounting():
    """With admit_late, requests that cannot make their deadline are
    served anyway and accounted as deadline misses; without it they are
    dropped — the miss count is identical either way."""
    residency = {0: {"a": 2}}
    svc = QueueSim(CFGS, residency, COMPUTE).service_time("a", 2, 64)
    # back-to-back arrivals with deadlines only one service time out:
    # request k queues behind k-1 others, so only the first can make it
    reqs = lambda: [SimRequest(rid=i, model="a", tokens=64, arrival=0.0,  # noqa: E731
                               deadline=1.5 * svc) for i in range(4)]
    drop = QueueSim(CFGS, residency, COMPUTE)
    m_drop = drop.run(reqs())
    late = QueueSim(CFGS, residency, COMPUTE, admit_late=True)
    m_late = late.run(reqs())
    assert m_drop["served"] == 1 and m_drop["dropped"] == 3
    assert m_late["served"] == 4 and m_late["dropped"] == 0
    assert sum(not r.met_slo for r in late.done) == 3
    assert m_drop["deadline_misses"] == m_late["deadline_misses"] == 3
    assert m_drop["slo_attainment"] == m_late["slo_attainment"] == 0.25


def test_pod_failure_mid_queue():
    """A pod failing at time t keeps its in-flight work but takes no new
    arrivals — later requests re-route to the surviving pod."""
    residency = {0: {"a": 2}, 1: {"a": 0}}
    sim = QueueSim(CFGS, residency, COMPUTE, fail_at={0: 1.0})
    reqs = [SimRequest(rid=i, model="a", tokens=16, arrival=0.5 * i,
                       deadline=0.5 * i + 5.0) for i in range(5)]
    sim.run(reqs)
    pods = {r.arrival: r.pod for r in sim.done}
    assert pods[0.0] == 0 and pods[0.5] == 0     # pre-failure: precision
    assert all(p == 1 for t, p in pods.items() if t >= 1.0)
    assert len(sim.done) == 5                     # nothing lost, re-routed


def test_empty_residency_drops_everything():
    sim = QueueSim(CFGS, {}, COMPUTE)
    m = sim.run([SimRequest(rid=0, model="a", tokens=16, arrival=0.0,
                            deadline=9.0)])
    assert m["served"] == 0 and m["dropped"] == 1
    assert m["slo_attainment"] == 0.0 and m["deadline_misses"] == 1
    # and an all-empty per-pod residency behaves identically
    sim2 = QueueSim(CFGS, {0: {}, 1: {}}, COMPUTE)
    m2 = sim2.run([SimRequest(rid=0, model="a", tokens=16, arrival=0.0,
                              deadline=9.0)])
    assert m2["served"] == 0 and m2["dropped"] == 1


def test_seed_determinism():
    residency = {0: {"a": 2, "b": 1}, 1: {"a": 1, "b": 2}}
    m1, n1 = _sim(residency, rate=40.0, seed=7)
    m2, n2 = _sim(residency, rate=40.0, seed=7)
    assert n1 == n2 and m1 == m2
    m3, n3 = _sim(residency, rate=40.0, seed=8)
    assert (n3, m3) != (n1, m1)                  # different draw


def test_latency_attribution_exact_with_stalls():
    """queue_s + stall_s + service_s telescopes exactly to delivered
    latency, and each component isolates its cause: a cold model stalls
    (available_at), back-to-back arrivals queue."""
    residency = {0: {"a": 2}}
    sim0 = QueueSim(CFGS, residency, COMPUTE)
    svc = sim0.service_time("a", 2, 64)
    stall_until = 4.0 * svc
    reqs = [SimRequest(rid=i, model="a", tokens=64, arrival=0.1 * i * svc,
                       deadline=20.0 * svc + stall_until)
            for i in range(3)]
    sim = QueueSim(CFGS, residency, COMPUTE,
                   available_at={(0, "a"): stall_until})
    m = sim.run(reqs)
    assert m["served"] == 3 and m["attribution_max_err"] == 0.0
    r0, r1, r2 = sim.done
    # first request: pure loading stall, no queueing
    assert r0.queue_s == 0.0
    assert r0.stall_s == stall_until - r0.arrival
    assert abs(r0.service_s - svc) < 1e-12
    # later requests queue behind r0 past the load, so no stall remains
    assert r1.stall_s == 0.0 and r1.queue_s > 0.0
    for r in sim.done:
        assert r.queue_s + r.stall_s + r.service_s == r.latency
    att = m["attribution"]
    assert att["stall"]["sum"] > 0 and att["queue"]["sum"] > 0
    assert abs(att["queue"]["frac"] + att["stall"]["frac"]
               + att["service"]["frac"] - 1.0) < 1e-12


def test_event_tap_decision_inert_and_conserved():
    """Attaching an EventLog changes nothing — metrics and per-request
    outcomes are identical — while the log satisfies the conservation
    law and records the scored candidate set per route decision."""
    from repro.obs import EventLog

    residency = {0: {"a": 2, "b": 1}, 1: {"a": 1, "b": 2}}
    arr = lambda: poisson_arrivals(80.0, 10.0, list(CFGS), [0.7, 0.3],  # noqa: E731
                                   tokens=64, slo_s=2.0, seed=11)
    plain = QueueSim(CFGS, residency, COMPUTE)
    m_off = plain.run(arr())
    log = EventLog()
    tapped = QueueSim(CFGS, residency, COMPUTE, events=log,
                      run_label="inert-check")
    m_on = tapped.run(arr())
    assert m_on == m_off
    assert [(r.rid, r.pod, r.start, r.finish) for r in tapped.done] == \
        [(r.rid, r.pod, r.start, r.finish) for r in plain.done]
    c = log.conservation()
    assert c["ok"] and c["n_arrivals"] == len(arr())
    assert c["by_kind"].get("finish", 0) + c["by_kind"].get("miss", 0) \
        == m_on["served"]
    assert c["by_kind"].get("drop", 0) == m_on["dropped"]
    routes = [e for e in log.events if e.kind == "route"]
    assert len(routes) == c["n_arrivals"]
    served = {r.rid for r in tapped.done}
    for e in routes:
        if e.attrs["chosen"] >= 0 and e.rid in served:
            assert any(cand["pod"] == e.attrs["chosen"]
                       for cand in e.attrs["candidates"])
    # phase events carry durations that rebuild the attribution
    for kind in ("queue", "stall", "service"):
        evs = {e.rid: e.attrs["dur"] for e in log.events if e.kind == kind}
        for r in tapped.done:
            assert evs[r.rid] == getattr(r, f"{kind}_s")


def test_metrics_empty_done_pinned():
    """No completed request: every percentile/attribution key is an
    explicit 0.0 and ``n`` pins the sample count, so downstream tables
    never confuse 'nothing served' with 'served instantly'."""
    sim = QueueSim(CFGS, {}, COMPUTE)
    m = sim.run([SimRequest(rid=0, model="a", tokens=16, arrival=0.0,
                            deadline=9.0)])
    assert m["n"] == 0 and m["served"] == 0 and m["dropped"] == 1
    assert m["p50_latency"] == m["p95_latency"] == m["p99_latency"] == 0.0
    assert m["attribution_max_err"] == 0.0
    for ph in ("queue", "stall", "service"):
        assert m["attribution"][ph] == {"sum": 0.0, "frac": 0.0,
                                        "p50": 0.0, "p95": 0.0,
                                        "p99": 0.0}
    # a truly empty run pins identically
    m2 = QueueSim(CFGS, {}, COMPUTE).metrics()
    assert m2["n"] == 0 and m2["p99_latency"] == 0.0


def test_transfer_time_matches_pod_cache_byte_math():
    """simulator.transfer_time (what ServingPlan availability times are
    built from, via the measured catalog) == the seconds PodCache
    actually takes for the same transition."""
    from repro.serving.loader import PodCache, WeightStore
    from repro.serving.simulator import transfer_time

    bw = 250e6
    store = WeightStore(CFGS, lazy=True)
    for frm, to in ((-1, 0), (-1, 2), (0, 2), (1, 2)):
        pod = PodCache(store, capacity_bytes=10**12, bandwidth_Bps=bw)
        if frm >= 0:
            pod.resident["a"] = frm              # no params needed: lazy
        ev = pod.request_load("a", to, now=0.0)
        want = transfer_time(CFGS["a"], frm, to, bw)
        assert abs(ev.seconds - want) < 1e-12
        assert ev.bytes == partition.delta_bytes(CFGS["a"], frm, to)
    # shrinks are instant on both sides
    assert transfer_time(CFGS["a"], 2, 1, bw) == 0.0
