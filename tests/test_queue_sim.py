"""Queueing simulator: SLO attainment vs load, caching quality effects."""

from repro import configs
from repro.serving.simulator import QueueSim, SimRequest, poisson_arrivals

from repro.models import partition

CFGS = {"a": configs.get_smoke("qwen1.5-0.5b"),
        "b": configs.get_smoke("stablelm-12b")}
# calibrate pod compute so one full-depth 64-token request takes ~50 ms
_c = partition.submodel_flops_per_token(CFGS["a"], CFGS["a"].n_exits - 1,
                                        ctx=64)
COMPUTE = 64 * _c / 0.05


def _sim(residency, rate, seed=0, duration=30.0):
    sim = QueueSim(CFGS, residency, COMPUTE, seed=seed)
    arr = poisson_arrivals(rate, duration, list(CFGS), [0.7, 0.3],
                           tokens=64, slo_s=2.0, seed=seed)
    return sim.run(arr), len(arr)


def test_slo_degrades_with_load():
    residency = {0: {"a": 2, "b": 2}, 1: {"a": 2, "b": 2}}
    low, _ = _sim(residency, rate=2.0)
    high, _ = _sim(residency, rate=200.0)
    assert low["slo_attainment"] > high["slo_attainment"]
    assert low["p95_latency"] <= high["p95_latency"] + 1e-9


def test_smaller_submodels_carry_more_load():
    """Under overload, caching small submodels (lower precision, faster)
    serves more requests within SLO — the precision/latency trade the
    paper's QoE objective navigates."""
    big = {0: {"a": 2, "b": 2}, 1: {"a": 2, "b": 2}}
    small = {0: {"a": 0, "b": 0}, 1: {"a": 0, "b": 0}}
    m_big, n = _sim(big, rate=100.0)
    m_small, _ = _sim(small, rate=100.0)
    assert m_small["served"] > m_big["served"]
    assert m_small["slo_attainment"] > m_big["slo_attainment"]
    # per-served precision is lower for small submodels...
    per_big = m_big["avg_precision"] * n / m_big["served"]
    per_small = m_small["avg_precision"] * n / m_small["served"]
    assert per_small < per_big
    # ...but TOTAL delivered precision is higher — the paper's Sec. III
    # motivation, reproduced at the queueing level
    assert m_small["avg_precision"] > m_big["avg_precision"]


def test_uncached_model_dropped():
    residency = {0: {"a": 1}}
    sim = QueueSim(CFGS, residency, COMPUTE)
    reqs = [SimRequest(rid=0, model="b", tokens=16, arrival=0.0,
                       deadline=10.0)]
    m = sim.run(reqs)
    assert m["dropped"] == 1 and m["served"] == 0


def test_routing_prefers_precision_with_slack():
    residency = {0: {"a": 0}, 1: {"a": 2}}
    sim = QueueSim(CFGS, residency, COMPUTE)
    reqs = [SimRequest(rid=i, model="a", tokens=16, arrival=float(i),
                       deadline=float(i) + 5.0) for i in range(4)]
    m = sim.run(reqs)
    assert all(r.pod == 1 for r in sim.done)       # deep submodel wins


def test_deadline_miss_accounting():
    """With admit_late, requests that cannot make their deadline are
    served anyway and accounted as deadline misses; without it they are
    dropped — the miss count is identical either way."""
    residency = {0: {"a": 2}}
    svc = QueueSim(CFGS, residency, COMPUTE).service_time("a", 2, 64)
    # back-to-back arrivals with deadlines only one service time out:
    # request k queues behind k-1 others, so only the first can make it
    reqs = lambda: [SimRequest(rid=i, model="a", tokens=64, arrival=0.0,  # noqa: E731
                               deadline=1.5 * svc) for i in range(4)]
    drop = QueueSim(CFGS, residency, COMPUTE)
    m_drop = drop.run(reqs())
    late = QueueSim(CFGS, residency, COMPUTE, admit_late=True)
    m_late = late.run(reqs())
    assert m_drop["served"] == 1 and m_drop["dropped"] == 3
    assert m_late["served"] == 4 and m_late["dropped"] == 0
    assert sum(not r.met_slo for r in late.done) == 3
    assert m_drop["deadline_misses"] == m_late["deadline_misses"] == 3
    assert m_drop["slo_attainment"] == m_late["slo_attainment"] == 0.25


def test_pod_failure_mid_queue():
    """A pod failing at time t keeps its in-flight work but takes no new
    arrivals — later requests re-route to the surviving pod."""
    residency = {0: {"a": 2}, 1: {"a": 0}}
    sim = QueueSim(CFGS, residency, COMPUTE, fail_at={0: 1.0})
    reqs = [SimRequest(rid=i, model="a", tokens=16, arrival=0.5 * i,
                       deadline=0.5 * i + 5.0) for i in range(5)]
    sim.run(reqs)
    pods = {r.arrival: r.pod for r in sim.done}
    assert pods[0.0] == 0 and pods[0.5] == 0     # pre-failure: precision
    assert all(p == 1 for t, p in pods.items() if t >= 1.0)
    assert len(sim.done) == 5                     # nothing lost, re-routed


def test_empty_residency_drops_everything():
    sim = QueueSim(CFGS, {}, COMPUTE)
    m = sim.run([SimRequest(rid=0, model="a", tokens=16, arrival=0.0,
                            deadline=9.0)])
    assert m["served"] == 0 and m["dropped"] == 1
    assert m["slo_attainment"] == 0.0 and m["deadline_misses"] == 1
    # and an all-empty per-pod residency behaves identically
    sim2 = QueueSim(CFGS, {0: {}, 1: {}}, COMPUTE)
    m2 = sim2.run([SimRequest(rid=0, model="a", tokens=16, arrival=0.0,
                              deadline=9.0)])
    assert m2["served"] == 0 and m2["dropped"] == 1


def test_seed_determinism():
    residency = {0: {"a": 2, "b": 1}, 1: {"a": 1, "b": 2}}
    m1, n1 = _sim(residency, rate=40.0, seed=7)
    m2, n2 = _sim(residency, rate=40.0, seed=7)
    assert n1 == n2 and m1 == m2
    m3, n3 = _sim(residency, rate=40.0, seed=8)
    assert (n3, m3) != (n1, m1)                  # different draw


def test_transfer_time_matches_pod_cache_byte_math():
    """simulator.transfer_time (what ServingPlan availability times are
    built from, via the measured catalog) == the seconds PodCache
    actually takes for the same transition."""
    from repro.serving.loader import PodCache, WeightStore
    from repro.serving.simulator import transfer_time

    bw = 250e6
    store = WeightStore(CFGS, lazy=True)
    for frm, to in ((-1, 0), (-1, 2), (0, 2), (1, 2)):
        pod = PodCache(store, capacity_bytes=10**12, bandwidth_Bps=bw)
        if frm >= 0:
            pod.resident["a"] = frm              # no params needed: lazy
        ev = pod.request_load("a", to, now=0.0)
        want = transfer_time(CFGS["a"], frm, to, bw)
        assert abs(ev.seconds - want) < 1e-12
        assert ev.bytes == partition.delta_bytes(CFGS["a"], frm, to)
    # shrinks are instant on both sides
    assert transfer_time(CFGS["a"], 2, 1, bw) == 0.0
