"""Queueing simulator: SLO attainment vs load, caching quality effects."""

from repro import configs
from repro.serving.simulator import QueueSim, SimRequest, poisson_arrivals

from repro.models import partition

CFGS = {"a": configs.get_smoke("qwen1.5-0.5b"),
        "b": configs.get_smoke("stablelm-12b")}
# calibrate pod compute so one full-depth 64-token request takes ~50 ms
_c = partition.submodel_flops_per_token(CFGS["a"], CFGS["a"].n_exits - 1,
                                        ctx=64)
COMPUTE = 64 * _c / 0.05


def _sim(residency, rate, seed=0, duration=30.0):
    sim = QueueSim(CFGS, residency, COMPUTE, seed=seed)
    arr = poisson_arrivals(rate, duration, list(CFGS), [0.7, 0.3],
                           tokens=64, slo_s=2.0, seed=seed)
    return sim.run(arr), len(arr)


def test_slo_degrades_with_load():
    residency = {0: {"a": 2, "b": 2}, 1: {"a": 2, "b": 2}}
    low, _ = _sim(residency, rate=2.0)
    high, _ = _sim(residency, rate=200.0)
    assert low["slo_attainment"] > high["slo_attainment"]
    assert low["p95_latency"] <= high["p95_latency"] + 1e-9


def test_smaller_submodels_carry_more_load():
    """Under overload, caching small submodels (lower precision, faster)
    serves more requests within SLO — the precision/latency trade the
    paper's QoE objective navigates."""
    big = {0: {"a": 2, "b": 2}, 1: {"a": 2, "b": 2}}
    small = {0: {"a": 0, "b": 0}, 1: {"a": 0, "b": 0}}
    m_big, n = _sim(big, rate=100.0)
    m_small, _ = _sim(small, rate=100.0)
    assert m_small["served"] > m_big["served"]
    assert m_small["slo_attainment"] > m_big["slo_attainment"]
    # per-served precision is lower for small submodels...
    per_big = m_big["avg_precision"] * n / m_big["served"]
    per_small = m_small["avg_precision"] * n / m_small["served"]
    assert per_small < per_big
    # ...but TOTAL delivered precision is higher — the paper's Sec. III
    # motivation, reproduced at the queueing level
    assert m_small["avg_precision"] > m_big["avg_precision"]


def test_uncached_model_dropped():
    residency = {0: {"a": 1}}
    sim = QueueSim(CFGS, residency, COMPUTE)
    reqs = [SimRequest(rid=0, model="b", tokens=16, arrival=0.0,
                       deadline=10.0)]
    m = sim.run(reqs)
    assert m["dropped"] == 1 and m["served"] == 0


def test_routing_prefers_precision_with_slack():
    residency = {0: {"a": 0}, 1: {"a": 2}}
    sim = QueueSim(CFGS, residency, COMPUTE)
    reqs = [SimRequest(rid=i, model="a", tokens=16, arrival=float(i),
                       deadline=float(i) + 5.0) for i in range(4)]
    m = sim.run(reqs)
    assert all(r.pod == 1 for r in sim.done)       # deep submodel wins
