"""The Workload API: protocol/coercion basics, streaming families, the
chunked engine, the legacy shims, and the aggregation-exactness property
tests (per-(BS, model) counts are an exact representation of Eq. 40/45-49
demand — only the summation order can differ)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - single-example fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.online import OnlineConfig, OnlineSim, run_online
from repro.mec.scenario import MECConfig
from repro.traces import (AggregatedWorkload, DenseWorkload, PoissonWorkload,
                          Trace, TraceLogWorkload, as_workload,
                          available_workloads, check_trace, check_workload,
                          default_stream, default_workload, make_trace,
                          make_workload)
from repro.traces import engine as E

CFG = MECConfig(n_users=50)
OCFG = OnlineConfig(n_slots=12)


def stat_workload(cfg=CFG, n_slots=OCFG.n_slots, seed=0):
    return DenseWorkload(make_trace("stationary", cfg, n_slots, seed=seed),
                         cfg.n_bs, cfg.n_models)


# ---------------------------------------------------------------- protocol

def test_dense_workload_counts_match_trace():
    wl = stat_workload()
    counts = wl.counts()
    assert counts.shape == (OCFG.n_slots, CFG.n_bs, CFG.n_models)
    assert counts.dtype == np.float64
    # every masked request lands in exactly one (BS, model) cell
    assert counts.sum() == wl.trace.mask.sum() == wl.total()
    assert wl.exact and wl.n_users == CFG.n_users


def test_iter_chunks_covers_horizon_in_order():
    wl = stat_workload()
    spans, parts = [], []
    for t0, t1, c in wl.iter_chunks(5):
        spans.append((t0, t1))
        parts.append(c)
        assert c.shape == (t1 - t0, CFG.n_bs, CFG.n_models)
    assert spans == [(0, 5), (5, 10), (10, 12)]
    np.testing.assert_array_equal(np.concatenate(parts), wl.counts())


def test_as_workload_coercions():
    wl = stat_workload()
    assert as_workload(wl) is wl
    dense = as_workload(wl.trace, cfg=CFG)
    assert isinstance(dense, DenseWorkload)
    np.testing.assert_array_equal(dense.counts(), wl.counts())
    agg = as_workload(wl.counts())
    assert isinstance(agg, AggregatedWorkload) and agg.exact
    np.testing.assert_array_equal(agg.counts(), wl.counts())
    with pytest.raises(ValueError, match="n_bs"):
        as_workload(wl.trace)           # no aggregation shape
    with pytest.raises(TypeError, match="cannot interpret"):
        as_workload({"not": "a workload"})
    with pytest.raises(ValueError, match="count tensor"):
        AggregatedWorkload(np.zeros((3, 4)))


def test_registry_builds_all_families():
    names = available_workloads()
    assert {"stationary", "poisson_zipf", "request_log"} <= set(names)
    for name in names:
        if name == "request_log":
            continue                    # needs log arrays, tested below
        kw = {"users_per_slot": 500.0} if name == "poisson_zipf" else {}
        wl = make_workload(name, CFG, OCFG.n_slots, seed=1, **kw)
        check_workload(wl, CFG, OCFG)
        assert wl.counts().shape == (OCFG.n_slots, CFG.n_bs, CFG.n_models)
    with pytest.raises(KeyError, match="poisson_zipf"):
        make_workload("nope", CFG, OCFG.n_slots)


# ------------------------------------------------------ streaming families

def test_poisson_chunk_layout_invariance():
    wl = PoissonWorkload(10, CFG.n_bs, CFG.n_models, 1e5, seed=3,
                         chunk_slots=4)
    whole = wl.counts()
    assert whole.shape == (10, CFG.n_bs, CFG.n_models)
    for step in (1, 3, 7, 10):
        parts = [c for _, _, c in wl.iter_chunks(step)]
        np.testing.assert_array_equal(np.concatenate(parts), whole)
    # counter-based keying: same seed reproduces, other seeds differ
    np.testing.assert_array_equal(
        PoissonWorkload(10, CFG.n_bs, CFG.n_models, 1e5, seed=3).counts(),
        whole)
    assert not np.array_equal(
        PoissonWorkload(10, CFG.n_bs, CFG.n_models, 1e5, seed=4).counts(),
        whole)


def test_poisson_mean_tracks_popularity():
    wl = PoissonWorkload(400, 3, 4, 1e4, seed=0, zipf=0.8)
    got = wl.counts().mean(axis=0)
    lam = 1e4 / 3 * wl.pop
    np.testing.assert_allclose(got, lam, rtol=0.05)


def test_trace_log_matches_dense_aggregation():
    rng = np.random.default_rng(7)
    n_req = 500
    slot = rng.integers(0, OCFG.n_slots, n_req)
    home = rng.integers(0, CFG.n_bs, n_req)
    model = rng.integers(0, CFG.n_models, n_req)
    wl = TraceLogWorkload(slot, home, model, n_slots=OCFG.n_slots,
                          n_bs=CFG.n_bs, n_models=CFG.n_models)
    ref = np.zeros((OCFG.n_slots, CFG.n_bs, CFG.n_models))
    np.add.at(ref, (slot, home, model), 1.0)
    np.testing.assert_array_equal(wl.counts(), ref)
    # chunk slices agree with the whole-horizon tensor
    for t0, t1, c in wl.iter_chunks(5):
        np.testing.assert_array_equal(c, ref[t0:t1])
    assert wl.total() == n_req
    with pytest.raises(ValueError, match="model"):
        TraceLogWorkload(slot, home, model + CFG.n_models,
                         n_slots=OCFG.n_slots, n_bs=CFG.n_bs,
                         n_models=CFG.n_models)
    with pytest.raises(ValueError, match="one entry per request"):
        TraceLogWorkload(slot[:-1], home, model, n_slots=OCFG.n_slots,
                         n_bs=CFG.n_bs, n_models=CFG.n_models)


def test_make_workload_request_log_family():
    wl = make_workload("request_log", CFG, OCFG.n_slots,
                       slot=[0, 0, 3], home=[1, 2, 0], model=[0, 1, 2])
    check_workload(wl, CFG, OCFG)
    assert wl.total() == 3 and wl.family == "request_log"


# ------------------------------------------------- engine: chunks + unified

def test_chunked_scan_bit_identical_to_one_shot():
    wl = stat_workload()
    stream = default_stream(CFG, OCFG, 0)
    one = run_online(wl, "cocar-ol", cfg=CFG, ocfg=OCFG, engine="scan",
                     stream=stream)
    for chunk in (1, 5, 7):
        ch = run_online(wl, "cocar-ol", cfg=CFG, ocfg=OCFG, engine="scan",
                        stream=stream, chunk_slots=chunk)
        np.testing.assert_array_equal(one["slot_qoe"], ch["slot_qoe"])
        np.testing.assert_array_equal(one["final_state"].lvl,
                                      ch["final_state"].lvl)


def test_unified_engines_agree():
    wl = stat_workload()
    stream = default_stream(CFG, OCFG, 0)
    a = run_online(wl, "lfu", cfg=CFG, ocfg=OCFG, engine="numpy",
                   stream=stream)
    b = run_online(wl, "lfu", cfg=CFG, ocfg=OCFG, engine="scan",
                   stream=stream)
    assert a["workload"] == b["workload"] == wl.name
    np.testing.assert_allclose(a["slot_qoe"], b["slot_qoe"], rtol=1e-9)
    np.testing.assert_array_equal(np.asarray(a["final_state"].lvl),
                                  np.asarray(b["final_state"].lvl))
    with pytest.raises(ValueError, match="engine"):
        run_online(wl, "lfu", cfg=CFG, ocfg=OCFG, engine="pallas")
    with pytest.raises(TypeError, match="cfg"):
        run_online(wl, "lfu")


def test_new_api_emits_no_deprecation_warning(recwarn):
    run_online(stat_workload(), "lfu", cfg=CFG, ocfg=OCFG, engine="numpy")
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


# ------------------------------------------------- error-message contracts

def test_check_trace_error_names_workload_and_family():
    tr = make_trace("flash_crowd", CFG, 8, seed=0)
    bad = OnlineConfig(n_slots=9)
    with pytest.raises(ValueError) as exc:
        check_trace(tr, CFG, bad)
    msg = str(exc.value)
    assert "flash_crowd" in msg                       # name AND family
    assert "make_trace('flash_crowd', cfg, n_slots=9" in msg
    assert "repro.traces.available()" in msg


def test_check_workload_error_names_family_and_registry():
    wl = PoissonWorkload(8, CFG.n_bs, CFG.n_models, 100.0, name="mega")
    with pytest.raises(ValueError) as exc:
        check_workload(wl, CFG, OCFG)
    msg = str(exc.value)
    assert "'mega'" in msg and "'poisson_zipf'" in msg
    assert f"make_workload('poisson_zipf', cfg, n_slots={OCFG.n_slots}" in msg
    assert "available_workloads" in msg
    wrong_shape = AggregatedWorkload(
        np.zeros((OCFG.n_slots, CFG.n_bs + 1, CFG.n_models)))
    with pytest.raises(ValueError, match="n_bs"):
        check_workload(wrong_shape, CFG, OCFG)


# ------------------------------------------------------ property: exactness

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), t=st.integers(0, OCFG.n_slots - 1),
       family=st.sampled_from(["stationary", "flash_crowd", "mobility"]))
def test_aggregation_qoe_exactness(seed, t, family):
    """Counts-driven routing (Eq. 41 over aggregated demand) equals the
    per-user sum: same QoE within float summation-order drift, hits
    exactly (they are integer counts)."""
    trace = make_trace(family, CFG, OCFG.n_slots, seed=seed)
    sim = OnlineSim(CFG, OCFG, trace=trace)
    m_u, home = sim.draw_slot_requests(t)
    q_user, hits_user = sim.route(m_u, home)
    q_cnt, hits_cnt = sim.route_counts(sim.workload.counts()[t])
    assert hits_cnt == hits_user
    np.testing.assert_allclose(q_cnt, q_user, rtol=1e-9, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), perm_seed=st.integers(0, 10))
def test_user_permutation_invariance(seed, perm_seed):
    """Relabeling users changes nothing downstream: the aggregated count
    tensor is bit-identical, so every engine result is too."""
    trace = make_trace("stationary", CFG, OCFG.n_slots, seed=seed)
    perm = np.random.default_rng(perm_seed).permutation(CFG.n_users)
    permuted = Trace(name=trace.name, model=trace.model[:, perm],
                     home=trace.home[:, perm], mask=trace.mask[:, perm],
                     meta=dict(trace.meta))
    a = DenseWorkload(trace, CFG.n_bs, CFG.n_models)
    b = DenseWorkload(permuted, CFG.n_bs, CFG.n_models)
    np.testing.assert_array_equal(a.counts(), b.counts())
    stream = default_stream(CFG, OCFG, 0)
    ra = run_online(a, "cocar-ol", cfg=CFG, ocfg=OCFG, engine="scan",
                    stream=stream)
    rb = run_online(b, "cocar-ol", cfg=CFG, ocfg=OCFG, engine="scan",
                    stream=stream)
    np.testing.assert_array_equal(ra["slot_qoe"], rb["slot_qoe"])
    np.testing.assert_array_equal(ra["final_state"].lvl,
                                  rb["final_state"].lvl)
