"""Serving runtime (loader, engine, failures, stragglers) and training
substrate (checkpoint atomicity, preemption resume, learning)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.serving import EdgeCluster, PodCache, Request, WeightStore


def make_cluster(cap=10_000_000, n_pods=2):
    cfgs = {"qwen-s": configs.get_smoke("qwen1.5-0.5b"),
            "mix-s": configs.get_smoke("mixtral-8x7b")}
    store = WeightStore(cfgs, seed=0)
    return EdgeCluster(store, n_pods=n_pods, capacity_bytes=cap,
                       bandwidth_Bps=1e9)


def test_delta_load_and_serve():
    cl = make_cluster()
    cl.apply_caching({0: {"qwen-s": 0}})
    cl.tick(1.0)
    assert cl.pods[0].cache.resident["qwen-s"] == 0
    ev = cl.pods[0].cache.request_load("qwen-s", 2, cl.now)
    assert ev.bytes > 0
    from repro.models import partition
    cfg = cl.store.cfgs["qwen-s"]
    assert ev.bytes == partition.delta_bytes(cfg, 0, 2)
    cl.tick(ev.seconds + 0.01)
    assert cl.pods[0].cache.resident["qwen-s"] == 2
    r = Request(rid=0, model="qwen-s", tokens=[1, 2], max_new=3, home=0,
                deadline=cl.now + 100)
    assert cl.submit([r]) == 1
    assert len(r.output) == 3 and r.precision > 0.9


def test_capacity_enforced():
    cfgs = {"qwen-s": configs.get_smoke("qwen1.5-0.5b")}
    store = WeightStore(cfgs)
    from repro.models import partition
    full = partition.submodel_bytes(cfgs["qwen-s"], 2)
    cache = PodCache(store, capacity_bytes=full - 1, bandwidth_Bps=1e9)
    with pytest.raises(MemoryError):
        cache.request_load("qwen-s", 2, 0.0)
    cache.request_load("qwen-s", 0, 0.0)        # smaller submodel fits
    cache.tick(1e9)
    assert cache.resident["qwen-s"] == 0


def test_failure_reroute():
    cl = make_cluster()
    cl.apply_caching({0: {"qwen-s": 2}, 1: {"qwen-s": 1}})
    cl.tick(1.0)
    cl.fail_pod(0)
    r = Request(rid=1, model="qwen-s", tokens=[3], max_new=2, home=0,
                deadline=cl.now + 100)
    cl.submit([r])
    assert r.served_by == 1                      # re-routed to survivor


def test_straggler_mitigation():
    cl = make_cluster()
    cl.apply_caching({0: {"qwen-s": 2}, 1: {"qwen-s": 0}})
    cl.tick(1.0)
    cl.pods[0].busy_until = cl.now + 1e6         # pod 0 is a straggler
    r = Request(rid=2, model="qwen-s", tokens=[3], max_new=2, home=0,
                deadline=cl.now + 10)
    cl.submit([r])
    assert r.served_by == 1                      # lower precision, on time
    assert r.precision < cl.precision_of("qwen-s", 2)


def test_no_pod_available_goes_cloud():
    cl = make_cluster()
    r = Request(rid=3, model="qwen-s", tokens=[1], max_new=1, home=0,
                deadline=cl.now + 10)
    cl.submit([r])
    assert r.missed and not r.done


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def _train_cfg():
    from repro.training.data import char_vocab
    _, V = char_vocab()
    return configs.get_smoke("qwen1.5-0.5b").replace(
        vocab_size=max(V, 64), n_layers=2, d_model=96, d_ff=192)


def test_loss_decreases_and_deep_exit_wins():
    from repro.training.data import char_stream
    from repro.training.loop import TrainConfig, train
    cfg = _train_cfg()
    tc = TrainConfig(steps=120, batch=8, seq=64, log_every=20)
    _, hist = train(cfg, tc, char_stream(8, 64, 200), log_fn=lambda *_: None)
    first, last = hist[0], hist[-1]
    assert last["loss"] < first["loss"] * 0.8
    # the deeper exit must end at lower CE — the paper's precision ladder
    assert last["ce_per_exit"][-1] < last["ce_per_exit"][0]


def test_checkpoint_roundtrip_and_preemption():
    from repro.training import checkpoint as CKPT
    from repro.training.data import char_stream
    from repro.training.loop import TrainConfig, train
    cfg = _train_cfg()
    with tempfile.TemporaryDirectory() as ck:
        tc = TrainConfig(steps=40, batch=4, seq=32, ckpt_dir=ck,
                         ckpt_every=10, log_every=40, preempt_at=35)
        with pytest.raises(RuntimeError, match="preemption"):
            train(cfg, tc, char_stream(4, 32, 80), log_fn=lambda *_: None)
        assert CKPT.latest_step(ck) == 30
        tc2 = TrainConfig(steps=40, batch=4, seq=32, ckpt_dir=ck,
                          ckpt_every=10, log_every=40)
        state, hist = train(cfg, tc2, char_stream(4, 32, 80),
                            log_fn=lambda *_: None)
        assert int(state["opt"]["step"]) == 40
        # restore equality
        restored, step = CKPT.restore(ck, state)
        assert step == 40
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_onto_mesh(tmp_path):
    """Checkpoint saved unsharded restores onto a (new) mesh with the
    production sharding rules — the elastic-scaling path."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.distribution import sharding as shd
    from repro.models import model as M
    from repro.training import checkpoint as CKPT
    cfg = configs.get_smoke("chatglm3-6b")
    params = M.init(cfg, jax.random.key(0))
    CKPT.save(tmp_path, params, 5)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    spec = shd.param_specs(cfg, mesh, params, mode="serve")
    shardings = shd.named(mesh, spec)
    restored, step = CKPT.restore(tmp_path, params, shardings=shardings)
    assert step == 5
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_partial(tmp_path):
    """A crash mid-save must never corrupt the published checkpoints."""
    from repro.training import checkpoint as CKPT
    state = {"w": jnp.ones((4, 4)), "step": jnp.int32(7)}
    CKPT.save(tmp_path, state, 10)
    # simulate garbage from a crashed save
    bad = tmp_path / ".tmp_crashed"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"junk")
    assert CKPT.latest_step(tmp_path) == 10
    restored, step = CKPT.restore(tmp_path, state)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones((4, 4)))
