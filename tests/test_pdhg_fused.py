"""The fused mixed-precision PDHG backend vs the reference kernel: the
bit-exact conformance contract.

Three layers, all riding on tests/harness.py:

  * kernel layer — the Pallas engine (interpret mode on CPU) against its
    lax.scan realization: same step math, state agreement to ≤1e-12
    (FMA-contraction noise only), and the pure-f64 fused path within
    op-reordering distance of ``LP._pdhg_kernel``;
  * pipeline layer — ``lp_backend="pallas"`` through the offline and
    policy grids and the sharded executor makes *bit-identical*
    decisions (cache/routing arrays, winning trials) to
    ``lp_backend="reference"``;
  * certificate layer — the rounding-margin certificate: the fused
    fractional gap stays orders of magnitude below every uniform's
    distance to its rounding threshold, so decision identity is implied,
    not coincidental.

Plus the hypothesis property tests (padding inertness of the fused
kernel, uniform-consumption locality of Alg. 1 rounding) backing the
executor's slice-per-bucket RNG scheme.
"""
import os

import harness
import numpy as np
import pytest
from harness import assert_same_offline, decision_margin, make_instance

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                # bare local runs only
    from _hypothesis_fallback import given, settings, st

from repro.core import cocar as CC
from repro.core import lp as LP
from repro.core.rounding import draw_rounding_uniforms, round_from_uniforms
from repro.kernels import pdhg_fused as PF
from repro.mec.scenario import stack_instances
from repro.scale import GridSpec, run_grid

HETERO = [(0, 40, 3), (1, 50, 4), (2, 35, 3)]
ITERS, S, BO = 300, 2, 3


def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


def _data(inst):
    return LP.pdhg_data(inst)


# ---------------------------------------------------------------------------
# kernel layer
# ---------------------------------------------------------------------------

def test_fused_f64_matches_reference_closely():
    """With polish == iters the fused path is the reference algorithm
    with reordered ops — pure f64, gap at accumulated-roundoff scale."""
    with _x64():
        inst = make_instance(seed=2, n_users=60)
        data = _data(inst)
        x_r, A_r = LP._pdhg_kernel(data, 400)
        x_f, A_f = PF.pdhg_fused(data, 400, polish=400, engine="scan")
        assert float(np.abs(np.asarray(x_f) - np.asarray(x_r)).max()) < 1e-10
        assert float(np.abs(np.asarray(A_f) - np.asarray(A_r)).max()) < 1e-10


def test_mixed_precision_gap_small_and_finite():
    with _x64():
        inst = make_instance(seed=3, n_users=60)
        gap = PF.fused_vs_reference_gap(_data(inst), 600)
    assert 0.0 <= gap < 1e-3


@pytest.mark.slow_compile
def test_pallas_interpret_matches_scan_engine():
    """The conformance gate for the kernel itself: both engines execute
    the identical fused step.  XLA contracts mul+add chains into FMAs
    differently for the scan body (compiled standalone) and the unrolled
    kernel block, so the f32 sweep carries f32-ulp noise (~1e-7) between
    engines and the pure-f64 path ≤1e-12 — and shared uniforms round
    both to identical decisions, which is the contract that matters."""
    with _x64():
        inst = make_instance(seed=4, n_users=30)
        data = _data(inst)
        # pure f64: only f64 FMA noise between engines
        x_s64, A_s64 = PF.pdhg_fused(data, 40, polish=40, engine="scan")
        x_p64, A_p64 = PF.pdhg_fused(data, 40, polish=40, engine="pallas")
        assert float(np.abs(np.asarray(x_p64)
                            - np.asarray(x_s64)).max()) < 1e-12
        assert float(np.abs(np.asarray(A_p64)
                            - np.asarray(A_s64)).max()) < 1e-12
        # mixed precision: f32-sweep FMA noise, still decision-inert
        x_s, A_s = PF.pdhg_fused(data, 80, polish=16, engine="scan")
        x_p, A_p = PF.pdhg_fused(data, 80, polish=16, engine="pallas")
        assert float(np.abs(np.asarray(x_p) - np.asarray(x_s)).max()) < 2e-5
        assert float(np.abs(np.asarray(A_p) - np.asarray(A_s)).max()) < 2e-5
        u_cat, u_phi = draw_rounding_uniforms(11, 4, inst.N, inst.M,
                                              inst.U, inst.H)
        oh = inst.onehot_mu()
        xs, As = round_from_uniforms(np.asarray(x_s), np.asarray(A_s),
                                     oh, u_cat, u_phi)
        xp, Ap = round_from_uniforms(np.asarray(x_p), np.asarray(A_p),
                                     oh, u_cat, u_phi)
        harness.assert_decisions_identical(xs, As, xp, Ap,
                                           msg="(pallas vs scan)")


@pytest.mark.slow_compile
def test_pallas_block_remainder_and_short_runs():
    """Iteration counts that don't divide the block, and runs shorter
    than one block, must execute exactly ``iters`` steps."""
    with _x64():
        inst = make_instance(seed=5, n_users=20)
        data = _data(inst)
        for iters, polish, block in ((37, 5, 8), (6, 2, 8), (16, 16, 4)):
            # tolerance: f64-only runs see f64 FMA noise; any f32 sweep
            # raises the engine-vs-engine floor to f32-ulp scale
            tol = 1e-12 if polish >= iters else 2e-5
            x_s, A_s = PF.pdhg_fused(data, iters, polish=polish,
                                     engine="scan")
            x_p, A_p = PF.pdhg_fused(data, iters, polish=polish,
                                     engine="pallas", block=block)
            assert float(np.abs(np.asarray(x_p) - np.asarray(x_s)).max()) \
                < tol, (iters, polish, block)
            assert float(np.abs(np.asarray(A_p) - np.asarray(A_s)).max()) \
                < tol, (iters, polish, block)


def test_solve_lp_pdhg_backend_api():
    inst = make_instance(seed=6, n_users=30)
    res = LP.solve_lp_pdhg(inst, iters=ITERS, backend="pallas")
    assert res.primal_res < 0.05
    assert res.obj > 0
    with pytest.raises(ValueError, match="unknown LP backend"):
        LP._lp_solve_kernel(_data(inst), 10, backend="nope")
    with pytest.raises(ValueError, match="unknown engine"):
        PF.pdhg_fused(_data(inst), 10, engine="mosaic")


# ---------------------------------------------------------------------------
# pipeline layer: decision identity end to end
# ---------------------------------------------------------------------------

def test_offline_grid_decisions_identical_across_backends():
    """cocar_grid(lp_backend="pallas") == cocar_grid(lp_backend=
    "reference"): bit-identical cache/routing decisions and winning
    trials on a heterogeneous padded grid."""
    insts = harness.hetero_insts(HETERO)
    ref = CC.cocar_grid(insts, seed=0, pdhg_iters=ITERS, best_of=BO,
                        n_seeds=S)
    pal = CC.cocar_grid(insts, seed=0, pdhg_iters=ITERS, best_of=BO,
                        n_seeds=S, lp_backend="pallas")
    assert_same_offline(ref, pal)
    for per_r, per_p in zip(ref, pal):
        for (_, _, ir), (_, _, ip) in zip(per_r, per_p):
            np.testing.assert_array_equal(ir["trial_objs"], ip["trial_objs"])
            harness.assert_obj_close(ir["obj"], ip["obj"])


def test_sharded_executor_fused_matches_vmap():
    """The fused backend through shard_map + bucketed batching stays
    decision-identical to its single-device dispatch."""
    insts = harness.hetero_insts(HETERO)
    kw = dict(kind="offline", insts=insts, seed=0, n_seeds=S, best_of=BO,
              pdhg_iters=ITERS, lp_backend="pallas")
    ref = run_grid(GridSpec(**kw, backend="vmap", max_buckets=1))
    out = run_grid(GridSpec(**kw, backend="sharded", devices=1,
                            max_buckets=2, chunk_size=2))
    assert_same_offline(ref.results, out.results)


def test_policy_grid_decisions_identical_across_backends():
    """All five policies (CoCaR + SPR³ both re-solve the LP) keep
    bit-identical decisions under the fused backend."""
    insts = harness.hetero_insts(HETERO[:2])
    stacked = stack_instances(insts)
    uniforms = CC.policy_uniforms(stacked, 3, S, BO)
    gat = CC.gat_grid_policies(stacked, 0, episodes=4)
    ref = CC.policy_grid_device(stacked, pdhg_iters=ITERS, best_of=BO,
                                n_seeds=S, uniforms=uniforms, gat=gat)
    pal = CC.policy_grid_device(stacked, pdhg_iters=ITERS, best_of=BO,
                                n_seeds=S, uniforms=uniforms, gat=gat,
                                lp_backend="pallas")
    for p in CC.OFFLINE_POLICIES:
        for i, inst in enumerate(insts):
            harness.assert_decisions_identical(
                ref[p]["x"][i, :, :inst.N], ref[p]["A"][i, :, :inst.N,
                                                        :inst.U],
                pal[p]["x"][i, :, :inst.N], pal[p]["A"][i, :, :inst.N,
                                                        :inst.U],
                msg=f"({p}[{i}])")
            for k in ref[p]["metrics"]:
                np.testing.assert_allclose(ref[p]["metrics"][k][i],
                                           pal[p]["metrics"][k][i],
                                           atol=1e-9, err_msg=f"{p}.{k}")


# ---------------------------------------------------------------------------
# certificate layer
# ---------------------------------------------------------------------------

def test_rounding_margin_certifies_decision_identity():
    """The fused fractional gap must sit far below every uniform's
    distance to its rounding threshold — decisions then *cannot* differ,
    rather than merely not differing on this draw."""
    insts, stacked = harness.padded_stack(HETERO)
    u_cat, u_phi = CC.offline_uniforms(stacked, 7, S, BO)
    ref = CC.offline_pipeline_device(stacked, u_cat, u_phi,
                                     pdhg_iters=ITERS, n_seeds=S)
    pal = CC.offline_pipeline_device(stacked, u_cat, u_phi,
                                     pdhg_iters=ITERS, n_seeds=S,
                                     lp_backend="pallas")
    for i, inst in enumerate(insts):
        N, U = inst.N, inst.U
        gap = max(
            float(np.abs(ref["x_frac"][i, :N] - pal["x_frac"][i, :N]).max()),
            float(np.abs(ref["A_frac"][i, :N, :U]
                         - pal["A_frac"][i, :N, :U]).max()))
        m = decision_margin(ref["x_frac"][i, :N], ref["A_frac"][i, :N, :U],
                            insts[i].onehot_mu(), u_cat[i, :, :N],
                            u_phi[i, :, :N, :U])
        assert m["min"] > 0
        assert gap < m["min"] / 10.0, (i, gap, m)
        # the sharper per-comparison certificate (what bench_lp gates at
        # scale, where the global min-margin collapses) must also certify
        cert = harness.threshold_shift_certificate(
            ref["x_frac"][i, :N], ref["A_frac"][i, :N, :U],
            pal["x_frac"][i, :N], pal["A_frac"][i, :N, :U],
            insts[i].onehot_mu(), u_cat[i, :, :N], u_phi[i, :, :N, :U])
        assert cert["certified"], (i, cert)
        assert cert["headroom"] > 10.0, (i, cert)


# ---------------------------------------------------------------------------
# property tests (hypothesis; single-example fallback on bare machines)
# ---------------------------------------------------------------------------

def test_hypothesis_is_installed_on_ci():
    """The fallback shim is for bare local machines ONLY: on CI the real
    hypothesis must be importable (requirements.txt pins it)."""
    if os.environ.get("CI"):
        import hypothesis  # noqa: F401


@settings(max_examples=8, deadline=None)
@given(n_users=st.integers(8, 20), n_bs=st.integers(2, 4),
       pad_bs=st.integers(1, 3), pad_users=st.integers(1, 8),
       seed=st.integers(0, 3))
def test_fused_padding_is_exactly_inert(n_users, n_bs, pad_bs, pad_users,
                                        seed):
    """Padded base-station rows AND padded user columns of the fused A
    stay exactly 0.0 through both precision phases (the zero step sizes
    folded into tau_A), and the primal stays finite in [0, 1]."""
    with _x64():
        inst = make_instance(seed=seed, n_users=n_users, n_bs=n_bs)
        stacked = stack_instances([inst], pad_to=(n_bs + pad_bs,
                                                  n_users + pad_users))
        data = type(stacked.data)(*(v[0] for v in stacked.data))
        x, A = PF.pdhg_fused(data, 48, polish=8, engine="scan")
        x, A = np.asarray(x), np.asarray(A)
    assert (A[inst.N:] == 0.0).all()
    assert (A[:, inst.U:] == 0.0).all()
    assert np.isfinite(x).all() and (x >= 0).all() and (x <= 1).all()
    assert np.isfinite(A).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 4), m=st.integers(2, 4), u=st.integers(3, 8),
       h=st.integers(1, 3), t=st.integers(2, 4), row=st.integers(0, 3),
       trial=st.integers(0, 3), seed=st.integers(0, 100))
def test_rounding_uniform_consumption_is_local(n, m, u, h, t, row, trial,
                                               seed):
    """Alg. 1 rounding consumes uniforms positionally: perturbing the
    uniforms of one trial / one BS row changes no other trial's or row's
    decisions.  This locality is what lets the scale executor draw
    uniforms once at the global max shape and slice them per bucket."""
    row, trial = row % n, trial % t
    rng = np.random.default_rng(seed)
    x_frac = rng.random((n, m, h + 1))
    A_frac = rng.random((n, u, h))
    m_u = rng.integers(0, m, size=u)
    onehot = np.zeros((u, m))
    onehot[np.arange(u), m_u] = 1.0
    u_cat = rng.random((t, n, m))
    u_phi = rng.random((t, n, u, h))
    x0, A0 = round_from_uniforms(x_frac, A_frac, onehot, u_cat, u_phi)

    # perturb every uniform of one trial: other trials bit-unchanged
    u_cat2, u_phi2 = u_cat.copy(), u_phi.copy()
    u_cat2[trial] = rng.random((n, m))
    u_phi2[trial] = rng.random((n, u, h))
    x1, A1 = round_from_uniforms(x_frac, A_frac, onehot, u_cat2, u_phi2)
    others = [tt for tt in range(t) if tt != trial]
    harness.assert_decisions_identical(x0[others], A0[others],
                                       x1[others], A1[others],
                                       msg="(trial locality)")

    # perturb one BS row's uniforms: other rows bit-unchanged
    u_cat3, u_phi3 = u_cat.copy(), u_phi.copy()
    u_cat3[:, row] = rng.random((t, m))
    u_phi3[:, row] = rng.random((t, u, h))
    x2, A2 = round_from_uniforms(x_frac, A_frac, onehot, u_cat3, u_phi3)
    keep = [nn for nn in range(n) if nn != row]
    harness.assert_decisions_identical(x0[:, keep], A0[:, keep],
                                       x2[:, keep], A2[:, keep],
                                       msg="(row locality)")
