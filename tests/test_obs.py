"""Observability subsystem (``repro.obs``) contract tests.

Three layers, mirroring the subsystem:

  * host-side tracing/manifests — pure-python span nesting, exports,
    retrace accounting, and run provenance (no jax required);
  * jit-safe diagnostics taps — the solver/engine curves must be
    DECISION-INERT: bit-identical x/A/QoE with the tap on or off, on the
    reference kernel, the fused kernel's scan engine, the offline and
    policy device grids, the online scan, and the sharded executor;
  * regression invariants — repeat sweeps retrace nothing
    (compile-cache deltas stay zero), ``solve_lp_pdhg`` carries an
    honest ``converged`` flag, and ``scripts/report.py`` renders the
    artifacts and gates on convergence.
"""
import importlib.util
import json
import pathlib
import sys

import numpy as np
import pytest
from harness import assert_same_offline, make_instance

from repro.obs import (Tracer, config_hash, lp_diag_summary,
                       register_jit, retrace_snapshot, run_manifest,
                       total_retraces_since, write_manifest)

ITERS = 150          # truncated solver budget: cheap, deterministic


# ---------------------------------------------------------------------------
# tracing (pure host)
# ---------------------------------------------------------------------------

def test_span_nesting_and_summary():
    tr = Tracer()
    with tr.span("outer", kind="test"):
        with tr.span("inner") as sp:
            assert sp.depth == 1
    spans = tr.spans
    assert [s.name for s in spans] == ["outer", "inner"]
    assert spans[0].depth == 0 and spans[0].parent == -1
    assert spans[1].parent == 0
    assert spans[0].seconds >= spans[1].seconds >= 0.0
    assert spans[0].attrs == {"kind": "test"}
    summ = tr.summary(top=1)
    assert summ["by_name"]["outer"]["count"] == 1
    assert len(summ["slowest"]) == 1


def test_span_exports(tmp_path):
    tr = Tracer()
    with tr.span("a", n=1):
        with tr.span("b"):
            pass
    jl = tr.export_jsonl(tmp_path / "t.trace.jsonl")
    rows = [json.loads(line) for line in
            pathlib.Path(jl).read_text().splitlines()]
    assert [r["name"] for r in rows] == ["a", "b"]
    assert rows[0]["attrs"] == {"n": 1}
    ch = json.loads(pathlib.Path(
        tr.export_chrome(tmp_path / "t.trace.chrome.json")).read_text())
    ev = ch["traceEvents"]
    assert len(ev) == 2 and all(e["ph"] == "X" for e in ev)
    assert ev[0]["ts"] == 0.0 and ev[1]["ts"] >= 0.0
    assert ev[1]["tid"] == 1                     # depth encodes nesting


def test_retrace_accounting_via_registry():
    class FakeJit:
        def __init__(self):
            self.n = 0

        def _cache_size(self):
            return self.n

    fn = FakeJit()
    register_jit("test:fake", fn)
    tr = Tracer()
    snap = retrace_snapshot()
    with tr.span("warm") as sp:
        fn.n += 2                                # "compiled twice"
    assert sp.retraces == 2
    assert total_retraces_since(snap) == 2
    with tr.span("hot") as sp:
        pass                                     # no new executables
    assert sp.retraces == 0


# ---------------------------------------------------------------------------
# manifests
# ---------------------------------------------------------------------------

def test_run_manifest_fields():
    m = run_manifest(config={"a": 1}, seeds={"seed": 0})
    assert m["schema"] == "repro.obs.manifest/v1"
    assert m["git"] is None or "sha" in m["git"]
    assert m["python"].count(".") >= 1          # "3.10.x" version string
    assert m["seeds"] == {"seed": 0}
    assert m["config_hash"] == config_hash({"a": 1})
    # jax block is honest about whether jax was imported
    assert m["jax"]["imported"] in (True, False)


def test_config_hash_stable_under_key_order():
    assert config_hash({"a": 1, "b": 2}) == config_hash({"b": 2, "a": 1})
    assert config_hash({"a": 1}) != config_hash({"a": 2})


def test_write_manifest_sibling(tmp_path):
    res = tmp_path / "grid.json"
    res.write_text("[]")
    p = write_manifest(res, config={"k": 1})
    assert pathlib.Path(p).name == "grid.manifest.json"
    m = json.loads(pathlib.Path(p).read_text())
    assert m["config"] == {"k": 1}


# ---------------------------------------------------------------------------
# solver diagnostics: convergence flag + decision inertness
# ---------------------------------------------------------------------------

def test_solve_lp_pdhg_converged_flag():
    from repro.core import lp as LP

    inst = make_instance(n_users=24)
    full = LP.solve_lp_pdhg(inst, iters=4000)
    assert full.converged and full.tol == LP.PDHG_TOL
    short = LP.solve_lp_pdhg(inst, iters=20, check_every=10)
    assert not short.converged
    assert short.primal_res > short.tol


def test_reference_diag_inert_and_curves():
    from repro.core import lp as LP

    inst = make_instance(n_users=24)
    off = LP.solve_lp_pdhg(inst, iters=ITERS, check_every=40)
    on = LP.solve_lp_pdhg(inst, iters=ITERS, check_every=40,
                          diagnostics=True)
    np.testing.assert_array_equal(off.x, on.x)
    np.testing.assert_array_equal(off.A, on.A)
    d = on.diag
    # ITERS=150, stride 40 -> samples at 40, 80, 120 and the final 150
    assert list(d["iters"]) == [40, 80, 120, 150]
    assert d["primal_res"].shape == d["dual_res"].shape == d["obj"].shape
    summ = lp_diag_summary(d)
    assert summ["final_residual"] == pytest.approx(float(
        d["primal_res"][-1]))
    assert summ["n_samples"] == 4


def test_fused_scan_diag_inert():
    import jax
    import jax.numpy as jnp

    from repro.core import lp as LP
    from repro.kernels.pdhg_fused import pdhg_fused

    inst = make_instance(n_users=24)
    data = jax.tree_util.tree_map(jnp.asarray, LP.pdhg_data(inst))
    x0, A0 = pdhg_fused(data, ITERS, engine="scan")
    out = pdhg_fused(data, ITERS, engine="scan", diagnostics=True,
                     diag_stride=40)
    np.testing.assert_array_equal(np.asarray(x0), np.asarray(out[0]))
    np.testing.assert_array_equal(np.asarray(A0), np.asarray(out[1]))
    d = out[2]
    assert float(d["polish_delta"]) >= 0.0
    assert d["primal_res"].shape == d["iters"].shape


def test_offline_grid_diag_inert():
    from repro.core.cocar import cocar_grid

    insts = [make_instance(seed=s, n_users=20) for s in (0, 1)]
    kw = dict(seed=0, pdhg_iters=ITERS, best_of=2, n_seeds=2)
    off = cocar_grid(insts, backend="device", **kw)
    on = cocar_grid(insts, backend="device", diagnostics=True, **kw)
    assert_same_offline(off, on)
    summ = on[0][0][2]["lp_diag"]["summary"]
    assert {"final_residual", "converged", "iters_to_tol"} <= set(summ)


def test_sharded_grid_diag_inert():
    from repro.core.cocar import cocar_grid

    insts = [make_instance(seed=s, n_users=20) for s in (0, 1, 2)]
    kw = dict(seed=0, pdhg_iters=ITERS, best_of=2, n_seeds=1)
    off = cocar_grid(insts, backend="device", **kw)
    on = cocar_grid(insts, backend="sharded", diagnostics=True, **kw)
    assert_same_offline(off, on)
    assert "lp_diag" in on[0][0][2]


def test_policy_grid_diag_inert():
    from repro.scale import GridSpec, run_grid

    insts = [make_instance(seed=s, n_users=20) for s in (0, 1)]
    kw = dict(kind="policy", insts=insts, seed=0, n_seeds=1, best_of=2,
              pdhg_iters=ITERS, episodes=4, backend="vmap")
    off = run_grid(GridSpec(**kw))
    on = run_grid(GridSpec(**kw, diagnostics=True))
    for p in off.results:
        assert_same_offline(off.results[p], on.results[p])
    diags = on.stats["lp_diag"]
    assert len(diags) == len(insts)
    assert all("final_residual" in d for d in diags)
    assert "lp_diag" not in off.stats


def test_online_scan_diag_inert():
    from repro.core.online import OnlineConfig, run_online
    from repro.mec.scenario import MECConfig
    from repro.traces.registry import default_workload

    cfg = MECConfig(n_bs=3, n_users=30, n_models=4, seed=0)
    ocfg = OnlineConfig(n_slots=12, rounds=2)
    wl = default_workload(cfg, ocfg)
    off = run_online(wl, "cocar-ol", cfg=cfg, ocfg=ocfg, engine="scan")
    on = run_online(wl, "cocar-ol", cfg=cfg, ocfg=ocfg, engine="scan",
                    diagnostics=True)
    np.testing.assert_array_equal(off["slot_qoe"], on["slot_qoe"])
    np.testing.assert_array_equal(off["final_state"].lvl,
                                  on["final_state"].lvl)
    d = on["diagnostics"]
    assert set(d) == {"hit_rate", "dl_in_flight", "evictions", "cache_mb"}
    assert all(v.shape == (12,) for v in d.values())
    assert np.all((d["hit_rate"] >= 0.0) & (d["hit_rate"] <= 1.0))
    assert "diagnostics" not in off


def test_online_grid_sharded_diag_inert():
    from repro.core.online import OnlineConfig
    from repro.mec.scenario import MECConfig
    from repro.traces.engine import run_online_grid
    from repro.traces.registry import make_trace

    cfg = MECConfig(n_bs=3, n_users=30, n_models=4, seed=0)
    jobs = [dict(cfg=cfg, algo=a, trace=make_trace("stationary", cfg, 10,
                                                   seed=0))
            for a in ("cocar-ol", "lfu")]
    ocfg = OnlineConfig(n_slots=10, rounds=2)
    off = run_online_grid(jobs, ocfg, backend="vmap")
    on = run_online_grid(jobs, ocfg, backend="sharded", diagnostics=True)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a["slot_qoe"], b["slot_qoe"])
        assert b["diagnostics"]["hit_rate"].shape == (10,)


# ---------------------------------------------------------------------------
# retrace regression: repeat dispatches must not recompile
# ---------------------------------------------------------------------------

def test_repeat_sweep_zero_retraces():
    from repro.core.cocar import cocar_grid

    insts = [make_instance(seed=s, n_users=20) for s in (0, 1)]
    kw = dict(seed=0, pdhg_iters=ITERS, best_of=2, n_seeds=1,
              backend="device", diagnostics=True)
    cocar_grid(insts, **kw)                      # warm every cache
    snap = retrace_snapshot()
    again = cocar_grid(insts, **kw)
    assert total_retraces_since(snap) == 0, (
        "repeat sweep recompiled a registered jit entry point")
    assert len(again) == 2


def test_executor_stats_spans():
    from repro.scale import GridSpec, run_grid

    insts = [make_instance(seed=s, n_users=20) for s in (0, 1)]
    res = run_grid(GridSpec(kind="offline", insts=insts, seed=0,
                            n_seeds=1, best_of=2, pdhg_iters=ITERS,
                            backend="vmap"))
    assert res.stats["seconds"] > 0.0
    assert res.stats["retraces"] >= 0
    assert res.stats["chunks"] >= 1


# ---------------------------------------------------------------------------
# report.py rendering + convergence gate
# ---------------------------------------------------------------------------

def _report_mod():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "scripts" / "report.py")
    spec = importlib.util.spec_from_file_location("obs_report", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["obs_report"] = mod
    spec.loader.exec_module(mod)
    return mod


def _fake_artifacts(root, converged=True):
    rows = [{"zipf": 0.4, "lp_obj": 17.0, "pdhg_final_residual": 0.004,
             "pdhg_converged": True},
            {"zipf": 0.8, "lp_obj": 17.5,
             "pdhg_final_residual": 0.004 if converged else 0.2,
             "pdhg_converged": converged}]
    (root / "grid.json").write_text(json.dumps(rows))
    write_manifest(root / "grid.json", config={"smoke": True})
    tr = Tracer()
    with tr.span("sweep", kind="offline"):
        with tr.span("chunk", kind="offline", bucket="(3, 20)", chunk=0,
                     n_chunks=1, batch=2, pad_rows=0, in_bytes=1024):
            pass
    tr.export_jsonl(root / "grid.trace.jsonl")


def test_report_renders_and_gates(tmp_path, capsys):
    rep = _report_mod()
    _fake_artifacts(tmp_path, converged=True)
    assert rep.main([str(tmp_path), "--check-converged"]) == 0
    out = capsys.readouterr().out
    assert "== Manifests ==" in out
    assert "== Spans ==" in out
    assert "== Chunks ==" in out
    assert "== Convergence (grid.json) ==" in out
    assert "check-converged: OK" in out


def test_report_gate_fails_on_nonconverged(tmp_path, capsys):
    rep = _report_mod()
    _fake_artifacts(tmp_path, converged=False)
    assert rep.main([str(tmp_path), "--check-converged"]) == 1
    assert "1 window(s) above tolerance" in capsys.readouterr().out


def test_report_gate_fails_without_data(tmp_path):
    rep = _report_mod()
    assert rep.main([str(tmp_path), "--check-converged"]) == 1


def _fake_serving(misses, att_frac=0.2):
    att = {ph: {"sum": 1.0, "frac": att_frac, "p50": 0.01, "p95": 0.05,
                "p99": 0.1} for ph in ("queue", "stall", "service")}
    return {"offline": {"per_policy": {
        "cocar": {"delayed": {"deadline_misses": misses},
                  "attribution": att},
        "lfu": {"delayed": {"deadline_misses": misses + 1.0},
                "attribution": att}}}}


def test_report_attribution_table(tmp_path, capsys):
    rep = _report_mod()
    (tmp_path / "BENCH_serving.json").write_text(
        json.dumps(_fake_serving(2.0)))
    rep.report_attribution(tmp_path)
    out = capsys.readouterr().out
    assert "== Latency attribution" in out
    for needle in ("cocar", "lfu", "queue", "stall", "service", "20.0%"):
        assert needle in out
    # no serving payload -> section absent entirely
    rep.report_attribution(tmp_path / "nowhere")
    assert "attribution" not in capsys.readouterr().out


def test_deadline_miss_gate(tmp_path, capsys):
    """check_deadline_misses: None without a fresh payload, ok when at
    or below baseline, counts regressing policies above it — and the
    --check-converged gate turns a regression into exit 1."""
    rep = _report_mod()
    assert rep.check_deadline_misses(tmp_path) is None
    (tmp_path / "BENCH_serving.json").write_text(
        json.dumps(_fake_serving(3.0)))
    base = _fake_serving(3.0)
    assert rep.check_deadline_misses(tmp_path, baseline=base) == 0
    better = _fake_serving(2.0)                  # fewer misses: fine
    assert rep.check_deadline_misses(tmp_path, baseline=better) == 2
    out = capsys.readouterr().out
    assert "REGRESSION" in out
    # a baseline missing one policy gates only on the shared ones
    del base["offline"]["per_policy"]["lfu"]
    assert rep.check_deadline_misses(tmp_path, baseline=base) == 0


def test_check_converged_fails_on_miss_regression(tmp_path, capsys,
                                                  monkeypatch):
    rep = _report_mod()
    _fake_artifacts(tmp_path, converged=True)
    (tmp_path / "BENCH_serving.json").write_text(
        json.dumps(_fake_serving(5.0)))
    monkeypatch.setattr(rep, "_baseline_serving",
                        lambda: _fake_serving(1.0))
    assert rep.main([str(tmp_path), "--check-converged"]) == 1
    assert "regressed on deadline misses" in capsys.readouterr().out
    monkeypatch.setattr(rep, "_baseline_serving",
                        lambda: _fake_serving(5.0))
    assert rep.main([str(tmp_path), "--check-converged"]) == 0
    assert "no deadline-miss regressions" in capsys.readouterr().out


@pytest.mark.slow_compile
def test_sweep_smoke_end_to_end(tmp_path, monkeypatch, capsys):
    """``sweep --smoke`` in-process: rows converge, artifacts land, and
    report.py renders them with the gate green."""
    from repro.experiments import sweep as SW

    monkeypatch.chdir(tmp_path)
    rows = SW.main(smoke=True)
    assert len(rows) == 2
    assert all(r["pdhg_converged"] for r in rows)
    ci = tmp_path / "results" / "sweep" / "ci"
    for name in ("grid.json", "grid.manifest.json", "grid.trace.jsonl",
                 "grid.trace.chrome.json"):
        assert (ci / name).exists(), name
    capsys.readouterr()
    rep = _report_mod()
    assert rep.main([str(ci), "--check-converged"]) == 0
    assert "check-converged: OK" in capsys.readouterr().out
