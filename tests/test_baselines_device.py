"""The Sec. VII-B baseline zoo, dual-engine: every policy's device kernel
vs its NumPy oracle — decision-identical on shared uniforms/params — plus
the edge cases (no feasible BS, routing precision ties, GatMARL rollout
determinism) and the fused policy grid end to end."""
import numpy as np

from repro.core import baselines as BL
from repro.core import cocar as CC
from repro.core import lp as LP
from repro.mec import metrics as MET
from harness import make_instance, tiny_instance

from repro.mec.scenario import MECConfig, stack_instances


def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


def _dev(fn, inst, *args):
    """Run a device baseline kernel on one unpadded instance."""
    data = LP.pdhg_data(inst)
    with _x64():
        x, A = fn(data, *args)
    return np.asarray(x), np.asarray(A)


# ---------------------------------------------------------------------------
# per-policy dual-engine agreement on random instances
# ---------------------------------------------------------------------------

def test_greedy_device_matches_host():
    for seed in range(3):
        inst = make_instance(seed=seed, n_users=30, n_bs=4)
        xh, Ah = BL.greedy(inst)
        xd, Ad = _dev(BL.greedy_device, inst)
        assert np.array_equal(xh, xd)
        assert np.array_equal(Ah, Ad)


def test_random_device_matches_host_on_shared_uniforms():
    inst = make_instance(seed=1, n_users=30, n_bs=4)
    u_perm, u_h, u_route = BL.draw_baseline_uniforms(
        5, inst.N, inst.M, inst.U, n_seeds=4)
    for s in range(4):
        xh, Ah = BL.random_from_uniforms(inst, u_perm[s], u_h[s],
                                         u_route[s])
        xd, Ad = _dev(BL.random_device, inst, u_perm[s], u_h[s], u_route[s])
        assert np.array_equal(xh, xd)
        assert np.array_equal(Ah, Ad)


def test_gat_rollout_deterministic_and_dual_engine():
    """Fixed seed: training is cached, two rollouts are bit-identical, and
    the vmappable device rollout reproduces the host decisions."""
    inst = make_instance(seed=2, n_users=25, n_bs=3)
    params = BL.gat_policy(inst, seed=0, episodes=6)
    x1, A1 = BL.gat_rollout_host(inst, params)
    x2, A2 = BL.gat_rollout_host(inst, params)
    assert np.array_equal(x1, x2) and np.array_equal(A1, A2)
    feats = BL.gat_features(inst)
    adj = BL.gat_adj(inst)
    xd, Ad = _dev(BL.gat_rollout_device, inst, params, feats, adj)
    assert np.array_equal(x1, xd)
    assert np.array_equal(A1, Ad)


# ---------------------------------------------------------------------------
# edge cases, identical on both engines
# ---------------------------------------------------------------------------

def test_route_best_exact_precision_tie_keeps_smallest_bs():
    """Two BSs cache the user's model at the same level — an exact
    precision tie; both engines must route to the smaller BS index."""
    inst = tiny_instance(n_bs=2, m_u=(0, 0), R=100.0)
    x = np.zeros((2, 2, 3))
    x[:, :, 0] = 1.0
    for n in range(2):                       # both BSs cache model 0 at h2
        x[n, 0] = [0, 0, 1]
    Ah = BL._route_best(inst, x)
    lvl = np.argmax(x, axis=-1)
    data = LP.pdhg_data(inst)
    with _x64():
        import jax.numpy as jnp
        Ad = np.asarray(BL._route_best_device(data, jnp.asarray(lvl)))
    assert np.array_equal(Ah, Ad)
    assert Ah[0, 0, 1] == 1.0 and Ah[1, 0, 1] == 0.0


def test_user_with_no_feasible_bs_stays_unserved_both_engines():
    """The requested model is cached nowhere: Greedy's home routing and
    the best-precision router both leave the user unserved (A row all
    zero), on both engines, and metrics count the miss identically."""
    # R fits only model 0's full submodel (size 20); model 1 never cached
    inst = tiny_instance(n_bs=1, m_u=(0, 1), R=20.0)
    xh, Ah = BL.greedy(inst)
    xd, Ad = _dev(BL.greedy_device, inst)
    assert np.array_equal(xh, xd) and np.array_equal(Ah, Ad)
    assert Ah[:, 1, :].sum() == 0.0          # user 1 unserved
    mh = MET.window_metrics(inst, xh, Ah)
    data = LP.pdhg_data(inst)
    with _x64():
        md = MET.window_metrics_device(
            data, xd, MET.enforce_device(data, xd, Ad))
    assert mh["hits"] == int(md["hits"]) == 1
    assert abs(mh["avg_qoe"] - float(md["avg_qoe"])) < 1e-9


def test_enforce_device_matches_host_on_noisy_routes():
    """Duplicate routes + routes at uncached submodels + latency
    violations: the execution-time enforcement must kick out the same
    routes on both engines."""
    inst = make_instance(seed=3, n_users=25, n_bs=3)
    xg, _ = BL.greedy(inst)
    # route EVERY user everywhere its model is cached (dupes galore)
    x_sel = xg[:, inst.m_u, 1:]
    A = (x_sel > 0).astype(np.float64)
    Ah = MET.enforce(inst, xg, A)
    data = LP.pdhg_data(inst)
    with _x64():
        Ad = np.asarray(MET.enforce_device(data, xg, A))
    assert np.array_equal(Ah, Ad)
    assert (Ah.sum(axis=(0, 2)) <= 1.0 + 1e-12).all()


# ---------------------------------------------------------------------------
# the fused policy grid end to end
# ---------------------------------------------------------------------------

HETERO = [(0, 22, 3), (1, 28, 4)]


def test_policy_grid_device_matches_host_per_policy():
    """All five policies on a padded heterogeneous stack: identical
    cache/routing decisions per (window, seed, policy), metrics within
    1e-9."""
    insts = [make_instance(seed=s, n_users=u, n_bs=n) for s, u, n in HETERO]
    stacked = stack_instances(insts)
    n_seeds = 2
    uniforms = CC.policy_uniforms(stacked, 3, n_seeds, best_of=2)
    gat = CC.gat_grid_policies(stacked, 0, episodes=5)
    dev = CC.policy_grid_device(stacked, pdhg_iters=250, best_of=2,
                                n_seeds=n_seeds, uniforms=uniforms, gat=gat)
    host = CC.policy_grid_host(stacked, uniforms, gat,
                               dev["cocar_frac"]["x"],
                               dev["cocar_frac"]["A"],
                               dev["spr3_frac"], n_seeds=n_seeds)
    for p in CC.OFFLINE_POLICIES:
        for i, inst in enumerate(insts):
            for s in range(n_seeds):
                xh, Ah, mh = host[p][i][s]
                assert np.array_equal(dev[p]["x"][i, s, :inst.N], xh), p
                assert np.array_equal(
                    dev[p]["A"][i, s, :inst.N, :inst.U], Ah), p
                for k, v in mh.items():
                    assert abs(float(dev[p]["metrics"][k][i, s]) - v) \
                        < 1e-9, (p, k)


def test_improvement_ratio_summary():
    means = {"cocar": [0.6, 0.66], "greedy": [0.3, 0.36],
             "random": [0.1, 0.2], "spr3": [0.2, 0.2],
             "gatmarl": [0.15, 0.15]}
    out = CC.improvement_ratio(means)
    assert out["best_baseline"] == "greedy"
    assert abs(out["ratio"] - 0.63 / 0.33) < 1e-12


def test_run_policy_sweep_rows_and_summary():
    from repro.experiments.sweep import run_policy_sweep
    rows, summary = run_policy_sweep(
        base=MECConfig(n_users=18), axes={"zipf": (0.4, 0.8)},
        pdhg_iters=150, best_of=2, n_seeds=1, episodes=4)
    assert len(rows) == 2 * len(CC.OFFLINE_POLICIES)
    assert {r["policy"] for r in rows} == set(CC.OFFLINE_POLICIES)
    for r in rows:
        assert 0.0 <= r["hit_rate"] <= 1.0
        assert r["avg_qoe"] <= r["avg_precision"] + 1e-12
    assert summary["ratio"] > 0
    assert summary["best_baseline"] in CC.OFFLINE_POLICIES


def test_spr3_relaxation_consistency():
    """The device relaxation must transform the pytree exactly as the
    host relaxes the instance (sizes/precision/budgets)."""
    inst = make_instance(seed=4, n_users=20, n_bs=3)
    relaxed = BL.spr3_relaxed(inst)
    data = LP.pdhg_data(inst)
    with _x64():
        rdata = BL.spr3_relax_device(data)
        rdata = type(rdata)(*(np.asarray(v) for v in rdata))
    ref = LP.pdhg_data(relaxed)
    np.testing.assert_array_equal(rdata.sizes, ref.sizes)
    np.testing.assert_array_equal(rdata.prec, ref.prec)
    np.testing.assert_array_equal(rdata.prec_u, ref.prec_u)
    np.testing.assert_array_equal(rdata.s_u, ref.s_u)


def test_qoe_bounds_in_window_metrics():
    """QoE is precision discounted by latency slack: 0 ≤ qoe ≤ precision,
    and a window with no served users reports zero."""
    inst = make_instance(seed=5, n_users=20, n_bs=3)
    sc_x, sc_A = BL.greedy(inst)
    m = MET.window_metrics(inst, sc_x, sc_A)
    assert 0.0 <= m["avg_qoe"] <= m["avg_precision"] + 1e-12
    empty_A = np.zeros_like(sc_A)
    m0 = MET.window_metrics(inst, sc_x, empty_A)
    assert m0["avg_qoe"] == 0.0 and m0["hits"] == 0
