"""The decision bridge: control-plane caching arrays / online cache
states -> per-pod residency plans with measured loading times, executed
by the queue simulator (no hand-constructed residency anywhere)."""
import numpy as np
import pytest

from repro import configs
from repro.core.online import OnlineConfig, run_online
from repro.mec.catalog import (crosscheck_table3, make_catalog,
                               table3_mem_rate)
from repro.mec.scenario import MECConfig
from repro.models import partition
from repro.serving.plan import (cache_levels, catalog_precisions,
                                check_mid_download_never_serves,
                                execute_plan, plan_from_offline,
                                plans_from_online_states)
from repro.serving.simulator import SimRequest
from repro.traces.registry import default_workload

ARCHS = ("qwen1.5-0.5b", "stablelm-12b", "chatglm3-6b")
SMOKE = {a: configs.get_smoke(a) for a in ARCHS}


def _onehot(lvl, H=3):
    """(N, M) levels -> (N, M, H+1) one-hot, the control-plane layout."""
    lvl = np.asarray(lvl)
    x = np.zeros(lvl.shape + (H + 1,))
    np.put_along_axis(x, lvl[..., None], 1.0, axis=-1)
    return x


# ---------------------------------------------------------------------------
# offline decisions -> plans
# ---------------------------------------------------------------------------

def test_plan_from_offline_mapping():
    lvl = np.array([[0, 2], [3, 1]])
    plan = plan_from_offline(_onehot(lvl), names=("a", "b"), policy="cocar")
    assert plan.residency == {0: {"b": 1}, 1: {"a": 2, "b": 0}}
    assert plan.source == "offline:cocar"
    np.testing.assert_array_equal(plan.lvl, lvl)
    assert plan.n_pods == 2 and plan.max_load_s() == 0.0


def test_plan_from_offline_load_times_from_catalog():
    cat = make_catalog("measured", cfgs=SMOKE, tokens=64)
    names = list(SMOKE)
    lvl = np.array([[2, 0, 1], [0, 3, 0]])
    prev = np.array([[1, 0, 1], [0, 0, 0]])
    plan = plan_from_offline(_onehot(lvl), names, catalog=cat,
                             x_prev=_onehot(prev))
    # upgraded (pod, model) pairs get the transition's measured seconds
    assert plan.available_at[(0, names[0])] == cat.load_seconds(0, 1, 2)
    assert plan.available_at[(1, names[1])] == cat.load_seconds(1, 0, 3)
    # unchanged residency ((0, names[2]) stays at level 1) loads nothing
    assert (0, names[2]) not in plan.available_at
    # and the measured seconds are exactly delta_bytes / bandwidth
    nb = partition.delta_bytes(SMOKE[names[0]], 0, 1)
    assert abs(plan.available_at[(0, names[0])]
               - nb / (cat.bandwidth_MBps * 1e6)) < 1e-12
    # default x_prev is a cold start: every resident level loads
    cold = plan_from_offline(_onehot(lvl), names, catalog=cat)
    assert (0, names[2]) in cold.available_at
    assert cold.max_load_s() >= plan.max_load_s()


def test_cache_levels_rejects_bad_shape():
    with pytest.raises(ValueError, match="one-hot"):
        cache_levels(np.zeros((2, 3)))
    with pytest.raises(ValueError, match="names"):
        plan_from_offline(_onehot(np.zeros((2, 3), int)), names=("a",))


def test_execute_plan_load_delay_costs_slo():
    """The same decision, with vs without its measured loading delay:
    delay can only hurt SLO attainment, and early requests stall until
    the bytes land."""
    # a deliberately slow link so the smoke models' bytes take seconds
    cat = make_catalog("measured", cfgs=SMOKE, tokens=64,
                       bandwidth_MBps=0.5)
    names = list(SMOKE)
    lvl = np.array([[3, 0, 0], [0, 1, 0]])
    plan = plan_from_offline(_onehot(lvl), names, catalog=cat)
    c = partition.submodel_flops_per_token(SMOKE[names[0]], 2, ctx=64)
    compute = 64 * c / 0.05
    t0 = plan.available_at[(0, names[0])]
    assert t0 > 1.0                              # the delay is material
    reqs = lambda: [SimRequest(rid=i, model=names[0], tokens=64,  # noqa: E731
                               arrival=0.1 * i, deadline=0.1 * i + 0.1)
                    for i in range(8)]
    hot = execute_plan(plan, SMOKE, compute, reqs(), catalog=cat,
                       names=names, with_load_delay=False)
    cold = execute_plan(plan, SMOKE, compute, reqs(), catalog=cat,
                        names=names, with_load_delay=True, admit_late=True)
    assert hot["slo_attainment"] > cold["slo_attainment"]
    assert cold["p95_latency"] > hot["p95_latency"]
    # delivered precision comes from the catalog ladder, not the default
    assert hot["avg_precision"] == pytest.approx(float(cat.prec[0, 3]))


# ---------------------------------------------------------------------------
# online per-slot states -> plans
# ---------------------------------------------------------------------------

CFG = MECConfig(n_bs=3, n_users=40, n_models=4, seed=0)
OCFG = OnlineConfig(n_slots=12, rounds=2)


def test_record_states_numpy_scan_identical():
    wl = default_workload(CFG, OCFG)
    a = run_online(wl, "cocar-ol", cfg=CFG, ocfg=OCFG, engine="numpy",
                   record_states=True)
    b = run_online(wl, "cocar-ol", cfg=CFG, ocfg=OCFG, engine="scan",
                   record_states=True)
    for k in ("lvl", "dl", "target"):
        assert a["states"][k].shape == (OCFG.n_slots, CFG.n_bs,
                                        CFG.n_models)
        np.testing.assert_array_equal(
            np.asarray(a["states"][k], np.int32),
            np.asarray(b["states"][k], np.int32))
    # recording is decision-inert
    off = run_online(wl, "cocar-ol", cfg=CFG, ocfg=OCFG, engine="scan")
    np.testing.assert_array_equal(off["slot_qoe"], b["slot_qoe"])
    assert "states" not in off


def test_mid_download_never_serves():
    wl = default_workload(CFG, OCFG)
    out = run_online(wl, "cocar-ol", cfg=CFG, ocfg=OCFG, engine="scan",
                     record_states=True)
    verdict = check_mid_download_never_serves(out["states"])
    assert verdict["ok"] and not verdict["vacuous"]
    # residency built from lvl structurally excludes in-flight targets
    names = [f"m{i}" for i in range(CFG.n_models)]
    plans = plans_from_online_states(out["states"], names, algo="cocar-ol")
    assert len(plans) == OCFG.n_slots
    dl = np.asarray(out["states"]["dl"], bool)
    tgt = np.asarray(out["states"]["target"])
    for t, plan in enumerate(plans):
        for n, m in zip(*np.nonzero(dl[t])):
            res = plan.residency[n].get(names[m], -1)
            assert res + 1 < tgt[t, n, m]
    # a doctored state (serving the in-flight target) is caught
    bad = {k: np.asarray(v).copy() for k, v in out["states"].items()}
    n0 = tuple(np.argwhere(dl)[0])
    bad["lvl"][n0] = bad["target"][n0]
    assert not check_mid_download_never_serves(bad)["ok"]


# ---------------------------------------------------------------------------
# measured catalog provenance
# ---------------------------------------------------------------------------

def test_measured_catalog_crosschecks_table3():
    cat = make_catalog("measured", cfgs=SMOKE, tokens=64)
    chk = crosscheck_table3(cat)
    band = table3_mem_rate()
    assert chk["ok"]
    assert chk["bandwidth_MBps"] == pytest.approx(band["median"])
    assert band["min"] < band["median"] < band["max"]
    # an out-of-band bandwidth fails the cross-check
    fast = make_catalog("measured", cfgs=SMOKE, tokens=64,
                        bandwidth_MBps=10 * band["max"])
    assert not crosscheck_table3(fast)["ok"]
    # shrinks are instant, upgrades strictly positive
    assert np.all(cat.loadD[:, 2, 1] == 0.0)
    assert np.all(cat.loadD[:, 0, 1:] > 0.0)


def test_catalog_precisions_match_ladder():
    cat = make_catalog("measured", cfgs=SMOKE, tokens=64)
    names = list(SMOKE)
    prec = catalog_precisions(cat, names)
    assert prec[(names[0], 0)] == float(cat.prec[0, 1])
    assert prec[(names[2], 2)] == float(cat.prec[2, 3])
    assert len(prec) == len(names) * cat.H
