"""Request-level telemetry: streaming metrics, event log, watermarks.

Four layers, mirroring the subsystem:

  * histograms/counters/gauges — percentile math, serialization
    roundtrips, and the merge laws (associative + commutative, property
    tested) that let per-run registries fold in any order;
  * the Prometheus textfile exporter, validated with the same parser
    ``scripts/check_metrics.py`` runs as a CI gate;
  * the per-request event log and its conservation law (every arrival
    terminates exactly once as finish | miss | drop);
  * the stack taps — QueueSim attribution exactness and decision
    inertness, online diagnostics folding, executor memory watermarks.
"""
import importlib.util
import json
import pathlib
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - single-example fallback
    from _hypothesis_fallback import given, settings, st

from repro.obs import (COUNT_EDGES, UNIT_EDGES, Counter, EventLog, Gauge,
                       Histogram, MetricsRegistry, memory_snapshot,
                       observe_online_diag, observe_queue_sim)


def _check_metrics_mod():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "scripts" / "check_metrics.py")
    spec = importlib.util.spec_from_file_location("obs_check_metrics", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["obs_check_metrics"] = mod
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------

def test_histogram_counts_and_percentiles():
    h = Histogram("lat", edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0, 9.0):
        h.observe(v)
    assert h.n == 5 and h.counts == [1, 2, 1, 1]
    assert h.mean == pytest.approx((0.5 + 1.5 + 1.5 + 3.0 + 9.0) / 5)
    # percentiles stay inside the observed range and are monotone in q
    qs = [h.percentile(q) for q in (1, 25, 50, 75, 99)]
    assert all(0.5 <= v <= 9.0 for v in qs)
    assert qs == sorted(qs)
    # empty histogram pins to zero, not NaN
    assert Histogram("e").percentile(50) == 0.0
    assert Histogram("e").mean == 0.0


def test_histogram_percentile_single_value():
    h = Histogram("one", edges=(1.0, 2.0))
    h.observe(1.5, count=100)
    for q in (1, 50, 99):
        assert h.percentile(q) == pytest.approx(1.5)


def test_histogram_roundtrip_and_bad_edges():
    h = Histogram("x", edges=(0.1, 0.2))
    h.observe(0.15)
    h2 = Histogram.from_dict(json.loads(json.dumps(h.to_dict())))
    assert (h2.edges, h2.counts, h2.n, h2.total) == \
        (h.edges, h.counts, h.n, h.total)
    assert (h2.vmin, h2.vmax) == (h.vmin, h.vmax)
    with pytest.raises(ValueError):
        Histogram("bad", edges=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("bad", edges=())
    with pytest.raises(ValueError):
        h.merge(Histogram("other", edges=(0.1, 0.2, 0.3)))


def _merged(parts):
    out = Histogram("m", edges=(0.25, 0.5, 1.0))
    for p in parts:
        out.merge(p)
    return out


def _hist_of(values):
    h = Histogram("m", edges=(0.25, 0.5, 1.0))
    h.observe_many(values)
    return h


def _state(h):
    return (h.counts, h.n, h.total, h.vmin, h.vmax)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=2.0), min_size=1,
                max_size=8),
       st.lists(st.floats(min_value=0.0, max_value=2.0), min_size=1,
                max_size=8),
       st.lists(st.floats(min_value=0.0, max_value=2.0), min_size=1,
                max_size=8))
def test_histogram_merge_associative_commutative(a, b, c):
    """Merging per-run histograms is order-independent: (a+b)+c ==
    a+(b+c) == any permutation == observing the concatenation."""
    ha, hb, hc = _hist_of(a), _hist_of(b), _hist_of(c)
    left = _merged([_merged([_hist_of(a), _hist_of(b)]), _hist_of(c)])
    right = _merged([_hist_of(a), _merged([_hist_of(b), _hist_of(c)])])
    perm = _merged([hc, ha, hb])
    pooled = _hist_of(list(a) + list(b) + list(c))
    assert _state(left) == _state(right) == _state(perm)
    assert _state(left)[:2] == _state(pooled)[:2]
    assert left.total == pytest.approx(pooled.total)
    assert (left.vmin, left.vmax) == (pooled.vmin, pooled.vmax)


# ---------------------------------------------------------------------------
# counters / gauges / registry
# ---------------------------------------------------------------------------

def test_counter_and_gauge_semantics():
    c = Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge("g")
    g.set(5.0)
    g.set(2.0)
    assert g.value == 2.0 and g.hwm == 5.0       # high-water mark sticks


def test_registry_merge_and_redeclare():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.histogram("h", (1.0, 2.0)).observe(0.5)
    b.histogram("h", (1.0, 2.0)).observe(1.5)
    b.histogram("only_b", (1.0,)).observe(0.1)
    a.counter("n").inc(3)
    b.counter("n").inc(4)
    a.gauge("mem").set(10.0)
    b.gauge("mem").set(7.0)
    a.merge(b)
    assert a.histogram("h", (1.0, 2.0)).n == 2
    assert a.histogram("only_b", (1.0,)).n == 1
    assert a.counters["n"].value == 7
    assert a.gauges["mem"].value == 10.0 and a.gauges["mem"].hwm == 10.0
    with pytest.raises(ValueError):
        a.histogram("h", (1.0, 3.0))             # edge re-declare mismatch
    # roundtrip keeps the whole registry mergeable
    back = MetricsRegistry.from_dict(
        json.loads(json.dumps(a.to_dict())))
    assert back.to_dict() == a.to_dict()


def test_prometheus_export_passes_schema_gate(tmp_path):
    """The exporter's textfile must satisfy the exact parser ci.sh runs
    (cumulative buckets, +Inf == _count, typed samples)."""
    cm = _check_metrics_mod()
    reg = MetricsRegistry()
    reg.histogram("request_latency_seconds").observe_many(
        [0.004, 0.09, 1.7, 80.0])                # incl. overflow bucket
    reg.counter("requests_served_total").inc(4)
    reg.gauge("online_cache_mb").set(123.5)
    path = tmp_path / "m.prom"
    reg.export_prometheus(path)
    assert cm.check_file(path, require=("repro_request_latency_seconds",
                                        "repro_requests_served_total")) == []
    fams = cm.parse_textfile(path.read_text())
    hist = fams["repro_request_latency_seconds"]
    assert hist["type"] == "histogram"
    inf = [v for n, lb, v in hist["samples"]
           if n.endswith("_bucket") and '+Inf' in lb]
    assert inf == [4.0]
    # a doctored file (broken cumulativity) must FAIL the gate
    text = path.read_text().replace(
        'repro_request_latency_seconds_bucket{le="+Inf"} 4',
        'repro_request_latency_seconds_bucket{le="+Inf"} 2')
    bad = tmp_path / "bad.prom"
    bad.write_text(text)
    assert cm.check_file(bad) != []
    # and a missing required family is reported
    errs = cm.check_file(path, require=("repro_absent_total",))
    assert any("repro_absent_total" in e for e in errs)


# ---------------------------------------------------------------------------
# event log conservation
# ---------------------------------------------------------------------------

def _emit_lifecycle(log, rid, terminal="finish"):
    log.emit("arrival", rid, 0.0)
    log.emit("route", rid, 0.0, chosen=0)
    log.emit(terminal, rid, 1.0)


def test_event_log_conservation_ok(tmp_path):
    log = EventLog()
    log.new_run("a")
    _emit_lifecycle(log, 0, "finish")
    _emit_lifecycle(log, 1, "miss")
    log.new_run("b")
    _emit_lifecycle(log, 0, "drop")              # same rid, new run: fine
    c = log.conservation()
    assert c["ok"] and c["n_arrivals"] == c["n_terminals"] == 3
    assert c["by_kind"]["arrival"] == 3 and c["by_kind"]["route"] == 3
    # jsonl roundtrip preserves the verdict
    p = log.export_jsonl(tmp_path / "ev.jsonl")
    back = EventLog.read_jsonl(p)
    assert len(back) == len(log)
    assert back.conservation() == c


def test_event_log_conservation_failures():
    log = EventLog()
    log.new_run()
    log.emit("arrival", 0, 0.0)                  # never terminated
    log.emit("arrival", 1, 0.0)
    log.emit("finish", 1, 1.0)
    log.emit("finish", 1, 2.0)                   # double-terminated
    log.emit("drop", 2, 0.0)                     # orphan terminal
    c = log.conservation()
    assert not c["ok"]
    assert (c["unterminated"], c["orphans"], c["duplicates"]) == (1, 1, 1)
    with pytest.raises(ValueError):
        log.emit("teleport", 3, 0.0)


# ---------------------------------------------------------------------------
# stack taps
# ---------------------------------------------------------------------------

def test_observe_queue_sim_matches_sim_state():
    from repro import configs
    from repro.serving.simulator import QueueSim, poisson_arrivals

    from repro.models import partition
    cfgs = {"a": configs.get_smoke("qwen1.5-0.5b")}
    c = partition.submodel_flops_per_token(cfgs["a"], 0, ctx=64)
    sim = QueueSim(cfgs, {0: {"a": 0}}, 64 * c / 0.05)
    arr = poisson_arrivals(50.0, 5.0, ["a"], [1.0], tokens=64, seed=3)
    m = sim.run(arr)
    reg = MetricsRegistry()
    observe_queue_sim(reg, sim)
    assert reg.histogram("request_latency_seconds").n == m["served"]
    assert reg.counters["requests_served_total"].value == m["served"]
    assert reg.counters["requests_dropped_total"].value == m["dropped"]
    assert reg.counters["deadline_misses_total"].value == \
        m["deadline_misses"]
    # histogram mass telescopes exactly like the attribution identity
    parts = sum(reg.histogram(f"request_{ph}_seconds").total
                for ph in ("queue", "stall", "service"))
    assert parts == pytest.approx(
        reg.histogram("request_latency_seconds").total, abs=1e-9)


def test_observe_online_diag_folds_curves():
    reg = MetricsRegistry()
    diag = {"hit_rate": np.array([0.25, 0.75, 1.0]),
            "dl_in_flight": np.array([0.0, 2.0, 1.0]),
            "evictions": np.array([0.0, 3.0, 1.0]),
            "cache_mb": np.array([100.0, 180.0, 120.0])}
    observe_online_diag(reg, diag)
    assert reg.histogram("online_hit_rate", UNIT_EDGES).n == 3
    assert reg.histogram("online_dl_in_flight", COUNT_EDGES).n == 3
    assert reg.counters["online_evictions_total"].value == 4.0
    g = reg.gauges["online_cache_mb"]
    assert g.value == 120.0 and g.hwm == 180.0   # final value, peak hwm


def test_memory_snapshot_host_and_device():
    snap = memory_snapshot()
    assert snap["host_rss_kb"] > 0
    assert snap["host_maxrss_kb"] > 0
    import jax.numpy as jnp
    keep = jnp.zeros((1024,), jnp.float32) + 1   # ensure a live array
    snap2 = memory_snapshot()
    assert snap2["device_live_bytes"] >= keep.nbytes
    assert snap2["device_live_arrays"] >= 1


def test_executor_watermarks_decision_inert():
    """diagnostics=True adds peak memory watermarks to executor stats
    (and per-chunk span attrs) without changing a single decision."""
    from harness import assert_same_offline, make_instance

    from repro.obs import tracing as OT
    from repro.scale import GridSpec, run_grid

    insts = [make_instance(seed=s, n_users=20) for s in (0, 1)]
    kw = dict(kind="offline", insts=insts, seed=0, n_seeds=1, best_of=2,
              pdhg_iters=150, backend="vmap")
    off = run_grid(GridSpec(**kw))
    n0 = len(OT.TRACER.spans)
    on = run_grid(GridSpec(**kw, diagnostics=True))
    assert_same_offline(off.results, on.results)
    for k in ("peak_host_rss_kb", "peak_host_maxrss_kb",
              "peak_device_live_bytes"):
        assert k in on.stats, k
        assert k not in off.stats                # skipped when off
    assert on.stats["peak_host_rss_kb"] > 0
    # every chunk span of the diagnostics run carries the watermarks
    chunks = [s for s in OT.TRACER.spans[n0:] if s.name == "chunk"]
    assert chunks
    for s in chunks:
        assert "host_rss_kb" in s.attrs
        assert "device_live_bytes" in s.attrs
