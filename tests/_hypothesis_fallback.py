"""Single-example stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite must collect and run without optional dependencies.  When
``hypothesis`` is available the property tests use it unchanged; when it is
missing, this module makes each ``@given`` test run ONCE with a fixed
representative draw from its strategies (midpoint integers, first element
of sampled_from, minimal lists) — degraded coverage, but the invariant is
still exercised instead of the whole module failing at import.
"""
from __future__ import annotations


class _Strategy:
    def __init__(self, example):
        self.example = example


class _Strategies:
    @staticmethod
    def integers(min_value=0, max_value=0):
        return _Strategy((min_value + max_value) // 2)

    @staticmethod
    def sampled_from(elements):
        return _Strategy(list(elements)[0])

    @staticmethod
    def lists(elements, min_size=0, max_size=None, **_):
        return _Strategy([elements.example] * max(min_size, 1))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy((min_value + max_value) / 2.0)

    @staticmethod
    def booleans():
        return _Strategy(False)


st = _Strategies()


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        # no functools.wraps: pytest must see a parameterless signature,
        # not the strategy-filled arguments of the wrapped test
        def wrapper():
            fixed = [s.example for s in arg_strategies]
            kw = {k: s.example for k, s in kw_strategies.items()}
            return fn(*fixed, **kw)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def settings(*_, **__):
    def deco(fn):
        return fn
    return deco
