"""The sharded grid executor (``repro.scale``): bucket planning, the
bucketed-padding == max-padding decision identity for all five offline
policies AND the online scan engine, chunked streaming, the shard_map
path (on a 1-device mesh — the multi-device run is exercised by
``benchmarks/bench_scale.py`` under
``--xla_force_host_platform_device_count=8`` in CI), mesh validation,
and jit-cache stability across repeated sweeps."""
import harness
import numpy as np
import pytest
from harness import assert_same_offline

from repro.core import cocar as CC
from repro.core.online import OnlineConfig
from repro.mec.scenario import MECConfig, stack_instances
from repro.scale import GridSpec, plan_buckets, run_grid
from repro.scale.executor import compiled_cache_stats
from repro.traces import engine as E
from repro.traces.registry import make_trace

#: heterogeneous (seed, n_users, n_bs) grid shared by the identity tests
HETERO = [(0, 16, 3), (1, 20, 4), (2, 16, 3), (3, 24, 4), (4, 20, 3)]


def hetero_insts():
    return harness.hetero_insts(HETERO)


# ---------------------------------------------------------------------------
# bucket planning
# ---------------------------------------------------------------------------

def test_plan_one_bucket_is_global_max_pad():
    plan = plan_buckets([(3, 16), (4, 20), (3, 24)], max_buckets=1)
    assert len(plan) == 1
    b = plan.buckets[0]
    assert (b.n_bs, b.n_users) == (4, 24)
    assert b.indices == (0, 1, 2)


def test_plan_one_shape_per_bucket():
    shapes = [(3, 16), (4, 20), (5, 24)]
    plan = plan_buckets(shapes, max_buckets=8)
    assert len(plan) == 3
    for b, (n, u) in zip(plan.buckets, shapes):
        assert (b.n_bs, b.n_users) == (n, u)
        assert len(b.indices) == 1


def test_plan_covers_indices_and_fits_members():
    shapes = [(3, 40), (6, 10), (3, 41), (6, 12), (4, 38), (5, 11)]
    plan = plan_buckets(shapes, max_buckets=2)
    assert len(plan) == 2
    seen = sorted(i for b in plan.buckets for i in b.indices)
    assert seen == list(range(len(shapes)))
    for b in plan.buckets:
        for i in b.indices:
            n, u = shapes[i]
            assert n <= b.n_bs and u <= b.n_users
    # merging similar shapes must waste fewer cells than one global pad
    assert plan.padded_cells() < plan_buckets(shapes, 1).padded_cells()


def test_plan_key_stable_and_rounding():
    shapes = [(3, 15), (3, 17)]
    p1 = plan_buckets(shapes, max_buckets=2, round_users_to=8)
    p2 = plan_buckets(list(shapes), max_buckets=2, round_users_to=8)
    assert p1.key == p2.key
    assert all(b.n_users % 8 == 0 for b in p1.buckets)
    with pytest.raises(ValueError):
        plan_buckets(shapes, max_buckets=0)
    with pytest.raises(ValueError):
        plan_buckets([], max_buckets=1)


def test_stack_pad_to_and_signature():
    insts = hetero_insts()[:2]
    stk = stack_instances(insts, pad_to=(6, 32))
    assert stk.signature == (2, 6, 32, 4, insts[0].H)
    assert stk.data.T.shape == (2, 6, 32, insts[0].H)
    # pads are zeros beyond each instance's true rows
    assert not stk.data.bs_mask[:, 5:].any()
    with pytest.raises(ValueError):
        stack_instances(insts, pad_to=(3, 32))    # smaller than max N


def test_make_host_mesh_validates_device_count():
    import jax

    from repro.launch.mesh import make_host_mesh

    n = len(jax.devices())
    mesh = make_host_mesh(data=n, model=1)
    assert mesh.shape == {"data": n, "model": 1}
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_host_mesh(data=n + 1, model=1)
    with pytest.raises(ValueError):
        make_host_mesh(data=0, model=1)


# ---------------------------------------------------------------------------
# offline kind: bucketing / chunking / shard_map decision identity
# ---------------------------------------------------------------------------

S, BO, ITERS = 2, 2, 250


def offline_grid(**kw):
    spec = dict(kind="offline", insts=hetero_insts(), seed=0, n_seeds=S,
                best_of=BO, pdhg_iters=ITERS, backend="vmap",
                max_buckets=1)
    spec.update(kw)
    return run_grid(GridSpec(**spec))


def test_offline_bucketed_matches_max_padded():
    ref = offline_grid()
    assert ref.stats["plan"] == ((4, 24, 5),)
    # k=4 = one bucket per distinct shape (three of them single-instance)
    for k in (2, 3, 4):
        out = offline_grid(max_buckets=k)
        assert len(out.stats["plan"]) == k
        assert_same_offline(ref.results, out.results)
    # every result is at its true shape
    for inst, per_seed in zip(hetero_insts(), ref.results):
        for x, A, _ in per_seed:
            assert x.shape == (inst.N, inst.M, inst.H + 1)
            assert A.shape == (inst.N, inst.U, inst.H)


def test_offline_chunked_matches_one_chunk():
    ref = offline_grid()                   # one bucket, one chunk of 5
    out = offline_grid(chunk_size=2)       # same bucket, three chunks of 2
    assert ref.stats["chunks"] == 1 and out.stats["chunks"] == 3
    assert out.stats["peak_chunk_in_bytes"] < ref.stats["peak_chunk_in_bytes"]
    assert_same_offline(ref.results, out.results)
    # chunking composes with bucketing
    both = offline_grid(max_buckets=2, chunk_size=2)
    assert_same_offline(ref.results, both.results)


def test_offline_sharded_matches_vmap():
    """shard_map over a 1-device mesh must be decision-identical to the
    plain vmap dispatch (the multi-device identity is gated in CI by
    bench_scale under 8 forced host devices)."""
    ref = offline_grid(max_buckets=2)
    out = offline_grid(max_buckets=2, backend="sharded", devices=1)
    assert out.stats["devices"] == 1
    assert_same_offline(ref.results, out.results)


def test_offline_matches_legacy_single_dispatch():
    """The executor's 1-bucket vmap path == the pre-scale fused dispatch
    (same kernel, same uniforms, same unstacking)."""
    insts = hetero_insts()
    stacked = stack_instances(insts)
    u_cat, u_phi = CC.offline_uniforms(stacked, 0, S, BO)
    dev = CC.offline_pipeline_device(stacked, u_cat, u_phi,
                                     pdhg_iters=ITERS, n_seeds=S)
    legacy = CC._unstack_device(stacked, dev, S)
    assert_same_offline(legacy, offline_grid().results)


def test_offline_per_element_rng_layout_invariant():
    """The O(chunk)-memory ``per_element`` scheme must be invariant to
    bucketing, chunking, AND the shard_map backend (its draws are keyed
    on the original grid index, so the layout cannot reach them)."""
    ref = offline_grid(rng="per_element")
    for kw in (dict(max_buckets=3), dict(chunk_size=2),
               dict(max_buckets=2, chunk_size=2,
                    backend="sharded", devices=1)):
        out = offline_grid(rng="per_element", **kw)
        assert_same_offline(ref.results, out.results)
    for inst, per_seed in zip(hetero_insts(), ref.results):
        for x, A, _ in per_seed:
            assert x.shape == (inst.N, inst.M, inst.H + 1)
    with pytest.raises(ValueError, match="unknown rng"):
        offline_grid(rng="per-window")


def test_policy_per_element_rng_bucket_invariant():
    insts = hetero_insts()[:2]
    kw = dict(kind="policy", insts=insts, seed=0, n_seeds=1, best_of=BO,
              pdhg_iters=ITERS, episodes=5, backend="vmap",
              rng="per_element")
    ref = run_grid(GridSpec(**kw, max_buckets=1))
    out = run_grid(GridSpec(**kw, max_buckets=2, chunk_size=1))
    for p in CC.OFFLINE_POLICIES:
        for i in range(len(insts)):
            x1, A1, m1 = ref.results[p][i][0]
            x2, A2, m2 = out.results[p][i][0]
            np.testing.assert_array_equal(x1, x2, err_msg=f"{p}[{i}]")
            np.testing.assert_array_equal(A1, A2, err_msg=f"{p}[{i}]")


def test_compiled_cache_stable_across_repeats():
    """Re-running the same spec must hit both the executor's compiled-fn
    cache and jit's shape cache — no retraces (the stack_instances
    recompile-churn satellite)."""
    offline_grid(max_buckets=2)
    before = compiled_cache_stats()
    offline_grid(max_buckets=2)
    after = compiled_cache_stats()
    assert set(after) == set(before)
    for k in before:
        if before[k] >= 0:                 # -1 = no _cache_size API
            assert after[k] == before[k]


# ---------------------------------------------------------------------------
# policy kind: all five policies, bucketed == max-padded
# ---------------------------------------------------------------------------

def test_policy_bucketed_matches_max_padded():
    insts = hetero_insts()[:4]
    kw = dict(kind="policy", insts=insts, seed=0, n_seeds=S, best_of=BO,
              pdhg_iters=ITERS, episodes=5, backend="vmap")
    ref = run_grid(GridSpec(**kw, max_buckets=1))
    out = run_grid(GridSpec(**kw, max_buckets=2))
    assert len(out.stats["plan"]) == 2
    # lp_obj is a plain einsum over the padded axes — the reduction order
    # (not the decisions) shifts with the padding target, so it carries
    # the usual ~1e-15 float slack rather than bit equality
    np.testing.assert_allclose(ref.stats["lp_obj"], out.stats["lp_obj"],
                               rtol=1e-12)
    for p in CC.OFFLINE_POLICIES:
        for i, inst in enumerate(insts):
            for s in range(S):
                x1, A1, m1 = ref.results[p][i][s]
                x2, A2, m2 = out.results[p][i][s]
                np.testing.assert_array_equal(x1, x2, err_msg=f"{p}[{i},{s}]")
                np.testing.assert_array_equal(A1, A2, err_msg=f"{p}[{i},{s}]")
                assert x1.shape == (inst.N, inst.M, inst.H + 1)
                assert m1 == m2


def test_policy_matches_legacy_policy_grid():
    insts = hetero_insts()[:2]
    stacked = stack_instances(insts)
    uniforms = CC.policy_uniforms(stacked, 0, S, BO)
    gat = CC.gat_grid_policies(stacked, 0, 5)
    dev = CC.policy_grid_device(stacked, seed=0, pdhg_iters=ITERS,
                                best_of=BO, n_seeds=S, uniforms=uniforms,
                                gat=gat)
    res = run_grid(GridSpec(kind="policy", insts=insts, seed=0, n_seeds=S,
                            best_of=BO, pdhg_iters=ITERS, episodes=5,
                            backend="vmap", max_buckets=1))
    for p in CC.OFFLINE_POLICIES:
        for i, inst in enumerate(insts):
            for s in range(S):
                x_n, A_n, _ = res.results[p][i][s]
                np.testing.assert_array_equal(
                    dev[p]["x"][i, s, :inst.N], x_n)
                np.testing.assert_array_equal(
                    dev[p]["A"][i, s, :inst.N, :inst.U], A_n)


# ---------------------------------------------------------------------------
# online kind: shape-bucketed scan grids
# ---------------------------------------------------------------------------

OCFG = OnlineConfig(n_slots=12, rounds=2)


def _online_jobs():
    # twin of benchmarks/bench_scale.py::_online_jobs — the CI bench gates
    # the same mixed-shape grid this asserts on; keep them in sync
    cfg_a = MECConfig(n_bs=3, n_users=40, n_models=4, seed=0)
    cfg_b = MECConfig(n_bs=4, n_users=30, n_models=4, seed=1)
    tr_a = make_trace("stationary", cfg_a, OCFG.n_slots, seed=0)
    tr_b = make_trace("flash_crowd", cfg_b, OCFG.n_slots, seed=1)
    return ([dict(cfg=cfg_a, algo=a, trace=tr_a)
             for a in ("cocar-ol", "lfu", "random")]
            + [dict(cfg=cfg_b, algo=a, trace=tr_b, seed=1)
               for a in ("cocar-ol", "lfu-mad")])


def test_online_bucketed_grid_matches_solo_runs():
    jobs = _online_jobs()
    res = run_grid(GridSpec(kind="online", jobs=jobs, ocfg=OCFG,
                            backend="vmap"))
    assert len(res.results) == len(jobs)
    assert len(res.stats["plan"]) == 2     # two shape buckets
    for j, g in zip(jobs, res.results):
        from repro.core.online import run_online
        solo = run_online(j["trace"], j["algo"], cfg=j["cfg"], ocfg=OCFG,
                          engine="scan", seed=j.get("seed", 0))
        np.testing.assert_array_equal(g["slot_qoe"], solo["slot_qoe"])
        np.testing.assert_array_equal(g["final_state"].lvl,
                                      solo["final_state"].lvl)
        np.testing.assert_array_equal(g["final_state"].O,
                                      solo["final_state"].O)


def test_online_sharded_chunked_matches_vmap():
    jobs = _online_jobs()
    ref = run_grid(GridSpec(kind="online", jobs=jobs, ocfg=OCFG,
                            backend="vmap"))
    out = run_grid(GridSpec(kind="online", jobs=jobs, ocfg=OCFG,
                            backend="sharded", devices=1, chunk_size=2))
    for a, b in zip(ref.results, out.results):
        np.testing.assert_array_equal(a["slot_qoe"], b["slot_qoe"])
        np.testing.assert_array_equal(a["final_state"].lvl,
                                      b["final_state"].lvl)
    assert run_grid(GridSpec(kind="online", jobs=[], ocfg=OCFG)).results \
        == []


# ---------------------------------------------------------------------------
# spec validation + progress reporting
# ---------------------------------------------------------------------------

def test_run_grid_validates_spec():
    with pytest.raises(ValueError, match="unknown grid kind"):
        run_grid(GridSpec(kind="nope", insts=hetero_insts()))
    with pytest.raises(ValueError, match="spec.insts"):
        run_grid(GridSpec(kind="offline", insts=[]))
    with pytest.raises(ValueError, match="spec.jobs"):
        run_grid(GridSpec(kind="online"))
    with pytest.raises(ValueError, match="unknown backend"):
        run_grid(GridSpec(kind="offline", insts=hetero_insts(),
                          backend="tpu"))
    with pytest.raises(ValueError, match="only meaningful"):
        run_grid(GridSpec(kind="offline", insts=hetero_insts(),
                          backend="vmap", devices=8))


def test_progress_callback_sees_every_chunk():
    seen = []
    offline_grid(max_buckets=2, chunk_size=2, progress=seen.append)
    assert len(seen) >= 3                  # 5 instances, 2 buckets, chunk 2
    assert all(ev["batch"] > 0 and ev["seconds"] >= 0 for ev in seen)
    assert {ev["bucket"] for ev in seen} == {(3, 20), (4, 24)}
