"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU, assert output shapes + no NaNs, and check the
serving paths (prefill + decode) agree with the training forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.config import build_plan

ARCHS = configs.ARCH_IDS


def _batch(cfg, key, B, S):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, : S - cfg.frontend_len]
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = configs.get_smoke(arch)
    plan = build_plan(cfg)
    key = jax.random.key(0)
    params = M.init(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    logits, aux = M.apply_train(cfg, params, batch, plan)
    assert len(logits) == cfg.n_exits
    for lg in logits:
        assert lg.shape == (B, S, cfg.padded_vocab)
        assert not np.any(np.isnan(np.asarray(lg))), f"{arch}: NaN logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.launch.steps import init_train_state, make_train_step
    cfg = configs.get_smoke(arch)
    B, S = 2, 32
    key = jax.random.key(1)
    state = init_train_state(cfg, key)
    batch = _batch(cfg, key, B, S)
    batch["labels"] = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size,
                                          batch["tokens"].shape),
        jnp.int32)
    step = jax.jit(make_train_step(cfg))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["grad_norm"]) > 0
    assert int(state2["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(t | prefill(x[:T])) must equal the training forward at the
    same position — exercises every cache type (KV, ring, conv, ssm, lstm)."""
    cfg = configs.get_smoke(arch)
    plan = build_plan(cfg)
    key = jax.random.key(2)
    params = M.init(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)

    logits_all, _ = M.apply_train(cfg, params, batch, plan)
    full = logits_all[-1]                      # (B, S, V) final exit

    cache = M.cache_init(cfg, B, S + 4, plan)
    lg_pref, cache = M.prefill(cfg, params, batch, cache, plan=plan)
    np.testing.assert_allclose(np.asarray(lg_pref), np.asarray(full[:, -1]),
                               atol=2e-3, rtol=2e-3)

    # one decode step == training forward on the extended sequence
    nxt = jnp.argmax(lg_pref, -1)[:, None].astype(jnp.int32)
    lg_dec, cache = M.decode(cfg, params, nxt, jnp.int32(S), cache, plan=plan)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    logits2, _ = M.apply_train(cfg, params, batch2, plan)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(logits2[-1][:, -1]),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_submodel_is_prefix(arch):
    """Serving exit j must equal the training forward's exit-j logits —
    the paper's submodel h_j is literally a prefix + its own head."""
    cfg = configs.get_smoke(arch)
    plan = build_plan(cfg)
    key = jax.random.key(3)
    params = M.init(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    logits_all, _ = M.apply_train(cfg, params, batch, plan)
    for j in range(cfg.n_exits):
        cache = M.cache_init(cfg, B, S, plan)
        lg, _ = M.prefill(cfg, params, batch, cache, exit_idx=j, plan=plan)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_all[j][:, -1]),
                                   atol=2e-3, rtol=2e-3)
