"""Shared conformance harness for the dual-engine test suite.

Every "two engines, one algorithm" test in this repo asserts the same
contract: *decisions* (0/1 cache and routing arrays, winning trial
indices) must be bit-identical across engines, while *objectives and
metrics* — plain float reductions whose summation order may differ —
agree to 1e-9.  This module is the single home of that contract:
instance builders (``make_instance``, ``tiny_instance``, heterogeneous
grids), the identity assertions (``assert_decisions_identical``,
``assert_same_offline``, ``assert_obj_close``), and the rounding
certificates (``decision_margin``, ``threshold_shift_certificate``)
that make the fused mixed-precision LP backend's decision identity
checkable rather than merely observed.

Used by tests/test_offline_batched.py, tests/test_baselines_device.py,
tests/test_scale.py, tests/test_pdhg_fused.py, and
benchmarks/bench_lp.py.
"""
import numpy as np

from repro.core.jdcr import JDCRInstance, tree_sum
from repro.mec.scenario import MECConfig, Scenario, stack_instances


# ---------------------------------------------------------------------------
# instance builders
# ---------------------------------------------------------------------------

def make_instance(seed=0, n_users=40, n_bs=3, n_models=4):
    """One scenario window from a seeded config — the stock random
    instance every dual-engine test starts from."""
    cfg = MECConfig(n_bs=n_bs, n_users=n_users, n_models=n_models, seed=seed)
    sc = Scenario(cfg)
    return sc.instance(0, sc.empty_cache())


def tiny_instance(n_bs=1, m_u=(0, 1), prec2=(0.9, 0.8), R=25.0,
                  ddl=10.0, sizes12=(10.0, 20.0)):
    """Hand-built 2-model, 2-submodel instance for repair edge cases:
    negligible latencies (unless ``ddl`` is shrunk), zero load times."""
    M, H = 2, 2
    U = len(m_u)
    sizes = np.zeros((M, H + 1))
    sizes[:, 1], sizes[:, 2] = sizes12
    prec = np.zeros((M, H + 1))
    prec[:, 1] = np.asarray(prec2) / 2.0
    prec[:, 2] = np.asarray(prec2)
    flops = np.zeros((M, H + 1))
    flops[:, 1:] = 1e-3
    x_prev = np.zeros((n_bs, M, H + 1))
    x_prev[:, :, 0] = 1.0
    return JDCRInstance(
        sizes=sizes, prec=prec, flops=flops,
        loadD=np.zeros((M, H + 1, H + 1)),
        R=np.full(n_bs, R), C=np.full(n_bs, 100.0),
        phi=np.full(n_bs, 100.0), wired=np.full((n_bs, n_bs), 1e12),
        lam=np.zeros((n_bs, n_bs)), m_u=np.asarray(m_u),
        d_u=np.full(U, 0.1), ddl=np.full(U, ddl),
        s_u=np.full(U, 10.0), home=np.zeros(U, dtype=int),
        x_prev=x_prev)


def hetero_insts(spec):
    """A heterogeneous grid from ``[(seed, n_users, n_bs), ...]`` — the
    padded-stack fixture shape every identity test sweeps over."""
    return [make_instance(seed=s, n_users=u, n_bs=n) for s, u, n in spec]


def padded_stack(spec):
    """``(insts, stacked)`` for a heterogeneous grid spec — instances at
    their true shapes plus the max-padded :class:`StackedWindows`."""
    insts = hetero_insts(spec)
    return insts, stack_instances(insts)


# ---------------------------------------------------------------------------
# decision-identity assertions
# ---------------------------------------------------------------------------

def assert_decisions_identical(x_a, A_a, x_b, A_b, msg=""):
    """The core contract: 0/1 cache and routing arrays bit-equal."""
    np.testing.assert_array_equal(np.asarray(x_a), np.asarray(x_b),
                                  err_msg=f"cache decisions differ {msg}")
    np.testing.assert_array_equal(np.asarray(A_a), np.asarray(A_b),
                                  err_msg=f"routing decisions differ {msg}")


def assert_obj_close(a, b, atol=1e-9, msg=""):
    """Objectives/metrics: float reductions, 1e-9 not bit equality."""
    assert abs(float(a) - float(b)) < atol, (msg, float(a), float(b))


def assert_same_offline(a, b):
    """Two ``results[window][seed] = (x, A, info)`` offline grids make
    identical decisions: arrays bit-equal, same winning trial per seed
    (``info`` may be a metrics dict on policy grids — then only the
    arrays are compared)."""
    for per_a, per_b in zip(a, b):
        for (xa, Aa, ia), (xb, Ab, ib) in zip(per_a, per_b):
            assert_decisions_identical(xa, Aa, xb, Ab)
            if isinstance(ia, dict) and "best_t" in ia:
                assert ia["best_t"] == ib["best_t"]


# ---------------------------------------------------------------------------
# the rounding-margin certificate
# ---------------------------------------------------------------------------

def _thresholds(x_frac, A_frac, onehot_mu):
    """The two threshold families Alg. 1's rounding compares uniforms
    against: categorical partial sums ``cums (..., H)`` and Bernoulli
    routing probabilities ``phi (n, u, h)``."""
    x_frac = np.asarray(x_frac, np.float64)
    A_frac = np.asarray(A_frac, np.float64)
    probs = np.clip(x_frac, 0.0, 1.0)
    den = np.maximum(tree_sum(probs, -1), 1e-12)
    probs = probs / den[..., None]
    # the same left-to-right partial sums round_from_uniforms compares
    cums = np.cumsum(probs[..., :-1], axis=-1)
    xa = np.einsum("nmh,um->nuh", x_frac[..., :, :, 1:], onehot_mu)
    phi = np.where(xa > 1e-12, A_frac / np.maximum(xa, 1e-12), 0.0)
    return cums, np.clip(phi, 0.0, 1.0)


def decision_margin(x_frac, A_frac, onehot_mu, u_cat, u_phi):
    """Distance of every rounding uniform to its nearest decision
    threshold, for the given fractional solution.

    Alg. 1 decisions are threshold crossings: the categorical draw
    compares ``u_cat`` against partial sums of the normalized x†[n,m,:],
    the Bernoulli routing draw compares ``u_phi`` against
    φ = clip(A†/x_a, 0, 1).  A perturbed fractional solution (e.g. the
    fused mixed-precision LP backend's, within ``gap`` of the reference)
    moves each threshold by O(gap / min-normalizer); decisions therefore
    cannot flip while the reported margins stay far above that.  This is
    the certificate ``benchmarks/bench_lp.py`` records next to the
    measured fused-vs-reference gap and ``tests/test_pdhg_fused.py``
    asserts on — turning "decisions happened to match" into "decisions
    had slack to spare".

    Returns ``{"cat": float, "phi": float, "min": float}`` (each the
    minimum over all trials and entries; padded users, whose ``phi``
    threshold is pinned at 0, are excluded from the phi margin).
    """
    onehot_mu = np.asarray(onehot_mu, np.float64)
    u_cat = np.asarray(u_cat, np.float64)
    u_phi = np.asarray(u_phi, np.float64)
    cums, phi_p = _thresholds(x_frac, A_frac, onehot_mu)
    margin_cat = float(np.min(np.abs(u_cat[..., None] - cums)))
    user_mask = onehot_mu.sum(-1) > 0                       # (U,)
    d_phi = np.abs(u_phi - phi_p)
    margin_phi = float(np.min(np.where(user_mask[None, :, None],
                                       d_phi, np.inf)))
    return {"cat": margin_cat, "phi": margin_phi,
            "min": min(margin_cat, margin_phi)}


def threshold_shift_certificate(x_ref, A_ref, x_pal, A_pal, onehot_mu,
                                u_cat, u_phi):
    """Per-comparison certificate that two fractional solutions round to
    identical decisions under the given uniforms.

    For every rounding comparison, the uniform's distance to the
    *reference* threshold must exceed the shift of that same threshold
    under the perturbed solution — the uniform then lands on the same
    side of both, so every threshold crossing (and hence the whole
    round → repair → argmax chain, which consumes only the crossings)
    resolves identically.  This is sharper than ``decision_margin``'s
    global minimum: a large fractional gap on a slack threshold and a
    razor-thin margin on an *unmoved* threshold both certify, which is
    what makes the certificate hold at bench scale where the global
    min-margin (a minimum over ~1e5 draws) collapses below the global
    max-gap.

    Returns ``{"certified": bool, "headroom": float}`` — headroom is the
    minimum margin/shift ratio over all moved thresholds (inf when no
    threshold moved); certified requires margin > shift (or shift == 0)
    everywhere.
    """
    onehot_mu = np.asarray(onehot_mu, np.float64)
    u_cat = np.asarray(u_cat, np.float64)
    u_phi = np.asarray(u_phi, np.float64)
    cums_r, phi_r = _thresholds(x_ref, A_ref, onehot_mu)
    cums_p, phi_p = _thresholds(x_pal, A_pal, onehot_mu)
    user_mask = onehot_mu.sum(-1) > 0

    m_cat = np.abs(u_cat[..., None] - cums_r)
    s_cat = np.broadcast_to(np.abs(cums_r - cums_p), m_cat.shape)
    m_phi = np.where(user_mask[None, :, None], np.abs(u_phi - phi_r),
                     np.inf)
    s_phi = np.broadcast_to(np.abs(phi_r - phi_p), m_phi.shape)

    def _family(m, s):
        ok = bool(((s < m) | (s == 0.0)).all())
        moved = s > 0.0
        if not moved.any():
            return ok, float("inf")
        with np.errstate(divide="ignore"):
            ratio = np.where(moved, m / np.maximum(s, 1e-300), np.inf)
        return ok, float(ratio.min())

    ok_c, head_c = _family(m_cat, s_cat)
    ok_p, head_p = _family(m_phi, s_phi)
    return {"certified": ok_c and ok_p,
            "headroom": min(head_c, head_p)}
