"""Batched PDHG + vectorized rounding: the one-dispatch path must agree
with the per-instance oracles (scipy objectives, scalar rounding stats)."""
import numpy as np
import pytest

from repro.core import lp as LP
from repro.core.cocar import cocar_windows_batched
from repro.core.jdcr import check_feasible
from repro.core.rounding import round_solution, round_solution_batch
from repro.mec.scenario import (MECConfig, Scenario, config_grid,
                                stack_instances)


def make_instance(seed=0, n_users=40, n_bs=3, n_models=4):
    cfg = MECConfig(n_bs=n_bs, n_users=n_users, n_models=n_models, seed=seed)
    sc = Scenario(cfg)
    return sc.instance(0, sc.empty_cache())


HETERO = [(0, 40, 3), (1, 50, 4), (2, 35, 3), (3, 30, 2)]


def test_config_grid_cross_product():
    base = MECConfig(n_users=50)
    cfgs = config_grid(base, {"n_bs": (4, 6), "zipf": (0.4, 0.8),
                              "mem_capacity_mb": (300.0, 500.0),
                              "ddl_s": (0.25, 0.35)})
    assert len(cfgs) == 16
    assert len({(c.n_bs, c.zipf, c.mem_capacity_mb, c.ddl_s)
                for c in cfgs}) == 16
    # untouched fields come from the base
    assert all(c.n_users == 50 for c in cfgs)


def test_stack_instances_pads_and_unstacks():
    insts = [make_instance(seed=s, n_users=u, n_bs=n) for s, u, n in HETERO]
    stk = stack_instances(insts)
    N_max = max(i.N for i in insts)
    U_max = max(i.U for i in insts)
    assert stk.data.T.shape == (len(insts), N_max, U_max, insts[0].H)
    # padded BSs have no memory, padded users no precision
    for i, inst in enumerate(insts):
        assert np.all(stk.data.R[i, inst.N:] == 0)
        assert np.all(stk.data.prec_u[i, inst.U:] == 0)
    x = np.zeros((len(insts), N_max, insts[0].M, insts[0].H + 1))
    A = np.zeros((len(insts), N_max, U_max, insts[0].H))
    for (xi, Ai), inst in zip(stk.unstack(x, A), insts):
        assert xi.shape == (inst.N, inst.M, inst.H + 1)
        assert Ai.shape == (inst.N, inst.U, inst.H)


def test_stack_rejects_heterogeneous_catalogs():
    a = make_instance(n_models=4)
    b = make_instance(n_models=5)
    with pytest.raises(ValueError):
        stack_instances([a, b])


def test_batched_pdhg_matches_scipy_per_instance():
    """Every element of a padded heterogeneous stack must reach its own
    HiGHS optimum, exactly like the scalar PDHG path does — and the
    reported objs must match the unstacked solutions (padding holds no
    routing mass)."""
    insts = [make_instance(seed=s, n_users=u, n_bs=n) for s, u, n in HETERO]
    stk = stack_instances(insts)
    res = LP.solve_lp_pdhg_batched(stk.data, iters=3000)
    for i, (inst, (x_f, A_f)) in enumerate(zip(insts,
                                               stk.unstack(res.x, res.A))):
        _, _, obj_ref = LP.solve_lp_scipy(inst)
        obj = inst.objective(A_f)
        assert obj >= obj_ref * 0.97 - 1e-6
        assert obj <= obj_ref * 1.03 + 0.5        # near-feasible overshoot
        assert abs(res.objs[i] - obj) < 1e-4


def test_batched_elements_equal_solo_solves():
    """Padding is inert by construction: each element of a heterogeneous
    stack must reproduce the solo scalar solve of its own instance."""
    insts = [make_instance(seed=s, n_users=u, n_bs=n) for s, u, n in HETERO]
    stk = stack_instances(insts)
    res = LP.solve_lp_pdhg_batched(stk.data, iters=1000)
    for inst, (x_f, A_f) in zip(insts, stk.unstack(res.x, res.A)):
        solo = LP.solve_lp_pdhg(inst, iters=1000)
        np.testing.assert_allclose(x_f, solo.x, atol=1e-4)
        np.testing.assert_allclose(A_f, solo.A, atol=1e-4)


def test_batched_matches_scalar_pdhg():
    """Batch-of-one must be bit-comparable to the scalar jit path."""
    inst = make_instance()
    stk = stack_instances([inst])
    res_b = LP.solve_lp_pdhg_batched(stk.data, iters=1500)
    res_s = LP.solve_lp_pdhg(inst, iters=1500)
    np.testing.assert_allclose(res_b.x[0], res_s.x, atol=1e-5)
    np.testing.assert_allclose(res_b.A[0], res_s.A, atol=1e-5)


def test_round_solution_batch_shapes_and_marginals():
    """Batched trials are iid draws of Alg. 1: caching rows stay one-hot
    and the empirical E[objective] over trials matches the LP objective
    (Lemma 2) just like looping round_solution does."""
    inst = make_instance(n_users=60)
    x_f, A_f, obj = LP.solve_lp_scipy(inst)
    T = 256
    xs, As = round_solution_batch(inst, x_f, A_f, key=0, n_trials=T)
    assert xs.shape == (T, inst.N, inst.M, inst.H + 1)
    assert As.shape == (T, inst.N, inst.U, inst.H)
    assert np.allclose(xs.sum(-1), 1.0)
    vals = [inst.objective(A) for A in As]
    se = np.std(vals) / np.sqrt(T)
    assert abs(np.mean(vals) - obj) < max(5 * se, 0.05 * obj)
    # scalar wrapper is the T=1 special case
    x1, A1 = round_solution(inst, x_f, A_f, key=0)
    assert x1.shape == (inst.N, inst.M, inst.H + 1)
    assert A1.shape == (inst.N, inst.U, inst.H)


def test_batched_rounding_matches_scalar_statistically():
    """Vectorized best_of draws and the scalar loop agree on the rounding
    distribution under a fixed overall budget of draws."""
    inst = make_instance(n_users=50)
    x_f, A_f, _ = LP.solve_lp_scipy(inst)
    _, As = round_solution_batch(inst, x_f, A_f, key=7, n_trials=200)
    batch_vals = np.array([inst.objective(A) for A in As])
    scalar_vals = np.array([inst.objective(
        round_solution(inst, x_f, A_f, key=1000 + s)[1]) for s in range(200)])
    pooled = np.sqrt(batch_vals.var() / 200 + scalar_vals.var() / 200)
    assert abs(batch_vals.mean() - scalar_vals.mean()) < 5 * pooled


def test_cocar_windows_batched_end_to_end():
    insts = [make_instance(seed=s, n_users=u, n_bs=n) for s, u, n in HETERO]
    outs = cocar_windows_batched(insts, seed=0, pdhg_iters=2000, best_of=4)
    assert len(outs) == len(insts)
    for inst, (x, A, info) in zip(insts, outs):
        assert check_feasible(inst, x, A)["ok"]
        assert info["lp_obj"] > 0


def test_sweep_grid_one_dispatch():
    """The default 16-variant sweep solves through a single vmapped
    dispatch and returns one metrics row per variant."""
    from repro.experiments.sweep import DEFAULT_AXES, run_sweep
    rows = run_sweep(base=MECConfig(n_users=30), pdhg_iters=800, best_of=2)
    n_variants = int(np.prod([len(v) for v in DEFAULT_AXES.values()]))
    assert len(rows) == n_variants >= 16
    for row in rows:
        assert set(DEFAULT_AXES) <= set(row)
        assert 0.0 <= row["hit_rate"] <= 1.0
        assert row["lp_obj"] > 0
