"""Executable checks of the paper's Theorems 1–5 against rounding draws."""
import pytest

from repro.core import lp as LP
from repro.core import theory
from repro.mec.scenario import MECConfig, Scenario


@pytest.fixture(scope="module")
def solved():
    cfg = MECConfig(n_users=200, seed=4)
    sc = Scenario(cfg)
    inst = sc.instance(0, sc.empty_cache())
    x_f, A_f, obj = LP.solve_lp_scipy(inst)
    return inst, x_f, A_f, obj


def test_theorem1_holds_empirically(solved):
    """Obj >= (1-δ)² P† for ≥90% of draws (Thm 1: w.h.p.)."""
    inst, x_f, A_f, obj = solved
    ratio = theory.theorem1_ratio(inst, obj)
    if ratio is None:
        pytest.skip("outside the theorem regime (P+ < 4 ln|H|)")
    from repro.core.rounding import round_solution
    ok = 0
    n = 50
    for s in range(n):
        _, A_i = round_solution(inst, x_f, A_f, s)
        if inst.objective(A_i) >= ratio * obj:
            ok += 1
    assert ok >= 0.9 * n, (ok, n, ratio)


def test_theorem2_memory_violation_bounded(solved):
    """Rounded memory never exceeds R by more than Thm 2's factor."""
    inst, x_f, A_f, obj = solved
    emp = theory.empirical_violations(inst, x_f, A_f, draws=100)
    b = theory.bounds(inst, x_f, A_f, obj)
    # the theorem factor is loose; the empirical max must sit below it
    assert emp["memory_factor_max"] <= max(b["thm2_memory_factor"]) + 0.5
    # Lemma 1: each BS's EXPECTED memory use respects its capacity
    assert max(emp["memory_expectation_per_bs"]) <= 1.05


def test_route_violation_small(solved):
    """Σ_nh Ã <= small constant (Thm 3 regime: η† <= 1)."""
    inst, x_f, A_f, _ = solved
    emp = theory.empirical_violations(inst, x_f, A_f, draws=100)
    assert emp["route_max"] <= 4


def test_objective_concentrates(solved):
    """Lemma 2 + concentration: std/mean of the rounded objective is small."""
    inst, x_f, A_f, obj = solved
    emp = theory.empirical_violations(inst, x_f, A_f, draws=100)
    assert abs(emp["obj_mean"] - obj) / obj < 0.05
    assert emp["obj_std"] / emp["obj_mean"] < 0.2
