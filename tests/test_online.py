"""CoCaR-OL: download state machine (Eqs. 35–37), QoE routing, knapsack
fitting, and end-to-end ordering vs baselines."""
import numpy as np
import pytest

from repro.core.online import OnlineConfig, OnlineSim, run_online
from repro.mec.scenario import MECConfig


def make_sim(**kw):
    ocfg = OnlineConfig(**kw)
    cfg = MECConfig(n_users=100)
    return OnlineSim(cfg, ocfg), ocfg


def test_download_state_machine_sequential():
    """Eq. 35: submodels download in order and become servable the slot
    their Δ completes (Eq. 37)."""
    sim, ocfg = make_sim(n_slots=10)
    n, m = 0, 0
    s = sim.sc.sizes
    # enqueue an upgrade h0 -> h2 (two deltas)
    sim.O[n, m, 0] = s[m, 1]
    sim.O[n, m, 1] = s[m, 2] - s[m, 1]
    budget = sim.W[n] * ocfg.slot_s
    slots_h1 = int(np.ceil(s[m, 1] / budget))
    for t in range(slots_h1):
        assert np.argmax(sim.X[n, m]) == 0
        sim.routine_update()
    assert np.argmax(sim.X[n, m]) == 1          # h1 live after its delta
    total_slots = int(np.ceil(s[m, 2] / budget))
    for t in range(total_slots - slots_h1):
        sim.routine_update()
    assert np.argmax(sim.X[n, m]) == 2          # then h2


def test_shrink_is_immediate():
    sim, _ = make_sim(n_slots=10)
    sim.X[0, 0, :] = 0
    sim.X[0, 0, 3] = 1
    X_hyp, shrunk = sim._fit(0, 1, 3)
    assert X_hyp is not None
    # applying a shrink never leaves memory violated
    used = (X_hyp[0] * sim.sc.sizes).sum()
    assert used <= sim.sc.R[0] + 1e-9


def test_route_respects_deadline():
    sim, _ = make_sim(n_slots=10)
    q, lat = sim.qoe_matrix()
    assert np.all(q[lat > sim.cfg.ddl_s] == 0)


def test_qoe_decays_with_latency():
    sim, _ = make_sim(n_slots=10)
    sim.X[:, :, :] = 0
    sim.X[:, :, 1] = 1                           # everything cached small
    q, lat = sim.qoe_matrix()
    # farther targets (higher latency) never yield higher QoE for the same
    # cached submodel
    m = 0
    for nh in range(sim.N):
        order = np.argsort(lat[nh, :, m])
        qs = q[nh, order, m]
        assert np.all(np.diff(qs) <= 1e-9)


def test_partition_beats_no_partition():
    from repro.traces.registry import default_workload
    cfg = MECConfig(n_users=150)
    ocfg_p = OnlineConfig(n_slots=50)
    ocfg_np = OnlineConfig(n_slots=50, partition=False)
    r_p = run_online(default_workload(cfg, ocfg_p), "cocar-ol",
                     cfg=cfg, ocfg=ocfg_p, engine="numpy")
    r_np = run_online(default_workload(cfg, ocfg_np), "cocar-ol",
                      cfg=cfg, ocfg=ocfg_np, engine="numpy")
    assert r_p["avg_qoe"] > r_np["avg_qoe"]


def test_cocarol_beats_lfu_and_random():
    from repro.traces.registry import default_workload
    cfg = MECConfig(n_users=150)
    ocfg = OnlineConfig(n_slots=50)
    wl = default_workload(cfg, ocfg)
    r = {a: run_online(wl, a, cfg=cfg, ocfg=ocfg, engine="numpy")
         for a in ("cocar-ol", "lfu", "random")}
    assert r["cocar-ol"]["avg_qoe"] > r["lfu"]["avg_qoe"]
    assert r["cocar-ol"]["avg_qoe"] > r["random"]["avg_qoe"]


def test_all_policies_replay_identical_stream():
    """Fairness/determinism: the request trace is pre-drawn from its own
    key, so no policy's RNG consumption can perturb another's stream."""
    cfg = MECConfig(n_users=100)
    ocfg = OnlineConfig(n_slots=15, pop_change_every=5)
    sims = {a: OnlineSim(cfg, ocfg) for a in ("cocar-ol", "lfu", "random")}
    ref = sims["cocar-ol"].trace
    for sim in sims.values():
        np.testing.assert_array_equal(sim.trace.model, ref.model)
        np.testing.assert_array_equal(sim.trace.home, ref.home)
    # and run_online itself is a pure function of (cfg, ocfg, algo, seed)
    from repro.traces.registry import default_workload
    wl = default_workload(cfg, ocfg)
    r1 = run_online(wl, "lfu", cfg=cfg, ocfg=ocfg, engine="numpy", seed=3)
    r2 = run_online(wl, "lfu", cfg=cfg, ocfg=ocfg, engine="numpy", seed=3)
    assert r1["avg_qoe"] == r2["avg_qoe"]
    assert r1["hit_rate"] == r2["hit_rate"]
    np.testing.assert_array_equal(r1["slot_qoe"], r2["slot_qoe"])


def test_run_online_custom_trace():
    """run_online accepts any registered trace family."""
    from repro.traces import make_trace
    cfg = MECConfig(n_users=80)
    ocfg = OnlineConfig(n_slots=10)
    tr = make_trace("flash_crowd", cfg, ocfg.n_slots, seed=1, n_events=1,
                    duration=5)
    r = run_online(tr, "cocar-ol", cfg=cfg, ocfg=ocfg, engine="numpy")
    assert 0 <= r["avg_qoe"] <= 1 and 0 <= r["hit_rate"] <= 1


def test_trace_shape_mismatch_rejected():
    """A trace whose length/width doesn't match the run is an error, not a
    silently mis-normalized avg QoE."""
    from repro.traces import make_trace
    cfg = MECConfig(n_users=80)
    ocfg = OnlineConfig(n_slots=10)
    long_tr = make_trace("stationary", cfg, 40, seed=0)
    with pytest.raises(ValueError):
        run_online(long_tr, "lfu", cfg=cfg, ocfg=ocfg, engine="numpy")
    with pytest.raises(ValueError):
        run_online(long_tr, "lfu", cfg=cfg, ocfg=ocfg, engine="scan")
    thin = MECConfig(n_users=50)
    with pytest.raises(ValueError):
        run_online(make_trace("stationary", cfg, 10, seed=0), "lfu",
                   cfg=thin, ocfg=ocfg, engine="numpy")


def test_scan_backend_matches_numpy_backend():
    from repro.traces.registry import default_workload
    cfg = MECConfig(n_users=60)
    ocfg = OnlineConfig(n_slots=20)
    wl = default_workload(cfg, ocfg)
    for algo in ("cocar-ol", "random"):
        a = run_online(wl, algo, cfg=cfg, ocfg=ocfg, engine="numpy")
        b = run_online(wl, algo, cfg=cfg, ocfg=ocfg, engine="scan")
        assert abs(a["avg_qoe"] - b["avg_qoe"]) < 1e-9
        assert abs(a["hit_rate"] - b["hit_rate"]) < 1e-9


def test_memory_never_violated():
    cfg = MECConfig(n_users=100)
    ocfg = OnlineConfig(n_slots=30)
    sim = OnlineSim(cfg, ocfg)
    rng = np.random.default_rng(0)
    for t in range(ocfg.n_slots):
        sim.routine_update()
        m_u, home = sim.draw_slot_requests(t)
        counts = np.zeros((sim.N, sim.M))
        np.add.at(counts, (home, m_u), 1.0)
        sim.hist.append(counts)
        for n in rng.integers(0, sim.N, size=ocfg.rounds):
            sim.adjust_bs(n)
        # resident + in-flight targets must fit
        for n in range(sim.N):
            used = (sim.X[n] * sim.sc.sizes).sum()
            for m in range(sim.M):
                if sim.O[n, m].sum() > 0:
                    tgt = sim.target[n, m]
                    cur = int(np.argmax(sim.X[n, m]))
                    used += sim.sc.sizes[m, tgt] - sim.sc.sizes[m, cur]
            assert used <= sim.sc.R[n] * 1.001, (t, n, used)
