"""CoCaR core: LP solver equivalence, rounding guarantees (Lemmas 1–2 as
statistical tests), repair feasibility — including hypothesis property tests
over random JDCR instances."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - single-example fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import lp as LP
from repro.core.cocar import cocar_window
from repro.core.jdcr import check_feasible
from repro.core.rounding import repair, round_solution
from repro.mec.scenario import MECConfig, Scenario


def small_instance(seed=0, n_users=60, n_bs=3, n_models=4):
    cfg = MECConfig(n_bs=n_bs, n_users=n_users, n_models=n_models, seed=seed)
    sc = Scenario(cfg)
    return sc.instance(0, sc.empty_cache())


def warm_instance(seed=0, n_users=60, n_bs=3, n_models=4):
    cfg = MECConfig(n_bs=n_bs, n_users=n_users, n_models=n_models, seed=seed)
    sc = Scenario(cfg)
    inst = sc.instance(0, sc.empty_cache())
    x, A, _ = cocar_window(inst, seed=seed)
    return sc.instance(1, x)


def test_lp_scipy_feasible_fractional():
    inst = small_instance()
    x, A, obj = LP.solve_lp_scipy(inst)
    assert obj > 0
    res = check_feasible(inst, x, A, atol=1e-6)
    assert res["ok"], res


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pdhg_matches_scipy(seed):
    """Property: the JAX PDHG solver reaches the HiGHS optimum."""
    inst = small_instance(seed=seed, n_users=40)
    _, _, obj_ref = LP.solve_lp_scipy(inst)
    res = LP.solve_lp_pdhg(inst, iters=3000)
    assert res.obj >= obj_ref * 0.97 - 1e-6
    assert res.obj <= obj_ref * 1.03 + 0.5          # near-feasible overshoot


def test_rounding_expectation_matches_lp():
    """Lemma 2: E[rounded objective] == LP objective (statistical)."""
    inst = warm_instance()
    x_f, A_f, obj = LP.solve_lp_scipy(inst)
    vals = []
    for s in range(200):
        _, A_i = round_solution(inst, x_f, A_f, s)
        vals.append(inst.objective(A_i))
    mean = np.mean(vals)
    se = np.std(vals) / np.sqrt(len(vals))
    assert abs(mean - obj) < max(5 * se, 0.05 * obj), (mean, obj, se)


def test_rounding_one_submodel_per_type():
    """Constraint (1) holds for every rounded draw by construction."""
    inst = small_instance()
    x_f, A_f, _ = LP.solve_lp_scipy(inst)
    for s in range(20):
        x_i, A_i = round_solution(inst, x_f, A_f, s)
        assert np.allclose(x_i.sum(-1), 1.0)
        assert np.all(A_i <= x_i[:, inst.m_u, 1:] + 1e-9)   # (14)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_repair_always_feasible(seed):
    """Property: repair output satisfies every constraint of P1."""
    inst = small_instance(seed=seed % 17, n_users=50)
    x_f, A_f, _ = LP.solve_lp_scipy(inst)
    x_i, A_i = round_solution(inst, x_f, A_f, seed)
    x, A = repair(inst, x_i, A_i)
    res = check_feasible(inst, x, A, atol=1e-6)
    assert res["ok"], res


def test_cocar_beats_random_and_greedy():
    from repro.core import baselines as BL
    from repro.mec import metrics as MET
    inst = warm_instance(n_users=120)
    x, A, _ = cocar_window(inst, seed=0)
    m_c = MET.window_metrics(inst, x, A)
    for fn in (lambda: BL.greedy(inst), lambda: BL.random_policy(inst, 0)):
        xb, Ab = fn()
        m_b = MET.window_metrics(inst, xb, Ab)
        assert m_c["avg_precision"] >= m_b["avg_precision"]


def test_cocar_near_lr_bound():
    """At paper-like scale (concentration regime, P† >> 4ln|H|) CoCaR lands
    near the LR bound — the paper reports a 7.5% gap at full scale."""
    inst = warm_instance(n_users=200, n_bs=5, n_models=8)
    _, _, obj = LP.solve_lp_scipy(inst)
    best = 0.0
    for s in range(3):
        x, A, _ = cocar_window(inst, seed=s)
        from repro.mec import metrics as MET
        best = max(best, MET.window_metrics(inst, x, A)["precision_sum"])
    assert best >= 0.75 * obj, (best, obj)


def test_approximation_ratio_theorem1():
    """Thm 1: rounded objective ≥ (1-δ)² P† w.h.p. when P† ≥ 4 ln|H|."""
    inst = warm_instance(n_users=200)
    x_f, A_f, obj = LP.solve_lp_scipy(inst)
    n_sub = inst.M * inst.H
    delta = np.sqrt(4 * np.log(n_sub) / obj)
    if delta >= 1:
        pytest.skip("P+ too small for the theorem's regime")
    bound = (1 - delta) ** 2 * obj
    ok = 0
    for s in range(20):
        _, A_i = round_solution(inst, x_f, A_f, s)
        if inst.objective(A_i) >= bound:
            ok += 1
    assert ok >= 18, f"bound {bound:.2f} met only {ok}/20 times"
