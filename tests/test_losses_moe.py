"""Loss-path and MoE invariants (property tests included)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - single-example fallback
    from _hypothesis_fallback import given, settings, st

from repro import configs
from repro.launch.steps import chunked_exit_ce, cross_entropy
from repro.models import model as M
from repro.models.layers import exit_head_fwd


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.sampled_from([16, 24, 64]),
       seed=st.integers(0, 100))
def test_chunked_ce_equals_plain(b, s, seed):
    """The memory-optimized chunked CE must equal the direct computation."""
    cfg = configs.get_smoke("qwen1.5-0.5b")
    key = jax.random.key(seed)
    params = M.init(cfg, key)
    h = jax.random.normal(key, (b, s, cfg.d_model))
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    head = params["exits"][0]
    plain = cross_entropy(exit_head_fwd(cfg, head, h), labels)
    chunked = chunked_exit_ce(cfg, head, h, labels, chunk=8)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(chunked),
                               atol=1e-5, rtol=1e-5)


def test_ce_ignore_index():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, 2, -1, -1]])
    # uniform logits: CE = log(8) on the 2 valid tokens
    np.testing.assert_allclose(float(cross_entropy(logits, labels)),
                               np.log(8), atol=1e-6)


def test_moe_group_padding_consistent():
    """Routing decisions must not depend on padding to the group size."""
    from repro.models.moe import moe_fwd, moe_init
    cfg = configs.get_smoke("mixtral-8x7b")
    key = jax.random.key(0)
    p = moe_init(key, cfg)
    x33 = jax.random.normal(key, (2, 33, cfg.d_model))
    out33, _ = moe_fwd(cfg, p, x33)
    out32, _ = moe_fwd(cfg, p, x33[:, :32])
    # shared prefix tokens agree (same groups, pads excluded from capacity)
    np.testing.assert_allclose(np.asarray(out33[:, :32]),
                               np.asarray(out32), atol=2e-5, rtol=2e-5)


def test_moe_outputs_finite_and_sparse():
    from repro.models.moe import moe_fwd, moe_init
    cfg = configs.get_smoke("mixtral-8x22b")
    key = jax.random.key(1)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    out, aux = moe_fwd(cfg, p, x)
    assert np.all(np.isfinite(np.asarray(out)))
    assert float(aux) >= 1.0 - 1e-6          # E * sum(me*ce) >= 1 at balance


def test_flash_threshold_boundary():
    """attend() must be continuous across the dense/flash dispatch size."""
    from repro.models.layers import attend
    key = jax.random.key(2)
    B, H, K, E = 1, 4, 2, 32
    for S in (1024, 2048, 4096):
        q = jax.random.normal(key, (B, S, H, E))
        k = jax.random.normal(key, (B, S, K, E))
        v = jax.random.normal(key, (B, S, K, E))
        out = attend(q, k, v, causal=True)
        assert out.shape == (B, S, H * E)
        assert np.all(np.isfinite(np.asarray(out[:, -1])))
