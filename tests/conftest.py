import os
import sys

# tests see the single real CPU device (the 512-device override is ONLY for
# launch/dryrun.py, which sets XLA_FLAGS itself before importing jax)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make _hypothesis_fallback importable from test modules
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
