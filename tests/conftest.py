import os
import sys

# tests see the single real CPU device (the 512-device override is ONLY for
# launch/dryrun.py, which sets XLA_FLAGS itself before importing jax)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make _hypothesis_fallback importable from test modules
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# Hard per-test timeout (seconds), enabled by REPRO_TEST_TIMEOUT (CI sets
# it; unset locally).  A hung XLA dispatch never returns control to the
# Python signal machinery, so a plain SIGALRM handler cannot fail the test
# — faulthandler's watchdog thread dumps every stack and kills the process
# instead, which is exactly the "fail fast with a traceback" CI wants.
_TEST_TIMEOUT = float(os.environ.get("REPRO_TEST_TIMEOUT", "0") or 0)


@pytest.fixture(autouse=_TEST_TIMEOUT > 0)
def _per_test_timeout():
    import faulthandler

    faulthandler.dump_traceback_later(_TEST_TIMEOUT, exit=True)
    yield
    faulthandler.cancel_dump_traceback_later()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
