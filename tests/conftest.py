import os
import sys

# tests see the single real CPU device (the 512-device override is ONLY for
# launch/dryrun.py, which sets XLA_FLAGS itself before importing jax)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make _hypothesis_fallback importable from test modules
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# Hard per-test timeout (seconds), enabled by REPRO_TEST_TIMEOUT (CI sets
# it; unset locally).  A hung XLA dispatch never returns control to the
# Python signal machinery, so a plain SIGALRM handler cannot fail the test
# — faulthandler's watchdog thread dumps every stack and kills the process
# instead, which is exactly the "fail fast with a traceback" CI wants.
#
# Tests that legitimately need longer (big one-off compiles, e.g. the
# Pallas interpret-mode kernels) mark themselves with
# ``@pytest.mark.slow_compile`` (timeout × 3) or
# ``@pytest.mark.timeout_factor(k)`` — the budget scales instead of the
# watchdog being disabled, so a genuine hang still dies, just later.
_TEST_TIMEOUT = float(os.environ.get("REPRO_TEST_TIMEOUT", "0") or 0)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow_compile: triple the REPRO_TEST_TIMEOUT watchdog "
        "budget (one-off heavy jit/interpret compiles)")
    config.addinivalue_line(
        "markers", "timeout_factor(k): scale the REPRO_TEST_TIMEOUT "
        "watchdog budget by k for this test")


@pytest.fixture(autouse=_TEST_TIMEOUT > 0)
def _per_test_timeout(request):
    import faulthandler

    budget = _TEST_TIMEOUT
    if request.node.get_closest_marker("slow_compile") is not None:
        budget *= 3.0
    factor = request.node.get_closest_marker("timeout_factor")
    if factor is not None and factor.args:
        budget *= float(factor.args[0])
    faulthandler.dump_traceback_later(budget, exit=True)
    yield
    faulthandler.cancel_dump_traceback_later()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
