"""Integration test for the multi-pod dry-run (deliverable e), run in a
subprocess because the 512-device XLA override must precede jax's first
initialization (the main test process already initialized 1 CPU device)."""
import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.parametrize("arch,shape,multi", [
    ("xlstm-125m", "decode_32k", False),
    ("zamba2-1.2b", "long_500k", True),
])
def test_dryrun_cell_compiles(tmp_path, arch, shape, multi):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--force", "--out", str(tmp_path)]
    if multi:
        cmd.append("--multi-pod")
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in ("XLA_FLAGS",)})
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env=env, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    mesh = "2x16x16" if multi else "16x16"
    rec = json.loads((tmp_path / mesh / f"{arch}__{shape}.json").read_text())
    assert rec["ok"] is True
    assert rec["flops_per_device"] > 0
    assert rec["collective_bytes_per_device"] >= 0
    assert "peak_bytes_per_device" in rec


def test_skip_cell_documented(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
           "stablelm-12b", "--shape", "long_500k", "--force",
           "--out", str(tmp_path)]
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=300,
                       env=env, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(
        (tmp_path / "16x16" / "stablelm-12b__long_500k.json").read_text())
    assert rec["ok"] is None and "attention" in rec["skipped"]
