"""Distribution layer: spec coverage, divisibility fallbacks, hint no-ops,
HLO analyzer correctness, and a real (tiny-mesh) sharded train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.distribution import sharding as shd
from repro.models import model as M


def fake_mesh(data=16, model=16):
    """Abstract 256-'device' mesh for spec construction only (no compile)."""
    import types
    m = types.SimpleNamespace()
    m.shape = {"data": data, "model": model}
    return m


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_specs_cover_and_rank(arch):
    cfg = configs.get_config(arch)
    shapes = jax.eval_shape(lambda: M.init(cfg, jax.random.key(0)))
    specs = shd.param_specs(cfg, fake_mesh(), shapes)
    flat_s, _ = jax.tree_util.tree_flatten(shapes)
    flat_p, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        # every sharded dim must divide (or the rule must have fallen back)
        for dim, ax in enumerate(spec):
            if ax == "model":
                assert leaf.shape[dim] % 16 == 0, (arch, leaf.shape, spec, dim)
            if ax == "data":
                assert leaf.shape[dim] % 16 == 0, (arch, leaf.shape, spec, dim)


def test_qwen3_heads_padded_and_sharded():
    """40 heads % 16 != 0 -> §Perf pads q-heads to 48 so wq shards over
    'model' (48·128 = 6144 divides 16); whisper (12 heads, no clean pad
    with K=12) falls back to no 'model' on wq."""
    cfg = configs.get_config("qwen3-14b")
    assert cfg.n_heads_padded == 48
    shapes = jax.eval_shape(lambda: M.init(cfg, jax.random.key(0)))
    specs = shd.param_specs(cfg, fake_mesh(), shapes)
    wq = specs["segments"][0]["attn"]["wq"]
    assert "model" in tuple(wq)
    cfg_w = configs.get_config("whisper-small")
    shapes_w = jax.eval_shape(lambda: M.init(cfg_w, jax.random.key(0)))
    specs_w = shd.param_specs(cfg_w, fake_mesh(), shapes_w)
    assert "model" not in tuple(specs_w["segments"][0]["attn"]["wq"])


def test_padded_heads_outputs_identical():
    """Zero-weight padded heads must not change the model's outputs."""
    import jax.numpy as jnp
    cfg0 = configs.get_smoke("qwen3-14b")
    cfg1 = cfg0.replace(q_head_pad=8)          # 4 -> 8 heads
    k = jax.random.key(0)
    p1 = M.init(cfg1, k)
    # build the unpadded params by slicing the padded ones
    p0 = jax.tree.map(lambda x: x, p1)
    H, Hp, E = cfg0.n_heads, cfg1.n_heads_padded, cfg0.head_dim
    K = cfg0.n_kv_heads
    G, Gp = H // K, Hp // K
    D = cfg0.d_model
    for seg in p0["segments"]:
        wq = seg["attn"]["wq"]                   # (L, D, Hp*E)
        L = wq.shape[0]
        seg["attn"]["wq"] = wq.reshape(L, D, K, Gp, E)[:, :, :, :G] \
            .reshape(L, D, H * E)
        wo = seg["attn"]["wo"]                   # (L, Hp*E, D)
        seg["attn"]["wo"] = wo.reshape(L, K, Gp, E, D)[:, :, :G] \
            .reshape(L, H * E, D)
    batch = {"tokens": jnp.arange(2 * 32).reshape(2, 32) % cfg0.vocab_size}
    l0, _ = M.apply_train(cfg0, p0, batch)
    l1, _ = M.apply_train(cfg1, p1, batch)
    np.testing.assert_allclose(np.asarray(l0[-1]), np.asarray(l1[-1]),
                               atol=1e-5, rtol=1e-5)


def test_hint_is_noop_without_mesh():
    x = jnp.ones((4, 8, 16))
    y = shd.hint_btd(x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_batch_dim_spec_divisibility():
    m = fake_mesh(4, 2)
    assert shd.batch_dim_spec(m, 8) == ("data",)
    assert shd.batch_dim_spec(m, 1) is None
    assert shd.batch_dim_spec(m, 6) is None


def test_hlo_analyzer_scan_trip_counts():
    from repro.launch.hlo_analysis import analyse_hlo

    def loop(x, w):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    hlo = jax.jit(loop).lower(x, w).compile().as_text()
    r = analyse_hlo(hlo)
    assert r["flops"] == pytest.approx(8 * 2 * 256 ** 3, rel=0.01)


def test_hlo_analyzer_collectives():
    from repro.launch.hlo_analysis import analyse_hlo
    if jax.device_count() < 1:
        pytest.skip("no devices")
    # single-device program: no collectives
    hlo = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text()
    r = analyse_hlo(hlo)
    assert r["collective_bytes"] == 0


def test_sharded_train_step_tiny_mesh():
    """End-to-end pjit train step on a real 1x1 mesh (CPU) using the
    production sharding rules."""
    from repro.launch.steps import init_train_state, make_train_step
    cfg = configs.get_smoke("stablelm-12b")
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shapes = jax.eval_shape(lambda: M.init(cfg, jax.random.key(0)))
    pspec = shd.param_specs(cfg, mesh, shapes)
    psh = shd.named(mesh, pspec)
    with mesh:
        state = init_train_state(cfg, jax.random.key(0))
        state = {"params": jax.device_put(state["params"], psh),
                 "opt": state["opt"]}
        batch = {
            "tokens": jnp.zeros((2, 32), jnp.int32),
            "labels": jnp.zeros((2, 32), jnp.int32),
        }
        step = jax.jit(make_train_step(cfg))
        state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
