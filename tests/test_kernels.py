"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU (the TPU lowering path is identical)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,H,K,S,T,E,causal,window", [
    (2, 8, 4, 256, 256, 32, True, 0),
    (1, 4, 4, 256, 256, 64, True, 64),
    (1, 6, 2, 128, 384, 32, True, 0),
    (1, 4, 4, 128, 128, 32, False, 0),
    (2, 4, 1, 128, 256, 16, True, 0),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, K, S, T, E, causal, window, dtype):
    ks = jax.random.split(jax.random.key(S + T + E + H), 3)
    q = jax.random.normal(ks[0], (B, H, S, E), dtype)
    k = jax.random.normal(ks[1], (B, K, T, E), dtype)
    v = jax.random.normal(ks[2], (B, K, T, E), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,K,T,E,L", [
    (2, 8, 4, 512, 64, 300),
    (1, 16, 2, 1024, 32, 1024),
    (3, 4, 4, 256, 128, 1),
    (1, 8, 8, 256, 64, 255),               # MHA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, K, T, E, L, dtype):
    ks = jax.random.split(jax.random.key(T + E + L), 3)
    q = jax.random.normal(ks[0], (B, H, E), dtype)
    k = jax.random.normal(ks[1], (B, T, K, E), dtype)
    v = jax.random.normal(ks[2], (B, T, K, E), dtype)
    out = ops.decode_attention(q, k, v, jnp.int32(L), block_k=128)
    r = ref.decode_attention_ref(q, k, v, L)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,NC,c,P,N", [
    (2, 4, 4, 32, 16, 16),
    (1, 2, 8, 64, 32, 32),
    (1, 1, 16, 128, 64, 64),               # production tile shape
])
def test_ssm_chunk_scan(B, H, NC, c, P, N):
    ks = jax.random.split(jax.random.key(c + P + NC), 4)
    xb = jax.random.normal(ks[0], (B, H, NC, c, P))
    Bc = jax.random.normal(ks[1], (B, NC, c, N))
    Cc = jax.random.normal(ks[2], (B, NC, c, N))
    cum = -jnp.cumsum(
        jax.nn.softplus(jax.random.normal(ks[3], (B, H, NC, c))), -1) * 0.1
    y, st = ops.ssm_chunk_scan(xb, Bc, Cc, cum)
    yr, sr = ref.ssm_chunk_scan_ref(xb, Bc, Cc, cum)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(st, sr, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,D,V", [(64, 128, 512), (32, 64, 256),
                                   (256, 256, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_early_exit_head(T, D, V, dtype):
    ks = jax.random.split(jax.random.key(T + D + V), 3)
    h = jax.random.normal(ks[0], (T, D), dtype)
    nw = (jnp.abs(jax.random.normal(ks[1], (D,))) + 0.5).astype(dtype)
    W = jax.random.normal(ks[2], (D, V), dtype)
    tok, conf = ops.early_exit_head(h, nw, W, block_t=32, block_v=128)
    tr, cr = ref.early_exit_head_ref(h, nw, W)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(tr))
        np.testing.assert_allclose(conf, cr, atol=1e-5, rtol=1e-5)
    else:
        # bf16: ties may flip the argmax; confidences must still agree
        agree = np.mean(np.asarray(tok) == np.asarray(tr))
        assert agree > 0.95
        np.testing.assert_allclose(np.asarray(conf, np.float32),
                                   np.asarray(cr, np.float32),
                                   atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("E,C,D,F", [
    (4, 64, 128, 256), (8, 128, 512, 256), (2, 32, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(E, C, D, F, dtype):
    ks = jax.random.split(jax.random.key(E + C + D), 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    out = ops.moe_gmm(x, w, block_c=32, block_f=64, block_d=64)
    r = ref.moe_gmm_ref(x, w)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# explicit interpret=True: every kernel module must honour the flag directly
# (the auto-select path above infers it from the platform; CI pins it so a
# TPU-hosted run still exercises the interpreter-validated semantics)
# ---------------------------------------------------------------------------

def test_flash_attention_interpret_explicit():
    from repro.kernels import flash_attention as _fa
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = _fa.flash_attention(q, k, v, block_q=64, block_k=64,
                              interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(out, r, atol=2e-5, rtol=2e-5)


def test_decode_attention_interpret_explicit():
    from repro.kernels import decode_attention as _dec
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (1, 4, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    out = _dec.decode_attention(q, k, v, jnp.int32(100), block_k=128,
                                interpret=True)
    r = ref.decode_attention_ref(q, k, v, 100)
    np.testing.assert_allclose(out, r, atol=2e-5, rtol=2e-5)


def test_ssm_chunk_scan_interpret_explicit():
    from repro.kernels import ssm_scan as _ssm
    ks = jax.random.split(jax.random.key(9), 4)
    xb = jax.random.normal(ks[0], (1, 2, 4, 32, 16))
    Bc = jax.random.normal(ks[1], (1, 4, 32, 16))
    Cc = jax.random.normal(ks[2], (1, 4, 32, 16))
    cum = -jnp.cumsum(
        jax.nn.softplus(jax.random.normal(ks[3], (1, 2, 4, 32))), -1) * 0.1
    y, st = _ssm.ssm_chunk_scan(xb, Bc, Cc, cum, interpret=True)
    yr, sr = ref.ssm_chunk_scan_ref(xb, Bc, Cc, cum)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(st, sr, atol=1e-4, rtol=1e-4)


def test_early_exit_head_interpret_explicit():
    from repro.kernels import early_exit as _ee
    ks = jax.random.split(jax.random.key(10), 3)
    h = jax.random.normal(ks[0], (32, 64))
    nw = jnp.abs(jax.random.normal(ks[1], (64,))) + 0.5
    W = jax.random.normal(ks[2], (64, 256))
    tok, conf = _ee.early_exit_head(h, nw, W, block_t=32, block_v=128,
                                    interpret=True)
    tr, cr = ref.early_exit_head_ref(h, nw, W)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(tr))
    np.testing.assert_allclose(conf, cr, atol=1e-5, rtol=1e-5)


def test_moe_gmm_interpret_explicit():
    from repro.kernels import moe_gmm as _gmm
    ks = jax.random.split(jax.random.key(11), 2)
    x = jax.random.normal(ks[0], (2, 32, 64))
    w = jax.random.normal(ks[1], (2, 64, 64))
    out = _gmm.moe_gmm(x, w, block_c=32, block_f=64, block_d=64,
                       interpret=True)
    r = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(out, r, atol=2e-4, rtol=2e-4)


@pytest.mark.slow_compile
def test_pdhg_fused_interpret_explicit():
    """The fused PDHG kernel honours interpret=True and agrees with the
    scan engine (same _fused_step source) on a small instance."""
    from harness import make_instance
    from repro.core import lp as LP
    from repro.kernels.pdhg_fused import pdhg_fused
    from jax.experimental import enable_x64
    inst = make_instance(seed=6, n_users=16, n_bs=2)
    with enable_x64():
        data = jax.tree.map(jnp.asarray, LP.pdhg_data(inst))
        xs, As = pdhg_fused(data, 24, polish=24, engine="scan")
        xp, Ap = pdhg_fused(data, 24, polish=24, engine="pallas",
                            block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(xp), np.asarray(xs), atol=1e-12)
    np.testing.assert_allclose(np.asarray(Ap), np.asarray(As), atol=1e-12)


def test_flash_matches_model_attention():
    """The kernel agrees with the model's blocked-attention path."""
    from repro.models.flash import flash_attention as model_flash
    ks = jax.random.split(jax.random.key(0), 3)
    B, S, H, K, E = 2, 256, 8, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, E))
    k = jax.random.normal(ks[1], (B, S, K, E))
    v = jax.random.normal(ks[2], (B, S, K, E))
    m = model_flash(q, k, v, True, 0, 0, 64, 64)
    p = ops.flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), block_q=64, block_k=64)
    np.testing.assert_allclose(m, p.transpose(0, 2, 1, 3),
                               atol=2e-5, rtol=2e-5)
