from repro.distribution.sharding import (batch_specs, cache_specs,  # noqa: F401
                                         param_specs, shard_axis)
