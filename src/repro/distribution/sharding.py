"""Sharding rules: 2D FSDP("data") × TP("model"), pure DP over "pod".

Policy (baseline — iterated in EXPERIMENTS.md §Perf):
  * every weight shards its TP-natural dim (heads / d_ff / vocab / d_inner)
    over "model" and the complementary d_model dim over "data" (FSDP), so
    optimizer state fits at 141B params on 256 chips;
  * TP dims that are not divisible by the model-axis size (e.g. qwen3's 40
    heads, whisper's 12) fall back to FSDP-only for that weight — the waste
    shows up in the roofline MODEL/HLO ratio and is a §Perf target;
  * activations shard batch over ("pod","data") when divisible (long_500k has
    batch 1 → replicated);
  * KV caches shard batch over data and kv-heads over "model" when divisible.
Params are replicated across "pod" (gradient all-reduce is the only DCN
collective — the cross-pod axis is pure DP).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, build_plan


def shard_axis(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 1


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % shard_axis(mesh, axis) == 0


D, M = "data", "model"


def _leaf_spec(cfg: ModelConfig, mesh: Mesh, names, leaf) -> P:
    """names: list of str path keys (e.g. ['segments','0','attn','wq'])."""
    last = names[-1]
    stacked = ("segments" in names or
               ("encoder" in names and "layers" in names))
    lead = (None,) if stacked else ()
    shape = leaf.shape
    H, K, E = cfg.n_heads_padded, cfg.n_kv_heads, cfg.head_dim
    hdiv = _div(H, mesh, M)
    kdiv = _div(K, mesh, M)

    # --- 1D / small leaves: replicate -------------------------------------
    if last in ("ln", "ln1", "ln2", "ln3", "qn", "kn", "adapter_norm",
                "dt_bias", "A_log", "D", "b", "bif", "conv_bB", "conv_bC"):
        return P(*([None] * len(shape)))
    if last == "norm":                       # mamba/mlstm norm over d_inner
        if "mamba" in names:
            return P(*lead, M)
        return P(*([None] * len(shape)))
    if last == "conv_bx":
        return P(*lead, M)

    # --- embeddings / heads -------------------------------------------------
    if last == "tok":
        # replicated over data, D over model: the token gather stays local
        # (a vocab-sharded table turns every lookup into a batch all-gather)
        return P(None, M)
    if last == "adapter":
        return P(D, None)
    if last == "head":
        return P(D, M)

    # --- attention -----------------------------------------------------------
    if last == "wq":
        return P(*lead, D, M if hdiv else None)
    if last in ("wk", "wv"):
        return P(*lead, D, M if kdiv else None)
    if last == "wo":
        # mlstm wo is a gate (D,D) input-sharded; attention wo is (H*E, D)
        if "segments" in names and _is_xlstm_leaf(names):
            return P(*lead, D, None)
        return P(*lead, M if hdiv else None, D)
    if last == "bq":
        return P(*lead, M if hdiv else None)
    if last in ("bk", "bv"):
        return P(*lead, M if kdiv else None)

    # --- FFN -------------------------------------------------------------------
    if last in ("w1", "w3"):
        if len(shape) - len(lead) == 3:      # MoE (E, D, F)
            return P(*lead, None, D, M)
        return P(*lead, D, M)
    if last == "w2":
        if len(shape) - len(lead) == 3:      # MoE (E, F, D)
            return P(*lead, None, M, D)
        return P(*lead, M, D)
    if last == "router":
        return P(*lead, D, None)

    # --- mamba2 -------------------------------------------------------------
    if last in ("z_proj", "x_proj"):
        return P(*lead, D, M)
    if last in ("B_proj", "C_proj"):
        return P(*lead, D, None)
    if last == "dt_proj":
        return P(*lead, D, M if _div(cfg.ssm_heads, mesh, M) else None)
    if last == "conv_x":
        return P(*lead, None, M)
    if last in ("conv_B", "conv_C"):
        return P(*lead, None, None)
    if last == "out_proj":
        return P(*lead, M, D)

    # --- xlstm ---------------------------------------------------------------
    if last in ("wif",):
        return P(*lead, D, None)
    if last in ("wd",):
        return P(*lead, D, None)
    if last == "w":                          # slstm input proj (D, 4D)
        return P(*lead, D, None)
    if last == "r":                          # slstm recurrent (4, H, P, P)
        return P(*([None] * len(shape)))

    return P(*([None] * len(shape)))


def _is_xlstm_leaf(names) -> bool:
    # attention weights live under an "attn"/"xattn" sub-dict; xlstm block
    # weights (wq/wk/wv/wo/wd) are flat in the layer dict
    return "attn" not in names and "xattn" not in names


def _paths(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def _names_of(path):
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def param_specs(cfg: ModelConfig, mesh: Mesh, params_tree, mode="train"):
    """Pytree of PartitionSpec matching ``params_tree`` (real or abstract).

    mode="train": 2D FSDP("data")×TP("model") — optimizer state must fit.
    mode="serve": weight-stationary TP — the FSDP dim is dropped (no per-layer
    weight all-gathers, which dominate collectives at decode batch sizes);
    MoE expert weights, too large for TP-only, shard over BOTH axes on their
    d_ff dim instead (no gather; the w2 psum output is tiny at decode)."""
    flat, tdef = _paths(params_tree)
    specs = [_leaf_spec(cfg, mesh, _names_of(p), l) for p, l in flat]
    if mode == "serve":
        specs = [_serve_override(cfg, mesh, _names_of(p), l, s)
                 for (p, l), s in zip(flat, specs)]
    return jax.tree_util.tree_unflatten(tdef, specs)


def _serve_override(cfg: ModelConfig, mesh: Mesh, names, leaf, spec: P) -> P:
    last = names[-1]
    stacked = ("segments" in names or
               ("encoder" in names and "layers" in names))
    lead = (None,) if stacked else ()
    both = (D, M)
    if last in ("w1", "w3") and len(leaf.shape) - len(lead) == 3:   # MoE
        return P(*lead, None, None, both)
    if last == "w2" and len(leaf.shape) - len(lead) == 3:
        return P(*lead, None, both, None)
    # drop the FSDP ("data") dim everywhere else: weight-stationary TP
    out = []
    for ax in spec:
        out.append(None if ax == D else ax)
    return P(*out)


def opt_specs(cfg: ModelConfig, mesh: Mesh, params_tree):
    ps = param_specs(cfg, mesh, params_tree)
    from jax.sharding import PartitionSpec
    return {"master": ps, "m": ps, "v": ps, "step": PartitionSpec()}


# ---------------------------------------------------------------------------
# activations / batch / cache
# ---------------------------------------------------------------------------

def _dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _dp_axes(mesh)]))


def batch_dim_spec(mesh: Mesh, batch: int):
    return _dp_axes(mesh) if batch % dp_size(mesh) == 0 else None


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, mode: str):
    """Specs for the input batch dict."""
    bd = batch_dim_spec(mesh, batch)
    spec = {"tokens": P(bd, None)}
    if mode == "train":
        spec["labels"] = P(bd, None)
    if cfg.family == "vlm":
        spec["patches"] = P(bd, None, None)
    if cfg.family == "encdec":
        spec["frames"] = P(bd, None, None)
    return spec


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, plan=None):
    """Per-segment cache specs mirroring models.model.cache_init."""
    plan = plan or build_plan(cfg)
    bd = batch_dim_spec(mesh, batch)
    kdiv = _div(cfg.n_kv_heads, mesh, M)
    kv = P(None, bd, None, M if kdiv else None, None)
    out = []
    for seg in plan.segments:
        if seg.kind in ("dense", "moe"):
            out.append({"k": kv, "v": kv})
        elif seg.kind == "shared_attn":
            skv = P(bd, None, M if kdiv else None, None)
            out.append({"k": skv, "v": skv})
        elif seg.kind == "mamba":
            hdiv = _div(cfg.ssm_heads, mesh, M)
            out.append({
                "conv_x": P(None, bd, None, M),
                "conv_B": P(None, bd, None, None),
                "conv_C": P(None, bd, None, None),
                "state": P(None, bd, M if hdiv else None, None, None)})
        elif seg.kind == "mlstm":
            out.append({"C": P(None, bd, None, None, None),
                        "n": P(None, bd, None, None),
                        "m": P(None, bd, None)})
        elif seg.kind == "slstm":
            out.append({k: P(None, bd, None) for k in ("h", "c", "n", "m")})
        elif seg.kind == "xdec":
            out.append({"k": kv, "v": kv, "xk": kv, "xv": kv})
        else:
            raise ValueError(seg.kind)
    return out


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# in-model sharding hints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------

def _ambient_mesh():
    m = jax.interpreters.pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def hint(x, *axes):
    """with_sharding_constraint resolved against the ambient mesh.

    axes entries: "batch" (shard over ("pod","data") when divisible),
    "model" (shard over "model" when divisible), or None.  Outside a mesh
    context (CPU unit tests) this is the identity.
    """
    m = _ambient_mesh()
    if m is None or "model" not in m.shape:
        return x
    bd = _dp_axes(m)
    bsz = int(np.prod([m.shape[a] for a in bd]))
    spec = []
    for dim, a in enumerate(axes):
        if a == "batch" and x.shape[dim] % bsz == 0 and x.shape[dim] > 0:
            spec.append(bd)
        elif a == "model" and x.shape[dim] % m.shape["model"] == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*spec)))


def hint_btd(h):
    """(B, S, D) or (B, 1, D) activations: batch over data axes."""
    return hint(h, "batch", None, None)
