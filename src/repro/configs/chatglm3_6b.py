"""chatglm3-6b — dense, 2D-RoPE (rotary on half the head dims), GQA kv=2
[arXiv:2406.12793].

28L, d_model 4096, 32 heads (GQA kv=2), d_ff 13696, vocab 65024.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    train_microbatches=2,
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=65024, head_dim=128, rope_variant="half",
)

SMOKE = ModelConfig(
    name="chatglm3-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, rope_variant="half",
    exit_layers=(2, 3, 4), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
