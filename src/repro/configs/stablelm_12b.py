"""stablelm-12b — dense GQA [hf:stabilityai/stablelm-2-12b family].

40L, d_model 5120, 32 heads (GQA kv=8), d_ff 13824, vocab 100352.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    train_microbatches=4,
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab_size=100352, head_dim=160,
)

SMOKE = ModelConfig(
    name="stablelm-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16,
    exit_layers=(2, 3, 4), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
