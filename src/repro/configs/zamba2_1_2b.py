"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

38 Mamba2 layers, d_model 2048, a single shared attention+MLP block applied
every 6 Mamba layers (weights reused), ssm_state 64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    train_microbatches=2,
    name="zamba2-1.2b", family="hybrid_mamba",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128, attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid_mamba",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, head_dim=16,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16, attn_every=2,
    exit_layers=(2, 4, 6), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
