"""qwen3-14b — dense GQA with qk-norm [hf:Qwen/Qwen3-14B family].

40L, d_model 5120, 40 heads (GQA kv=8, head_dim 128), d_ff 17408,
vocab 151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    train_microbatches=4,
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17408,
    vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    # §Perf: 40 heads don't divide the 16-way model axis — pad to 48
    # zero-weight heads (outputs identical) so attention shards over TP
    q_head_pad=48,
    # seq_parallel=True was tried and REFUTED (EXPERIMENTS.md §Perf iter 3):
    # GSPMD reshards around the blocked-attention scan instead of emitting
    # reduce-scatter/all-gather, inflating collectives 8.5x

)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, qk_norm=True,
    exit_layers=(2, 3, 4), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
