"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

12 blocks, d_model 768, 4 heads (head_dim 192), vocab 50304; no separate FFN
(d_ff=0 — xLSTM blocks carry their own projections).  sLSTM at blocks {3, 9},
mLSTM elsewhere (≈ the paper's [7:1]-style mostly-mLSTM mix).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    train_microbatches=2,
    name="xlstm-125m", family="xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, head_dim=192, rope_variant="none",
    slstm_at=(3, 9), ssm_chunk=128,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="xlstm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=512, head_dim=16, rope_variant="none",
    slstm_at=(1,), ssm_chunk=16,
    exit_layers=(2, 3, 4), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
