"""qwen1.5-0.5b — dense MHA with QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L, d_model 1024, 16 heads (kv=16), d_ff 2816, vocab 151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab_size=151936, head_dim=64, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, head_dim=16, qkv_bias=True,
    exit_layers=(2, 3, 4), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
