"""The paper's own catalog: ViT-class dynamic DNNs on CIFAR-10 (Tables II/III).

These attributes drive the paper-faithful reproduction benchmarks (Table IV/V,
Figs 6-14).  Memory in MB, FLOPs in GFLOPs per request, loading times in
seconds (cloud->BS at the paper's 800 Mbps with measured constants).
"""

# Table II — the three ViT submodels
VIT_SUBMODELS = [
    {"memory_mb": 174.32, "gflops": 5.70, "precision": 0.8417},
    {"memory_mb": 227.42, "gflops": 7.56, "precision": 0.9413},
    {"memory_mb": 342.05, "gflops": 11.29, "precision": 0.9894},
]

# Table III — loading latency (s): row = original submodel (0 = none),
# col = target submodel.
VIT_LOAD_S = [
    [0.68860, 0.87696, 1.05821],   # from scratch
    [0.00000, 0.24794, 0.46098],   # from submodel 1
    [0.04238, 0.00000, 0.25082],   # from submodel 2
    [0.04725, 0.04242, 0.00000],   # from submodel 3
]

# Motivating example (Sec. III): two model types A and B.
MOTIVATING = {
    "A": [{"memory_gb": 0.5, "precision": 0.84, "load_s": 0.04},
          {"memory_gb": 0.8, "precision": 0.92, "load_s": 0.71},
          {"memory_gb": 1.2, "precision": 0.98, "load_s": 1.06}],
    "B": [{"memory_gb": 0.6, "precision": 0.80, "load_s": 0.53},
          {"memory_gb": 1.0, "precision": 0.90, "load_s": 0.89},
          {"memory_gb": 1.5, "precision": 0.96, "load_s": 1.33}],
    "switch_B2_to_B3_s": 0.43,
}
