"""Architecture registry: one module per assigned arch (+ the paper's own
ViT edge catalog).  ``get_config(name)`` returns the full production config,
``get_smoke(name)`` a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "zamba2-1.2b", "stablelm-12b", "chatglm3-6b", "qwen1.5-0.5b",
    "qwen3-14b", "pixtral-12b", "mixtral-8x22b", "mixtral-8x7b",
    "whisper-small", "xlstm-125m",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _load(name: str):
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MOD[name]}")


def get_config(name: str):
    return _load(name).CONFIG


def get_smoke(name: str):
    return _load(name).SMOKE
