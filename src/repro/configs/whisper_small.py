"""whisper-small — encoder-decoder with stub audio conv frontend
[arXiv:2212.04356].

12 encoder + 12 decoder layers, d_model 768, 12 heads (MHA), d_ff 3072,
vocab 51865.  The conv frontend is a STUB: ``input_specs`` supplies
precomputed post-conv frame embeddings (B, encoder_len, d_model).
Positional encoding is sinusoidal (paper uses learned for the decoder —
noted deviation, irrelevant to system behaviour).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=51865, head_dim=64, rope_variant="none",
    encoder_layers=12, encoder_len=1500, frontend="audio",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, head_dim=16, rope_variant="none",
    encoder_layers=2, encoder_len=16, frontend="audio",
    exit_layers=(2, 3, 4), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
