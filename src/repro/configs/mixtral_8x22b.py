"""mixtral-8x22b — MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L, d_model 6144, 48 heads (GQA kv=8, head_dim 128), expert d_ff 16384,
vocab 32768.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    train_microbatches=8,
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, head_dim=128, sliding_window=4096,
    n_experts=8, top_k=2, capacity_factor=1.25, moe_group_size=2048,
)

SMOKE = ModelConfig(
    name="mixtral22-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, sliding_window=16,
    n_experts=4, top_k=2, capacity_factor=2.0, moe_group_size=32,
    exit_layers=(2, 3, 4), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
