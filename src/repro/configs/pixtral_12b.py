"""pixtral-12b — VLM: stub pixtral-ViT frontend + mistral-nemo-style decoder
[hf:mistralai/Pixtral-12B-2409].

40L, d_model 5120, 32 heads (GQA kv=8, head_dim 128), d_ff 14336,
vocab 131072.  The vision frontend is a STUB: ``input_specs`` supplies
precomputed patch embeddings (B, frontend_len, d_model), merged before the
text tokens (prefix-causal).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    train_microbatches=4,
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=131072, head_dim=128, rope_theta=1e6,
    frontend="patch", frontend_len=256,
)

SMOKE = ModelConfig(
    name="pixtral-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16,
    frontend="patch", frontend_len=8,
    exit_layers=(2, 3, 4), dtype="float32", param_dtype="float32", remat=False,
    vocab_pad_multiple=16,
)
