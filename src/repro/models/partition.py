"""Dynamic-DNN partitioning — the paper's core object model.

A model type ``m`` (a ModelConfig) is disassembled into submodels
``h_1 ≺ … ≺ h_H`` (paper Sec. III): submodel j = embed + segments up to
``plan.exit_after[j]`` + exit head j (+ shared block, + encoder).  Because
segment params are stacked, the Δ between consecutive submodels is a
contiguous parameter slice — so r_h (memory), Δr_h (switch download bytes)
and c_h (FLOPs/token) are all *derived from the real architecture*, giving
the MEC catalog its sizes and the loader its transfer volumes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig, Plan, build_plan


def _nbytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def _nparams(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


@functools.lru_cache(maxsize=64)
def _shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init(cfg, jax.random.key(0)))


def submodel_params(cfg: ModelConfig, params, j: int, plan: Plan = None):
    """Truncate a real (or abstract) param tree to submodel j (0-based)."""
    plan = plan or build_plan(cfg)
    last = plan.exit_after[j]
    out = {"embed": params["embed"],
           "segments": list(params["segments"][: last + 1]),
           "exits": list(params["exits"][: j + 1])}
    if "shared" in params:
        out["shared"] = params["shared"]
    if "encoder" in params:
        out["encoder"] = params["encoder"]
    return out


def submodel_bytes(cfg: ModelConfig, j: int) -> int:
    return _nbytes(submodel_params(cfg, _shapes(cfg), j))


def submodel_param_count(cfg: ModelConfig, j: int = None) -> int:
    if j is None:
        j = cfg.n_exits - 1
    return _nparams(submodel_params(cfg, _shapes(cfg), j))


def delta_bytes(cfg: ModelConfig, i: int, j: int) -> int:
    """Download bytes to switch submodel i -> j (paper D^swit); i=-1 means
    cold load from nothing (paper D^new)."""
    if j <= i:
        return 0                       # shrink = eviction, ~free (paper Sec VI)
    lo = 0 if i < 0 else submodel_bytes(cfg, i)
    return submodel_bytes(cfg, j) - lo


def delta_segments(cfg: ModelConfig, params, i: int, j: int, plan: Plan = None):
    """The actual Δ param subtree transferred for an i->j upgrade."""
    plan = plan or build_plan(cfg)
    lo_seg = -1 if i < 0 else plan.exit_after[i]
    hi_seg = plan.exit_after[j]
    return {"segments": list(params["segments"][lo_seg + 1: hi_seg + 1]),
            "exits": list(params["exits"][i + 1: j + 1])}


# ---------------------------------------------------------------------------
# analytic FLOPs (forward, per token) — feeds c_h and roofline MODEL_FLOPS
# ---------------------------------------------------------------------------

def _layer_flops(cfg: ModelConfig, kind: str, ctx: int) -> float:
    D, F = cfg.d_model, cfg.d_ff
    H, K, E = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn_ctx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
    attn = 2 * D * (H * E + 2 * K * E) + 2 * H * E * D \
        + 2 * 2 * H * E * attn_ctx                     # qkv+out proj + scores/av
    ffn = 3 * 2 * D * F
    ffn_ng = 2 * 2 * D * F
    if kind == "dense":
        return attn + ffn
    if kind == "moe":
        router = 2 * D * cfg.n_experts
        return attn + router + cfg.top_k * ffn
    if kind == "mamba":
        I, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        proj = 2 * D * (2 * I + 2 * N + Hs) + 2 * I * D
        conv = 2 * cfg.ssm_conv * (I + 2 * N)
        ssd = 2 * 2 * I * N + 2 * 2 * cfg.ssm_chunk * (N + cfg.ssm_head_dim) * Hs
        return proj + conv + ssd
    if kind == "mlstm":
        P = D // H
        return 5 * 2 * D * D + 4 * 2 * D * P
    if kind == "slstm":
        P = D // H
        return 2 * D * 4 * D + 4 * 2 * D * P + 2 * D * D
    if kind in ("xdec",):
        xattn = 2 * D * H * E + 2 * H * E * D + 2 * 2 * H * E * cfg.encoder_len
        return attn + xattn + ffn_ng
    if kind in ("encoder", "shared_attn"):
        return attn + (ffn if kind == "shared_attn" else ffn_ng)
    raise ValueError(kind)


def submodel_flops_per_token(cfg: ModelConfig, j: int, ctx: int = 2048,
                             plan: Plan = None) -> float:
    """Forward FLOPs per decoder token for submodel j (c_h in the paper)."""
    plan = plan or build_plan(cfg)
    total = 0.0
    for seg in plan.segments[: plan.exit_after[j] + 1]:
        total += seg.n_layers * _layer_flops(cfg, seg.kind, ctx)
    total += 2 * cfg.d_model * cfg.padded_vocab          # exit head
    if plan.has_encoder:
        total += cfg.encoder_layers * _layer_flops(cfg, "encoder", cfg.encoder_len) \
            * cfg.encoder_len / max(ctx, 1)
    return total


def model_flops(cfg: ModelConfig, batch: int, seq: int, mode: str) -> float:
    """Roofline MODEL_FLOPS: 6·N·D for train, 2·N_active·D for inference."""
    n_active = active_param_count(cfg)
    tokens = batch * seq if mode == "train" else batch  # decode: 1 tok/step
    if mode == "prefill":
        tokens = batch * seq
    mult = 6 if mode == "train" else 2
    return mult * n_active * tokens


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k of n_experts expert params)."""
    n = submodel_param_count(cfg)
    if cfg.n_experts:
        expert = 3 * cfg.d_model * cfg.d_ff            # w1,w2,w3 per expert
        inactive = (cfg.n_experts - cfg.top_k) * expert * cfg.n_layers
        n -= inactive
    return n


def catalog_entry(cfg: ModelConfig, ctx: int = 2048):
    """(r_h bytes, Δr_h bytes, c_h flops/token) per submodel — the paper's
    Table II analogue, derived from the real architecture."""
    out = []
    for j in range(cfg.n_exits):
        out.append({
            "r_h": submodel_bytes(cfg, j),
            "delta_r": delta_bytes(cfg, j - 1, j),
            "c_h": submodel_flops_per_token(cfg, j, ctx),
        })
    return out
