"""Top-level model API: init / apply_train / prefill / decode over a Plan.

The dynamic-DNN technique is built in: ``apply_train`` emits logits at every
exit head (multi-exit joint training, paper Sec. III), and the serve paths
take ``exit_idx`` so a *submodel* — a prefix of the segment list + its own
ExtNet head — can be executed directly, which is exactly what a BS serves
when submodel ``h_j`` is cached.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distribution.sharding import hint, hint_btd
from repro.models import transformer as T
from repro.models.config import ModelConfig, Plan, build_plan
from repro.models.layers import (embed_frontend, embed_init,
                                 embed_tokens, exit_head_fwd, exit_head_init,
                                 rms_norm)


def sinusoidal(positions, D):
    half = D // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half, dtype=np.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> dict:
    plan = build_plan(cfg)
    keys = jax.random.split(key, len(plan.segments) + cfg.n_exits + 3)
    ki = iter(keys)
    params = {"embed": embed_init(next(ki), cfg), "segments": [], "exits": []}
    for seg in plan.segments:
        if seg.kind == "shared_attn":
            params["segments"].append({})       # weights live in params["shared"]
        else:
            params["segments"].append(
                T.seg_init(next(ki), cfg, seg.kind, seg.n_layers))
    if any(s.kind == "shared_attn" for s in plan.segments):
        params["shared"] = T.shared_attn_init(next(ki), cfg)
    for _ in range(cfg.n_exits):
        params["exits"].append(exit_head_init(next(ki), cfg))
    if plan.has_encoder:
        params["encoder"] = {
            "layers": T.seg_init(next(ki), cfg, "encoder", cfg.encoder_layers),
            "norm": jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        }
    return params


# ---------------------------------------------------------------------------
# embedding / encoder front
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, batch):
    """Returns decoder-side input hidden states (B, S, D)."""
    if cfg.family == "vlm":
        pe = embed_frontend(cfg, params["embed"], batch["patches"])
        te = embed_tokens(cfg, params["embed"], batch["tokens"])
        return hint_btd(jnp.concatenate([pe, te], axis=1))
    h = embed_tokens(cfg, params["embed"], batch["tokens"])
    if cfg.family == "encdec":
        S = h.shape[1]
        h = h + sinusoidal(jnp.arange(S), cfg.d_model)[None].astype(h.dtype)
    return hint_btd(h)


def run_encoder(cfg: ModelConfig, params, frames):
    """frames: (B, T, D) stub post-conv audio embeddings."""
    h = embed_frontend(cfg, params["embed"], frames)
    T_ = h.shape[1]
    h = h + sinusoidal(jnp.arange(T_), cfg.d_model)[None].astype(h.dtype)
    h, _ = T.seg_fwd(cfg, "encoder", params["encoder"]["layers"], None, h,
                     jnp.arange(T_))
    return rms_norm(h, params["encoder"]["norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# training forward: logits at every exit
# ---------------------------------------------------------------------------

def apply_train(cfg: ModelConfig, params, batch, plan: Plan = None,
                consume=None):
    """Forward with logits at every exit head (multi-exit joint training).

    ``consume(j, h)``, when given, is applied to the exit's *hidden states*
    as soon as they are produced (the loss computes its own chunked head+CE,
    so full (B,S,V) logits tensors are never materialized).
    """
    plan = plan or build_plan(cfg)
    h = _embed(cfg, params, batch)
    S = h.shape[1]
    positions = jnp.arange(S)
    enc_out = None
    if plan.has_encoder:
        enc_out = run_encoder(cfg, params, batch["frames"])

    exit_of_seg = {s: j for j, s in enumerate(plan.exit_after)}
    outs, aux = [], 0.0
    for seg in plan.segments:
        sp = params["segments"][seg.index]
        h, a = T.seg_fwd(cfg, seg.kind, sp, params.get("shared"), h, positions,
                         enc_kv=enc_out)
        aux = aux + a
        if seg.index in exit_of_seg:
            j = exit_of_seg[seg.index]
            if consume is None:
                lg = exit_head_fwd(cfg, params["exits"][j], h)
                outs.append(hint(lg, "batch", None, "model"))
            else:
                outs.append(consume(j, h))
    return outs, aux


# ---------------------------------------------------------------------------
# serving: prefill / decode with KV-and-state caches
# ---------------------------------------------------------------------------

def cache_init(cfg: ModelConfig, B: int, max_len: int, plan: Plan = None):
    plan = plan or build_plan(cfg)
    return [T.seg_cache_init(cfg, seg, B, max_len, enc_len=cfg.encoder_len)
            for seg in plan.segments]


def prefill(cfg: ModelConfig, params, batch, cache, exit_idx: int = -1,
            plan: Plan = None):
    """Returns (last-position logits (B, V), updated cache)."""
    plan = plan or build_plan(cfg)
    exit_idx = exit_idx % cfg.n_exits
    last_seg = plan.exit_after[exit_idx]
    h = _embed(cfg, params, batch)
    S = h.shape[1]
    positions = jnp.arange(S)
    enc_out = None
    if plan.has_encoder:
        enc_out = run_encoder(cfg, params, batch["frames"])

    new_cache = list(cache)
    for seg in plan.segments[: last_seg + 1]:
        sp = params["segments"][seg.index]
        h, new_cache[seg.index] = T.seg_prefill(
            cfg, seg, sp, params.get("shared"), h, positions,
            cache[seg.index], enc_out=enc_out)
    logits = exit_head_fwd(cfg, params["exits"][exit_idx], h[:, -1:, :])
    return logits[:, 0, :], new_cache


def decode(cfg: ModelConfig, params, tokens, pos, cache, exit_idx: int = -1,
           plan: Plan = None):
    """One decode step. tokens: (B, 1) int32, pos: scalar int32."""
    plan = plan or build_plan(cfg)
    exit_idx = exit_idx % cfg.n_exits
    last_seg = plan.exit_after[exit_idx]
    h = embed_tokens(cfg, params["embed"], tokens)
    if cfg.family == "encdec":
        h = h + sinusoidal(jnp.asarray(pos)[None], cfg.d_model)[None].astype(h.dtype)

    new_cache = list(cache)
    for seg in plan.segments[: last_seg + 1]:
        sp = params["segments"][seg.index]
        h, new_cache[seg.index] = T.seg_decode(
            cfg, seg, sp, params.get("shared"), h, pos, cache[seg.index])
    logits = exit_head_fwd(cfg, params["exits"][exit_idx], h)
    return logits[:, 0, :], new_cache
