"""Mixture-of-Experts layer (Mixtral-style top-2), GShard einsum dispatch.

TPU-native formulation: tokens are reshaped into groups of ``moe_group_size``;
within each group a capacity-bounded one-hot dispatch tensor routes tokens to
experts via einsum (no scatter/gather), which shards cleanly under GSPMD:
the group axis follows the batch ("data") sharding and each expert's hidden
dim shards over "model".  HLO FLOPs ≈ capacity_factor × active-expert FLOPs,
so the roofline's MODEL_FLOPS/HLO_FLOPs ratio stays honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import pdtype


def moe_init(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(k0, (D, E)) * D ** -0.5).astype(jnp.float32),
        "w1": (jax.random.normal(k1, (E, D, F)) * D ** -0.5).astype(pdtype(cfg)),
        "w3": (jax.random.normal(k2, (E, D, F)) * D ** -0.5).astype(pdtype(cfg)),
        "w2": (jax.random.normal(k3, (E, F, D)) * F ** -0.5).astype(pdtype(cfg)),
    }


def _capacity(cfg: ModelConfig, group: int) -> int:
    c = int(cfg.capacity_factor * group * cfg.top_k / cfg.n_experts)
    return max(cfg.top_k, (c + 3) // 4 * 4)


def moe_fwd(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar f32)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    # one group of all tokens at decode (S==1): per-token groups waste
    # capacity slots (C >= top_k each); groups never cross batch rows when
    # S % g == 0, so train/prefill reshapes stay local
    g = min(cfg.moe_group_size, T)
    xf = x.reshape(T, D)
    valid = None
    if T % g:
        pad = g - T % g
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        valid = jnp.arange(T + pad) < T       # pads get no expert assignment
        T = T + pad
    G = T // g
    C = _capacity(cfg, g)

    xg = xf.reshape(G, g, D)
    logits = (xg.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)                        # (G,g,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # one-hot expert assignment per slot: (G, g, k, E)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    if valid is not None:
        onehot = onehot * valid.reshape(G, g)[:, :, None, None]
    # position of each (token, slot) within its expert queue, slot-major so
    # first-choice assignments win capacity over second choices.
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, k * g, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat                      # (G,kg,E)
    pos_in_expert = pos_in_expert.reshape(G, k, g, E).transpose(0, 2, 1, 3)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                       # (G,g,k)
    keep = (pos < C).astype(jnp.float32)

    # dispatch (G,g,E,C) one-hot; combine adds gate weights
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)                 # (G,g,E,C)
    comb = jnp.einsum("gsk,gske,gskc->gsec", gate_vals, onehot, pos_oh)

    xin = jnp.einsum("gsec,gsd->gecd", disp.astype(x.dtype), xg)         # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", xin, p["w1"].astype(x.dtype))
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xin, p["w3"].astype(x.dtype))
    hout = jnp.einsum("gecf,efd->gecd", h, p["w2"].astype(x.dtype))
    out = jnp.einsum("gsec,gecd->gsd", comb.astype(x.dtype), hout)

    # Switch-style load-balancing auxiliary loss
    me = jnp.mean(probs, axis=(0, 1))                                    # (E,)
    ce = jnp.mean(onehot[..., 0, :] if k == 1 else jnp.max(onehot, 2), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    out = out.reshape(T, D)[:B * S]
    return out.reshape(B, S, D), aux
