"""Model configuration + execution-plan machinery.

A model is described by a ``ModelConfig`` and compiled (at trace time, in
Python) into a ``Plan``: an ordered tuple of ``Segment``s, each a homogeneous
stack of layers that is stored stacked on a leading ``L`` axis and executed
with ``jax.lax.scan``.  Segments are split at

  * kind changes (e.g. mamba -> shared attention block in zamba2), and
  * dynamic-DNN exit boundaries (the paper's submodel cut points),

so that the paper's submodel ``h_j`` is *literally* a prefix of the segment
list plus exit head ``j`` — and a submodel switch loads exactly the Δ-segment
parameters (paper Sec. III / Fig. 1).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid_mamba | xlstm | encdec | vlm
    n_layers: int                    # backbone (decoder) depth
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention options -------------------------------------------------
    rope_variant: str = "full"       # full | half (chatglm 2d-rope) | none
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 -> full attention
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048       # GShard dispatch group length
    # --- SSM (mamba2) -------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0              # hybrid: insert shared attn block after
                                     # every `attn_every` mamba layers
    # --- xlstm ---------------------------------------------------------------
    slstm_at: Tuple[int, ...] = ()   # backbone indices that are sLSTM blocks
    # --- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0
    encoder_len: int = 0             # stub frontend sequence length (frames)
    # --- stub multimodal frontend -------------------------------------------
    frontend: str = "none"           # none | patch | audio
    frontend_len: int = 0            # patches prepended to the text sequence
    # --- dynamic DNN (the paper's technique) ---------------------------------
    exit_layers: Tuple[int, ...] = ()   # 1-based backbone depths with exit
                                        # heads; () -> (L/3, 2L/3, L)
    exit_loss_weights: Tuple[float, ...] = ()
    # --- TP head padding (§Perf): pad q heads with zero-weight heads so the
    # head dim divides the model axis; wo's padded input rows are zero, so
    # outputs are bit-identical to the unpadded model ----------------------
    q_head_pad: int = 0              # 0 -> no padding
    seq_parallel: bool = False       # §Perf: shard the residual stream's S
                                     # over "model" (Megatron-SP: RS+AG
                                     # replaces the post-attn/FFN all-reduce)
    # --- training memory (§Perf): gradient-accumulation microbatches so the
    # remat-saved per-layer residuals fit 16 GB/chip HBM at train_4k --------
    train_microbatches: int = 1
    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    vocab_pad_multiple: int = 256
    remat: bool = True

    # ------------------------------------------------------------------ ---
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.exit_layers:
            L = self.n_layers
            cuts = sorted({max(1, math.ceil(L / 3)), max(1, math.ceil(2 * L / 3)), L})
            object.__setattr__(self, "exit_layers", tuple(cuts))
        if self.exit_layers[-1] != self.n_layers:
            raise ValueError("last exit must sit at the full depth")
        if not self.exit_loss_weights:
            n = len(self.exit_layers)
            w = tuple(0.3 for _ in range(n - 1)) + (1.0,)
            object.__setattr__(self, "exit_loss_weights", w)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def n_heads_padded(self) -> int:
        return max(self.q_head_pad, self.n_heads)

    @property
    def n_exits(self) -> int:
        return len(self.exit_layers)

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        # reset derived fields when their drivers change, so __post_init__
        # recomputes them instead of keeping stale values
        if "n_layers" in kw and "exit_layers" not in kw:
            kw["exit_layers"] = ()
        if ("exit_layers" in kw or "n_layers" in kw) \
                and "exit_loss_weights" not in kw:
            kw["exit_loss_weights"] = ()
        if ("d_model" in kw or "n_heads" in kw) and "head_dim" not in kw:
            kw["head_dim"] = 0
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Plan
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    kind: str          # dense | moe | mamba | mlstm | slstm | shared_attn | xdec
    n_layers: int
    index: int         # position in plan
    depth_end: int     # cumulative backbone depth after this segment
                       # (shared_attn does not advance backbone depth)


@dataclass(frozen=True)
class Plan:
    segments: Tuple[Segment, ...]
    exit_after: Tuple[int, ...]    # segment index whose output feeds exit j
    has_encoder: bool = False


def _backbone_kinds(cfg: ModelConfig):
    """Per-backbone-layer kind list, plus inserted (non-backbone) blocks."""
    kinds = []
    if cfg.family in ("dense", "vlm"):
        kinds = [("dense", True)] * cfg.n_layers
    elif cfg.family == "moe":
        kinds = [("moe", True)] * cfg.n_layers
    elif cfg.family == "hybrid_mamba":
        for i in range(cfg.n_layers):
            kinds.append(("mamba", True))
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0 and i + 1 < cfg.n_layers:
                kinds.append(("shared_attn", False))
    elif cfg.family == "xlstm":
        for i in range(cfg.n_layers):
            kinds.append(("slstm" if i in cfg.slstm_at else "mlstm", True))
    elif cfg.family == "encdec":
        kinds = [("xdec", True)] * cfg.n_layers
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return kinds


def build_plan(cfg: ModelConfig) -> Plan:
    kinds = _backbone_kinds(cfg)
    exit_set = set(cfg.exit_layers)
    segments = []
    exit_after = {}
    cur_kind, cur_count = None, 0
    depth = 0

    def flush():
        nonlocal cur_kind, cur_count
        if cur_kind is not None and cur_count > 0:
            segments.append(Segment(cur_kind, cur_count, len(segments), depth))
            cur_kind, cur_count = None, 0

    for kind, is_backbone in kinds:
        if kind != cur_kind:
            flush()
            cur_kind = kind
        cur_count += 1
        if is_backbone:
            depth += 1
            if depth in exit_set:
                flush()
                exit_after[depth] = len(segments) - 1
        if kind == "shared_attn":
            flush()

    flush()
    exits = tuple(exit_after[d] for d in cfg.exit_layers)
    return Plan(tuple(segments), exits, has_encoder=cfg.family == "encdec")


def submodel_plan(plan: Plan, j: int) -> Plan:
    """The paper's submodel h_{j+1}: plan truncated at exit j (0-based)."""
    last_seg = plan.exit_after[j]
    return Plan(plan.segments[: last_seg + 1], plan.exit_after[: j + 1],
                plan.has_encoder)
