"""Segment stacks: stacked-and-scanned homogeneous layer groups.

Every segment kind provides init / fwd (train, full-seq) / prefill / decode /
cache_init with a uniform signature, so ``model.py`` can execute a Plan by
iterating segments.  Layer params are stacked on a leading ``L`` axis and run
with ``jax.lax.scan`` (small HLO, O(1) compile cost in depth) — which is also
what makes the paper's Δ-submodel loading a contiguous prefix slice.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distribution.sharding import hint, hint_btd
from repro.models import mamba2, moe, xlstm
from repro.models.config import ModelConfig, Segment
from repro.models.layers import (attn_decode, attn_fwd, attn_init,
                                 attn_prefill, ffn_fwd, ffn_init, pdtype,
                                 rms_norm, xattn_fwd, xattn_kv)


def _hint_stream(cfg, h):
    """Residual-stream constraint: batch over data; with seq_parallel also
    S over "model" (intended to elicit reduce-scatter + all-gather, Megatron
    SP — measured counterproductive under GSPMD here, see EXPERIMENTS.md
    §Perf; kept as an opt-in flag, default off)."""
    if cfg.seq_parallel and h.shape[1] > 1:
        return hint(h, "batch", "model", None)
    return hint_btd(h)


def _norm_init(cfg):
    return jnp.ones((cfg.d_model,), pdtype(cfg))


# ---------------------------------------------------------------------------
# per-layer inits
# ---------------------------------------------------------------------------

def _dense_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg), "attn": attn_init(k1, cfg),
            "ln2": _norm_init(cfg), "ffn": ffn_init(k2, cfg, gated=True)}


def _moe_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg), "attn": attn_init(k1, cfg),
            "ln2": _norm_init(cfg), "moe": moe.moe_init(k2, cfg)}


def _mamba_layer_init(key, cfg):
    return {"ln": _norm_init(cfg), "mamba": mamba2.mamba_init(key, cfg)}


def _xdec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _norm_init(cfg), "attn": attn_init(k1, cfg),
            "ln2": _norm_init(cfg), "xattn": attn_init(k2, cfg),
            "ln3": _norm_init(cfg), "ffn": ffn_init(k3, cfg, gated=False)}


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg), "attn": attn_init(k1, cfg),
            "ln2": _norm_init(cfg), "ffn": ffn_init(k2, cfg, gated=False)}


_LAYER_INIT = {
    "dense": _dense_layer_init,
    "moe": _moe_layer_init,
    "mamba": _mamba_layer_init,
    "mlstm": xlstm.mlstm_init,
    "slstm": xlstm.slstm_init,
    "xdec": _xdec_layer_init,
    "encoder": _enc_layer_init,
}


def seg_init(key, cfg: ModelConfig, kind: str, n_layers: int):
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: _LAYER_INIT[kind](k, cfg))(keys)


def shared_attn_init(key, cfg: ModelConfig):
    """zamba2's shared attention+MLP block (one copy, applied many times)."""
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg), "attn": attn_init(k1, cfg),
            "ln2": _norm_init(cfg), "ffn": ffn_init(k2, cfg, gated=True)}


# ---------------------------------------------------------------------------
# per-layer forwards (single layer; used inside scan)
# ---------------------------------------------------------------------------

def _dense_fwd(cfg, lp, h, positions, causal=True):
    h = h + attn_fwd(cfg, lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                     positions, causal=causal, window=cfg.sliding_window)
    h = h + ffn_fwd(cfg, lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                    gated=True)
    return h


def _moe_fwd(cfg, lp, h, positions):
    h = h + attn_fwd(cfg, lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                     positions, window=cfg.sliding_window)
    mo, aux = moe.moe_fwd(cfg, lp["moe"], rms_norm(h, lp["ln2"], cfg.norm_eps))
    return h + mo, aux


def _enc_fwd(cfg, lp, h, positions):
    h = h + attn_fwd(cfg, lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                     positions, causal=False, use_rope=False)
    h = h + ffn_fwd(cfg, lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                    gated=False)
    return h


# ---------------------------------------------------------------------------
# segment stack: train forward
# ---------------------------------------------------------------------------

def seg_fwd(cfg: ModelConfig, kind: str, sp, shared, h, positions, enc_kv=None):
    """Full-sequence forward of one segment. Returns (h, aux_loss)."""
    if kind == "shared_attn":
        lp = shared
        h = h + attn_fwd(cfg, lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                         positions)
        h = h + ffn_fwd(cfg, lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, 0.0

    if kind == "xdec":
        return _xdec_seg_fwd(cfg, sp, h, positions, enc_kv)

    if kind == "dense":
        body = lambda hh, lp: (_dense_fwd(cfg, lp, hh, positions), 0.0)
    elif kind == "moe":
        body = lambda hh, lp: _moe_fwd(cfg, lp, hh, positions)
    elif kind == "mamba":
        body = lambda hh, lp: (
            hh + mamba2.mamba_fwd(cfg, lp["mamba"],
                                  rms_norm(hh, lp["ln"], cfg.norm_eps)), 0.0)
    elif kind == "mlstm":
        body = lambda hh, lp: (xlstm.mlstm_fwd(cfg, lp, hh), 0.0)
    elif kind == "slstm":
        body = lambda hh, lp: (xlstm.slstm_fwd(cfg, lp, hh), 0.0)
    elif kind == "encoder":
        body = lambda hh, lp: (_enc_fwd(cfg, lp, hh, positions), 0.0)
    else:
        raise ValueError(kind)

    fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    h, auxs = jax.lax.scan(lambda hh, lp: fn(_hint_stream(cfg, hh), lp), h, sp)
    return h, jnp.sum(jnp.asarray(auxs))


def _xdec_seg_fwd(cfg, sp, h, positions, enc_out):
    """Whisper-style decoder segment: self-attn + cross-attn + FFN.

    enc_out: (B, T, D) encoder output (cross K/V computed per layer)."""
    def body(hh, lp):
        hh = hint_btd(hh)
        hh = hh + attn_fwd(cfg, lp["attn"],
                           rms_norm(hh, lp["ln1"], cfg.norm_eps), positions,
                           use_rope=False)
        ek, ev = xattn_kv(cfg, lp["xattn"], enc_out)
        hh = hh + xattn_fwd(cfg, lp["xattn"],
                            rms_norm(hh, lp["ln2"], cfg.norm_eps), ek, ev)
        hh = hh + ffn_fwd(cfg, lp["ffn"], rms_norm(hh, lp["ln3"], cfg.norm_eps),
                          gated=False)
        return hh, 0.0

    fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    h, _ = jax.lax.scan(lambda hh, lp: fn(hh, lp), h, sp)
    return h, 0.0


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def seg_cache_init(cfg: ModelConfig, seg: Segment, B: int, max_len: int,
                   enc_len: int = 0):
    L = seg.n_layers
    K, E = cfg.n_kv_heads, cfg.head_dim
    kv_dt = jnp.dtype(cfg.dtype)
    skv = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if seg.kind in ("dense", "moe"):
        return {"k": jnp.zeros((L, B, skv, K, E), kv_dt),
                "v": jnp.zeros((L, B, skv, K, E), kv_dt)}
    if seg.kind == "shared_attn":
        return {"k": jnp.zeros((B, max_len, K, E), kv_dt),
                "v": jnp.zeros((B, max_len, K, E), kv_dt)}
    if seg.kind == "mamba":
        c = mamba2.mamba_cache_init(cfg, B)
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), c)
    if seg.kind == "mlstm":
        c = xlstm.mlstm_cache_init(cfg, B)
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), c)
    if seg.kind == "slstm":
        c = xlstm.slstm_cache_init(cfg, B)
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), c)
    if seg.kind == "xdec":
        return {"k": jnp.zeros((L, B, max_len, K, E), kv_dt),
                "v": jnp.zeros((L, B, max_len, K, E), kv_dt),
                "xk": jnp.zeros((L, B, enc_len, K, E), kv_dt),
                "xv": jnp.zeros((L, B, enc_len, K, E), kv_dt)}
    raise ValueError(seg.kind)


# ---------------------------------------------------------------------------
# segment stack: prefill
# ---------------------------------------------------------------------------

def seg_prefill(cfg: ModelConfig, seg: Segment, sp, shared, h, positions,
                cache, enc_out=None):
    kind = seg.kind
    if kind == "shared_attn":
        lp = shared
        a, ck, cv = attn_prefill(cfg, lp["attn"],
                                 rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 positions, cache["k"], cache["v"])
        h = h + a
        h = h + ffn_fwd(cfg, lp["ffn"], rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, {"k": ck, "v": cv}

    if kind in ("dense", "moe"):
        def body(hh, xs):
            lp, ck, cv = xs
            hh = hint_btd(hh)
            a, ck2, cv2 = attn_prefill(cfg, lp["attn"],
                                       rms_norm(hh, lp["ln1"], cfg.norm_eps),
                                       positions, ck, cv,
                                       window=cfg.sliding_window)
            hh = hh + a
            hn = rms_norm(hh, lp["ln2"], cfg.norm_eps)
            if kind == "moe":
                mo, _ = moe.moe_fwd(cfg, lp["moe"], hn)
                hh = hh + mo
            else:
                hh = hh + ffn_fwd(cfg, lp["ffn"], hn)
            return hh, (ck2, cv2)

        h, (ck, cv) = jax.lax.scan(body, h, (sp, cache["k"], cache["v"]))
        return h, {"k": ck, "v": cv}

    if kind == "mamba":
        def body(hh, xs):
            lp, _ = xs
            hh = hint_btd(hh)
            out, c = mamba2.mamba_prefill(cfg, lp["mamba"],
                                          rms_norm(hh, lp["ln"], cfg.norm_eps))
            return hh + out, c

        h, c = jax.lax.scan(body, h, (sp, cache))
        return h, c

    if kind == "mlstm":
        def body(hh, xs):
            lp, _ = xs
            out, st = xlstm.mlstm_fwd(cfg, lp, hint_btd(hh), return_state=True)
            return out, st

        h, st = jax.lax.scan(body, h, (sp, cache))
        return h, st

    if kind == "slstm":
        def body(hh, xs):
            lp, _ = xs
            out, st = xlstm.slstm_fwd(cfg, lp, hint_btd(hh), return_state=True)
            return out, st

        h, st = jax.lax.scan(body, h, (sp, cache))
        return h, st

    if kind == "xdec":
        def body(hh, xs):
            lp, ck, cv, _, _ = xs
            hh = hint_btd(hh)
            a, ck2, cv2 = attn_prefill(cfg, lp["attn"],
                                       rms_norm(hh, lp["ln1"], cfg.norm_eps),
                                       positions, ck, cv)
            hh = hh + a
            ek, ev = xattn_kv(cfg, lp["xattn"], enc_out)
            hh = hh + xattn_fwd(cfg, lp["xattn"],
                                rms_norm(hh, lp["ln2"], cfg.norm_eps), ek, ev)
            hh = hh + ffn_fwd(cfg, lp["ffn"],
                              rms_norm(hh, lp["ln3"], cfg.norm_eps), gated=False)
            return hh, (ck2, cv2, ek.astype(ck2.dtype), ev.astype(cv2.dtype))

        h, (ck, cv, xk, xv) = jax.lax.scan(
            body, h, (sp, cache["k"], cache["v"], cache["xk"], cache["xv"]))
        return h, {"k": ck, "v": cv, "xk": xk, "xv": xv}

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# segment stack: decode (one token)
# ---------------------------------------------------------------------------

def seg_decode(cfg: ModelConfig, seg: Segment, sp, shared, h1, pos, cache):
    kind = seg.kind
    if kind == "shared_attn":
        lp = shared
        a, ck, cv = attn_decode(cfg, lp["attn"],
                                rms_norm(h1, lp["ln1"], cfg.norm_eps), pos,
                                cache["k"], cache["v"])
        h1 = h1 + a
        h1 = h1 + ffn_fwd(cfg, lp["ffn"], rms_norm(h1, lp["ln2"], cfg.norm_eps))
        return h1, {"k": ck, "v": cv}

    if kind in ("dense", "moe"):
        def body(hh, xs):
            lp, ck, cv = xs
            hh = hint_btd(hh)
            a, ck2, cv2 = attn_decode(cfg, lp["attn"],
                                      rms_norm(hh, lp["ln1"], cfg.norm_eps),
                                      pos, ck, cv, window=cfg.sliding_window)
            hh = hh + a
            hn = rms_norm(hh, lp["ln2"], cfg.norm_eps)
            if kind == "moe":
                mo, _ = moe.moe_fwd(cfg, lp["moe"], hn)
                hh = hh + mo
            else:
                hh = hh + ffn_fwd(cfg, lp["ffn"], hn)
            return hh, (ck2, cv2)

        h1, (ck, cv) = jax.lax.scan(body, h1, (sp, cache["k"], cache["v"]))
        return h1, {"k": ck, "v": cv}

    if kind == "mamba":
        def body(hh, xs):
            lp, c = xs
            hh = hint_btd(hh)
            out, c2 = mamba2.mamba_decode(cfg, lp["mamba"],
                                          rms_norm(hh, lp["ln"], cfg.norm_eps), c)
            return hh + out, c2

        h1, c = jax.lax.scan(body, h1, (sp, cache))
        return h1, c

    if kind == "mlstm":
        def body(hh, xs):
            lp, c = xs
            out, c2 = xlstm.mlstm_decode(cfg, lp, hint_btd(hh), c)
            return out, c2

        h1, c = jax.lax.scan(body, h1, (sp, cache))
        return h1, c

    if kind == "slstm":
        def body(hh, xs):
            lp, c = xs
            out, c2 = xlstm.slstm_decode(cfg, lp, hint_btd(hh), c)
            return out, c2

        h1, c = jax.lax.scan(body, h1, (sp, cache))
        return h1, c

    if kind == "xdec":
        def body(hh, xs):
            lp, ck, cv, xk, xv = xs
            hh = hint_btd(hh)
            a, ck2, cv2 = attn_decode(cfg, lp["attn"],
                                      rms_norm(hh, lp["ln1"], cfg.norm_eps),
                                      pos, ck, cv)
            hh = hh + a
            hh = hh + xattn_fwd(cfg, lp["xattn"],
                                rms_norm(hh, lp["ln2"], cfg.norm_eps), xk, xv)
            hh = hh + ffn_fwd(cfg, lp["ffn"],
                              rms_norm(hh, lp["ln3"], cfg.norm_eps), gated=False)
            return hh, (ck2, cv2, xk, xv)

        h1, (ck, cv, xk, xv) = jax.lax.scan(
            body, h1, (sp, cache["k"], cache["v"], cache["xk"], cache["xv"]))
        return h1, {"k": ck, "v": cv, "xk": xk, "xv": xv}

    raise ValueError(kind)
