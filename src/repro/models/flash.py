"""Blocked (flash-style) attention in pure JAX with a custom VJP.

Never materializes the (S, T) score matrix: nested ``lax.scan`` over
(q-block, kv-block) tiles with online softmax, f32 accumulators, and a
flash-style backward (one recompute of the tile probabilities, dq carried as
an f32 buffer).  This is simultaneously

  * the memory-feasible attention path for long-sequence cells
    (prefill_32k / train_4k), and
  * the pure-jnp oracle structure mirrored by ``kernels/flash_attention``.

Layout: q (B, S, H, E); k, v (B, T, K, E) with H = G·K (GQA).  The mask is
positional: causal with optional sliding window, with ``q_offset`` giving the
absolute position of query row 0.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos, kpos, causal, window):
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok = kpos[None, :] <= qpos[:, None]
    if window:
        ok = ok & (kpos[None, :] > qpos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _fwd(q, k, v, causal, window, q_offset, bq, bk):
    B, S, H, E = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = E ** -0.5
    nq, nk = S // bq, T // bk
    qb = q.reshape(B, nq, bq, K, G, E)
    kb = k.reshape(B, nk, bk, K, E)
    vb = v.reshape(B, nk, bk, K, E)

    def q_step(_, qi_idx):
        qi, iq = qi_idx
        qpos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(carry, kv_idx):
            m, l, acc = carry
            kj, vj, jk = kv_idx
            kpos = jk * bk + jnp.arange(bk)
            s = jnp.einsum("bqkge,btke->bkgqt", qi, kj).astype(jnp.float32)
            s = s * scale + _mask(qpos, kpos, causal, window)[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * l + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqt,btke->bkgqe", p.astype(qi.dtype), vj)
            acc_new = corr[..., None] * acc + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq), jnp.float32)
        a0 = jnp.zeros((B, K, G, bq, E), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (o, lse)

    _, (ob, lseb) = jax.lax.scan(
        q_step, None, (qb.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nq)))
    # ob: (nq, B, K, G, bq, E) -> (B, S, H, E)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, E)
    return out, lseb   # lse kept in block layout (nq,B,K,G,bq) for the bwd


def _bwd_impl(q, k, v, out, lse, dout, causal, window, q_offset, bq, bk):
    B, S, H, E = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = E ** -0.5
    nq, nk = S // bq, T // bk
    qb = q.reshape(B, nq, bq, K, G, E).transpose(1, 0, 2, 3, 4, 5)
    dob = dout.reshape(B, nq, bq, K, G, E).transpose(1, 0, 2, 3, 4, 5)
    ob = out.reshape(B, nq, bq, K, G, E).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, bk, K, E).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, bk, K, E).transpose(1, 0, 2, 3, 4)
    # D_i = rowsum(dout * out)
    Db = jnp.sum(dob.astype(jnp.float32) * ob.astype(jnp.float32), axis=-1)
    # Db: (nq, B, bq, K, G); lse: (nq, B, K, G, bq)
    Db = Db.transpose(0, 1, 3, 4, 2)                     # (nq,B,K,G,bq)

    def kv_step(dq_acc, kv_idx):
        kj, vj, jk = kv_idx
        kpos = jk * bk + jnp.arange(bk)

        def q_step(carry, q_idx):
            dk_j, dv_j = carry
            qi, doi, lsei, Di, iq = q_idx
            qpos = q_offset + iq * bq + jnp.arange(bq)
            s = jnp.einsum("bqkge,btke->bkgqt", qi, kj).astype(jnp.float32)
            s = s * scale + _mask(qpos, kpos, causal, window)[None, None, None]
            p = jnp.exp(s - lsei[..., None])                       # (B,K,G,q,t)
            dp = jnp.einsum("bqkge,btke->bkgqt", doi, vj).astype(jnp.float32)
            ds = p * (dp - Di[..., None]) * scale
            dqi = jnp.einsum("bkgqt,btke->bqkge", ds.astype(qi.dtype), kj)
            dk_j = dk_j + jnp.einsum("bkgqt,bqkge->btke",
                                     ds.astype(qi.dtype), qi).astype(jnp.float32)
            dv_j = dv_j + jnp.einsum("bkgqt,bqkge->btke",
                                     p.astype(doi.dtype), doi).astype(jnp.float32)
            return (dk_j, dv_j), dqi

        z = jnp.zeros((B, bk, K, E), jnp.float32)
        (dk_j, dv_j), dqs = jax.lax.scan(
            q_step, (z, z), (qb, dob, lse, Db, jnp.arange(nq)))
        dq_acc = dq_acc + dqs.astype(jnp.float32)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, B, bq, K, G, E), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(kv_step, dq0, (kb, vb, jnp.arange(nk)))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, E).astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, T, K, E).astype(k.dtype)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, T, K, E).astype(v.dtype)
    return dq, dk, dv


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, q_offset=0,
                    block_q=512, block_k=1024):
    """q: (B,S,H,E); k,v: (B,T,K,E) -> (B,S,H,E)."""
    out, _ = _fwd(q, k, v, causal, window, q_offset,
                  min(block_q, q.shape[1]), min(block_k, k.shape[1]))
    return out


def _vjp_fwd(q, k, v, causal, window, q_offset, block_q, block_k):
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    out, lse = _fwd(q, k, v, causal, window, q_offset, bq, bk)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, window, q_offset, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    return _bwd_impl(q, k, v, out, lse, dout, causal, window, q_offset, bq, bk)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
