# Intentionally import-light: submodules import each other and
# repro.distribution; a fat package __init__ creates cycles.
from repro.models.config import ModelConfig, Plan, Segment, build_plan, submodel_plan  # noqa: F401
