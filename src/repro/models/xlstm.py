"""xLSTM blocks: chunked mLSTM (matrix memory, linear-attention-like) and
sLSTM (scalar memory, true recurrence), with exponential gating + stabilizers.

mLSTM uses a chunkwise-parallel formulation (like SSD): intra-chunk quadratic
matmuls + an inter-chunk ``lax.scan`` carrying (C, n, m).  Decode is the O(1)
recurrence — which is what makes the ``long_500k`` cell feasible for this arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import pdtype, rms_norm

MINF = -1e30


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    sd = D ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (D, D)) * sd).astype(pdtype(cfg)),
        "wk": (jax.random.normal(ks[1], (D, D)) * sd).astype(pdtype(cfg)),
        "wv": (jax.random.normal(ks[2], (D, D)) * sd).astype(pdtype(cfg)),
        "wif": (jax.random.normal(ks[3], (D, 2 * H)) * sd).astype(jnp.float32),
        "bif": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "wo": (jax.random.normal(ks[4], (D, D)) * sd).astype(pdtype(cfg)),
        "wd": (jax.random.normal(ks[5], (D, D)) * sd).astype(pdtype(cfg)),
        "norm": jnp.ones((D,), pdtype(cfg)),
    }


def _mlstm_qkvg(cfg, p, xn):
    B, S, D = xn.shape
    H = cfg.n_heads
    P = D // H
    q = (xn @ p["wq"].astype(xn.dtype)).reshape(B, S, H, P)
    k = (xn @ p["wk"].astype(xn.dtype)).reshape(B, S, H, P)
    v = (xn @ p["wv"].astype(xn.dtype)).reshape(B, S, H, P)
    gif = xn.astype(jnp.float32) @ p["wif"] + p["bif"]
    logi = gif[..., :H]                                   # log input gate
    logf = jax.nn.log_sigmoid(gif[..., H:])               # log forget gate
    return q, k, v, logi, logf


def mlstm_core_chunked(q, k, v, logi, logf, chunk, state=None):
    """q,k,v: (B,S,H,P); logi/logf: (B,S,H). Returns (h, final_state)."""
    B, S, H, P = q.shape
    c = min(chunk, S)
    S0 = S
    if S % c:
        # pad: f=1 (logf=0) and i=0 (logi=-inf) leave the state untouched
        pad = c - S % c
        padt = lambda a, val=0.0: jnp.pad(
            a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
            constant_values=val)
        q, k, v = padt(q), padt(k), padt(v)
        logf = padt(logf)
        logi = padt(logi, MINF)
        S = S + pad
    NC = S // c
    sc = P ** -0.5

    qc = q.reshape(B, NC, c, H, P).astype(jnp.float32)
    kc = k.reshape(B, NC, c, H, P).astype(jnp.float32)
    vc = v.reshape(B, NC, c, H, P).astype(jnp.float32)
    lic = logi.reshape(B, NC, c, H)
    cumf = jnp.cumsum(logf.reshape(B, NC, c, H), axis=2)  # inclusive

    # intra-chunk log decay matrix  logD[i,j] = cumf_i - cumf_j + logi_j (j<=i)
    logD = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + lic[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    logD = jnp.where(tri[None, None, :, :, None], logD, MINF)

    if state is None:
        C0 = jnp.zeros((B, H, P, P), jnp.float32)
        n0 = jnp.zeros((B, H, P), jnp.float32)
        m0 = jnp.full((B, H), MINF, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def body(carry, inp):
        C, n, m = carry
        qq, kk, vv, lD, cf, li = inp                      # chunk-local
        # stabilizer per row
        m_intra = jnp.max(lD, axis=2)                     # (B,c,H)
        m_inter = cf + m[:, None, :]                      # (B,c,H)
        mi = jnp.maximum(m_intra, m_inter)
        Sij = jnp.exp(lD - mi[:, :, None, :])             # (B,i,j,H)
        qk = jnp.einsum("bihp,bjhp->bijh", qq, kk) * sc
        num = jnp.einsum("bijh,bijh,bjhp->bihp", qk, Sij, vv)
        den_vec = jnp.einsum("bijh,bjhp->bihp", Sij, kk)
        w_inter = jnp.exp(m_inter - mi)                   # (B,c,H)
        num = num + w_inter[..., None] * jnp.einsum("bihp,bhpq->bihq", qq, C) * sc
        den_vec = den_vec + w_inter[..., None] * n[:, None, :, :]
        den = jnp.abs(jnp.einsum("bihp,bihp->bih", qq, den_vec)) * sc
        h = num / jnp.maximum(den, jnp.exp(-mi))[..., None]

        # carry to next chunk
        cf_last = cf[:, -1, :]                            # (B,H)
        dj = cf_last[:, None, :] - cf + li                # (B,c,H) decay j->end
        m_new = jnp.maximum(cf_last + m, jnp.max(dj, axis=1))
        wC = jnp.exp(cf_last + m - m_new)
        wj = jnp.exp(dj - m_new[:, None, :])
        C_new = wC[:, :, None, None] * C + jnp.einsum("bjh,bjhp,bjhq->bhpq", wj, kk, vv)
        n_new = wC[:, :, None] * n + jnp.einsum("bjh,bjhp->bhp", wj, kk)
        return (C_new, n_new, m_new), h

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), logD.transpose(1, 0, 2, 3, 4),
          cumf.transpose(1, 0, 2, 3), lic.transpose(1, 0, 2, 3))
    (Cf, nf, mf), hs = jax.lax.scan(body, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H * P)[:, :S0]
    return h, {"C": Cf, "n": nf, "m": mf}


def mlstm_fwd(cfg, p, x, state=None, return_state=False):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v, logi, logf = _mlstm_qkvg(cfg, p, xn)
    h, st = mlstm_core_chunked(q, k, v, logi, logf, cfg.ssm_chunk or 128, state)
    o = jax.nn.sigmoid(xn @ p["wo"].astype(xn.dtype))
    out = (o * h.astype(xn.dtype)) @ p["wd"].astype(xn.dtype)
    if return_state:
        return x + out, st
    return x + out


def mlstm_cache_init(cfg, B):
    H, P = cfg.n_heads, cfg.d_model // cfg.n_heads
    return {"C": jnp.zeros((B, H, P, P), jnp.float32),
            "n": jnp.zeros((B, H, P), jnp.float32),
            "m": jnp.full((B, H), MINF, jnp.float32)}


def mlstm_decode(cfg, p, x1, cache):
    """x1: (B,1,D) single step recurrence."""
    xn = rms_norm(x1, p["norm"], cfg.norm_eps)
    q, k, v, logi, logf = _mlstm_qkvg(cfg, p, xn)
    B, _, H, P = q.shape
    qf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    li, lf = logi[:, 0], logf[:, 0]                       # (B,H)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf + m, li)
    fi = jnp.exp(lf + m - m_new)
    ii = jnp.exp(li - m_new)
    C_new = fi[:, :, None, None] * C + ii[:, :, None, None] * \
        jnp.einsum("bhp,bhq->bhpq", kf, vf)
    n_new = fi[:, :, None] * n + ii[:, :, None] * kf
    sc = P ** -0.5
    num = jnp.einsum("bhp,bhpq->bhq", qf, C_new) * sc
    den = jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n_new)) * sc
    h = (num / jnp.maximum(den, jnp.exp(-m_new))[..., None]).reshape(B, 1, H * P)
    o = jax.nn.sigmoid(xn @ p["wo"].astype(xn.dtype))
    out = (o * h.astype(xn.dtype)) @ p["wd"].astype(xn.dtype)
    return x1 + out, {"C": C_new, "n": n_new, "m": m_new}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.n_heads
    P = D // H
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": (jax.random.normal(k1, (D, 4 * D)) * D ** -0.5).astype(jnp.float32),
        "r": (jax.random.normal(k2, (4, H, P, P)) * P ** -0.5).astype(jnp.float32),
        "b": jnp.zeros((4 * D,), jnp.float32),
        "wd": (jax.random.normal(k3, (D, D)) * D ** -0.5).astype(pdtype(cfg)),
        "norm": jnp.ones((D,), pdtype(cfg)),
    }


def _slstm_step(cfg, p, gates_x, carry):
    """gates_x: (B, 4D) input contribution; carry: dict of (B,D) f32."""
    D, H = cfg.d_model, cfg.n_heads
    P = D // H
    B = gates_x.shape[0]
    h, c, n, m = carry["h"], carry["c"], carry["n"], carry["m"]
    hh = h.reshape(B, H, P)
    rec = jnp.stack([jnp.einsum("bhp,hpq->bhq", hh, p["r"][g])
                     for g in range(4)], axis=1).reshape(B, 4 * D)
    g = gates_x + rec
    zi, ii, ff, oo = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oo)
    logf = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(logf + m, ii)
    i_ = jnp.exp(ii - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_cache_init(cfg, B):
    D = cfg.d_model
    z = jnp.zeros((B, D), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((B, D), -30.0, jnp.float32)}


def slstm_fwd(cfg, p, x, state=None, return_state=False):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    B, S, D = x.shape
    gx = xn.astype(jnp.float32) @ p["w"] + p["b"]          # (B,S,4D)
    carry0 = state if state is not None else slstm_cache_init(cfg, B)

    def step(carry, g):
        new = _slstm_step(cfg, p, g, carry)
        return new, new["h"]

    carry_f, hs = jax.lax.scan(step, carry0, gx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)              # (B,S,D)
    out = x + h @ p["wd"].astype(x.dtype)
    if return_state:
        return out, carry_f
    return out


def slstm_decode(cfg, p, x1, cache):
    xn = rms_norm(x1, p["norm"], cfg.norm_eps)
    gx = xn[:, 0].astype(jnp.float32) @ p["w"] + p["b"]
    new = _slstm_step(cfg, p, gx, cache)
    out = x1 + (new["h"].astype(x1.dtype) @ p["wd"].astype(x1.dtype))[:, None, :]
    return out, new
