"""Core layers: norms, RoPE, GQA attention (full / cached-decode / cross), FFN.

Pure-JAX, functional: every layer is ``fwd(cfg, params, x, ...)`` with params a
dict pytree.  All softmax / norm accumulation happens in float32 regardless of
the compute dtype.  Shapes use ``B`` batch, ``S`` sequence, ``D`` d_model,
``H`` q-heads, ``K`` kv-heads, ``E`` head_dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.flash import flash_attention

NEG_INF = -1e30


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target."""
    for d in range(min(n, target), 0, -1):
        if n % d == 0:
            return d
    return n


def attend(q, k, v, *, causal=True, window=0, q_offset=0):
    """Dispatch between dense masked attention (small) and blocked flash
    attention (large, memory-bounded).  q: (B,S,H,E); k,v: (B,T,K,E)."""
    S, T = q.shape[1], k.shape[1]
    B, H, E = q.shape[0], q.shape[2], q.shape[3]
    if S >= 1024 and S * T > 4 * 1024 * 1024:
        bq = _pick_block(S, 512)
        bk = _pick_block(T, 1024)
        out = flash_attention(q, k, v, causal, window, q_offset, bq, bk)
        return out.reshape(B, S, H * E)
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(T)
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = kpos[None, :] <= qpos[:, None]
    if window:
        ok = ok & (kpos[None, :] > qpos[:, None] - window)
    mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    return gqa_attend(q, k, v, mask)


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x, w, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_tables(positions, dim, theta):
    """positions: (S,) int32 -> cos,sin (S, dim/2) float32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / dim))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, fraction=1.0):
    """x: (B, S, H, E); rotate the first ``fraction`` of E pairwise."""
    e = x.shape[-1]
    rot = int(e * fraction)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c = cos[None, :, None, : rot // 2].astype(jnp.float32)
    s = sin[None, :, None, : rot // 2].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * c - x2f * s
    o2 = x2f * c + x1f * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < e else out


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, n_heads=None, n_kv=None):
    H = n_heads or cfg.n_heads
    Hp = cfg.n_heads_padded if n_heads is None else H
    K = n_kv or cfg.n_kv_heads
    E, D = cfg.head_dim, cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sd = D ** -0.5
    wq = jax.random.normal(k1, (D, Hp * E)) * sd
    wo = jax.random.normal(k4, (Hp * E, D)) * (H * E) ** -0.5
    if Hp > H:
        # zero-pad PER KV GROUP (the (K, G, E) reshape is kv-major, so tail
        # padding would rewire which kv head each q head attends to);
        # wo's padded rows MUST be zero so outputs are unchanged
        G, Gp = H // K, Hp // K
        wq = wq.reshape(D, K, Gp, E).at[:, :, G:, :].set(0.0).reshape(D, Hp * E)
        wo = wo.reshape(K, Gp, E, D).at[:, G:, :, :].set(0.0).reshape(Hp * E, D)
    p = {
        "wq": wq.astype(pdtype(cfg)),
        "wk": (jax.random.normal(k2, (D, K * E)) * sd).astype(pdtype(cfg)),
        "wv": (jax.random.normal(k3, (D, K * E)) * sd).astype(pdtype(cfg)),
        "wo": wo.astype(pdtype(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hp * E,), pdtype(cfg))
        p["bk"] = jnp.zeros((K * E,), pdtype(cfg))
        p["bv"] = jnp.zeros((K * E,), pdtype(cfg))
    if cfg.qk_norm:
        p["qn"] = jnp.ones((E,), pdtype(cfg))
        p["kn"] = jnp.ones((E,), pdtype(cfg))
    return p


def _qkv(cfg, p, x, n_heads, n_kv, positions, use_rope=True):
    B, S, _ = x.shape
    E = cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, n_heads, E)
    k = k.reshape(B, S, n_kv, E)
    v = v.reshape(B, S, n_kv, E)
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    if use_rope and cfg.rope_variant != "none":
        frac = 0.5 if cfg.rope_variant == "half" else 1.0
        cos, sin = rope_tables(positions, E, cfg.rope_theta)
        q = apply_rope(q, cos, sin, frac)
        k = apply_rope(k, cos, sin, frac)
    return q, k, v


def gqa_attend(q, k, v, mask):
    """q: (B,S,H,E), k/v: (B,T,K,E), mask: (S,T) or (B,S,T) additive f32."""
    B, S, H, E = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, E)
    scores = jnp.einsum("bskge,btke->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (E ** -0.5)
    m = mask if mask.ndim == 3 else mask[None]
    scores = scores + m[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btke->bskge", w, v)
    return out.reshape(B, S, H * E)


def causal_mask(S, T=None, window=0, offset=0):
    """Additive (S,T) mask. offset = absolute position of query row 0."""
    T = T or S
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    ok = kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attn_fwd(cfg, p, x, positions, *, causal=True, window=0,
             n_heads=None, n_kv=None, use_rope=True):
    """Full (uncached) attention — training and encoder paths."""
    H = n_heads or cfg.n_heads_padded
    K = n_kv or cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x, H, K, positions, use_rope)
    out = attend(q, k, v, causal=causal, window=window)
    return out @ p["wo"].astype(x.dtype)


def attn_prefill(cfg, p, x, positions, cache_k, cache_v, *, window=0,
                 n_heads=None, n_kv=None):
    """Prefill: attend causally over x AND write k/v into the cache.

    cache_k/v: (B, Skv, K, E) with Skv >= S (or == window for SWA ring)."""
    H = n_heads or cfg.n_heads_padded
    K = n_kv or cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x, H, K, positions)
    S = x.shape[1]
    Skv = cache_k.shape[1]
    if window and Skv == window and S > window:
        # SWA ring buffer: retain only the trailing `window` tokens, placed at
        # slot (absolute_position % window) so decode can continue the ring.
        tail_k = jax.lax.dynamic_slice_in_dim(k, S - window, window, axis=1)
        tail_v = jax.lax.dynamic_slice_in_dim(v, S - window, window, axis=1)
        roll = S % window   # slot of absolute position (S - window)
        ck = jnp.roll(tail_k, roll, axis=1).astype(cache_k.dtype)
        cv = jnp.roll(tail_v, roll, axis=1).astype(cache_v.dtype)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), 0, axis=1)
    out = attend(q, k, v, causal=True, window=window)
    return out @ p["wo"].astype(x.dtype), ck, cv


def attn_decode(cfg, p, x1, pos, cache_k, cache_v, *, window=0,
                n_heads=None, n_kv=None):
    """Single-token decode. x1: (B,1,D); pos: scalar int32 (same across batch).

    cache is (B, Skv, K, E); for windowed attention Skv == window and the
    cache is a ring buffer indexed pos % window.
    """
    H = n_heads or cfg.n_heads_padded
    K = n_kv or cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x1, H, K, jnp.asarray(pos)[None])
    Skv = cache_k.shape[1]
    slot = pos % Skv if window else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                             slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                             slot, axis=1)
    kpos = jnp.arange(Skv)
    if window:
        valid = (kpos <= slot) | (pos >= Skv)   # ring fully valid once wrapped
    else:
        valid = kpos <= pos
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, None, :]
    out = gqa_attend(q, ck.astype(x1.dtype), cv.astype(x1.dtype),
                     jnp.broadcast_to(mask, (x1.shape[0], 1, Skv)))
    return out @ p["wo"].astype(x1.dtype), ck, cv


def xattn_init(key, cfg: ModelConfig):
    return attn_init(key, cfg)


def xattn_fwd(cfg, p, x, enc_k, enc_v):
    """Cross attention against precomputed encoder K/V: (B, Senc, K, E)."""
    B, S, _ = x.shape
    H, K, E = cfg.n_heads_padded, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, E)
    out = attend(q, enc_k.astype(x.dtype), enc_v.astype(x.dtype), causal=False)
    return out @ p["wo"].astype(x.dtype)


def xattn_kv(cfg, p, enc_out):
    B, T, _ = enc_out.shape
    K, E = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(B, T, K, E)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(B, T, K, E)
    return k, v


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------

def ffn_init(key, cfg: ModelConfig, gated=True):
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": (jax.random.normal(k1, (D, F)) * D ** -0.5).astype(pdtype(cfg)),
         "w2": (jax.random.normal(k2, (F, D)) * F ** -0.5).astype(pdtype(cfg))}
    if gated:
        p["w3"] = (jax.random.normal(k3, (D, F)) * D ** -0.5).astype(pdtype(cfg))
    return p


def ffn_fwd(cfg, p, x, gated=True):
    h = x @ p["w1"].astype(x.dtype)
    if gated:
        h = jax.nn.silu(h) * (x @ p["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    return h @ p["w2"].astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding / exit heads
# --------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    V, D = cfg.padded_vocab, cfg.d_model
    p = {"tok": (jax.random.normal(key, (V, D)) * 0.02).astype(pdtype(cfg))}
    if cfg.frontend in ("patch", "audio"):
        k2 = jax.random.fold_in(key, 1)
        p["adapter"] = (jax.random.normal(k2, (D, D)) * D ** -0.5).astype(pdtype(cfg))
        p["adapter_norm"] = jnp.ones((D,), pdtype(cfg))
    return p


def embed_tokens(cfg, p, tokens):
    return jnp.take(p["tok"], tokens, axis=0).astype(cdtype(cfg))


def embed_frontend(cfg, p, feats):
    """Stub modality frontend: precomputed embeddings -> adapter."""
    h = rms_norm(feats.astype(cdtype(cfg)), p["adapter_norm"], cfg.norm_eps)
    return h @ p["adapter"].astype(h.dtype)


def exit_head_init(key, cfg: ModelConfig):
    D, V = cfg.d_model, cfg.padded_vocab
    return {"norm": jnp.ones((D,), pdtype(cfg)),
            "head": (jax.random.normal(key, (D, V)) * D ** -0.5).astype(pdtype(cfg))}


def exit_head_fwd(cfg, p, x):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    return h @ p["head"].astype(h.dtype)
