"""Mamba2 (SSD) layer: chunked state-space scan, TPU-friendly.

The chunked (state-space-dual) formulation expresses almost all compute as
chunk-local matmuls (MXU-friendly, honest HLO FLOPs) plus a tiny inter-chunk
``lax.scan`` carrying the (H, P, N) state.  Decode is the O(1) recurrence.

Projections are kept *separate* (z / x / B / C / dt) rather than fused, so
each output dim shards cleanly: x,z over "model" (head-aligned: I = H·P),
B/C/dt small (replicated out-dim).  This is a TPU-sharding adaptation of the
reference CUDA layout, which fuses them for kernel-launch reasons that do not
apply here.

Shapes: B batch, S seq, D d_model, I=d_inner, H ssm heads, P head_dim,
N d_state, c chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import pdtype, rms_norm


def mamba_init(key, cfg: ModelConfig):
    D, I, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    sd = D ** -0.5
    return {
        "z_proj": (jax.random.normal(ks[0], (D, I)) * sd).astype(pdtype(cfg)),
        "x_proj": (jax.random.normal(ks[1], (D, I)) * sd).astype(pdtype(cfg)),
        "B_proj": (jax.random.normal(ks[2], (D, N)) * sd).astype(pdtype(cfg)),
        "C_proj": (jax.random.normal(ks[3], (D, N)) * sd).astype(pdtype(cfg)),
        "dt_proj": (jax.random.normal(ks[4], (D, H)) * sd).astype(pdtype(cfg)),
        "conv_x": (jax.random.normal(jax.random.fold_in(key, 7), (K, I)) * 0.1
                   ).astype(pdtype(cfg)),
        "conv_B": (jax.random.normal(jax.random.fold_in(key, 8), (K, N)) * 0.1
                   ).astype(pdtype(cfg)),
        "conv_C": (jax.random.normal(jax.random.fold_in(key, 9), (K, N)) * 0.1
                   ).astype(pdtype(cfg)),
        "conv_bx": jnp.zeros((I,), pdtype(cfg)),
        "conv_bB": jnp.zeros((N,), pdtype(cfg)),
        "conv_bC": jnp.zeros((N,), pdtype(cfg)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((I,), pdtype(cfg)),
        "out_proj": (jax.random.normal(ks[5], (I, D)) * I ** -0.5).astype(pdtype(cfg)),
    }


def _causal_conv(x, w, b, K):
    """Depthwise causal conv over time. x: (B, S, C)."""
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    wc = w.astype(x.dtype)
    out = sum(pad[:, i:i + x.shape[1], :] * wc[i][None, None, :] for i in range(K))
    return jax.nn.silu(out + b.astype(x.dtype))


def _ssd_inputs(cfg, p, x):
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    B_, S, _ = x.shape
    K = cfg.ssm_conv
    z = x @ p["z_proj"].astype(x.dtype)
    xr = _causal_conv(x @ p["x_proj"].astype(x.dtype), p["conv_x"], p["conv_bx"], K)
    Bs = _causal_conv(x @ p["B_proj"].astype(x.dtype), p["conv_B"], p["conv_bB"], K)
    Cs = _causal_conv(x @ p["C_proj"].astype(x.dtype), p["conv_C"], p["conv_bC"], K)
    xs = xr.reshape(B_, S, H, P)
    dt = jax.nn.softplus((x @ p["dt_proj"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"])                                 # (B,S,H)
    A = -jnp.exp(p["A_log"])                                             # (H,)
    la = dt * A[None, None, :]                                           # log decay
    xbar = xs.astype(jnp.float32) * dt[..., None]                        # (B,S,H,P)
    return z, xs, Bs, Cs, la, xbar


def mamba_fwd(cfg: ModelConfig, p, x, state0=None, return_state=False):
    """Full-sequence SSD. x: (B,S,D). state0: optional (B,H,P,N) carry-in."""
    c = cfg.ssm_chunk
    B_, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z, xs, Bs, Cs, la, xbar = _ssd_inputs(cfg, p, x)

    # pad to a chunk multiple: log-decay 0 (a=1) and zero inputs leave the
    # carried state untouched; padded outputs are sliced away
    S0 = S
    if S % c:
        pad = c - S % c
        padt = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        Bs, Cs, la, xbar = padt(Bs), padt(Cs), padt(la), padt(xbar)
        xs = padt(xs)
        S = S + pad
    NC = S // c

    lac = la.reshape(B_, NC, c, H)
    cum = jnp.cumsum(lac, axis=2)                                        # inclusive
    Bc = Bs.reshape(B_, NC, c, N).astype(jnp.float32)
    Cc = Cs.reshape(B_, NC, c, N).astype(jnp.float32)
    xbc = xbar.reshape(B_, NC, c, H, P)

    # ---- intra-chunk (quadratic in c, matmul-heavy) ----
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]                  # (B,NC,i,j,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bniN,bnjN->bnij", Cc, Bc)                           # (B,NC,c,c)
    scores = CB[:, :, :, :, None] * L                                    # (B,NC,i,j,H)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", scores, xbc)

    # ---- chunk states + inter-chunk carry ----
    total = cum[:, :, -1, :]                                             # (B,NC,H)
    decay_end = jnp.exp(total[:, :, None, :] - cum)                      # (B,NC,c,H)
    S_chunk = jnp.einsum("bnjh,bnjN,bnjhp->bnhpN", decay_end, Bc, xbc)

    def carry(s, inp):
        tot, sc = inp
        s_next = jnp.exp(tot)[:, :, None, None] * s + sc
        return s_next, s

    s0 = (jnp.zeros((B_, H, P, N), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))
    s_final, s_prev = jax.lax.scan(
        carry, s0, (total.transpose(1, 0, 2), S_chunk.transpose(1, 0, 2, 3, 4)))
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)                             # (B,NC,H,P,N)

    decay_pre = jnp.exp(cum)                                             # (B,NC,c,H)
    y_inter = jnp.einsum("bnih,bniN,bnhpN->bnihp", decay_pre, Cc, s_prev)

    y = (y_intra + y_inter).reshape(B_, S, H, P)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B_, S, cfg.d_inner)[:, :S0].astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, s_final
    return out


def mamba_cache_init(cfg: ModelConfig, B, dtype=jnp.float32):
    H, P, N, I, K = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                     cfg.d_inner, cfg.ssm_conv)
    return {"conv_x": jnp.zeros((B, K - 1, I), dtype),
            "conv_B": jnp.zeros((B, K - 1, N), dtype),
            "conv_C": jnp.zeros((B, K - 1, N), dtype),
            "state": jnp.zeros((B, H, P, N), jnp.float32)}


def mamba_prefill(cfg, p, x):
    """Run full fwd and also emit the decode cache."""
    out, s_final = mamba_fwd(cfg, p, x, return_state=True)
    K = cfg.ssm_conv
    tail = slice(-(K - 1), None)
    cache = {
        "conv_x": (x @ p["x_proj"].astype(x.dtype))[:, tail, :].astype(jnp.float32),
        "conv_B": (x @ p["B_proj"].astype(x.dtype))[:, tail, :].astype(jnp.float32),
        "conv_C": (x @ p["C_proj"].astype(x.dtype))[:, tail, :].astype(jnp.float32),
        "state": s_final,
    }
    return out, cache


def _conv_step(window, w, b):
    """window: (B, K, C) -> (B, C)."""
    out = jnp.sum(window * w[None, :, :].astype(window.dtype), axis=1)
    return jax.nn.silu(out + b.astype(window.dtype))


def mamba_decode(cfg: ModelConfig, p, x1, cache):
    """One-token recurrence. x1: (B,1,D)."""
    I, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    B_ = x1.shape[0]
    xt = x1[:, 0]
    z = xt @ p["z_proj"].astype(x1.dtype)
    xn = (xt @ p["x_proj"].astype(x1.dtype)).astype(jnp.float32)
    Bn = (xt @ p["B_proj"].astype(x1.dtype)).astype(jnp.float32)
    Cn = (xt @ p["C_proj"].astype(x1.dtype)).astype(jnp.float32)
    wx = jnp.concatenate([cache["conv_x"], xn[:, None]], axis=1)         # (B,K,I)
    wB = jnp.concatenate([cache["conv_B"], Bn[:, None]], axis=1)
    wC = jnp.concatenate([cache["conv_C"], Cn[:, None]], axis=1)
    xc = _conv_step(wx, p["conv_x"].astype(jnp.float32), p["conv_bx"])
    Bc = _conv_step(wB, p["conv_B"].astype(jnp.float32), p["conv_bB"])
    Cc = _conv_step(wC, p["conv_C"].astype(jnp.float32), p["conv_bC"])
    xs = xc.reshape(B_, H, P)
    dt = jax.nn.softplus((xt @ p["dt_proj"].astype(x1.dtype)).astype(jnp.float32)
                         + p["dt_bias"])                                 # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])                                         # (B,H)
    xbar = xs * dt[..., None]                                            # (B,H,P)
    s = cache["state"] * a[:, :, None, None] + \
        jnp.einsum("bhp,bN->bhpN", xbar, Bc)
    y = jnp.einsum("bN,bhpN->bhp", Cc, s) + p["D"][None, :, None] * xs
    y = y.reshape(B_, I).astype(x1.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(x1.dtype))[:, None, :]
    new_cache = {"conv_x": wx[:, 1:], "conv_B": wB[:, 1:], "conv_C": wC[:, 1:],
                 "state": s}
    return out, new_cache
