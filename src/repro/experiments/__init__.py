"""Multi-scenario experiment harnesses built on the batched solver."""
