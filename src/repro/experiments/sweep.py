"""Scenario-grid sweep through the batched PDHG solver.

Fans a cross-product of :class:`MECConfig` variants (topology size, Zipf
skew, memory capacity, deadline — the axes of the paper's Sec. VII
comparisons) into per-variant JDCR windows, solves ALL of them in one
vmapped PDHG dispatch (``cocar_windows_batched``), and emits one flat
results table: a list of row dicts, each carrying the swept axis values,
the LP objective, and the post-rounding window metrics.

``benchmarks/tables.py::sweep_table`` persists the table next to the other
paper tables; run standalone with

    PYTHONPATH=src python -m repro.experiments.sweep
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core.cocar import cocar_windows_batched
from repro.mec import metrics as MET
from repro.mec.scenario import MECConfig, Scenario, config_grid

#: Default sweep: 2^4 = 16 variants over the four axes the paper varies.
#: n_bs values sit close together on purpose — heterogeneous topologies are
#: padded to the max N for the single dispatch, so a tight spread keeps the
#: padding waste low (vary it wider when the question needs it).
DEFAULT_AXES = {
    "n_bs": (5, 6),
    "zipf": (0.4, 0.8),
    "mem_capacity_mb": (300.0, 500.0),
    "ddl_s": (0.25, 0.35),
}


def run_sweep(base: MECConfig = None, axes: dict = None, window: int = 0,
              pdhg_iters: int = 4000, best_of: int = 8, seed: int = 0):
    """Solve one CoCaR window per grid variant, all in one batched dispatch.

    Returns a list of row dicts (one per variant, in grid order).
    """
    base = base or MECConfig(n_users=40)
    axes = axes or DEFAULT_AXES
    cfgs = config_grid(base, axes)
    scenarios = [Scenario(c) for c in cfgs]
    insts = [sc.instance(window, sc.empty_cache()) for sc in scenarios]
    solved = cocar_windows_batched(insts, seed=seed, pdhg_iters=pdhg_iters,
                                   best_of=best_of)
    rows = []
    for cfg, inst, (x, A, info) in zip(cfgs, insts, solved):
        row = {k: getattr(cfg, k) for k in axes}
        row["lp_obj"] = info["lp_obj"]
        row.update(MET.window_metrics(inst, x, A))
        rows.append(row)
    return rows


def format_table(rows) -> str:
    """Fixed-width text rendering of a sweep table."""
    if not rows:
        return "(empty sweep)"
    cols = list(rows[0])
    widths = {c: max(len(c), 9) for c in cols}
    fmt = "  ".join(f"{{:>{widths[c]}}}" for c in cols)
    lines = [fmt.format(*cols)]
    for r in rows:
        lines.append(fmt.format(*(
            f"{v:.3f}" if isinstance(v, float) else str(v)
            for v in (r[c] for c in cols))))
    return "\n".join(lines)


def main():
    rows = run_sweep()
    print(format_table(rows))
    out = pathlib.Path("results") / "sweep"
    out.mkdir(parents=True, exist_ok=True)
    path = out / "grid.json"
    path.write_text(json.dumps(rows, indent=1, default=float))
    print(f"\n{len(rows)} variants -> {path}")
    return rows


if __name__ == "__main__":
    main()
