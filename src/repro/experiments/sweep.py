"""Scenario-grid sweeps: the fused offline pipeline and the vmapped scan
engine (online).

Offline: fans a cross-product of :class:`MECConfig` variants (topology
size, Zipf skew, memory capacity, deadline — the axes of the paper's
Sec. VII comparisons) into per-variant JDCR windows and runs LP →
randomized rounding → repair → metrics for ALL of them — optionally
crossed with ``n_seeds`` independent rounding seeds — in ONE jitted/
vmapped device dispatch (``repro.core.cocar.cocar_grid``), emitting one
flat results table: a list of row dicts, each carrying the swept axis
values, the LP objective, and the post-repair window metrics.
``backend="host"`` keeps the NumPy round+repair loop (the reference
path) behind the same interface.

Online: ``run_online_sweep`` crosses config variants with *workload
families* (``repro.traces.make_workload``: flash crowds, diurnal load,
MMPP bursts, mobility, streaming Poisson arrivals, …) and policies, and
runs the whole grid — aggregated per-(BS, model) demand tensors, never
per-user ones — in ONE ``lax.scan``+vmap dispatch
(``repro.traces.engine.run_online_grid``) instead of per-scenario Python
slot loops.

``benchmarks/tables.py::sweep_table`` persists the offline table next to
the other paper tables; run standalone with

    PYTHONPATH=src python -m repro.experiments.sweep            # offline
    PYTHONPATH=src python -m repro.experiments.sweep --online   # online

``--shard`` partitions any of the grids across a host-device mesh via
the ``repro.scale`` executor (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=K``); ``--devices``
and ``--chunk`` tune the mesh width and streaming chunk.

Observability (``repro.obs``): diagnostics taps are ON by default —
PDHG residual/convergence columns on offline rows, per-slot cache
telemetry summaries on online rows — and provably decision-inert
(``--no-diag`` compiles them out).  Every results JSON gets a sibling
``*.manifest.json`` (git SHA, jax/device info, seeds, config hash) and
``*.trace.jsonl`` / ``*.trace.chrome.json`` span exports; render them
with ``scripts/report.py results/sweep``.  ``--smoke`` runs a 2-window
offline CI grid into ``results/sweep/ci/``.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core.cocar import cocar_grid
from repro.mec.scenario import MECConfig, Scenario, config_grid
from repro.obs import TRACER, convergence_table, write_manifest

#: Default sweep: 2^4 = 16 variants over the four axes the paper varies.
#: n_bs values sit close together on purpose — heterogeneous topologies are
#: padded to the max N for the single dispatch, so a tight spread keeps the
#: padding waste low (vary it wider when the question needs it).
DEFAULT_AXES = {
    "n_bs": (5, 6),
    "zipf": (0.4, 0.8),
    "mem_capacity_mb": (300.0, 500.0),
    "ddl_s": (0.25, 0.35),
}


def run_sweep(base: MECConfig = None, axes: dict = None, window: int = 0,
              pdhg_iters: int = 4000, best_of: int = 8, seed: int = 0,
              n_seeds: int = 1, backend: str = "device",
              devices: int = None, chunk_size: int = 0,
              max_buckets: int = 1, diagnostics: bool = False):
    """One CoCaR window per (grid variant × rounding seed), the whole grid
    as ONE fused device dispatch — LP, rounding, repair, trial argmax and
    window metrics all inside the jit (mirroring the ``--online`` grid).
    ``backend="sharded"`` (the ``--shard`` flag) partitions the grid
    across a host-device mesh via ``repro.scale`` — decision-identical,
    just spread over ``devices`` devices in ``chunk_size`` streams.
    ``max_buckets > 1`` opts heterogeneous grids into size-bucketed
    padding (still decision-identical; only the reported ``lp_obj``
    carries ~1e-14 reduction-order slack).

    ``diagnostics=True`` taps the PDHG solver's residual curves inside
    the jit (``repro.obs``) and adds ``pdhg_final_residual`` /
    ``pdhg_converged`` columns to every row — decisions stay bit-
    identical (device/sharded backends only; the host reference loop
    has no tap).

    Returns a list of row dicts (variant-major, seed-minor, in grid
    order); with ``n_seeds > 1`` each row carries its ``rounding_seed``.
    """
    base = base or MECConfig(n_users=40)
    axes = axes or DEFAULT_AXES
    cfgs = config_grid(base, axes)
    scenarios = [Scenario(c) for c in cfgs]
    insts = [sc.instance(window, sc.empty_cache()) for sc in scenarios]
    grid = cocar_grid(insts, seed=seed, pdhg_iters=pdhg_iters,
                      best_of=best_of, n_seeds=n_seeds, backend=backend,
                      devices=devices, chunk_size=chunk_size,
                      max_buckets=max_buckets, diagnostics=diagnostics)
    rows = []
    for cfg, per_seed in zip(cfgs, grid):
        for s, (_x, _A, info) in enumerate(per_seed):
            row = {k: getattr(cfg, k) for k in axes}
            if n_seeds > 1:
                row["rounding_seed"] = s
            row["lp_obj"] = info["lp_obj"]
            row.update(info["metrics"])
            if "lp_diag" in info:
                summ = info["lp_diag"]["summary"]
                row["pdhg_final_residual"] = summ["final_residual"]
                row["pdhg_converged"] = summ["converged"]
            rows.append(row)
    return rows


def run_policy_sweep(base: MECConfig = None, axes: dict = None,
                     window: int = 0, pdhg_iters: int = 4000,
                     best_of: int = 8, seed: int = 0, n_seeds: int = 1,
                     episodes: int = 60, backend: str = "device",
                     devices: int = None, chunk_size: int = 0,
                     max_buckets: int = 1, diagnostics: bool = False):
    """The paper's Sec. VII-B headline comparison — CoCaR vs SPR³ /
    Greedy / Random / GatMARL — across (grid variants × rounding seeds ×
    policies), every policy's decisions AND the shared evaluation stage in
    ONE fused device dispatch (GatMARL training excepted: host-side,
    cached per topology).

    ``diagnostics=True`` (device/sharded only) taps the CoCaR LP's PDHG
    residuals per window and attaches a ``summary["convergence"]`` table
    over the grid; decisions stay bit-identical.

    Returns ``(rows, summary)``: one row dict per (variant, seed, policy)
    plus a summary with per-policy grid means and the CoCaR-vs-best-
    baseline improvement ratio.
    """
    from repro.core.baselines import spr3_relaxed
    from repro.core.cocar import (gat_grid_policies, policy_grid_host,
                                  policy_uniforms)
    from repro.core.lp import solve_lp_pdhg_batched
    from repro.mec.scenario import stack_instances

    base = base or MECConfig(n_users=40)
    axes = axes or DEFAULT_AXES
    cfgs = config_grid(base, axes)
    scenarios = [Scenario(c) for c in cfgs]
    insts = [sc.instance(window, sc.empty_cache()) for sc in scenarios]

    lp_diag = None
    if backend in ("device", "sharded"):
        from repro.scale import GridSpec, run_grid

        gr = run_grid(GridSpec(
            kind="policy", insts=insts, seed=seed, n_seeds=n_seeds,
            best_of=best_of, pdhg_iters=pdhg_iters, episodes=episodes,
            backend="vmap" if backend == "device" else "sharded",
            devices=devices, chunk_size=chunk_size,
            max_buckets=max_buckets, diagnostics=diagnostics))
        res = gr.results
        lp_diag = gr.stats.get("lp_diag")
        met = _policy_met(res, len(insts), n_seeds)
    elif backend == "host":
        stacked = stack_instances(insts)
        uniforms = policy_uniforms(stacked, seed, n_seeds, best_of)
        gat = gat_grid_policies(stacked, seed, episodes)
        res = solve_lp_pdhg_batched(stacked.data, iters=pdhg_iters)
        relaxed = stack_instances([spr3_relaxed(i) for i in insts])
        res_s = solve_lp_pdhg_batched(relaxed.data, iters=pdhg_iters)
        host = policy_grid_host(stacked, uniforms, gat, res.x, res.A,
                                {"x": res_s.x, "A": res_s.A},
                                n_seeds=n_seeds)
        met = _policy_met(host, len(stacked), n_seeds)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    rows, summary = _policy_rows(cfgs, axes, met, n_seeds)
    if lp_diag:
        summary["convergence"] = convergence_table(
            np.asarray([d["final_residual"] for d in lp_diag]),
            tol=lp_diag[0]["tol"])
    return rows, summary


def _policy_met(results, n_windows, n_seeds):
    """``results[policy][b][s] = (x, A, metrics)`` → per-policy metric
    arrays ``met[p][k] (B, S)``."""
    from repro.core.cocar import OFFLINE_POLICIES

    return {p: {k: np.asarray(
        [[results[p][b][s][2][k] for s in range(n_seeds)]
         for b in range(n_windows)])
        for k in results[p][0][0][2]} for p in OFFLINE_POLICIES}


def _policy_rows(cfgs, axes, met, n_seeds):
    """Flatten per-policy metric arrays ``met[p][k] (B, S)`` into the
    sweep's row table + headline summary."""
    from repro.core.cocar import OFFLINE_POLICIES, improvement_ratio

    rows = []
    for i, cfg in enumerate(cfgs):
        for s in range(n_seeds):
            for p in OFFLINE_POLICIES:
                row = {k: getattr(cfg, k) for k in axes}
                if n_seeds > 1:
                    row["rounding_seed"] = s
                row["policy"] = p
                row.update({k: float(v[i, s])
                            for k, v in met[p].items()})
                rows.append(row)
    summary = improvement_ratio(
        {p: met[p]["avg_precision"] for p in OFFLINE_POLICIES})
    summary["avg_qoe"] = {p: float(np.mean(met[p]["avg_qoe"]))
                          for p in OFFLINE_POLICIES}
    return rows, summary


#: Default online sweep: 2 config axes x 2 workload families x 2 policies
#: = 16 scenarios, one vmapped scan dispatch.
DEFAULT_ONLINE_AXES = {
    "zipf": (0.4, 0.8),
    "mem_capacity_mb": (300.0, 500.0),
}
DEFAULT_WORKLOADS = ("stationary", "flash_crowd")
DEFAULT_POLICIES = ("cocar-ol", "lfu")


def run_online_sweep(base: MECConfig = None, axes: dict = None,
                     workloads=None, policies=DEFAULT_POLICIES,
                     ocfg=None, seed: int = 0, backend: str = "vmap",
                     devices: int = None, chunk_size: int = 0,
                     diagnostics: bool = False, registry=None):
    """Cross (config grid x workload family x policy), run everything in
    one vmapped scan dispatch (``backend="sharded"`` spreads it across a
    host-device mesh).  ``workloads`` names registry families
    (``repro.traces.make_workload`` — per-user traces and the streaming
    ``poisson_zipf`` family alike; all flow through the unified
    aggregated-demand engine).  ``diagnostics=True`` taps the per-slot
    cache telemetry inside the scan (hit rate, downloads in flight,
    evictions, cache occupancy) and adds summary columns — decisions and
    QoE stay bit-identical.  With a ``registry``
    (``repro.obs.metrics.MetricsRegistry``) every job's per-slot curves
    are additionally folded into the shared streaming-histogram schema
    (``online_hit_rate`` / ``online_dl_in_flight`` / ``online_evictions``
    — the same types the serving plane exports), still after the fact
    and decision-inert.  Returns a list of row dicts in grid order."""
    from repro.core.online import OnlineConfig
    from repro.traces.engine import run_online_grid
    from repro.traces.registry import make_workload

    workloads = workloads or DEFAULT_WORKLOADS
    base = base or MECConfig(n_users=150)
    axes = axes or DEFAULT_ONLINE_AXES
    ocfg = ocfg or OnlineConfig(n_slots=60)
    cfgs = config_grid(base, axes)
    jobs, keys = [], []
    for cfg in cfgs:
        for wname in workloads:
            wl = make_workload(wname, cfg, ocfg.n_slots, seed=seed)
            for algo in policies:
                jobs.append(dict(cfg=cfg, algo=algo, workload=wl,
                                 seed=seed))
                keys.append((cfg, wl, algo))
    results = run_online_grid(jobs, ocfg, backend=backend,
                              devices=devices, chunk_size=chunk_size,
                              diagnostics=diagnostics)
    rows = []
    for (cfg, wl, algo), res in zip(keys, results):
        row = {k: getattr(cfg, k) for k in axes}
        row.update(workload=wl.name, family=wl.family, algo=algo,
                   avg_qoe=res["avg_qoe"], hit_rate=res["hit_rate"])
        if "diagnostics" in res:
            d = res["diagnostics"]
            row["mean_dl_in_flight"] = float(np.mean(d["dl_in_flight"]))
            row["evictions"] = float(np.sum(d["evictions"]))
            row["final_cache_mb"] = float(d["cache_mb"][-1])
            if registry is not None:
                from repro.obs import observe_online_diag

                observe_online_diag(registry, d)
        rows.append(row)
    return rows


def format_table(rows) -> str:
    """Fixed-width text rendering of a sweep table."""
    if not rows:
        return "(empty sweep)"
    cols = list(rows[0])
    widths = {c: max(len(c), 9) for c in cols}
    fmt = "  ".join(f"{{:>{widths[c]}}}" for c in cols)
    lines = [fmt.format(*cols)]
    for r in rows:
        lines.append(fmt.format(*(
            f"{v:.3f}" if isinstance(v, float) else str(v)
            for v in (r[c] for c in cols))))
    return "\n".join(lines)


#: CI smoke grid: two small offline windows at the smallest iteration
#: budget whose final PDHG residuals all clear ``obs.DEFAULT_TOL``
#: (measured: max final residual 6.6e-3 at 3000 iterations, tol 1e-2).
SMOKE_AXES = {"zipf": (0.4, 0.8)}
SMOKE_ITERS = 3000


def main(online: bool = False, backend: str = "device", n_seeds: int = 1,
         policies: bool = False, devices: int = None, chunk_size: int = 0,
         max_buckets: int = 1, diagnostics: bool = True,
         smoke: bool = False):
    payload, registry = None, None
    kind = "online" if online else "policy" if policies else "offline"
    out = pathlib.Path("results") / "sweep" / ("ci" if smoke else "")
    with TRACER.span("sweep", kind=kind, backend=backend, smoke=smoke,
                     diagnostics=diagnostics):
        if smoke:
            rows = run_sweep(base=MECConfig(n_users=20), axes=SMOKE_AXES,
                             pdhg_iters=SMOKE_ITERS, backend=backend,
                             n_seeds=n_seeds, devices=devices,
                             chunk_size=chunk_size,
                             diagnostics=diagnostics)
            name = "grid.json"
        elif online:
            from repro.obs import MetricsRegistry

            registry = MetricsRegistry() if diagnostics else None
            rows = run_online_sweep(
                backend="sharded" if backend == "sharded" else "vmap",
                devices=devices, chunk_size=chunk_size,
                diagnostics=diagnostics, registry=registry)
            name = "online_grid.json"
        elif policies:
            rows, summary = run_policy_sweep(backend=backend,
                                             n_seeds=n_seeds,
                                             devices=devices,
                                             chunk_size=chunk_size,
                                             max_buckets=max_buckets,
                                             diagnostics=diagnostics)
            name = "policy_grid.json"
            payload = {"rows": rows, "summary": summary}
        else:
            rows = run_sweep(backend=backend, n_seeds=n_seeds,
                             devices=devices, chunk_size=chunk_size,
                             max_buckets=max_buckets,
                             diagnostics=diagnostics)
            name = "grid.json"
    print(format_table(rows))
    out.mkdir(parents=True, exist_ok=True)
    path = out / name
    path.write_text(json.dumps(payload if payload is not None else rows,
                               indent=1, default=float))
    write_manifest(path,
                   config=dict(kind=kind, backend=backend,
                               n_seeds=n_seeds, devices=devices,
                               chunk_size=chunk_size,
                               max_buckets=max_buckets,
                               diagnostics=diagnostics, smoke=smoke),
                   seeds={"seed": 0, "n_seeds": n_seeds})
    TRACER.export_jsonl(path.with_name(path.stem + ".trace.jsonl"))
    TRACER.export_chrome(path.with_name(path.stem + ".trace.chrome.json"))
    if registry is not None:
        registry.export_prometheus(
            path.with_name(path.stem + ".metrics.prom"))
        registry.export_json(path.with_name(path.stem + ".metrics.json"))
    if policies:
        s = payload["summary"]
        print(f"\nCoCaR vs best baseline ({s['best_baseline']}): "
              f"{s['ratio']:.2f}x avg served precision")
        if "convergence" in s:
            c = s["convergence"]
            print(f"pdhg convergence: "
                  f"{c['n_windows'] - c['n_not_converged']}/"
                  f"{c['n_windows']} windows <= tol {c['tol']:g}")
    elif diagnostics and not online and backend != "host":
        bad = sum(1 for r in rows if not r.get("pdhg_converged", True))
        print(f"\npdhg convergence: {len(rows) - bad}/{len(rows)} "
              f"windows converged")
    print(f"\n{len(rows)} rows -> {path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="scenario-grid sweeps")
    ap.add_argument("--online", action="store_true",
                    help="trace-family grid through the scan engine")
    ap.add_argument("--policies", action="store_true",
                    help="CoCaR vs the Sec. VII-B baseline zoo, one "
                         "dispatch across (variants x seeds x policies)")
    ap.add_argument("--host", action="store_true",
                    help="NumPy round+repair reference loop")
    ap.add_argument("--shard", action="store_true",
                    help="partition the grid across a host-device mesh "
                         "(repro.scale; run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=K for K "
                         "virtual devices)")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh width for --shard (default: all devices)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="streaming chunk size (0 = one chunk per bucket)")
    ap.add_argument("--buckets", type=int, default=1,
                    help="max size buckets for heterogeneous grids "
                         "(1 = classic single padded shape)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="rounding seeds per variant (offline only)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny offline CI grid (2 windows, converging "
                         "iteration budget) written to results/sweep/ci/")
    ap.add_argument("--no-diag", action="store_true",
                    help="compile the solver/scan diagnostics taps out "
                         "(decisions are bit-identical either way)")
    args = ap.parse_args()
    if args.host and args.shard:
        ap.error("--host and --shard are mutually exclusive")
    if args.devices is not None and not args.shard:
        ap.error("--devices requires --shard (a plain run would "
                 "silently ignore it)")
    if args.smoke and (args.online or args.policies or args.host):
        ap.error("--smoke is an offline device/sharded grid; it takes "
                 "none of --online/--policies/--host")
    main(online=args.online,
         backend=("host" if args.host
                  else "sharded" if args.shard else "device"),
         n_seeds=args.seeds, policies=args.policies,
         devices=args.devices, chunk_size=args.chunk,
         max_buckets=args.buckets, diagnostics=not args.no_diag,
         smoke=args.smoke)
