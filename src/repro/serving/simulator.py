"""Queueing simulator for the edge data plane: arrivals, per-pod queues,
deadline-aware routing, and latency percentiles.

The EdgeCluster executes real generation; this simulator layers a discrete-
event queueing model on top (Poisson arrivals, service times from the
catalog FLOPs model) so serving-level metrics — p50/p95/p99 latency, SLO
attainment, per-pod utilization — can be studied against CoCaR(-OL) caching
decisions at arbitrary load, without running tokens for every request.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.models import partition


@dataclass(order=True)
class _Event:
    time: float
    kind: str = field(compare=False)       # "arrival" | "finish"
    payload: object = field(compare=False, default=None)


@dataclass
class SimRequest:
    rid: int
    model: str
    tokens: int
    arrival: float
    deadline: float
    start: float = -1.0
    finish: float = -1.0
    pod: int = -1
    precision: float = 0.0

    @property
    def latency(self):
        return self.finish - self.arrival if self.finish >= 0 else np.inf

    @property
    def met_slo(self):
        return self.finish >= 0 and self.finish <= self.deadline


class QueueSim:
    """Single-server-per-pod FCFS queues with precision-aware routing.

    ``residency`` usually comes from a control-plane decision via
    ``repro.serving.plan`` — ``{pod: {model: exit_idx}}``.  With
    ``available_at`` (``{(pod, model): t}``, e.g. a ServingPlan's
    measured loading times) a pod cannot start serving a submodel before
    its bytes have loaded; with ``fail_at`` (``{pod: t}``) a pod stops
    accepting requests from time t on — requests already in its queue
    complete, new arrivals re-route or drop.  ``admit_late`` serves
    requests that cannot meet their deadline anyway (counted as
    deadline misses) instead of dropping them at admission.
    """

    def __init__(self, cfgs: dict, residency: dict, compute_flops: float,
                 precisions=None, seed: int = 0, available_at: dict = None,
                 fail_at: dict = None, admit_late: bool = False):
        """residency: {pod: {model: exit_idx}}."""
        self.cfgs = cfgs
        self.residency = residency
        self.compute = compute_flops
        self.rng = np.random.default_rng(seed)
        self.busy_until = {p: 0.0 for p in residency}
        self.done: list = []
        self.dropped = 0
        self._prec = precisions or {}
        self.available_at = available_at or {}
        self.fail_at = fail_at or {}
        self.admit_late = admit_late

    def precision_of(self, model, j):
        if (model, j) in self._prec:
            return self._prec[(model, j)]
        cfg = self.cfgs[model]
        frac = cfg.exit_layers[j] / cfg.n_layers
        return 0.99 * (1 - 0.45 * (1 - frac) ** 1.5)

    def service_time(self, model, j, tokens):
        c = partition.submodel_flops_per_token(self.cfgs[model], j,
                                               ctx=max(tokens, 1))
        return tokens * c / self.compute

    def route(self, req: SimRequest):
        """Max precision among pods that can still meet the deadline.
        With ``admit_late``, falls back to the earliest-finishing pod
        when no pod can (the request completes late and is accounted a
        deadline miss)."""
        best, late = None, None
        for p, models in self.residency.items():
            if req.arrival >= self.fail_at.get(p, np.inf):
                continue
            j = models.get(req.model, -1)
            if j < 0:
                continue
            eta = max(self.busy_until[p], req.arrival,
                      self.available_at.get((p, req.model), 0.0))
            fin = eta + self.service_time(req.model, j, req.tokens)
            score = self.precision_of(req.model, j)
            if fin > req.deadline:
                if late is None or fin < late[3]:
                    late = (score, p, j, fin)
                continue
            if best is None or score > best[0]:
                best = (score, p, j, fin)
        if best is None and self.admit_late:
            return late
        return best

    def run(self, arrivals: list):
        """arrivals: list of SimRequest sorted by arrival time."""
        for req in sorted(arrivals, key=lambda r: r.arrival):
            choice = self.route(req)
            if choice is None:
                self.dropped += 1
                continue
            score, p, j, fin = choice
            req.pod = p
            req.start = max(self.busy_until[p], req.arrival,
                            self.available_at.get((p, req.model), 0.0))
            req.finish = fin
            req.precision = score
            self.busy_until[p] = fin
            self.done.append(req)
        return self.metrics()

    def metrics(self):
        lats = np.asarray([r.latency for r in self.done]) if self.done else \
            np.asarray([np.inf])
        total = len(self.done) + self.dropped
        return {
            "served": len(self.done),
            "dropped": self.dropped,
            # every request that did not complete by its deadline —
            # dropped at admission or served late (admit_late)
            "deadline_misses": (self.dropped
                                + sum(not r.met_slo for r in self.done)),
            "slo_attainment": (sum(r.met_slo for r in self.done) / total
                               if total else 0.0),
            "p50_latency": float(np.percentile(lats, 50)),
            "p95_latency": float(np.percentile(lats, 95)),
            "p99_latency": float(np.percentile(lats, 99)),
            "avg_precision": (sum(r.precision for r in self.done) / total
                              if total else 0.0),
        }


def transfer_time(cfg, from_exit: int, to_exit: int,
                  bandwidth_Bps: float) -> float:
    """Seconds to switch a pod's cached submodel — the same byte math
    ``loader.PodCache.request_load`` executes: an upgrade moves only the
    Δ parameter segments + the new exit head, a shrink is an instant
    slice.  ``from_exit=-1`` is a cold load."""
    if to_exit <= from_exit:
        return 0.0
    return partition.delta_bytes(cfg, from_exit, to_exit) / bandwidth_Bps


def poisson_arrivals(rate_per_s: float, duration_s: float, models: list,
                     popularity, tokens: int = 128, slo_s: float = 2.0,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    t, rid, out = 0.0, 0, []
    while True:
        t += rng.exponential(1.0 / rate_per_s)
        if t > duration_s:
            break
        m = models[rng.choice(len(models), p=popularity)]
        out.append(SimRequest(rid=rid, model=m, tokens=tokens, arrival=t,
                              deadline=t + slo_s))
        rid += 1
    return out
