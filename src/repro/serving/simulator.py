"""Queueing simulator for the edge data plane: arrivals, per-pod queues,
deadline-aware routing, and latency percentiles.

The EdgeCluster executes real generation; this simulator layers a discrete-
event queueing model on top (Poisson arrivals, service times from the
catalog FLOPs model) so serving-level metrics — p50/p95/p99 latency, SLO
attainment, per-pod utilization — can be studied against CoCaR(-OL) caching
decisions at arbitrary load, without running tokens for every request.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.models import partition


@dataclass(order=True)
class _Event:
    time: float
    kind: str = field(compare=False)       # "arrival" | "finish"
    payload: object = field(compare=False, default=None)


@dataclass
class SimRequest:
    rid: int
    model: str
    tokens: int
    arrival: float
    deadline: float
    start: float = -1.0
    finish: float = -1.0
    pod: int = -1
    precision: float = 0.0

    @property
    def latency(self):
        return self.finish - self.arrival if self.finish >= 0 else np.inf

    @property
    def met_slo(self):
        return self.finish >= 0 and self.finish <= self.deadline


class QueueSim:
    """Single-server-per-pod FCFS queues with precision-aware routing."""

    def __init__(self, cfgs: dict, residency: dict, compute_flops: float,
                 precisions=None, seed: int = 0):
        """residency: {pod: {model: exit_idx}}."""
        self.cfgs = cfgs
        self.residency = residency
        self.compute = compute_flops
        self.rng = np.random.default_rng(seed)
        self.busy_until = {p: 0.0 for p in residency}
        self.done: list = []
        self.dropped = 0
        self._prec = precisions or {}

    def precision_of(self, model, j):
        if (model, j) in self._prec:
            return self._prec[(model, j)]
        cfg = self.cfgs[model]
        frac = cfg.exit_layers[j] / cfg.n_layers
        return 0.99 * (1 - 0.45 * (1 - frac) ** 1.5)

    def service_time(self, model, j, tokens):
        c = partition.submodel_flops_per_token(self.cfgs[model], j,
                                               ctx=max(tokens, 1))
        return tokens * c / self.compute

    def route(self, req: SimRequest):
        """Max precision among pods that can still meet the deadline."""
        best = None
        for p, models in self.residency.items():
            j = models.get(req.model, -1)
            if j < 0:
                continue
            eta = max(self.busy_until[p], req.arrival)
            fin = eta + self.service_time(req.model, j, req.tokens)
            if fin > req.deadline:
                continue
            score = self.precision_of(req.model, j)
            if best is None or score > best[0]:
                best = (score, p, j, fin)
        return best

    def run(self, arrivals: list):
        """arrivals: list of SimRequest sorted by arrival time."""
        for req in sorted(arrivals, key=lambda r: r.arrival):
            choice = self.route(req)
            if choice is None:
                self.dropped += 1
                continue
            score, p, j, fin = choice
            req.pod = p
            req.start = max(self.busy_until[p], req.arrival)
            req.finish = fin
            req.precision = score
            self.busy_until[p] = fin
            self.done.append(req)
        return self.metrics()

    def metrics(self):
        lats = np.asarray([r.latency for r in self.done]) if self.done else \
            np.asarray([np.inf])
        total = len(self.done) + self.dropped
        return {
            "served": len(self.done),
            "dropped": self.dropped,
            "slo_attainment": (sum(r.met_slo for r in self.done) / total
                               if total else 0.0),
            "p50_latency": float(np.percentile(lats, 50)),
            "p95_latency": float(np.percentile(lats, 95)),
            "p99_latency": float(np.percentile(lats, 99)),
            "avg_precision": (sum(r.precision for r in self.done) / total
                              if total else 0.0),
        }


def poisson_arrivals(rate_per_s: float, duration_s: float, models: list,
                     popularity, tokens: int = 128, slo_s: float = 2.0,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    t, rid, out = 0.0, 0, []
    while True:
        t += rng.exponential(1.0 / rate_per_s)
        if t > duration_s:
            break
        m = models[rng.choice(len(models), p=popularity)]
        out.append(SimRequest(rid=rid, model=m, tokens=tokens, arrival=t,
                              deadline=t + slo_s))
        rid += 1
    return out
