"""Queueing simulator for the edge data plane: arrivals, per-pod queues,
deadline-aware routing, and latency percentiles.

The EdgeCluster executes real generation; this simulator layers a discrete-
event queueing model on top (Poisson arrivals, service times from the
catalog FLOPs model) so serving-level metrics — p50/p95/p99 latency, SLO
attainment, per-pod utilization — can be studied against CoCaR(-OL) caching
decisions at arbitrary load, without running tokens for every request.

Every served request carries an **exact latency attribution**: delivered
latency decomposes as ``queue_s + stall_s + service_s`` (wait for the
server, wait for the submodel's bytes per the plan's ``available_at`` —
Eq. 37 — then generation), a telescoping identity asserted to 1e-9 in
``metrics()``.  With an ``events`` log attached (``repro.obs.events``),
one event per lifecycle phase is emitted — arrival, route decision with
the scored candidate set, queue, stall, service, and exactly one
terminal (finish | miss | drop).  The tap is decision-inert: routing and
outcomes are bit-identical with telemetry on or off.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.models import partition


@dataclass(order=True)
class _Event:
    time: float
    kind: str = field(compare=False)       # "arrival" | "finish"
    payload: object = field(compare=False, default=None)


@dataclass
class SimRequest:
    rid: int
    model: str
    tokens: int
    arrival: float
    deadline: float
    start: float = -1.0
    finish: float = -1.0
    pod: int = -1
    precision: float = 0.0
    queue_s: float = 0.0       # wait for the chosen pod's server
    stall_s: float = 0.0       # extra wait for the submodel's bytes
    service_s: float = 0.0     # generation time

    @property
    def latency(self):
        return self.finish - self.arrival if self.finish >= 0 else np.inf

    @property
    def met_slo(self):
        return self.finish >= 0 and self.finish <= self.deadline


class QueueSim:
    """Single-server-per-pod FCFS queues with precision-aware routing.

    ``residency`` usually comes from a control-plane decision via
    ``repro.serving.plan`` — ``{pod: {model: exit_idx}}``.  With
    ``available_at`` (``{(pod, model): t}``, e.g. a ServingPlan's
    measured loading times) a pod cannot start serving a submodel before
    its bytes have loaded; with ``fail_at`` (``{pod: t}``) a pod stops
    accepting requests from time t on — requests already in its queue
    complete, new arrivals re-route or drop.  ``admit_late`` serves
    requests that cannot meet their deadline anyway (counted as
    deadline misses) instead of dropping them at admission.

    ``events`` (an ``repro.obs.events.EventLog`` or None) attaches the
    per-request lifecycle tap; ``run_label`` names this simulator's run
    scope in the shared log.  Both default off — the simulator computes
    identical routing, starts, and finishes either way.
    """

    def __init__(self, cfgs: dict, residency: dict, compute_flops: float,
                 precisions=None, seed: int = 0, available_at: dict = None,
                 fail_at: dict = None, admit_late: bool = False,
                 events=None, run_label: str = ""):
        """residency: {pod: {model: exit_idx}}."""
        self.cfgs = cfgs
        self.residency = residency
        self.compute = compute_flops
        self.rng = np.random.default_rng(seed)
        self.busy_until = {p: 0.0 for p in residency}
        self.done: list = []
        self.dropped = 0
        self._prec = precisions or {}
        self.available_at = available_at or {}
        self.fail_at = fail_at or {}
        self.admit_late = admit_late
        self.events = events
        self.run_label = run_label

    def precision_of(self, model, j):
        if (model, j) in self._prec:
            return self._prec[(model, j)]
        cfg = self.cfgs[model]
        frac = cfg.exit_layers[j] / cfg.n_layers
        return 0.99 * (1 - 0.45 * (1 - frac) ** 1.5)

    def service_time(self, model, j, tokens):
        c = partition.submodel_flops_per_token(self.cfgs[model], j,
                                               ctx=max(tokens, 1))
        return tokens * c / self.compute

    def route(self, req: SimRequest, candidates: list = None):
        """Max precision among pods that can still meet the deadline.
        With ``admit_late``, falls back to the earliest-finishing pod
        when no pod can (the request completes late and is accounted a
        deadline miss).  ``candidates`` (a list, or None) collects every
        scored option — the route event's candidate set — without
        touching the decision itself."""
        best, late = None, None
        for p, models in self.residency.items():
            if req.arrival >= self.fail_at.get(p, np.inf):
                continue
            j = models.get(req.model, -1)
            if j < 0:
                continue
            eta = max(self.busy_until[p], req.arrival,
                      self.available_at.get((p, req.model), 0.0))
            fin = eta + self.service_time(req.model, j, req.tokens)
            score = self.precision_of(req.model, j)
            feasible = fin <= req.deadline
            if candidates is not None:
                candidates.append({"pod": p, "exit": j, "score": score,
                                   "fin": fin, "feasible": feasible})
            if not feasible:
                if late is None or fin < late[3]:
                    late = (score, p, j, fin)
                continue
            if best is None or score > best[0]:
                best = (score, p, j, fin)
        if best is None and self.admit_late:
            return late
        return best

    def run(self, arrivals: list):
        """arrivals: list of SimRequest sorted by arrival time."""
        ev = self.events
        if ev is not None:
            ev.new_run(self.run_label)
        for req in sorted(arrivals, key=lambda r: r.arrival):
            if ev is not None:
                ev.emit("arrival", req.rid, req.arrival, model=req.model,
                        tokens=req.tokens, deadline=req.deadline)
            cands = None if ev is None else []
            choice = self.route(req, cands)
            if ev is not None:
                ev.emit("route", req.rid, req.arrival,
                        chosen=-1 if choice is None else choice[1],
                        candidates=cands)
            if choice is None:
                self.dropped += 1
                if ev is not None:
                    ev.emit("drop", req.rid, req.arrival)
                continue
            score, p, j, fin = choice
            req.pod = p
            # Exact latency attribution: start = max(busy, arrival,
            # available) split into the wait for the server (queue) and
            # the extra wait for the bytes (stall); the three phase
            # durations telescope back to finish - arrival.
            t_free = max(self.busy_until[p], req.arrival)
            req.start = max(t_free,
                            self.available_at.get((p, req.model), 0.0))
            req.queue_s = t_free - req.arrival
            req.stall_s = req.start - t_free
            req.service_s = fin - req.start
            req.finish = fin
            req.precision = score
            self.busy_until[p] = fin
            self.done.append(req)
            if ev is not None:
                ev.emit("queue", req.rid, req.arrival, dur=req.queue_s)
                ev.emit("stall", req.rid, req.arrival + req.queue_s,
                        dur=req.stall_s)
                ev.emit("service", req.rid, req.start, dur=req.service_s,
                        pod=p, exit=j, precision=score)
                ev.emit("finish" if req.met_slo else "miss", req.rid,
                        req.finish, latency=req.latency)
        return self.metrics()

    #: per-request attribution must telescope to delivered latency
    ATTRIBUTION_TOL = 1e-9

    def metrics(self):
        """Aggregate serving metrics.  Percentile keys are explicit
        zeros when no request completed (``n`` pins the sample count so
        zeros are distinguishable from fast requests); ``attribution``
        decomposes delivered latency per phase, with the per-request
        identity ``queue_s + stall_s + service_s == latency`` asserted
        to ``ATTRIBUTION_TOL``."""
        n = len(self.done)
        total = n + self.dropped
        phases = {"queue": [r.queue_s for r in self.done],
                  "stall": [r.stall_s for r in self.done],
                  "service": [r.service_s for r in self.done]}
        if n:
            lats = np.asarray([r.latency for r in self.done])
            pcts = {q: float(np.percentile(lats, q)) for q in (50, 95, 99)}
            lat_sum = float(lats.sum())
            err = float(np.max(np.abs(
                np.asarray(phases["queue"]) + np.asarray(phases["stall"])
                + np.asarray(phases["service"]) - lats)))
            assert err <= self.ATTRIBUTION_TOL, \
                f"latency attribution broken: max err {err}"
            attribution = {
                name: {
                    "sum": float(np.sum(vals)),
                    "frac": float(np.sum(vals) / lat_sum) if lat_sum
                    else 0.0,
                    "p50": float(np.percentile(vals, 50)),
                    "p95": float(np.percentile(vals, 95)),
                    "p99": float(np.percentile(vals, 99)),
                } for name, vals in phases.items()}
        else:
            pcts = {50: 0.0, 95: 0.0, 99: 0.0}
            err = 0.0
            attribution = {name: {"sum": 0.0, "frac": 0.0, "p50": 0.0,
                                  "p95": 0.0, "p99": 0.0}
                           for name in phases}
        return {
            "served": n,
            "n": n,
            "dropped": self.dropped,
            # every request that did not complete by its deadline —
            # dropped at admission or served late (admit_late)
            "deadline_misses": (self.dropped
                                + sum(not r.met_slo for r in self.done)),
            "slo_attainment": (sum(r.met_slo for r in self.done) / total
                               if total else 0.0),
            "p50_latency": pcts[50],
            "p95_latency": pcts[95],
            "p99_latency": pcts[99],
            "avg_precision": (sum(r.precision for r in self.done) / total
                              if total else 0.0),
            "attribution": attribution,
            "attribution_max_err": err,
        }


def transfer_time(cfg, from_exit: int, to_exit: int,
                  bandwidth_Bps: float) -> float:
    """Seconds to switch a pod's cached submodel — the same byte math
    ``loader.PodCache.request_load`` executes: an upgrade moves only the
    Δ parameter segments + the new exit head, a shrink is an instant
    slice.  ``from_exit=-1`` is a cold load."""
    if to_exit <= from_exit:
        return 0.0
    return partition.delta_bytes(cfg, from_exit, to_exit) / bandwidth_Bps


def poisson_arrivals(rate_per_s: float, duration_s: float, models: list,
                     popularity, tokens: int = 128, slo_s: float = 2.0,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    t, rid, out = 0.0, 0, []
    while True:
        t += rng.exponential(1.0 / rate_per_s)
        if t > duration_s:
            break
        m = models[rng.choice(len(models), p=popularity)]
        out.append(SimRequest(rid=rid, model=m, tokens=tokens, arrival=t,
                              deadline=t + slo_s))
        rid += 1
    return out
