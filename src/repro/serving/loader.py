"""Submodel weight residency: the paper's caching variable, made real.

``WeightStore`` is the "cloud": full parameter trees per model type.
``PodCache`` is one BS/pod's HBM: it holds *truncated* parameter trees
(prefix segments + exit head — exactly the paper's submodel h_j).  Because
segments are stacked, an upgrade i→j transfers only the Δ segments and the
new exit head; a shrink is a slice (instant).  Transfer time is
bytes / bandwidth — the same quantity the CoCaR-OL state machine tracks.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.models import model as M
from repro.models import partition


class WeightStore:
    def __init__(self, cfgs: dict, seed: int = 0, lazy: bool = False):
        """``lazy=True`` skips materializing the parameter trees — byte
        accounting (``PodCache.request_load`` / ``used_bytes``) works
        off ``jax.eval_shape``, so load-time simulation over multi-GB
        catalogs never allocates weights; only ``_materialize`` (i.e.
        actually serving) needs the real trees."""
        self.cfgs = dict(cfgs)
        self.params = {}
        if not lazy:
            for i, (name, cfg) in enumerate(self.cfgs.items()):
                self.params[name] = M.init(cfg, jax.random.key(seed + i))

    def set_params(self, name, params):
        self.params[name] = params


@dataclass
class LoadEvent:
    model: str
    from_exit: int
    to_exit: int
    bytes: int
    seconds: float
    done_at: float


class PodCache:
    """One pod's resident submodels + in-flight loads."""

    def __init__(self, store: WeightStore, capacity_bytes: int,
                 bandwidth_Bps: float):
        self.store = store
        self.capacity = capacity_bytes
        self.bw = bandwidth_Bps
        self.resident: dict = {}            # model -> exit idx (0-based)
        self.params: dict = {}              # model -> truncated tree
        self.loading: dict = {}             # model -> LoadEvent

    # ------------------------------------------------------------------
    def used_bytes(self) -> int:
        total = 0
        for name, j in self.resident.items():
            total += partition.submodel_bytes(self.store.cfgs[name], j)
        for name, ev in self.loading.items():
            total += partition.submodel_bytes(self.store.cfgs[name],
                                              ev.to_exit)
        return total

    def request_load(self, model: str, to_exit: int, now: float):
        """Start (or instantly apply) a submodel transition."""
        cfg = self.store.cfgs[model]
        cur = self.resident.get(model, -1)
        if model in self.loading:
            return None
        if to_exit == cur:
            return None
        if to_exit < cur:                   # shrink: instant slice
            self._materialize(model, to_exit)
            return LoadEvent(model, cur, to_exit, 0, 0.0, now)
        nbytes = partition.delta_bytes(cfg, cur, to_exit)
        projected = self.used_bytes() + nbytes
        if cur >= 0:
            projected -= 0                  # old prefix is reused
        if projected > self.capacity:
            raise MemoryError(f"{model}->{to_exit} would exceed capacity")
        secs = nbytes / self.bw
        ev = LoadEvent(model, cur, to_exit, nbytes, secs, now + secs)
        self.loading[model] = ev
        return ev

    def evict(self, model: str):
        self.resident.pop(model, None)
        self.params.pop(model, None)
        self.loading.pop(model, None)

    def tick(self, now: float):
        """Complete any finished loads."""
        done = [m for m, ev in self.loading.items() if ev.done_at <= now]
        for m in done:
            ev = self.loading.pop(m)
            self._materialize(m, ev.to_exit)
        return done

    def _materialize(self, model: str, j: int):
        cfg = self.store.cfgs[model]
        self.params[model] = partition.submodel_params(
            cfg, self.store.params[model], j)
        self.resident[model] = j

    def serveable(self, model: str):
        return self.resident.get(model, -1)
