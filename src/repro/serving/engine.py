"""Edge serving engine: batched prefill+decode over cached submodels, with
deadline-aware routing, straggler re-routing, and BS-failure handling.

The cluster advances a simulated clock (transfer/compute latencies come from
the catalog model) while *actually executing* generation with the cached
submodel parameters — so functional outputs are real and timing is
controllable on CPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models import partition
from repro.models.config import build_plan, submodel_plan
from repro.serving.loader import PodCache, WeightStore


@dataclass
class Request:
    rid: int
    model: str
    tokens: list
    max_new: int
    home: int
    deadline: float            # absolute sim-time deadline
    arrival: float = 0.0
    output: list = field(default_factory=list)
    served_by: int = -1
    precision: float = 0.0
    done: bool = False
    missed: bool = False


class EdgePod:
    def __init__(self, idx: int, store: WeightStore, capacity_bytes: int,
                 bandwidth_Bps: float, compute_flops: float):
        self.idx = idx
        self.cache = PodCache(store, capacity_bytes, bandwidth_Bps)
        self.compute = compute_flops
        self.failed = False
        self.busy_until = 0.0
        self._decode_fns = {}

    # -- actual execution ------------------------------------------------
    def _fns(self, model: str, exit_idx: int, batch: int, max_len: int):
        key = (model, exit_idx, batch, max_len)
        if key not in self._decode_fns:
            cfg = self.cache.store.cfgs[model]
            plan = build_plan(cfg)
            pf = jax.jit(lambda p, b, c: M.prefill(cfg, p, b, c,
                                                   exit_idx=exit_idx,
                                                   plan=plan))
            dc = jax.jit(lambda p, t, pos, c: M.decode(cfg, p, t, pos, c,
                                                       exit_idx=exit_idx,
                                                       plan=plan))
            self._decode_fns[key] = (pf, dc, plan)
        return self._decode_fns[key]

    def serve_batch(self, model: str, reqs: list, now: float):
        """Run real generation for a batch of same-model requests."""
        cfg = self.cache.store.cfgs[model]
        exit_idx = self.cache.serveable(model)
        assert exit_idx >= 0, "model not resident"
        params = self.cache.params[model]
        prompt = max(len(r.tokens) for r in reqs)
        max_new = max(r.max_new for r in reqs)
        B = len(reqs)
        max_len = prompt + max_new
        pf, dc, plan = self._fns(model, exit_idx, B, max_len)
        sub = submodel_plan(plan, exit_idx)
        cache = M.cache_init(cfg, B, max_len, sub)
        toks = np.zeros((B, prompt), np.int32)
        for i, r in enumerate(reqs):
            toks[i, -len(r.tokens):] = r.tokens     # left-pad with 0
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.encoder_len, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((B, cfg.frontend_len, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
        logits, kv = pf(params, batch, cache)
        outs = [[] for _ in reqs]
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for step in range(max_new):
            for i in range(B):
                outs[i].append(int(tok[i, 0]))
            logits, kv = dc(params, tok, jnp.int32(prompt + step), kv)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

        # simulated service time from the catalog's FLOPs model
        c_h = partition.submodel_flops_per_token(cfg, exit_idx, ctx=prompt)
        secs = (B * (prompt + max_new) * c_h) / self.compute
        self.busy_until = max(self.busy_until, now) + secs
        return outs, secs


class EdgeCluster:
    """Pods + control plane: routing, straggler re-route, failure handling."""

    def __init__(self, store: WeightStore, n_pods: int, capacity_bytes: int,
                 bandwidth_Bps: float = 100e9, compute_flops: float = 197e12,
                 precisions: dict = None):
        self.store = store
        self.pods = [EdgePod(i, store, capacity_bytes, bandwidth_Bps,
                             compute_flops) for i in range(n_pods)]
        self.now = 0.0
        self.log = []
        # measured/assumed per-(model, exit) precision ladder
        self.precisions = precisions or {}

    def precision_of(self, model, exit_idx):
        cfg = self.store.cfgs[model]
        if (model, exit_idx) in self.precisions:
            return self.precisions[(model, exit_idx)]
        frac = cfg.exit_layers[exit_idx] / cfg.n_layers
        return 0.99 * (1 - 0.45 * (1 - frac) ** 1.5)

    def apply_caching(self, decisions):
        """decisions: {pod_idx: {model: exit_idx or -1}} from the control
        plane (CoCaR / CoCaR-OL output)."""
        for pi, models in decisions.items():
            pod = self.pods[pi]
            for model, j in models.items():
                if j < 0:
                    pod.cache.evict(model)
                else:
                    pod.cache.request_load(model, j, self.now)

    def tick(self, dt: float):
        self.now += dt
        for pod in self.pods:
            if not pod.failed:
                pod.cache.tick(self.now)

    def fail_pod(self, idx: int):
        self.pods[idx].failed = True
        self.log.append(("fail", idx, self.now))

    def recover_pod(self, idx: int):
        self.pods[idx].failed = False
        self.log.append(("recover", idx, self.now))

    def route(self, req: Request):
        """Pick the pod maximizing precision subject to deadline slack;
        straggler mitigation = skip pods whose queue would miss the
        deadline, falling back to the next-best pod."""
        best, best_score = None, -1.0
        for pod in self.pods:
            if pod.failed:
                continue
            j = pod.cache.serveable(req.model)
            if j < 0:
                continue
            eta = max(pod.busy_until, self.now)
            if eta > req.deadline:
                continue                       # would straggle -> re-route
            score = self.precision_of(req.model, j)
            if score > best_score:
                best, best_score = pod, score
        return best

    def submit(self, reqs: list):
        """Route and execute a batch of requests; returns served count."""
        by_key = {}
        for r in reqs:
            r.arrival = self.now
            pod = self.route(r)
            if pod is None:
                r.missed = True
                self.log.append(("cloud", r.rid, self.now))
                continue
            by_key.setdefault((pod.idx, r.model), []).append(r)
        served = 0
        for (pi, model), group in by_key.items():
            pod = self.pods[pi]
            outs, secs = pod.serve_batch(model, group, self.now)
            j = pod.cache.serveable(model)
            for r, o in zip(group, outs):
                r.output = o
                r.served_by = pi
                r.precision = self.precision_of(model, j)
                r.done = True
                served += 1
        return served
