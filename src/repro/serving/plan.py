"""ServingPlan — the decision bridge from the paper's control plane to
the serving data plane.

The offline pipeline (``repro.core.cocar``) emits caching one-hots
``x (N, M, H+1)`` and the online engine (``repro.core.online`` /
``repro.traces.engine``) emits per-slot cache states ``lvl (N, M)`` with
in-flight download state ``(O, target)``.  The data plane
(``serving.simulator.QueueSim`` / ``serving.engine.EdgeCluster``) wants
per-pod residency maps ``{pod: {model: exit_idx}}`` plus, when loading
delay is simulated, the time each (pod, model) becomes serveable.

This module is that conversion — no hand-constructed residency profiles
anywhere:

  * :func:`plan_from_offline` — one window's decision array to a
    :class:`ServingPlan`, with per-(pod, model) availability times from
    the catalog's D_m matrix (measured bytes / bandwidth when the
    catalog source is ``measured``) given the previous cache state;
  * :func:`plans_from_online_states` — the per-slot residency schedule
    of an online run recorded with ``run_online(..,
    record_states=True)``.  A submodel mid-download never serves: the
    residency is the *current* level ``lvl``, and the in-flight target
    is structurally excluded (checked by
    :func:`check_mid_download_never_serves`);
  * :func:`execute_plan` — run a plan through :class:`QueueSim` with or
    without the loading delay, with the catalog's own precision ladder
    so delivered precision means the same thing on both planes.

Catalog-level indexing note: level ``j`` (0 = not cached) corresponds to
serving exit ``j - 1``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.serving.simulator import QueueSim


@dataclass
class ServingPlan:
    """A control-plane decision, expressed in data-plane terms."""
    residency: dict              # {pod: {model_name: exit_idx (0-based)}}
    available_at: dict = field(default_factory=dict)
    #: {(pod, model_name): sim-time s when the cached submodel is loaded}
    source: str = "offline"      # "offline:<policy>" | "online:<algo>@t"
    lvl: np.ndarray = None       # (N, M) catalog levels (0 = not cached)
    routing: np.ndarray = None   # optional control-plane A (N, U, H)

    @property
    def n_pods(self) -> int:
        return len(self.residency)

    def max_load_s(self) -> float:
        return max(self.available_at.values(), default=0.0)


def cache_levels(x) -> np.ndarray:
    """(N, M, H+1) caching one-hot -> (N, M) catalog levels."""
    x = np.asarray(x)
    if x.ndim != 3:
        raise ValueError(f"expected (N, M, H+1) one-hot, got {x.shape}")
    return np.argmax(x, axis=-1).astype(np.int32)


def plan_from_offline(x, names, catalog=None, x_prev=None,
                      policy: str = "offline", routing=None) -> ServingPlan:
    """One offline decision array -> a serving plan.

    ``x`` is a window's integral caching one-hot ``(N, M, H+1)`` (e.g.
    ``policy_grid_device`` output sliced to one (window, seed));
    ``names[m]`` labels model type m with the data-plane model name.
    With a ``catalog``, each upgraded (pod, model) gets an availability
    time ``loadD[m, prev, tgt]`` — the transition's loading latency from
    the previous cache state ``x_prev`` (default: empty cache, i.e. a
    cold start where everything resident must first be loaded).
    """
    lvl = cache_levels(x)
    N, M = lvl.shape
    if len(names) != M:
        raise ValueError(f"{M} model types but {len(names)} names")
    prev = (np.zeros((N, M), np.int32) if x_prev is None
            else cache_levels(x_prev))
    residency, available = {}, {}
    for n in range(N):
        residency[n] = {}
        for m in range(M):
            j = int(lvl[n, m])
            if j < 1:
                continue
            residency[n][names[m]] = j - 1
            if catalog is not None and j > prev[n, m]:
                available[(n, names[m])] = catalog.load_seconds(
                    m, int(prev[n, m]), j)
    return ServingPlan(residency=residency, available_at=available,
                       source=f"offline:{policy}", lvl=lvl, routing=routing)


def plan_from_online_state(lvl, dl, target, names,
                           source: str = "online") -> ServingPlan:
    """One recorded online slot state -> the slot's serving plan.

    ``lvl`` is the slot's cached level, ``dl`` the per-(BS, model)
    download-in-flight flag, ``target`` the in-flight download target.
    Residency is built from ``lvl`` alone — a submodel still downloading
    (``dl`` true, ``target > lvl``) is NOT resident at its target; the
    pod keeps serving the current level until the download lands, which
    is exactly the paper's Eq. 37 semantics.
    """
    lvl = np.asarray(lvl)
    N, M = lvl.shape
    residency = {}
    for n in range(N):
        residency[n] = {names[m]: int(lvl[n, m]) - 1
                        for m in range(M) if int(lvl[n, m]) >= 1}
    return ServingPlan(residency=residency, source=source, lvl=lvl)


def plans_from_online_states(states: dict, names,
                             algo: str = "cocar-ol") -> list:
    """The whole per-slot residency schedule of one online run:
    ``states`` is the ``run_online(.., record_states=True)`` export
    (``{"lvl": (T, N, M), "dl": (T, N, M), "target": (T, N, M)}``)."""
    T = states["lvl"].shape[0]
    return [plan_from_online_state(states["lvl"][t], states["dl"][t],
                                   states["target"][t], names,
                                   source=f"online:{algo}@{t}")
            for t in range(T)]


def check_mid_download_never_serves(states: dict) -> dict:
    """The online bridge's safety invariant: wherever a download is in
    flight, the *serving* level is strictly below the download target —
    i.e. no slot's residency ever exposes a submodel whose bytes have
    not fully arrived.  Returns the verdict plus coverage (how many
    slot-(BS, model) pairs were actually mid-download; a vacuously true
    check is reported as such)."""
    lvl = np.asarray(states["lvl"])
    dl = np.asarray(states["dl"], bool)
    target = np.asarray(states["target"])
    in_flight = int(dl.sum())
    ok = bool(np.all(lvl[dl] < target[dl])) if in_flight else True
    return {"ok": ok, "in_flight_pairs": in_flight,
            "vacuous": in_flight == 0}


def catalog_precisions(catalog, names) -> dict:
    """{(model, exit_idx): precision} from the catalog ladder, so the
    data plane reports exactly the precision the control plane
    optimized."""
    return {(name, j - 1): float(catalog.prec[m, j])
            for m, name in enumerate(names)
            for j in range(1, catalog.sizes.shape[1])}


def execute_plan(plan: ServingPlan, cfgs: dict, compute_flops: float,
                 arrivals: list, catalog=None, names=None,
                 with_load_delay: bool = True, admit_late: bool = False,
                 seed: int = 0, events=None, registry=None) -> dict:
    """Run one plan through the queue simulator.

    ``with_load_delay=True`` honours the plan's availability times (a
    pod cannot serve a submodel before its bytes have loaded);
    ``False`` is the idealised instant-loading counterfactual the
    ranking-survival comparison is made against.  Returns the
    ``QueueSim.metrics()`` dict.

    Telemetry taps (both default off, both decision-inert): ``events``
    is an ``repro.obs.events.EventLog`` collecting the per-request
    lifecycle; ``registry`` is an ``repro.obs.metrics.MetricsRegistry``
    into which the finished run's latency/attribution histograms and
    outcome counters are folded.
    """
    precisions = (catalog_precisions(catalog, names)
                  if catalog is not None and names is not None else None)
    sim = QueueSim(cfgs, plan.residency, compute_flops,
                   precisions=precisions, seed=seed,
                   available_at=plan.available_at if with_load_delay
                   else None,
                   admit_late=admit_late, events=events,
                   run_label=f"{plan.source}|delay={int(with_load_delay)}"
                             f"|seed={seed}")
    out = sim.run(arrivals)
    if registry is not None:
        from repro.obs import metrics as OM
        OM.observe_queue_sim(registry, sim)
    return out
