from repro.serving.engine import EdgeCluster, Request  # noqa: F401
from repro.serving.loader import PodCache, WeightStore  # noqa: F401
from repro.serving.plan import (ServingPlan,  # noqa: F401
                                check_mid_download_never_serves,
                                execute_plan, plan_from_offline,
                                plan_from_online_state,
                                plans_from_online_states)
from repro.serving.simulator import (QueueSim, SimRequest,  # noqa: F401
                                     poisson_arrivals, transfer_time)
