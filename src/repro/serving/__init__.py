from repro.serving.engine import EdgeCluster, Request  # noqa: F401
from repro.serving.loader import PodCache, WeightStore  # noqa: F401
