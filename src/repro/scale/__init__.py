"""Scale layer: the sharded, bucketed, chunk-streaming grid executor.

``run_grid(GridSpec(...))`` is the single entry point every grid in the
repo routes through — ``repro.core.cocar.cocar_grid``,
``repro.traces.engine.run_online_grid``, the sweep harness
(``repro.experiments.sweep``), and ``benchmarks/bench_scale.py``.  See
``repro.scale.executor`` for the architecture and
``docs/algorithms.md`` Sec. 9 for the grid-axes → mesh-axes → bucket
mapping.
"""
from repro.scale.buckets import Bucket, BucketPlan, plan_buckets
from repro.scale.executor import (GridResult, GridSpec,
                                  compiled_cache_stats, grid_mesh,
                                  run_grid)

__all__ = ["Bucket", "BucketPlan", "plan_buckets", "GridResult",
           "GridSpec", "compiled_cache_stats", "grid_mesh", "run_grid"]
