"""Sharded grid executor: one entry point for every scenario grid.

``run_grid(spec)`` takes a :class:`GridSpec` describing a grid of
independent work items — offline CoCaR windows, the five-policy
comparison, or online (scenario × workload × policy) scan jobs — and runs
it through three composable layers:

  1. **bucketed batching** (``repro.scale.buckets``): heterogeneous
     (N, U) windows are grouped into a small set of padded shapes
     instead of one global max-pad, bounding both compile count and
     padding waste;
  2. **mesh partitioning**: each bucket's batch axis is partitioned
     across a ``("data", "model")`` host-device mesh with
     ``jax.experimental.shard_map`` (``launch/mesh.py`` plumbing;
     ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` puts K
     virtual devices on one host) — the grid axes (variants × seeds ×
     policies / windows / workload families) all live on the stacked
     batch axis, so "data" is the only mesh axis the executor shards;
  3. **chunked streaming**: the batch is dispatched in fixed-size chunks
     whose device buffers are donated (``donate_argnums``), so peak live
     memory is O(chunk), not O(grid), as grids grow to thousands of
     scenarios.

Decision identity — the PR-3/PR-4 dual-engine discipline, now host-vmap
vs sharded — is engineered, not hoped for.  Padded rows are exactly
inert in every kernel, and the rounding/baseline randomness comes from
one of two schemes (``GridSpec.rng``), each invariant to the execution
layout:

  * ``"stacked"`` (default): drawn ONCE at the grid's global max shape
    — exactly the tensors the single-dispatch path consumes — and
    *sliced* per bucket, so the executor is bit-compatible with the
    legacy one-device dispatch.  The draw itself is O(grid) host bytes;
    right for grids whose uniforms fit in host RAM.
  * ``"per_element"``: one ``fold_in(seed, grid_index)`` key per
    element, drawn lazily per chunk at the global max shape and sliced
    — O(chunk) bytes end to end, and invariant to bucketing/chunking/
    sharding by construction (different numbers than ``"stacked"``, but
    self-consistent across every layout).  Use it when the grid scales
    past host RAM.

Under either scheme, any (bucketing × chunking × backend) combination
reproduces the same cache/routing arrays and winning trials
bit-identically (asserted in ``tests/test_scale.py`` and gated by
``benchmarks/bench_scale.py`` → ``scripts/check_bench.py``).

Compiled executables are cached module-level, keyed on (kind, backend,
mesh, static knobs); chunk shapes are padded to full chunks, so a whole
sweep compiles once per (bucket shape, chunk) and repeated sweeps with
the same :class:`~repro.scale.buckets.BucketPlan` key retrace nothing.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.obs import tracing as OT
from repro.scale.buckets import plan_buckets

GRID_KINDS = ("offline", "policy", "online")


@dataclass
class GridSpec:
    """One grid run: what to execute, and how to lay it out.

    ``kind`` selects the kernel family: ``"offline"`` (fused LP → round
    → repair → metrics over ``insts``), ``"policy"`` (all five offline
    policies over ``insts``), ``"online"`` (the scan engine over
    ``jobs`` + ``ocfg``).  ``backend="sharded"`` partitions each chunk
    across ``devices`` mesh devices; ``backend="vmap"`` runs the
    identical bucketed/chunked schedule on one device (the equivalence
    reference, and the sensible default when only one device exists).
    """
    kind: str
    insts: list = None           # offline / policy kinds
    jobs: list = None            # online kind
    ocfg: object = None          # online kind
    seed: int = 0
    n_seeds: int = 1             # offline/policy: rounding seeds
    best_of: int = 8
    pdhg_iters: int = 4000
    lp_backend: str = "reference"  # window LP solver ("reference"|"pallas")
    episodes: int = 150          # policy: GatMARL training budget
    backend: str = "sharded"     # "sharded" | "vmap"
    devices: int = None          # mesh size; None = all visible devices
    chunk_size: int = 0          # batch per dispatch; 0 = one chunk/bucket
    max_buckets: int = 4
    round_users_to: int = 1
    rng: str = "stacked"         # uniform-draw scheme, see run_grid
    progress: object = None      # callable(dict) per finished chunk
    diagnostics: bool = False    # jit-safe solver/engine telemetry tap


@dataclass
class GridResult:
    """``results`` in the kind's host shape (see ``run_grid``), plus
    scheduler stats: bucket plan key, chunk count, peak per-chunk input
    bytes vs the whole-grid bytes a one-shot dispatch would pin."""
    results: object
    stats: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# mesh + compiled-executable cache
# ---------------------------------------------------------------------------

def grid_mesh(devices: int = None):
    """A ("data", "model") host mesh with ``devices`` data shards (all
    visible devices by default) — ``launch.mesh.make_host_mesh`` with
    its device-count validation."""
    import jax

    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(data=int(devices or len(jax.devices())), model=1)


_COMPILED = {}


def _mesh_key(mesh):
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), mesh.devices.shape,
            tuple(d.id for d in mesh.devices.flat))


def compiled_cache_stats():
    """{cache key: jit-cache size} — exposed so tests can assert that
    repeated sweeps with the same bucket plan retrace nothing."""
    out = {}
    for k, fn in _COMPILED.items():
        size = fn._cache_size() if hasattr(fn, "_cache_size") else -1
        out[k] = size
    return out


def _compile(kind, mesh, n_args, make_inner, *statics):
    """Wrap ``make_inner()`` (a vmapped kernel over the batch axis) in
    shard_map over the mesh's "data" axis (identity when ``mesh`` is
    None), jit it with every array argument donated, and cache it.
    Every compiled entry point is registered with ``repro.obs`` so chunk
    spans count its retraces."""
    key = (kind, _mesh_key(mesh)) + tuple(statics)
    if key not in _COMPILED:
        import jax

        fn = make_inner()
        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            s = P("data")
            fn = shard_map(fn, mesh=mesh, in_specs=(s,) * n_args,
                           out_specs=s, check_rep=False)
        _COMPILED[key] = OT.register_jit(
            f"scale:{key}", jax.jit(fn, donate_argnums=tuple(range(n_args))))
    return _COMPILED[key]


# ---------------------------------------------------------------------------
# chunked streaming
# ---------------------------------------------------------------------------

def _nbytes(tree):
    import jax

    return sum(np.asarray(leaf).nbytes for leaf in jax.tree.leaves(tree))


def _take_rows(tree, take):
    import jax

    return jax.tree.map(lambda a: np.take(np.asarray(a), take, axis=0),
                        tree)


def _run_chunks(spec: GridSpec, mesh, fn, args, B: int, stats: dict,
                bucket_key=None):
    """Stream ``args`` through ``fn`` in fixed-size chunks; returns
    outputs concatenated back to batch size B (as host numpy).  ``args``
    is either a tuple of pytrees with a leading batch axis of size B, or
    a callable ``make(take) -> tuple`` that materializes one chunk's
    arguments on demand (how the ``per_element`` RNG mode keeps even the
    uniform draws at O(chunk)).

    Every chunk is padded to the full chunk size by repeating element 0
    (one compiled shape per bucket; the pad rows are sliced off), its
    inputs are laid out on the mesh with ``device_put`` before the call,
    and the compiled function donates them — the chunk's buffers die
    with its dispatch, so peak live memory tracks the chunk, not the
    grid.

    With ``spec.diagnostics`` each chunk span additionally records
    memory watermarks (``repro.obs.metrics.memory_snapshot``): live
    device-array bytes after the chunk's outputs land on the host, plus
    host RSS — and ``stats`` carries the grid-wide peaks.  The tap runs
    strictly after the dispatch, so it cannot perturb results; when
    diagnostics are off it is never called."""
    import jax
    from jax.experimental import enable_x64

    make = args if callable(args) else \
        (lambda take: tuple(_take_rows(a, take) for a in args))
    D = 1 if mesh is None else int(mesh.devices.size)
    chunk = int(spec.chunk_size) if spec.chunk_size else B
    chunk = -(-max(chunk, 1) // D) * D            # round up to mesh multiple
    sharding = None
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        sharding = NamedSharding(mesh, P("data"))

    outs = []
    n_chunks = -(-B // chunk)
    for ci, start in enumerate(range(0, B, chunk)):
        take = np.arange(start, min(start + chunk, B))
        if len(take) < chunk:                     # pad the tail chunk
            take = np.concatenate(
                [take, np.zeros(chunk - len(take), dtype=int)])
        if chunk == B and not callable(args):
            chunk_args = args                     # whole grid, one chunk:
        else:                                     # no identity row-copy
            chunk_args = make(take)
        in_bytes = sum(_nbytes(a) for a in chunk_args)
        pad_rows = int(chunk - (min(start + chunk, B) - start))
        with OT.TRACER.span("chunk", kind=spec.kind,
                            bucket=str(bucket_key), chunk=ci,
                            n_chunks=n_chunks, batch=int(len(take)),
                            pad_rows=pad_rows, in_bytes=in_bytes) as sp:
            with enable_x64():
                if sharding is not None:
                    chunk_args = tuple(jax.device_put(a, sharding)
                                       for a in chunk_args)
                else:
                    chunk_args = tuple(jax.device_put(a)
                                       for a in chunk_args)
                with warnings.catch_warnings():
                    # donation is best-effort: only inputs whose shape/
                    # layout matches an output can be reused (the online
                    # state is; most static tensors are not) — the
                    # mismatches are expected, not a bug
                    warnings.filterwarnings(
                        "ignore",
                        message="Some donated buffers were not usable")
                    out = fn(*chunk_args)
                out = jax.tree.map(np.asarray, out)
            if spec.diagnostics:
                from repro.obs.metrics import memory_snapshot

                mem = memory_snapshot()
                sp.attrs.update(mem)
                for k in ("device_live_bytes", "host_rss_kb",
                          "host_maxrss_kb"):
                    if k in mem:
                        stats[f"peak_{k}"] = max(
                            stats.get(f"peak_{k}", 0), mem[k])
        dt = sp.seconds
        outs.append(out)
        stats["chunks"] = stats.get("chunks", 0) + 1
        stats["peak_chunk_in_bytes"] = max(
            stats.get("peak_chunk_in_bytes", 0), in_bytes)
        stats["grid_in_bytes"] = stats.get("grid_in_bytes", 0) + in_bytes
        if spec.progress is not None:
            spec.progress({"bucket": bucket_key, "chunk": ci,
                           "n_chunks": n_chunks, "batch": int(len(take)),
                           "in_bytes": in_bytes, "seconds": dt,
                           "retraces": sp.retraces})
    if len(outs) == 1:
        out = outs[0]
    else:
        out = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *outs)
    return jax.tree.map(lambda a: a[:B], out)


def _fit_axes(arr, *dims):
    """Slice (or zero-pad) trailing axes of a globally-drawn tensor down
    to a bucket's padded sizes.  Real rows are always a prefix, so the
    values real rows consume are exactly the global draw's — the
    load-bearing fact behind bucket-invariant decisions."""
    arr = np.asarray(arr)
    for ax, size in dims:
        cur = arr.shape[ax]
        if size < cur:
            arr = np.take(arr, np.arange(size), axis=ax)
        elif size > cur:
            pad = [(0, 0)] * arr.ndim
            pad[ax] = (0, size - cur)
            arr = np.pad(arr, pad)
    return arr


def _mesh_of(spec: GridSpec):
    if spec.backend == "sharded":
        return grid_mesh(spec.devices)
    if spec.backend != "vmap":
        raise ValueError(f"unknown backend {spec.backend!r}; "
                         "one of ('sharded', 'vmap')")
    if spec.devices:
        raise ValueError(
            f"spec.devices={spec.devices} is only meaningful with "
            "backend='sharded' — a vmap run would silently ignore it")
    return None


def _element_key(seed, index):
    """The ``per_element`` RNG scheme: one PRNG key per original grid
    index, independent of bucketing/chunking/sharding by construction."""
    import jax
    from jax.experimental import enable_x64

    with enable_x64():
        return jax.random.fold_in(jax.random.PRNGKey(seed), int(index))


def _check_rng(spec: GridSpec):
    if spec.rng not in ("stacked", "per_element"):
        raise ValueError(f"unknown rng scheme {spec.rng!r}; "
                         "one of ('stacked', 'per_element')")


# ---------------------------------------------------------------------------
# kind: offline  (fused LP -> round -> repair -> argmax -> metrics)
# ---------------------------------------------------------------------------

def _run_offline(spec: GridSpec, mesh, stats):
    from repro.core import cocar as CC
    from repro.core.rounding import draw_rounding_uniforms
    from repro.mec.scenario import stack_instances

    insts = list(spec.insts)
    B = len(insts)
    M, H = insts[0].M, insts[0].H
    N_g = max(i.N for i in insts)
    U_g = max(i.U for i in insts)
    plan = plan_buckets([(i.N, i.U) for i in insts], spec.max_buckets,
                        round_users_to=spec.round_users_to)
    stats["plan"] = plan.key
    S, T = int(spec.n_seeds), max(int(spec.best_of), 1)
    if spec.rng == "stacked":
        # the same tensors offline_uniforms draws for the max-padded stack
        u_cat, u_phi = draw_rounding_uniforms(spec.seed, S * T, N_g, M,
                                              U_g, H, batch=B)

    results = [None] * B
    for bucket in plan.buckets:
        idx = np.asarray(bucket.indices)
        Nb, Ub = bucket.n_bs, bucket.n_users
        stacked = stack_instances([insts[i] for i in idx],
                                  pad_to=(Nb, Ub))
        if spec.rng == "stacked":
            args = (stacked.data,
                    _fit_axes(u_cat[idx], (2, Nb)),
                    _fit_axes(u_phi[idx], (2, Nb), (3, Ub)))
        else:
            def args(take, idx=idx, data=stacked.data, Nb=Nb, Ub=Ub):
                ucs, ups = zip(*(
                    draw_rounding_uniforms(_element_key(spec.seed, idx[j]),
                                           S * T, N_g, M, U_g, H)
                    for j in take))
                return (_take_rows(data, take),
                        np.stack([_fit_axes(u, (1, Nb)) for u in ucs]),
                        np.stack([_fit_axes(u, (1, Nb), (2, Ub))
                                  for u in ups]))
        fn = _compile("offline", mesh, 3, _offline_inner(spec),
                      int(spec.pdhg_iters), S, spec.lp_backend,
                      bool(spec.diagnostics))
        out = _run_chunks(spec, mesh, fn, args,
                          len(idx), stats, bucket_key=bucket.key)
        per = CC._unstack_device(stacked, out, S)
        for j, i in enumerate(idx):
            results[int(i)] = per[j]
    return results


def _offline_inner(spec: GridSpec):
    def make():
        import jax

        from repro.core.cocar import _pipeline_kernel

        iters, n_seeds = int(spec.pdhg_iters), int(spec.n_seeds)
        lp_backend = spec.lp_backend
        diagnostics = bool(spec.diagnostics)
        return jax.vmap(
            lambda d, uc, up: _pipeline_kernel(d, uc, up, iters, n_seeds,
                                               backend=lp_backend,
                                               diagnostics=diagnostics))
    return make


# ---------------------------------------------------------------------------
# kind: policy  (CoCaR + the four Sec. VII-B baselines)
# ---------------------------------------------------------------------------

def _run_policy(spec: GridSpec, mesh, stats):
    from repro.core import cocar as CC
    from repro.mec.scenario import stack_instances

    insts = list(spec.insts)
    B = len(insts)
    M, H = insts[0].M, insts[0].H
    N_g = max(i.N for i in insts)
    U_g = max(i.U for i in insts)
    plan = plan_buckets([(i.N, i.U) for i in insts], spec.max_buckets,
                        round_users_to=spec.round_users_to)
    stats["plan"] = plan.key
    S = int(spec.n_seeds)
    if spec.rng == "stacked":
        uniforms = CC.policy_uniforms_dims((B, N_g, M, U_g, H), spec.seed,
                                           S, spec.best_of)

    #: (axis slices to a bucket's padded sizes) per uniform tensor, in
    #: ``policy_uniforms`` order — axis 0 here is the per-element trial/
    #: seed axis; the batched tensors shift every axis right by one
    _CUTS = (((1, "N"),), ((1, "N"), (2, "U")), ((1, "N"),),
             ((1, "N"), (2, "U")), ((1, "N"),), ((1, "N"),), ((1, "U"),))

    results = {p: [None] * B for p in CC.OFFLINE_POLICIES}
    lp_obj = [None] * B
    lp_diag = [None] * B if spec.diagnostics else None
    for bucket in plan.buckets:
        idx = np.asarray(bucket.indices)
        Nb, Ub = bucket.n_bs, bucket.n_users
        stacked = stack_instances([insts[i] for i in idx],
                                  pad_to=(Nb, Ub))
        gat = CC.gat_grid_policies(stacked, spec.seed, spec.episodes)

        def cut(u, dims, off=0):
            return _fit_axes(u, *((ax + off, {"N": Nb, "U": Ub}[d])
                                  for ax, d in dims))

        if spec.rng == "stacked":
            args = ((stacked.data,)
                    + tuple(cut(u[idx], dims, off=1)
                            for u, dims in zip(uniforms, _CUTS))
                    + (gat[0], gat[1], gat[2]))
        else:
            def args(take, idx=idx, data=stacked.data, gat=gat, cut=cut):
                per = [CC.policy_uniforms_dims(
                    (None, N_g, M, U_g, H),
                    _element_key(spec.seed, idx[j]), S, spec.best_of)
                    for j in take]
                us = tuple(np.stack([cut(p[t], dims) for p in per])
                           for t, dims in enumerate(_CUTS))
                return ((_take_rows(data, take),) + us
                        + tuple(_take_rows(g, take) for g in gat))
        fn = _compile("policy", mesh, 11, _policy_inner(spec),
                      int(spec.pdhg_iters), S, spec.lp_backend,
                      bool(spec.diagnostics))
        out = _run_chunks(spec, mesh, fn, args, len(idx), stats,
                          bucket_key=bucket.key)
        for j, i in enumerate(idx):
            inst = insts[int(i)]
            lp_obj[int(i)] = float(out["lp_obj"][j])
            if lp_diag is not None:
                from repro.obs.diagnostics import lp_diag_summary

                curves = {k: np.asarray(v[j])
                          for k, v in out["lp_diag"].items()}
                lp_diag[int(i)] = lp_diag_summary(curves)
            for p in CC.OFFLINE_POLICIES:
                results[p][int(i)] = [
                    (out[p]["x"][j, s, :inst.N],
                     out[p]["A"][j, s, :inst.N, :inst.U],
                     {k: float(v[j, s])
                      for k, v in out[p]["metrics"].items()})
                    for s in range(S)]
    stats["lp_obj"] = lp_obj
    if lp_diag is not None:
        # JSON-safe per-window convergence summaries (curves stay on the
        # offline kind, which returns them per window in full)
        stats["lp_diag"] = lp_diag
    return results


def _policy_inner(spec: GridSpec):
    def make():
        import jax

        from repro.core.cocar import _policy_kernel

        iters, n_seeds = int(spec.pdhg_iters), int(spec.n_seeds)
        lp_backend = spec.lp_backend
        diagnostics = bool(spec.diagnostics)
        return jax.vmap(
            lambda *a: _policy_kernel(*a, iters, n_seeds,
                                      backend=lp_backend,
                                      diagnostics=diagnostics))
    return make


# ---------------------------------------------------------------------------
# kind: online  (the scan engine over (scenario x workload x policy) jobs;
# jobs carry aggregated-demand Workloads — grid_payloads materializes each
# job's (T, N, M) count tensor, so no per-user tensor reaches the mesh)
# ---------------------------------------------------------------------------

def _run_online(spec: GridSpec, mesh, stats):
    from repro.traces import engine as TE

    jobs = list(spec.jobs)
    payloads = TE.grid_payloads(jobs, spec.ocfg)
    B = len(payloads)

    # bucket online jobs by their exact array shapes — no padding needed,
    # so heterogeneous (n_bs, n_models, n_slots) grids just become
    # separate buckets
    groups = {}
    for i, pl in enumerate(payloads):
        key = (pl["counts"].shape, pl["stream"].adjust_ns.shape,
               pl["stream"].perms.shape)
        groups.setdefault(key, []).append(i)
    stats["plan"] = tuple(
        (key[0], len(idx)) for key, idx in sorted(groups.items()))

    results = [None] * B
    for key, idx in sorted(groups.items()):
        pls = [payloads[i] for i in idx]
        params = TE.OnlineParams(*(
            np.stack([np.asarray(getattr(pl["params"], f)) for pl in pls])
            for f in TE.OnlineParams._fields))
        st0 = TE.init_state(pls[0]["params"], spec.ocfg.dT_past)
        st0 = TE.OnlineState(*(
            np.broadcast_to(x, (len(idx),) + x.shape) for x in st0))
        args = (params, st0,
                np.stack([pl["counts"] for pl in pls]),
                np.stack([pl["stream"].adjust_ns for pl in pls]),
                np.stack([pl["stream"].u_model for pl in pls]),
                np.stack([pl["stream"].perms for pl in pls]),
                np.stack([pl["stream"].u_shrink for pl in pls]),
                np.asarray([pl["policy"] for pl in pls]))
        fn = _compile("online", mesh, 8,
                      _online_inner(bool(spec.diagnostics)),
                      bool(spec.diagnostics))
        stF, qoe, hits, diag, _ = _run_chunks(spec, mesh, fn, args, len(idx),
                                              stats, bucket_key=key[0])
        for j, i in enumerate(idx):
            tot = max(pls[j]["total"], 1.0)
            results[int(i)] = {
                "avg_qoe": float(qoe[j].sum()) / tot,
                "hit_rate": float(hits[j].sum()) / tot,
                "slot_qoe": qoe[j],
                "slot_hits": hits[j],
                "final_state": TE.OnlineState(*(x[j] for x in stF)),
            }
            if spec.diagnostics:
                results[int(i)]["diagnostics"] = {
                    k: np.asarray(v[j]) for k, v in diag.items()}
    return results


def _online_inner(diagnostics: bool = False):
    def make():
        import functools

        import jax

        from repro.traces.engine import _scan_run

        return jax.vmap(functools.partial(_scan_run,
                                          diagnostics=diagnostics))
    return make


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def run_grid(spec: GridSpec) -> GridResult:
    """Execute one grid.  Result shapes by kind (all at true, unpadded
    instance shapes, in the caller's original order):

      offline: ``results[b][s] = (x, A, info)`` — the ``cocar_grid``
               contract;
      policy:  ``results[policy][b][s] = (x, A, metrics)`` — the
               ``policy_grid_host`` contract (per-window LP objectives
               land in ``stats["lp_obj"]``);
      online:  ``results[job]`` summary dicts — the ``run_online_grid``
               contract.
    """
    if spec.kind not in GRID_KINDS:
        raise ValueError(f"unknown grid kind {spec.kind!r}; "
                         f"one of {GRID_KINDS}")
    _check_rng(spec)
    if spec.kind == "online":
        if spec.jobs is None or spec.ocfg is None:
            raise ValueError("online grids need spec.jobs and spec.ocfg")
        if not spec.jobs:
            return GridResult(results=[], stats={})
    elif not spec.insts:
        raise ValueError(f"{spec.kind} grids need spec.insts")

    mesh = _mesh_of(spec)
    stats = {"kind": spec.kind, "backend": spec.backend,
             "devices": 1 if mesh is None else int(mesh.devices.size)}
    runner = {"offline": _run_offline, "policy": _run_policy,
              "online": _run_online}[spec.kind]
    with OT.TRACER.span("run_grid", kind=spec.kind, backend=spec.backend,
                        devices=stats["devices"],
                        diagnostics=bool(spec.diagnostics)) as sp:
        results = runner(spec, mesh, stats)
    stats["seconds"] = sp.seconds
    stats["retraces"] = sp.retraces
    return GridResult(results=results, stats=stats)
