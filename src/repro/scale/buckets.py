"""Size-bucketed batch planning for heterogeneous scenario grids.

``stack_instances`` pads every window in a grid to the global max (N, U)
— one compiled shape, but on a wide grid (4-BS windows next to 12-BS
ones, 40-user windows next to 600-user ones) most of the batch is
padding, and the padded FLOPs are real FLOPs.  The other extreme — one
compile per distinct shape — trades the padding waste for compile churn.

``plan_buckets`` sits between the two: it groups the grid's (N, U)
shapes into at most ``max_buckets`` buckets, each padded to its members'
max, merging the shapes whose union wastes the fewest padded cells.
Correctness does not depend on the grouping at all — padded base
stations and users are exactly inert in every kernel (``bs_mask``, zero
``onehot_mu`` rows; see ``repro.core.lp``), so any plan reproduces the
max-padded stack's decisions bit-identically at the true shapes
(asserted in ``tests/test_scale.py``).  The plan only moves the
compile-count / padding-waste trade-off.

``BucketPlan.key`` is a stable, hashable signature of the padded shapes:
two sweeps whose grids bucket to the same key dispatch through the same
compiled executables (``repro.scale.executor`` keys its compiled-fn
cache on it), so repeated sweeps retrace nothing.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Bucket:
    """One padded shape and the grid indices stacked into it."""
    n_bs: int                    # padded N of this bucket
    n_users: int                 # padded U of this bucket
    indices: tuple               # original grid indices, ascending

    @property
    def key(self):
        return (self.n_bs, self.n_users)

    def __len__(self):
        return len(self.indices)


@dataclass(frozen=True)
class BucketPlan:
    buckets: tuple               # of Bucket, disjoint cover of the grid

    @property
    def key(self):
        """Stable jit-cache signature: padded shape + population per
        bucket.  Grids that plan to the same key hit the same compiled
        executables."""
        return tuple((b.n_bs, b.n_users, len(b.indices))
                     for b in self.buckets)

    def __len__(self):
        return len(self.buckets)

    def padded_cells(self) -> int:
        """Total (N_pad · U_pad) cells the plan dispatches — the padding
        cost the planner minimizes."""
        return sum(b.n_bs * b.n_users * len(b.indices)
                   for b in self.buckets)


def _round_up(v: int, quantum: int) -> int:
    return -(-v // max(quantum, 1)) * max(quantum, 1)


def plan_buckets(shapes, max_buckets: int = 4,
                 round_users_to: int = 1) -> BucketPlan:
    """Group grid shapes into at most ``max_buckets`` padded buckets.

    ``shapes`` is the grid's per-instance (N, U) list, in grid order.
    Greedy agglomeration: start from one bucket per distinct shape
    (sorted), then repeatedly merge the adjacent pair whose union adds
    the fewest padded cells, until the bucket count fits.  With
    ``max_buckets=1`` this degenerates to today's global max-padding;
    with ``max_buckets >= n_distinct_shapes`` every shape keeps its own
    exactly-fitting bucket.

    ``round_users_to`` rounds each bucket's padded U up to a multiple, so
    nearby grids (e.g. 150 vs 152 users) share compiled shapes across
    sweeps at a small padding cost.
    """
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    if not shapes:
        raise ValueError("plan_buckets needs at least one shape")
    by_shape = {}
    for i, (n, u) in enumerate(shapes):
        by_shape.setdefault((int(n), int(u)), []).append(i)

    # [[N_pad, U_pad, indices]], kept sorted by shape so merges are
    # deterministic and "adjacent" shapes are actually similar
    cells = [[n, u, idx] for (n, u), idx in sorted(by_shape.items())]

    def merge(a, b):
        return [max(a[0], b[0]), max(a[1], b[1]), a[2] + b[2]]

    def cost(c):
        return c[0] * c[1] * len(c[2])

    while len(cells) > max_buckets:
        best, best_waste = None, None
        for j in range(len(cells) - 1):
            a, b = cells[j], cells[j + 1]
            waste = cost(merge(a, b)) - cost(a) - cost(b)
            if best_waste is None or waste < best_waste:
                best, best_waste = j, waste
        cells[best:best + 2] = [merge(cells[best], cells[best + 1])]

    buckets = tuple(
        Bucket(n_bs=c[0], n_users=_round_up(c[1], round_users_to),
               indices=tuple(sorted(c[2])))
        for c in cells)
    return BucketPlan(buckets=buckets)
