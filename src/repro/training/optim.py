"""Pure-JAX AdamW with mixed-precision master weights.

State keeps an f32 master copy when params are low-precision (bf16), plus f32
first/second moments — all sharded identically to the params (ZeRO-style 2D
FSDP×TP sharding comes from the param specs in ``distribution/sharding.py``).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_at(oc: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps) /
                    jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


def adamw_init(params):
    # copy=True: the master must never alias the param buffer (donation)
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"master": f32(params), "m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(oc: AdamWConfig, params, grads, opt):
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(oc, step)
    b1, b2 = oc.b1, oc.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, mw):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        u = (m2 / c1) / (jnp.sqrt(v2 / c2) + oc.eps) + oc.weight_decay * mw
        return m2, v2, mw - lr * u

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    flat_w = tdef.flatten_up_to(opt["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    m_new = tdef.unflatten([o[0] for o in out])
    v_new = tdef.unflatten([o[1] for o in out])
    w_new = tdef.unflatten([o[2] for o in out])
    params_new = jax.tree.map(
        lambda w, p: w.astype(p.dtype), w_new, params)
    opt_new = {"master": w_new, "m": m_new, "v": v_new, "step": step}
    return params_new, opt_new, {"grad_norm": gnorm, "lr": lr}
