"""Fault-tolerant checkpointing: atomic, resumable, elastic-restorable.

Layout: <dir>/step_<k>/ { manifest.json, arrays.npz } written to a temp dir
and atomically renamed, so a crash mid-save never corrupts the latest
checkpoint.  ``restore_latest`` finds the newest complete checkpoint —
the auto-resume path after preemption/node failure.  Arrays are stored
unsharded; ``restore`` re-places them onto whatever sharding the (possibly
different-size, i.e. elastic) mesh prescribes.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


def save(ckpt_dir, state, step: int, keep_last: int = 3):
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(state)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef),
                "dtypes": [str(np.asarray(x).dtype) for x in leaves]}
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = ckpt_dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                    # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep_last)
    return str(final)


def _gc(ckpt_dir, keep_last):
    steps = sorted(p for p in pathlib.Path(ckpt_dir).glob("step_*"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir):
    p = pathlib.Path(ckpt_dir)
    if not p.exists():
        return None
    steps = sorted(p.glob("step_*"))
    for cand in reversed(steps):
        if (cand / "manifest.json").exists() and (cand / "arrays.npz").exists():
            return int(cand.name.split("_")[1])
    return None


def restore(ckpt_dir, state_like, step: int = None, shardings=None):
    """Restore into the structure of ``state_like``.  ``shardings``, when
    given, re-places every leaf (elastic restore onto a new mesh)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    leaves_like, treedef = _flatten(state_like)
    n = len(leaves_like)
    leaves = [data[f"a{i}"] for i in range(n)]
    leaves = [np.asarray(a, dtype=np.asarray(l).dtype)
              for a, l in zip(leaves, leaves_like)]
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(lambda x, s: jax.device_put(x, s),
                             state, shardings)
    return state, step
