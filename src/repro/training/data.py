"""Deterministic synthetic data pipeline (no external datasets offline).

Two sources:
  * ``char_corpus`` — a built-in text corpus tokenized at character level
    (real learnable structure: losses drop and deeper exits win, which is
    what calibrates the dynamic-DNN precision ladder);
  * ``markov_stream`` — a seeded first-order Markov token stream for
    arbitrary vocab sizes (shape-realistic load for big-vocab smoke tests).

Batches are yielded as {"tokens", "labels"} with next-token labels.
"""
from __future__ import annotations

import numpy as np

_CORPUS = (
    "the quick brown fox jumps over the lazy dog. "
    "mobile edge computing caches deep neural networks near users. "
    "dynamic submodels trade precision for loading latency. "
    "joint optimization of caching and routing maximizes quality of "
    "experience under memory compute and latency constraints. "
    "randomized rounding gives provable approximation guarantees. "
    "the expected future gain guides online submodel switching. "
) * 64


def char_vocab():
    chars = sorted(set(_CORPUS))
    return {c: i for i, c in enumerate(chars)}, len(chars)


def char_stream(batch: int, seq: int, steps: int, seed: int = 0):
    table, V = char_vocab()
    ids = np.asarray([table[c] for c in _CORPUS], dtype=np.int32)
    rng = np.random.default_rng(seed)
    n = len(ids) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        tok = np.stack([ids[s:s + seq] for s in starts])
        lab = np.stack([ids[s + 1:s + seq + 1] for s in starts])
        yield {"tokens": tok, "labels": lab}


def markov_stream(vocab: int, batch: int, seq: int, steps: int, seed: int = 0,
                  branch: int = 4):
    """Each token deterministically allows `branch` successors; the stream
    is learnable (entropy log2(branch)) at any vocab size."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branch), dtype=np.int32)
    for _ in range(steps):
        tok = np.empty((batch, seq + 1), dtype=np.int32)
        tok[:, 0] = rng.integers(0, vocab, size=batch)
        choices = rng.integers(0, branch, size=(batch, seq))
        for t in range(seq):
            tok[:, t + 1] = succ[tok[:, t], choices[:, t]]
        yield {"tokens": tok[:, :-1], "labels": tok[:, 1:]}
