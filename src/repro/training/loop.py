"""Training loop with fault tolerance: periodic atomic checkpoints,
auto-resume from the latest complete checkpoint, optional simulated
preemption (for the restart tests), and per-exit loss tracking (the
dynamic-DNN precision ladder comes from these).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.launch.steps import init_train_state, make_train_step
from repro.models.config import ModelConfig
from repro.training import checkpoint as CKPT
from repro.training.optim import AdamWConfig


@dataclass
class TrainConfig:
    steps: int = 200
    batch: int = 8
    seq: int = 64
    ckpt_dir: str = ""
    ckpt_every: int = 50
    keep_last: int = 3
    log_every: int = 20
    seed: int = 0
    preempt_at: int = -1        # simulate a node failure at this step


def train(cfg: ModelConfig, tc: TrainConfig, data_iter, oc=None,
          log_fn=print):
    oc = oc or AdamWConfig(total_steps=tc.steps)
    step_fn = jax.jit(make_train_step(cfg, oc), donate_argnums=(0,))
    state = init_train_state(cfg, jax.random.key(tc.seed))

    start = 0
    if tc.ckpt_dir:
        restored, step = CKPT.restore(tc.ckpt_dir, state)
        if restored is not None:
            state, start = restored, step
            log_fn(f"[resume] from checkpoint step {step}")

    history = []
    t0 = time.time()
    for step, batch in enumerate(data_iter, start=0):
        if step < start:
            continue                         # replay the stream deterministically
        if step >= tc.steps:
            break
        if step == tc.preempt_at:
            raise RuntimeError(f"simulated preemption at step {step}")
        state, metrics = step_fn(state, batch)
        if (step + 1) % tc.log_every == 0 or step == tc.steps - 1:
            m = {k: np.asarray(v).tolist() for k, v in metrics.items()}
            m["step"] = step + 1
            m["sec"] = round(time.time() - t0, 1)
            history.append(m)
            log_fn(f"step {step+1:5d} loss={m['loss']:.4f} "
                   f"ce_per_exit={[round(c, 3) for c in m['ce_per_exit']]}")
        if tc.ckpt_dir and (step + 1) % tc.ckpt_every == 0:
            CKPT.save(tc.ckpt_dir, state, step + 1, keep_last=tc.keep_last)
    if tc.ckpt_dir:
        CKPT.save(tc.ckpt_dir, state, min(tc.steps, step + 1),
                  keep_last=tc.keep_last)
    return state, history


def eval_exit_ce(cfg: ModelConfig, state, data_iter, n_batches=4):
    """Per-exit CE on held-out batches -> the measured precision ladder."""
    from repro.launch.steps import make_loss_fn
    loss_fn = jax.jit(make_loss_fn(cfg))
    ces = []
    for i, batch in enumerate(data_iter):
        if i >= n_batches:
            break
        _, extras = loss_fn(state["params"], batch)
        ces.append(np.asarray(extras["ce_per_exit"]))
    return np.mean(ces, axis=0)
