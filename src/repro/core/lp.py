"""LP solvers for problem P1-LR (paper Sec. V-A).

Two interchangeable backends:

  * ``solve_lp_scipy`` — sparse HiGHS (exact; correctness oracle and default
    at paper scale: ~10k vars solve in well under a second);
  * ``solve_lp_pdhg`` — matrix-free PDHG (Chambolle–Pock with diagonal
    preconditioning, PDLP-style) written in JAX and fully jit-compiled.
    This is the accelerator-native production path: the operator K is never
    materialized — every constraint family is applied functionally — so the
    solver scales to large (N·U·H) instances and can run on the serving mesh
    next to the data plane.

The PDHG iteration is a pure function of a :class:`PDHGData` pytree, so it
jits once per shape and vmaps across whole *batches* of windows:
``solve_lp_pdhg_batched`` solves a stack of instances (windows, seeds,
scenario-grid variants — see ``repro.mec.scenario.stack_instances``) in one
dispatch.  Heterogeneous (N, U) stacks are padded with inert base stations
(masked out of the routing update entirely via ``bs_mask``) and inert
users (zero precision and a zero one-hot row, so no mass ever moves
toward them); real rows see exactly the per-iteration updates of a solo
solve.

Both backends return fractional (x†, A†) with x (N,M,H+1) and A (N,U,H).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

from repro.core.jdcr import JDCRInstance
from repro.obs.diagnostics import DEFAULT_TOL as PDHG_TOL
from repro.obs.tracing import register_jit

#: Default sampling stride (iterations) for the diagnostics tap.
DIAG_STRIDE = 50


# ---------------------------------------------------------------------------
# scipy / HiGHS oracle
# ---------------------------------------------------------------------------

def solve_lp_scipy(inst: JDCRInstance):
    import scipy.sparse as sp
    from scipy.optimize import linprog

    N, M, H, U = inst.N, inst.M, inst.H, inst.U
    nx = N * M * (H + 1)
    nA = N * U * H
    nz = nx + nA

    def xid(n, m, h):
        return (n * M + m) * (H + 1) + h

    def aid(n, u, h):
        return nx + (n * U + u) * H + h

    c = np.zeros(nz)
    prec_u = inst.prec[inst.m_u, 1:]                       # (U, H)
    for n in range(N):
        base = nx + n * U * H
        c[base:base + U * H] = -prec_u.ravel()             # maximize

    rows, cols, vals, b_ub = [], [], [], []

    def add_row(cidx, cval, rhs):
        r = len(b_ub)
        rows.extend([r] * len(cidx))
        cols.extend(cidx)
        vals.extend(cval)
        b_ub.append(rhs)

    # (2) memory
    for n in range(N):
        idx = [xid(n, m, h) for m in range(M) for h in range(H + 1)]
        val = [inst.sizes[m, h] for m in range(M) for h in range(H + 1)]
        add_row(idx, val, inst.R[n])
    # (12) route, (15) latency, (16) load
    T = inst.e2e_latency()                                 # (N,U,H)
    L = inst.load_latency()                                # (N,U,H)
    for u in range(U):
        idx = [aid(n, u, h) for n in range(N) for h in range(H)]
        add_row(idx, [1.0] * len(idx), 1.0)
        add_row(idx, [T[n, u, h] for n in range(N) for h in range(H)],
                inst.ddl[u])
        add_row(idx, [L[n, u, h] for n in range(N) for h in range(H)],
                inst.s_u[u])
    # (14) A <= x
    for n in range(N):
        for u in range(U):
            m = inst.m_u[u]
            for h in range(H):
                add_row([aid(n, u, h), xid(n, m, h + 1)], [1.0, -1.0], 0.0)

    A_ub = sp.csr_matrix((vals, (rows, cols)), shape=(len(b_ub), nz))

    # (1) equality: one submodel slot per (n, m)
    er, ec, ev, b_eq = [], [], [], []
    for n in range(N):
        for m in range(M):
            r = len(b_eq)
            for h in range(H + 1):
                er.append(r)
                ec.append(xid(n, m, h))
                ev.append(1.0)
            b_eq.append(1.0)
    A_eq = sp.csr_matrix((ev, (er, ec)), shape=(len(b_eq), nz))

    res = linprog(c, A_ub=A_ub, b_ub=np.asarray(b_ub), A_eq=A_eq,
                  b_eq=np.asarray(b_eq), bounds=(0, 1), method="highs")
    if not res.success:
        raise RuntimeError(f"LP failed: {res.message}")
    z = res.x
    x = z[:nx].reshape(N, M, H + 1)
    A = z[nx:].reshape(N, U, H)
    return x, A, -res.fun


# ---------------------------------------------------------------------------
# JAX PDHG (matrix-free, diagonally preconditioned, batchable)
# ---------------------------------------------------------------------------

class PDHGData(NamedTuple):
    """Everything the PDHG iteration needs about one window, as arrays.

    A pure pytree: jit-traceable, and vmappable over a leading batch axis
    (see ``solve_lp_pdhg_batched``).  Shapes (unbatched):

      sizes      (M, H+1)   submodel memory footprints r_h
      prec       (M, H+1)   catalog precision p_h (slot 0 = 0) — unused by
                            the LP iteration itself, but the repair kernel
                            (``repro.core.rounding.repair_device``) rides
                            on the same pytree and keys eviction benefits
                            off the per-model precision
      prec_u     (U, H)     objective coefficients p_h per user
      T          (N, U, H)  end-to-end latency T̂ (paper Eq. 15)
      L          (N, U, H)  model-load latency (paper Eq. 16)
      onehot_mu  (U, M)     one-hot of each user's requested model type
      R          (N,)       memory capacity
      ddl        (U,)       latency budgets
      s_u        (U,)       initiation times (load-latency budgets)
      bs_mask    (N,)       1 for real base stations, 0 for padding; the
                            kernel freezes routing mass at masked rows and
                            sizes the route-dual step from the mask, so
                            padded rows never perturb real ones
      home_onehot (U, N)    one-hot of each user's home BS — unused by the
                            LP iteration, but the baseline kernels riding
                            on the same pytree (``repro.core.baselines``)
                            key home-BS routing off it; zero row for
                            padded users
    """
    sizes: object
    prec: object
    prec_u: object
    T: object
    L: object
    onehot_mu: object
    R: object
    ddl: object
    s_u: object
    bs_mask: object
    home_onehot: object


def pdhg_data(inst: JDCRInstance) -> PDHGData:
    """Extract the solver-facing arrays from one instance."""
    home_onehot = np.zeros((inst.U, inst.N))
    home_onehot[np.arange(inst.U), inst.home] = 1.0
    return PDHGData(
        sizes=np.asarray(inst.sizes, dtype=np.float64),
        prec=np.asarray(inst.prec, dtype=np.float64),
        prec_u=np.asarray(inst.prec[inst.m_u, 1:], dtype=np.float64),
        T=np.asarray(inst.e2e_latency(), dtype=np.float64),
        L=np.asarray(inst.load_latency(), dtype=np.float64),
        onehot_mu=inst.onehot_mu(),
        R=np.asarray(inst.R, dtype=np.float64),
        ddl=np.asarray(inst.ddl, dtype=np.float64),
        s_u=np.asarray(inst.s_u, dtype=np.float64),
        bs_mask=np.ones(inst.N),
        home_onehot=home_onehot)


def _pdhg_kernel(data: PDHGData, iters: int, diagnostics: bool = False,
                 diag_stride: int = DIAG_STRIDE):
    """One window's PDHG solve as a pure jnp function of ``data``.

    Chambolle–Pock with Pock–Chambolle diagonal step sizes (alpha = 1):
    tau_j = 1/sum_i |K_ij|, sigma_i = 1/sum_j |K_ij|.  Duals: the one-hot
    equality (N,M) is free, every inequality dual is projected to >= 0.

    With ``diagnostics=True`` the same iteration runs as nested scans over
    ``diag_stride``-sized segments (bit-identical composition — the scan
    body is unchanged and segment boundaries only read the carry) and the
    return grows a third element: a jit-safe pytree of curves sampled at
    each stride boundary plus the final iterate —

      iters       (S,) int32   sampled iteration counts
      primal_res  (S,)         scaled primal residual (the same masked
                               max the host ``pdhg_primal_residual``
                               computes: memory / max(R), route, A <= x,
                               one-submodel equality)
      dual_res    (S,)         fixed-point displacement of one extra
                               PDHG step at the sampled iterate (0 at a
                               saddle point)
      obj         (S,)         LP objective trajectory
    """
    import jax
    import jax.numpy as jnp

    sizes, prec_u, T, L, onehot_mu, R, ddl, s_u, bs_mask = (
        data.sizes, data.prec_u, data.T, data.L, data.onehot_mu,
        data.R, data.ddl, data.s_u, data.bs_mask)
    N, U, H = T.shape
    M = sizes.shape[0]

    def K(x, A):
        y_eq = x.sum(-1) - 1.0                                      # (N,M)
        y_mem = jnp.einsum("nmh,mh->n", x, sizes) - R               # (N,)
        y_route = A.sum(axis=(0, 2)) - 1.0                          # (U,)
        y_lat = jnp.einsum("nuh,nuh->u", A, T) - ddl                # (U,)
        y_load = jnp.einsum("nuh,nuh->u", A, L) - s_u               # (U,)
        xa = jnp.einsum("nmh,um->nuh", x[:, :, 1:], onehot_mu)      # (N,U,H)
        y_ax = A - xa                                               # (N,U,H)
        return y_eq, y_mem, y_route, y_lat, y_load, y_ax

    def KT(y):
        y_eq, y_mem, y_route, y_lat, y_load, y_ax = y
        gx = jnp.zeros((N, M, H + 1))
        gx += y_eq[:, :, None]
        gx += y_mem[:, None, None] * sizes[None]
        gx_sub = -jnp.einsum("nuh,um->nmh", y_ax, onehot_mu)        # (N,M,H)
        gx = gx.at[:, :, 1:].add(gx_sub)
        gA = y_route[None, :, None] + y_ax \
            + y_lat[None, :, None] * T + y_load[None, :, None] * L
        return gx, gA

    # row sums (per dual)
    r_eq = jnp.full((N, M), float(H + 1))
    r_mem = jnp.ones((N,)) * sizes.sum()
    r_route = jnp.ones((U,)) * bs_mask.sum() * H     # only real BSs route
    r_lat = T.sum(axis=(0, 2))
    r_load = L.sum(axis=(0, 2))
    r_ax = jnp.full((N, U, H), 2.0)
    sig = tuple(1.0 / jnp.maximum(r, 1e-9)
                for r in (r_eq, r_mem, r_route, r_lat, r_load, r_ax))
    # column sums (per primal)
    cx = jnp.ones((N, M, H + 1))                                    # eq
    cx += sizes[None]                                               # mem
    users_of_m = onehot_mu.sum(0)                                   # (M,)
    cx = cx.at[:, :, 1:].add(users_of_m[None, :, None])             # A<=x
    cA = jnp.ones((N, U, H)) + T + L + 1.0                          # route+lat+load+ax
    tau_x = 1.0 / jnp.maximum(cx, 1e-9)
    # masked rows get a zero step: A starts at 0 there and stays exactly 0,
    # so padded base stations never couple into the real rows' duals
    tau_A = bs_mask[:, None, None] / jnp.maximum(cA, 1e-9)

    def proj_dual(y):
        y_eq, *ineq = y
        return (y_eq,) + tuple(jnp.maximum(v, 0.0) for v in ineq)

    x = jnp.full((N, M, H + 1), 1.0 / (H + 1))
    A = jnp.zeros((N, U, H))
    y = tuple(jnp.zeros_like(v) for v in K(x, A))

    def body(carry, _):
        x, A, y = carry
        gx, gA = KT(y)
        # gradient of -objective wrt A is -prec
        x_new = jnp.clip(x - tau_x * gx, 0.0, 1.0)
        A_new = jnp.clip(A - tau_A * (gA - prec_u[None]), 0.0, 1.0)
        xb = 2 * x_new - x
        Ab = 2 * A_new - A
        Ky = K(xb, Ab)
        y_new = proj_dual(tuple(yy + s * kk
                                for yy, s, kk in zip(y, sig, Ky)))
        return (x_new, A_new, y_new), None

    if not diagnostics:
        (x, A, y), _ = jax.lax.scan(body, (x, A, y), None, length=iters)
        return x, A

    bs = bs_mask > 0                                            # (N,)
    um = onehot_mu.sum(-1) > 0                                  # (U,)
    r_scale = 1.0 / jnp.maximum(R.max(), 1e-9)

    def sample(carry):
        x, A, _ = carry
        y_eq, y_mem, y_route, _, _, y_ax = K(x, A)
        r_eq = jnp.max(jnp.where(bs[:, None], jnp.abs(y_eq), 0.0))
        r_mem = jnp.max(jnp.where(bs, y_mem, -jnp.inf)) * r_scale
        r_route = jnp.max(jnp.where(um, y_route, -jnp.inf))
        primal = jnp.maximum(
            jnp.maximum(jnp.maximum(r_eq, r_mem),
                        jnp.maximum(r_route, jnp.max(y_ax))), 0.0)
        (x2, A2, _), _ = body(carry, None)
        dual = jnp.maximum(jnp.abs(x2 - x).max(), jnp.abs(A2 - A).max())
        obj = jnp.einsum("nuh,uh->", A, prec_u)
        return primal, dual, obj

    n_seg, rem = divmod(int(iters), int(diag_stride))

    def seg(carry, _):
        carry, _ = jax.lax.scan(body, carry, None, length=diag_stride)
        return carry, sample(carry)

    carry = (x, A, y)
    curves = []
    if n_seg:
        carry, curves = jax.lax.scan(seg, carry, None, length=n_seg)
    if rem:
        carry, _ = jax.lax.scan(body, carry, None, length=rem)
    sampled = [diag_stride * (s + 1) for s in range(n_seg)]
    if rem or not n_seg:  # final iterate not already on a stride boundary
        final = sample(carry)
        sampled.append(int(iters))
        pr, dr, ob = (jnp.concatenate([curves[i], final[i][None]])
                      if n_seg else final[i][None] for i in range(3))
    else:
        pr, dr, ob = curves
    diag = {"iters": jnp.asarray(sampled, dtype=jnp.int32),
            "primal_res": pr, "dual_res": dr, "obj": ob}
    x, A, _ = carry
    return x, A, diag


#: LP solver backends: "reference" is the plain f64 kernel above;
#: "pallas" is the fused mixed-precision path (repro.kernels.pdhg_fused
#: — the Pallas engine on TPU, its lax.scan realization elsewhere).
LP_BACKENDS = ("reference", "pallas")


def _lp_solve_kernel(data, iters: int, backend: str = "reference",
                     diagnostics: bool = False,
                     diag_stride: int = DIAG_STRIDE):
    """Traceable (x, A) window solve dispatching on ``backend``.  Both
    backends return float64 x (N,M,H+1) / A (N,U,H); "pallas" produces
    fractionals within rounding-margin of the reference, so downstream
    decisions (rounding, repair, winning trials) are identical — the
    contract tests/test_pdhg_fused.py enforces.

    ``diagnostics=True`` appends a jit-safe curves pytree as a third
    return (see ``_pdhg_kernel``); the decision arrays are bit-identical
    either way (tests/test_obs.py)."""
    if backend == "reference":
        return _pdhg_kernel(data, iters, diagnostics=diagnostics,
                            diag_stride=diag_stride)
    if backend == "pallas":
        from repro.kernels.pdhg_fused import pdhg_fused
        return pdhg_fused(data, iters, diagnostics=diagnostics,
                          diag_stride=diag_stride)
    raise ValueError(f"unknown LP backend {backend!r}; one of {LP_BACKENDS}")


_JIT_CACHE = {}


def _jitted_kernel(batched: bool, backend: str = "reference",
                   diagnostics: bool = False,
                   diag_stride: int = DIAG_STRIDE):
    """Module-level jit cache: one compile per (batched, backend, diag,
    shape, iters) — repeat calls at the same shapes (e.g. window loops)
    skip tracing.  Every cached entry point is registered with
    ``repro.obs`` so span retrace counters see it."""
    mode = "batched" if batched else "single"
    # the stride is only a trace constant when the tap is on; normalize
    # it out of the key otherwise so diag-off callers share one compile
    key = (mode, backend, bool(diagnostics),
           int(diag_stride) if diagnostics else None)
    if key not in _JIT_CACHE:
        import jax
        fn = functools.partial(_lp_solve_kernel, backend=backend,
                               diagnostics=diagnostics,
                               diag_stride=diag_stride)
        if batched:
            fn = jax.vmap(fn, in_axes=(0, None))
        jitted = jax.jit(fn, static_argnums=(1,))
        name = f"lp:{mode}:{backend}:diag={int(bool(diagnostics))}"
        _JIT_CACHE[key] = register_jit(name, jitted)
    return _JIT_CACHE[key]


@dataclass
class PDHGResult:
    x: np.ndarray
    A: np.ndarray
    obj: float
    iters: int
    primal_res: float
    dual_res: float
    converged: bool = False
    tol: float = 0.0
    diag: object = None


@dataclass
class BatchedPDHGResult:
    """Padded batch solution: x (B,N,M,H+1), A (B,N,U,H), objs (B,).

    With heterogeneous stacks, slice each element back to its true (N_i,
    U_i) before use — ``StackedWindows.unstack`` does this.  ``diag``
    carries the batched diagnostics curves (leading axis B) when the run
    asked for them, else None.
    """
    x: np.ndarray
    A: np.ndarray
    objs: np.ndarray
    iters: int
    diag: object = None


def pdhg_primal_residual(inst: JDCRInstance, x, A) -> float:
    """Scaled primal feasibility residual of a fractional (x, A) — the
    max over memory / max(R), route, A <= x and the one-submodel
    equality (the same contract the device-side diagnostics sample and
    ``obs.DEFAULT_TOL`` are calibrated against)."""
    from repro.core.jdcr import check_feasible
    res = check_feasible(inst, x, A, atol=np.inf)
    primal = max(res["memory"] / max(inst.R.max(), 1e-9), res["route"],
                 res["A_le_x"], res["one_submodel"])
    return float(max(primal, 0.0))


def solve_lp_pdhg(inst: JDCRInstance, iters: int = 4000, check_every: int = 200,
                  tol: float = PDHG_TOL, backend: str = "reference",
                  diagnostics: bool = False):
    """One-window PDHG solve.  The result always carries a ``converged``
    flag (final residual vs ``tol``) instead of silently returning after
    the fixed iteration budget; ``diagnostics=True`` additionally attaches
    the device-sampled residual/objective curves (stride =
    ``check_every``) without changing x/A bits."""
    out = _jitted_kernel(batched=False, backend=backend,
                         diagnostics=diagnostics,
                         diag_stride=check_every)(pdhg_data(inst), iters)
    x, A = out[0], out[1]
    diag = ({k: np.asarray(v) for k, v in out[2].items()}
            if diagnostics else None)
    x = np.asarray(x)
    A = np.asarray(A)
    obj = inst.objective(A)
    primal = pdhg_primal_residual(inst, x, A)
    return PDHGResult(x=x, A=A, obj=obj, iters=iters,
                      primal_res=primal, dual_res=0.0,
                      converged=bool(primal <= tol), tol=float(tol),
                      diag=diag)


def solve_lp_pdhg_batched(data: PDHGData, iters: int = 4000,
                          backend: str = "reference",
                          diagnostics: bool = False,
                          diag_stride: int = DIAG_STRIDE) -> BatchedPDHGResult:
    """Solve a whole stack of windows in ONE vmapped, jitted dispatch.

    ``data`` is a :class:`PDHGData` whose every field carries a leading
    batch axis (build it with ``repro.mec.scenario.stack_instances``).
    Objectives are exact: padded users carry zero ``prec_u`` and padded
    base stations hold A == 0 throughout (``bs_mask``), so padding
    contributes nothing to the einsum.
    """
    out = _jitted_kernel(batched=True, backend=backend,
                         diagnostics=diagnostics,
                         diag_stride=diag_stride)(data, iters)
    x, A = out[0], out[1]
    diag = ({k: np.asarray(v) for k, v in out[2].items()}
            if diagnostics else None)
    x = np.asarray(x)
    A = np.asarray(A)
    objs = np.einsum("bnuh,buh->b", A, np.asarray(data.prec_u))
    return BatchedPDHGResult(x=x, A=A, objs=objs, iters=iters, diag=diag)
