"""Executable versions of the paper's theory (Thms 1–5): approximation and
constraint-violation bounds, checkable against empirical rounding draws.
"""
from __future__ import annotations

import numpy as np

from repro.core.jdcr import JDCRInstance
from repro.core.rounding import round_solution


def n_submodels(inst: JDCRInstance) -> int:
    return inst.M * inst.H


def theorem1_ratio(inst: JDCRInstance, lp_obj: float):
    """(1 - sqrt(4 ln|H| / P†))² — valid when P† >= 4 ln|H| (Thm 1)."""
    lH = np.log(n_submodels(inst))
    if lp_obj < 4 * lH:
        return None
    d = np.sqrt(4 * lH / lp_obj)
    return (1 - d) ** 2


def _violation_factor(zeta: float, inst: JDCRInstance):
    """(sqrt(2 ln|H| / ζ) + 1/√2)² + 1/2 — Thms 2–5's common shape."""
    lH = np.log(n_submodels(inst))
    if zeta <= 0:
        return np.inf
    return (np.sqrt(2 * lH / zeta) + 1 / np.sqrt(2)) ** 2 + 0.5


def bounds(inst: JDCRInstance, x_frac, A_frac, lp_obj: float):
    """All five theorem bounds for one fractional solution."""
    zeta_mem = np.einsum("nmh,mh->n", x_frac, inst.sizes)        # (N,)
    eta = A_frac.sum(axis=(0, 2))                                # (U,)
    T = inst.e2e_latency()
    L = inst.load_latency()
    lat = np.einsum("nuh,nuh->u", A_frac, T)
    load = np.einsum("nuh,nuh->u", A_frac, L)
    return {
        "thm1_ratio": theorem1_ratio(inst, lp_obj),
        "thm2_memory_factor": [
            float(_violation_factor(z / max(inst.R.max(), 1e-9) * 8, inst))
            for z in zeta_mem],
        "thm3_route_factor": float(np.median(
            [_violation_factor(e, inst) for e in eta if e > 0] or [np.inf])),
        "thm4_latency_factor": float(np.median(
            [_violation_factor(l / d, inst)
             for l, d in zip(lat, inst.ddl) if l > 0] or [np.inf])),
        "thm5_load_factor": float(np.median(
            [_violation_factor(l / max(s, 1e-9), inst)
             for l, s in zip(load, inst.s_u) if l > 0] or [np.inf])),
    }


def empirical_violations(inst: JDCRInstance, x_frac, A_frac, draws: int = 100,
                         seed: int = 0):
    """Empirical max violation factors over rounding draws (no repair)."""
    mem_f, route_f, obj = [], [], []
    used_per_bs = []
    T = inst.e2e_latency()
    lat_f = []
    for s in range(draws):
        x_i, A_i = round_solution(inst, x_frac, A_frac, seed + s)
        used = np.einsum("nmh,mh->n", x_i, inst.sizes)
        used_per_bs.append(used / inst.R)
        mem_f.append(float(np.max(used / inst.R)))
        route_f.append(float(np.max(A_i.sum(axis=(0, 2)))))
        lat = np.einsum("nuh,nuh->u", A_i, T)
        lat_f.append(float(np.max(lat / inst.ddl)))
        obj.append(inst.objective(A_i))
    return {
        "memory_factor_max": max(mem_f),
        # Lemma 1: per-BS expectation of memory use is <= R
        "memory_expectation_per_bs": np.mean(used_per_bs, axis=0).tolist(),
        "route_max": max(route_f),
        "latency_factor_max": max(lat_f),
        "obj_mean": float(np.mean(obj)),
        "obj_std": float(np.std(obj)),
    }
