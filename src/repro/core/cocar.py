"""CoCaR — the offline algorithm (paper Alg. 1 + Sec. V-D) and the
window-by-window offline driver.

``cocar_window`` handles one window; ``cocar_windows_batched`` solves many
independent windows (scenario-grid variants, seeds, parallel traces)
through ONE vmapped PDHG dispatch — the entry point the sweep harness
(``repro.experiments.sweep``) builds on.
"""
from __future__ import annotations

import numpy as np

from repro.core import lp as LP
from repro.core.jdcr import JDCRInstance
from repro.core.rounding import repair, round_solution_batch
from repro.mec import metrics as MET
from repro.mec.scenario import MECConfig, Scenario, stack_instances


def _round_and_repair(inst: JDCRInstance, x_f, A_f, seed: int, best_of: int):
    """All ``best_of`` Alg. 1 draws in one batched RNG op, then repair each
    and keep the feasible solution with the highest objective — every draw
    satisfies Thm 1's guarantee, so the max only tightens it (and cuts the
    repair losses from unlucky memory-overflow draws; draws are
    microseconds next to the LP solve)."""
    xs, As = round_solution_batch(inst, x_f, A_f, seed,
                                  n_trials=max(best_of, 1))
    best = None
    for x_i, A_i in zip(xs, As):
        x, A = repair(inst, x_i, A_i)
        val = inst.objective(A)
        if best is None or val > best[0]:
            best = (val, x, A)
    _, x, A = best
    return x, A


def cocar_window(inst: JDCRInstance, seed: int = 0, solver: str = "scipy",
                 pdhg_iters: int = 4000, best_of: int = 8):
    """One observation window: LP -> randomized rounding -> repair."""
    if solver == "pdhg":
        res = LP.solve_lp_pdhg(inst, iters=pdhg_iters)
        x_f, A_f, obj = res.x, res.A, res.obj
    else:
        x_f, A_f, obj = LP.solve_lp_scipy(inst)
    x, A = _round_and_repair(inst, x_f, A_f, seed, best_of)
    return x, A, {"lp_obj": obj}


def cocar_windows_batched(insts, seed: int = 0, pdhg_iters: int = 4000,
                          best_of: int = 8):
    """CoCaR over a stack of independent windows, LP-solved in ONE vmapped
    PDHG dispatch (rounding + repair stay per-window: repair is a
    host-side heuristic).

    Instances may differ in N and U (padded inside ``stack_instances``)
    but must share the catalog shape (M, H).  Returns a list of
    (x, A, info) triples aligned with ``insts``.
    """
    stacked = stack_instances(list(insts))
    res = LP.solve_lp_pdhg_batched(stacked.data, iters=pdhg_iters)
    out = []
    for i, (inst, (x_f, A_f)) in enumerate(
            zip(stacked.insts, stacked.unstack(res.x, res.A))):
        x, A = _round_and_repair(inst, x_f, A_f, seed * 7919 + i, best_of)
        out.append((x, A, {"lp_obj": inst.objective(A_f)}))
    return out


def lr_window(inst: JDCRInstance):
    """The LR upper bound (fractional optimum, paper's 'LR')."""
    _, _, obj = LP.solve_lp_scipy(inst)
    return obj


def run_offline(cfg: MECConfig, algo: str = "cocar", solver: str = "scipy",
                seed: int = 0, scenario: Scenario = None):
    """Runs `algo` over cfg.n_windows windows; returns aggregate metrics.

    algo in {cocar, lr, greedy, random, spr3, gatmarl}.
    """
    from repro.core import baselines as BL

    sc = scenario or Scenario(cfg)
    x_prev = sc.empty_cache()
    results, lr_objs = [], []
    for w in range(cfg.n_windows):
        inst = sc.instance(w, x_prev)
        if algo == "cocar":
            x, A, _ = cocar_window(inst, seed=seed * 1000 + w, solver=solver)
        elif algo == "lr":
            lr_objs.append(lr_window(inst) / inst.U)
            # LR is an upper bound, not a deployable policy: carry greedy
            # caching forward so later windows stay comparable
            x, A, _ = cocar_window(inst, seed=seed * 1000 + w, solver=solver)
        elif algo == "greedy":
            x, A = BL.greedy(inst)
        elif algo == "random":
            x, A = BL.random_policy(inst, seed=seed * 1000 + w)
        elif algo == "spr3":
            x, A = BL.spr3(inst, seed=seed * 1000 + w)
        elif algo == "gatmarl":
            x, A = BL.gatmarl(inst, seed=seed)
        else:
            raise ValueError(algo)
        results.append(MET.window_metrics(inst, x, A))
        x_prev = x
    agg = MET.aggregate(results)
    if algo == "lr":
        agg["lr_bound"] = float(np.mean(lr_objs))
    return agg
