"""CoCaR — the offline algorithm (paper Alg. 1 + Sec. V-D) and the
window-by-window offline driver.

``cocar_window`` handles one window on the host.  For grids, the whole
offline pipeline — LP (PDHG) → randomized rounding → repair → trial
argmax → window metrics — is a single jitted/vmapped device dispatch over
(windows × rounding seeds × best_of trials): ``offline_pipeline_device``,
driven by ``cocar_windows_batched(backend="device")`` and the sweep
harness (``repro.experiments.sweep``).

``offline_pipeline_host`` is the NumPy reference of the same computation
(per-window Python loops over seeds and trials).  Both consume the same
pre-drawn rounding uniforms and make decision-identical choices — the
offline counterpart of the PR-2 online-engine equivalence
(``docs/algorithms.md`` Sec. 7; asserted in
``tests/test_offline_batched.py`` / ``benchmarks/bench_offline.py``).
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core import lp as LP
from repro.core.jdcr import JDCRInstance, objective_sel
from repro.core.rounding import (draw_rounding_uniforms, repair,
                                 repair_device, round_from_uniforms)
from repro.mec import metrics as MET
from repro.mec.scenario import MECConfig, Scenario, StackedWindows, \
    stack_instances


def _round_and_repair(inst: JDCRInstance, x_f, A_f, seed: int, best_of: int):
    """All ``best_of`` Alg. 1 draws from one batched RNG op, then repair
    each and keep the feasible solution with the highest objective — every
    draw satisfies Thm 1's guarantee, so the max only tightens it (and cuts
    the repair losses from unlucky memory-overflow draws; draws are
    microseconds next to the LP solve)."""
    T = max(best_of, 1)
    u_cat, u_phi = draw_rounding_uniforms(seed, T, inst.N, inst.M, inst.U,
                                          inst.H)
    x_r, A_r = round_from_uniforms(np.asarray(x_f, np.float64),
                                   np.asarray(A_f, np.float64),
                                   inst.onehot_mu(), u_cat, u_phi)
    prec_u = inst.prec[inst.m_u, 1:]
    best = None
    for x_i, A_i in zip(x_r, A_r):
        x, A = repair(inst, x_i, A_i)
        val = objective_sel(prec_u, A)
        if best is None or val > best[0]:
            best = (val, x, A)
    _, x, A = best
    return x, A


def cocar_window(inst: JDCRInstance, seed: int = 0, solver: str = "scipy",
                 pdhg_iters: int = 4000, best_of: int = 8):
    """One observation window: LP -> randomized rounding -> repair."""
    if solver == "pdhg":
        res = LP.solve_lp_pdhg(inst, iters=pdhg_iters)
        x_f, A_f, obj = res.x, res.A, res.obj
    else:
        x_f, A_f, obj = LP.solve_lp_scipy(inst)
    x, A = _round_and_repair(inst, x_f, A_f, seed, best_of)
    return x, A, {"lp_obj": obj}


# ---------------------------------------------------------------------------
# the fused offline pipeline (one dispatch over windows × seeds × trials)
# ---------------------------------------------------------------------------

def _pipeline_kernel(data, u_cat, u_phi, iters, n_seeds):
    """One padded window through LP → round → repair → argmax → metrics,
    entirely in jnp.  ``u_cat (S·T, N, M)`` / ``u_phi (S·T, N, U, H)``
    carry ``n_seeds`` independent rounding seeds of ``best_of`` trials
    each; the best trial *per seed* is selected on device."""
    import jax
    import jax.numpy as jnp

    x_f, A_f = LP._pdhg_kernel(data, iters)
    x_r, A_r = round_from_uniforms(x_f, A_f, data.onehot_mu, u_cat, u_phi)
    x_p, A_p = jax.vmap(repair_device, in_axes=(None, 0, 0))(data, x_r, A_r)
    objs = jax.vmap(lambda a: objective_sel(data.prec_u, a))(A_p)
    T = objs.shape[0] // n_seeds
    objs = objs.reshape(n_seeds, T)
    best_t = jnp.argmax(objs, axis=1)                       # (S,)
    idx = jnp.arange(n_seeds) * T + best_t
    x_b, A_b = x_p[idx], A_p[idx]                           # (S, ...)
    met = jax.vmap(lambda xx, aa: MET.window_metrics_device(data, xx, aa))(
        x_b, A_b)
    lp_obj = jnp.einsum("nuh,uh->", A_f, data.prec_u)
    return {"x_frac": x_f, "A_frac": A_f, "x": x_b, "A": A_b,
            "trial_objs": objs, "best_t": best_t, "metrics": met,
            "lp_obj": lp_obj}


@functools.cache
def _pipeline_jitted():
    import jax
    fn = jax.vmap(_pipeline_kernel, in_axes=(0, 0, 0, None, None))
    return jax.jit(fn, static_argnums=(3, 4))


def offline_uniforms(stacked: StackedWindows, seed: int, n_seeds: int,
                     best_of: int):
    """The rounding randomness both pipeline engines share: one batched
    draw at the padded stack shape, ``(B, S·T, ...)``."""
    B = len(stacked)
    N, U, H = stacked.data.T.shape[1:]
    M = stacked.data.sizes.shape[1]
    return draw_rounding_uniforms(seed, n_seeds * max(best_of, 1),
                                  N, M, U, H, batch=B)


def offline_pipeline_device(stacked: StackedWindows, u_cat, u_phi,
                            pdhg_iters: int = 4000, n_seeds: int = 1):
    """The whole offline grid in ONE jitted/vmapped f64 dispatch.

    Returns a dict of padded numpy arrays: fractional solutions
    ``x_frac (B,N,M,H+1)`` / ``A_frac``, best-per-seed integral solutions
    ``x (B,S,...)`` / ``A``, per-trial objectives ``trial_objs (B,S,T)``,
    the winning trial indices ``best_t (B,S)``, window ``metrics`` (dict of
    (B,S) arrays), and ``lp_obj (B,)``.
    """
    from jax.experimental import enable_x64

    with enable_x64():
        out = _pipeline_jitted()(stacked.data, u_cat, u_phi,
                                 int(pdhg_iters), int(n_seeds))
    return {k: ({kk: np.asarray(vv) for kk, vv in v.items()}
                if isinstance(v, dict) else np.asarray(v))
            for k, v in out.items()}


def offline_pipeline_host(stacked: StackedWindows, x_frac, A_frac,
                          u_cat, u_phi, n_seeds: int = 1):
    """NumPy reference of ``offline_pipeline_device``'s round → repair →
    argmax → metrics stages: per-(window, seed, trial) Python loops over
    the *same* fractional solution and uniforms.  This is both the
    correctness oracle and the host-loop path the offline benchmark
    measures against.

    Returns ``results[b][s] = (x, A, info)`` at true (unpadded) shapes,
    with ``info = {lp_obj, obj, best_t, trial_objs, metrics}``.
    """
    T = u_cat.shape[1] // n_seeds
    results = []
    for i, (inst, (xf, Af)) in enumerate(
            zip(stacked.insts, stacked.unstack(x_frac, A_frac))):
        onehot_mu = inst.onehot_mu()
        prec_u = inst.prec[inst.m_u, 1:]
        xf = np.asarray(xf, np.float64)
        Af = np.asarray(Af, np.float64)
        lp_obj = float(inst.objective(Af))
        per_seed = []
        for s in range(n_seeds):
            sl = slice(s * T, (s + 1) * T)
            uc = u_cat[i, sl, :inst.N]
            up = u_phi[i, sl, :inst.N, :inst.U]
            x_r, A_r = round_from_uniforms(xf, Af, onehot_mu, uc, up)
            best = None
            vals = []
            for t in range(T):
                x_t, A_t = repair(inst, x_r[t], A_r[t])
                val = objective_sel(prec_u, A_t)
                vals.append(float(val))
                if best is None or val > best[0]:
                    best = (val, t, x_t, A_t)
            _, t_b, x_b, A_b = best
            info = {"lp_obj": lp_obj, "obj": float(best[0]), "best_t": t_b,
                    "trial_objs": np.asarray(vals),
                    "metrics": MET.window_metrics(inst, x_b, A_b)}
            per_seed.append((x_b, A_b, info))
        results.append(per_seed)
    return results


def _unstack_device(stacked: StackedWindows, out, n_seeds: int):
    """Slice the padded device pipeline outputs back into the
    ``results[b][s] = (x, A, info)`` shape of the host reference."""
    results = []
    for i, inst in enumerate(stacked.insts):
        per_seed = []
        for s in range(n_seeds):
            info = {"lp_obj": float(out["lp_obj"][i]),
                    "obj": float(out["trial_objs"][i, s,
                                                   out["best_t"][i, s]]),
                    "best_t": int(out["best_t"][i, s]),
                    "trial_objs": out["trial_objs"][i, s],
                    "metrics": {k: float(v[i, s])
                                for k, v in out["metrics"].items()}}
            per_seed.append((out["x"][i, s, :inst.N],
                             out["A"][i, s, :inst.N, :inst.U], info))
        results.append(per_seed)
    return results


def cocar_grid(insts, seed: int = 0, pdhg_iters: int = 4000,
               best_of: int = 8, n_seeds: int = 1, backend: str = "device"):
    """CoCaR over a grid of independent windows × rounding seeds.

    ``backend="device"``: ONE fused dispatch (LP → rounding → repair →
    objective/metrics, trial argmax on device).  ``backend="host"``: the
    legacy path — batched LP dispatch, then per-(window, seed, trial)
    NumPy rounding + repair.  Returns ``results[b][s] = (x, A, info)``.
    """
    stacked = stack_instances(list(insts))
    u_cat, u_phi = offline_uniforms(stacked, seed, n_seeds, best_of)
    if backend == "device":
        out = offline_pipeline_device(stacked, u_cat, u_phi,
                                      pdhg_iters=pdhg_iters,
                                      n_seeds=n_seeds)
        return _unstack_device(stacked, out, n_seeds)
    if backend != "host":
        raise ValueError(f"unknown backend {backend!r}")
    res = LP.solve_lp_pdhg_batched(stacked.data, iters=pdhg_iters)
    return offline_pipeline_host(stacked, res.x, res.A, u_cat, u_phi,
                                 n_seeds=n_seeds)


def cocar_windows_batched(insts, seed: int = 0, pdhg_iters: int = 4000,
                          best_of: int = 8, backend: str = "device"):
    """CoCaR over a stack of independent windows (scenario-grid variants,
    seeds, parallel traces) — one rounding seed per window, aligned with
    ``insts``.  Returns a list of (x, A, info) triples.

    Instances may differ in N and U (padded inside ``stack_instances``)
    but must share the catalog shape (M, H).
    """
    grid = cocar_grid(insts, seed=seed, pdhg_iters=pdhg_iters,
                      best_of=best_of, n_seeds=1, backend=backend)
    return [per_seed[0] for per_seed in grid]


def lr_window(inst: JDCRInstance):
    """The LR upper bound (fractional optimum, paper's 'LR')."""
    _, _, obj = LP.solve_lp_scipy(inst)
    return obj


def run_offline(cfg: MECConfig, algo: str = "cocar", solver: str = "scipy",
                seed: int = 0, scenario: Scenario = None):
    """Runs `algo` over cfg.n_windows windows; returns aggregate metrics.

    algo in {cocar, lr, greedy, random, spr3, gatmarl}.
    """
    from repro.core import baselines as BL

    sc = scenario or Scenario(cfg)
    x_prev = sc.empty_cache()
    results, lr_objs = [], []
    for w in range(cfg.n_windows):
        inst = sc.instance(w, x_prev)
        if algo == "cocar":
            x, A, _ = cocar_window(inst, seed=seed * 1000 + w, solver=solver)
        elif algo == "lr":
            lr_objs.append(lr_window(inst) / inst.U)
            # LR is an upper bound, not a deployable policy: carry greedy
            # caching forward so later windows stay comparable
            x, A, _ = cocar_window(inst, seed=seed * 1000 + w, solver=solver)
        elif algo == "greedy":
            x, A = BL.greedy(inst)
        elif algo == "random":
            x, A = BL.random_policy(inst, seed=seed * 1000 + w)
        elif algo == "spr3":
            x, A = BL.spr3(inst, seed=seed * 1000 + w)
        elif algo == "gatmarl":
            x, A = BL.gatmarl(inst, seed=seed)
        else:
            raise ValueError(algo)
        results.append(MET.window_metrics(inst, x, A))
        x_prev = x
    agg = MET.aggregate(results)
    if algo == "lr":
        agg["lr_bound"] = float(np.mean(lr_objs))
    return agg
