"""CoCaR — the offline algorithm (paper Alg. 1 + Sec. V-D) and the
window-by-window offline driver.

``cocar_window`` handles one window on the host.  For grids, the whole
offline pipeline — LP (PDHG) → randomized rounding → repair → trial
argmax → window metrics — is a single jitted/vmapped device dispatch over
(windows × rounding seeds × best_of trials): ``offline_pipeline_device``,
driven by ``cocar_windows_batched(backend="device")`` and the sweep
harness (``repro.experiments.sweep``).

``offline_pipeline_host`` is the NumPy reference of the same computation
(per-window Python loops over seeds and trials).  Both consume the same
pre-drawn rounding uniforms and make decision-identical choices — the
offline counterpart of the PR-2 online-engine equivalence
(``docs/algorithms.md`` Sec. 7; asserted in
``tests/test_offline_batched.py`` / ``benchmarks/bench_offline.py``).
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core import lp as LP
from repro.core.jdcr import JDCRInstance, objective_sel
from repro.core.rounding import (draw_rounding_uniforms, repair,
                                 repair_device, round_from_uniforms)
from repro.mec import metrics as MET
from repro.mec.scenario import MECConfig, Scenario, StackedWindows, stack_instances
from repro.obs.diagnostics import lp_diag_summary
from repro.obs.tracing import register_jit


def _round_and_repair(inst: JDCRInstance, x_f, A_f, seed: int, best_of: int):
    """All ``best_of`` Alg. 1 draws from one batched RNG op, then repair
    each and keep the feasible solution with the highest objective — every
    draw satisfies Thm 1's guarantee, so the max only tightens it (and cuts
    the repair losses from unlucky memory-overflow draws; draws are
    microseconds next to the LP solve)."""
    T = max(best_of, 1)
    u_cat, u_phi = draw_rounding_uniforms(seed, T, inst.N, inst.M, inst.U,
                                          inst.H)
    x_r, A_r = round_from_uniforms(np.asarray(x_f, np.float64),
                                   np.asarray(A_f, np.float64),
                                   inst.onehot_mu(), u_cat, u_phi)
    prec_u = inst.prec[inst.m_u, 1:]
    best = None
    for x_i, A_i in zip(x_r, A_r):
        x, A = repair(inst, x_i, A_i)
        val = objective_sel(prec_u, A)
        if best is None or val > best[0]:
            best = (val, x, A)
    _, x, A = best
    return x, A


def cocar_window(inst: JDCRInstance, seed: int = 0, solver: str = "scipy",
                 pdhg_iters: int = 4000, best_of: int = 8):
    """One observation window: LP -> randomized rounding -> repair."""
    if solver == "pdhg":
        res = LP.solve_lp_pdhg(inst, iters=pdhg_iters)
        x_f, A_f, obj = res.x, res.A, res.obj
    else:
        x_f, A_f, obj = LP.solve_lp_scipy(inst)
    x, A = _round_and_repair(inst, x_f, A_f, seed, best_of)
    return x, A, {"lp_obj": obj}


# ---------------------------------------------------------------------------
# the fused offline pipeline (one dispatch over windows × seeds × trials)
# ---------------------------------------------------------------------------

def _pipeline_kernel(data, u_cat, u_phi, iters, n_seeds,
                     backend: str = "reference", diagnostics: bool = False):
    """One padded window through LP → round → repair → argmax → metrics,
    entirely in jnp.  ``u_cat (S·T, N, M)`` / ``u_phi (S·T, N, U, H)``
    carry ``n_seeds`` independent rounding seeds of ``best_of`` trials
    each; the best trial *per seed* is selected on device.  ``backend``
    picks the LP solver ("reference" or "pallas", see
    ``repro.core.lp.LP_BACKENDS``) — decisions are identical either way.
    ``diagnostics=True`` adds the solver's residual/objective curves
    under ``"lp_diag"`` without changing any decision bit."""
    import jax
    import jax.numpy as jnp

    lp_out = LP._lp_solve_kernel(data, iters, backend,
                                 diagnostics=diagnostics)
    x_f, A_f = lp_out[0], lp_out[1]
    x_r, A_r = round_from_uniforms(x_f, A_f, data.onehot_mu, u_cat, u_phi)
    x_p, A_p = jax.vmap(repair_device, in_axes=(None, 0, 0))(data, x_r, A_r)
    objs = jax.vmap(lambda a: objective_sel(data.prec_u, a))(A_p)
    T = objs.shape[0] // n_seeds
    objs = objs.reshape(n_seeds, T)
    best_t = jnp.argmax(objs, axis=1)                       # (S,)
    idx = jnp.arange(n_seeds) * T + best_t
    x_b, A_b = x_p[idx], A_p[idx]                           # (S, ...)
    met = jax.vmap(lambda xx, aa: MET.window_metrics_device(data, xx, aa))(
        x_b, A_b)
    lp_obj = jnp.einsum("nuh,uh->", A_f, data.prec_u)
    out = {"x_frac": x_f, "A_frac": A_f, "x": x_b, "A": A_b,
           "trial_objs": objs, "best_t": best_t, "metrics": met,
           "lp_obj": lp_obj}
    if diagnostics:
        out["lp_diag"] = lp_out[2]
    return out


@functools.cache
def _pipeline_jitted(backend: str = "reference", diagnostics: bool = False):
    import jax
    fn = jax.vmap(functools.partial(_pipeline_kernel, backend=backend,
                                    diagnostics=diagnostics),
                  in_axes=(0, 0, 0, None, None))
    jitted = jax.jit(fn, static_argnums=(3, 4))
    return register_jit(
        f"cocar:pipeline:{backend}:diag={int(bool(diagnostics))}", jitted)


def offline_uniforms(stacked: StackedWindows, seed: int, n_seeds: int,
                     best_of: int):
    """The rounding randomness both pipeline engines share: one batched
    draw at the padded stack shape, ``(B, S·T, ...)``."""
    B = len(stacked)
    N, U, H = stacked.data.T.shape[1:]
    M = stacked.data.sizes.shape[1]
    return draw_rounding_uniforms(seed, n_seeds * max(best_of, 1),
                                  N, M, U, H, batch=B)


def offline_pipeline_device(stacked: StackedWindows, u_cat, u_phi,
                            pdhg_iters: int = 4000, n_seeds: int = 1,
                            lp_backend: str = "reference",
                            diagnostics: bool = False):
    """The whole offline grid in ONE jitted/vmapped f64 dispatch.

    Returns a dict of padded numpy arrays: fractional solutions
    ``x_frac (B,N,M,H+1)`` / ``A_frac``, best-per-seed integral solutions
    ``x (B,S,...)`` / ``A``, per-trial objectives ``trial_objs (B,S,T)``,
    the winning trial indices ``best_t (B,S)``, window ``metrics`` (dict of
    (B,S) arrays), and ``lp_obj (B,)`` — plus batched solver curves under
    ``lp_diag`` when ``diagnostics`` is on.
    """
    from jax.experimental import enable_x64

    with enable_x64():
        out = _pipeline_jitted(lp_backend, bool(diagnostics))(
            stacked.data, u_cat, u_phi, int(pdhg_iters), int(n_seeds))
    return {k: ({kk: np.asarray(vv) for kk, vv in v.items()}
                if isinstance(v, dict) else np.asarray(v))
            for k, v in out.items()}


def offline_pipeline_host(stacked: StackedWindows, x_frac, A_frac,
                          u_cat, u_phi, n_seeds: int = 1):
    """NumPy reference of ``offline_pipeline_device``'s round → repair →
    argmax → metrics stages: per-(window, seed, trial) Python loops over
    the *same* fractional solution and uniforms.  This is both the
    correctness oracle and the host-loop path the offline benchmark
    measures against.

    Returns ``results[b][s] = (x, A, info)`` at true (unpadded) shapes,
    with ``info = {lp_obj, obj, best_t, trial_objs, metrics}``.
    """
    T = u_cat.shape[1] // n_seeds
    results = []
    for i, (inst, (xf, Af)) in enumerate(
            zip(stacked.insts, stacked.unstack(x_frac, A_frac))):
        onehot_mu = inst.onehot_mu()
        prec_u = inst.prec[inst.m_u, 1:]
        xf = np.asarray(xf, np.float64)
        Af = np.asarray(Af, np.float64)
        lp_obj = float(inst.objective(Af))
        per_seed = []
        for s in range(n_seeds):
            sl = slice(s * T, (s + 1) * T)
            uc = u_cat[i, sl, :inst.N]
            up = u_phi[i, sl, :inst.N, :inst.U]
            x_r, A_r = round_from_uniforms(xf, Af, onehot_mu, uc, up)
            best = None
            vals = []
            for t in range(T):
                x_t, A_t = repair(inst, x_r[t], A_r[t])
                val = objective_sel(prec_u, A_t)
                vals.append(float(val))
                if best is None or val > best[0]:
                    best = (val, t, x_t, A_t)
            _, t_b, x_b, A_b = best
            info = {"lp_obj": lp_obj, "obj": float(best[0]), "best_t": t_b,
                    "trial_objs": np.asarray(vals),
                    "metrics": MET.window_metrics(inst, x_b, A_b)}
            per_seed.append((x_b, A_b, info))
        results.append(per_seed)
    return results


# ---------------------------------------------------------------------------
# the fused POLICY grid: CoCaR + all four Sec. VII-B baselines, one dispatch
# ---------------------------------------------------------------------------

#: Policy order of the fused comparison grid (paper Sec. VII-B zoo).
OFFLINE_POLICIES = ("cocar", "spr3", "greedy", "random", "gatmarl")


def _eval_policy(data, x, A):
    """Uniform evaluation stage: execution-time enforcement + window
    metrics, both on-device (identical thresholds to the host path)."""
    A_e = MET.enforce_device(data, x, A)
    return MET.window_metrics_device(data, x, A_e)


def _policy_kernel(data, u_cat, u_phi, u_cat_s, u_phi_s, u_perm, u_h,
                   u_route, gat_params, gat_feats, gat_adj, iters, n_seeds,
                   backend: str = "reference", diagnostics: bool = False):
    """One padded window through ALL five policies, entirely in jnp.

    CoCaR runs the fused LP → round → repair → argmax pipeline
    (``_pipeline_kernel``); SPR³ runs the *same* LP + rounding + repair
    kernels on the relaxed pytree (one trial per seed); Greedy and the
    GatMARL rollout are deterministic (computed once, broadcast across the
    seed axis); Random consumes one pre-drawn uniform set per seed.  Every
    policy then passes through the same enforcement + metrics stage.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import baselines as BL

    S = n_seeds
    out = {}

    # repaired CoCaR solutions already satisfy the execution-time checks
    # (enforce is an identity post-repair, asserted in
    # tests/test_offline_batched.py), so the pipeline's own metrics stand
    coc = _pipeline_kernel(data, u_cat, u_phi, iters, n_seeds,
                           backend=backend, diagnostics=diagnostics)
    out["cocar"] = {"x": coc["x"], "A": coc["A"], "metrics": coc["metrics"]}
    out["lp_obj"] = coc["lp_obj"]
    out["cocar_frac"] = {"x": coc["x_frac"], "A": coc["A_frac"]}
    if diagnostics:
        out["lp_diag"] = coc["lp_diag"]

    relaxed = BL.spr3_relax_device(data)
    xs_f, As_f = LP._lp_solve_kernel(relaxed, iters, backend)
    xs_r, As_r = round_from_uniforms(xs_f, As_f, relaxed.onehot_mu,
                                     u_cat_s, u_phi_s)
    xs, As = jax.vmap(repair_device, in_axes=(None, 0, 0))(relaxed,
                                                           xs_r, As_r)
    out["spr3"] = {"x": xs, "A": As,
                   "metrics": jax.vmap(
                       lambda xx, aa: _eval_policy(data, xx, aa))(xs, As)}
    out["spr3_frac"] = {"x": xs_f, "A": As_f}

    def once(x1, A1):
        met = _eval_policy(data, x1, A1)
        return {"x": jnp.broadcast_to(x1, (S,) + x1.shape),
                "A": jnp.broadcast_to(A1, (S,) + A1.shape),
                "metrics": jax.tree.map(
                    lambda v: jnp.broadcast_to(v, (S,)), met)}

    out["greedy"] = once(*BL.greedy_device(data))
    out["gatmarl"] = once(*BL.gat_rollout_device(data, gat_params,
                                                 gat_feats, gat_adj))

    xr, Ar = jax.vmap(BL.random_device, in_axes=(None, 0, 0, 0))(
        data, u_perm, u_h, u_route)
    out["random"] = {"x": xr, "A": Ar,
                     "metrics": jax.vmap(
                         lambda xx, aa: _eval_policy(data, xx, aa))(xr, Ar)}
    return out


@functools.cache
def _policy_jitted(backend: str = "reference", diagnostics: bool = False):
    import jax
    fn = jax.vmap(functools.partial(_policy_kernel, backend=backend,
                                    diagnostics=diagnostics),
                  in_axes=(0,) * 11 + (None, None))
    jitted = jax.jit(fn, static_argnums=(11, 12))
    return register_jit(
        f"cocar:policy:{backend}:diag={int(bool(diagnostics))}", jitted)


def policy_uniforms(stacked: StackedWindows, seed: int, n_seeds: int,
                    best_of: int):
    """All the randomness of one policy-grid run, pre-drawn at the padded
    stack shape and shared verbatim by both engines: CoCaR's rounding
    uniforms (``n_seeds × best_of`` trials), SPR³'s (one trial per seed),
    and the Random baseline's permutation/pick/route uniforms."""
    B, N, U, M, H = stacked.signature
    return policy_uniforms_dims((B, N, M, U, H), seed, n_seeds, best_of)


def policy_uniforms_dims(dims, seed, n_seeds: int, best_of: int):
    """``policy_uniforms`` from bare grid dimensions ``(B, N, M, U, H)``
    — same key splits, same draws.  The ``repro.scale`` executor draws
    these ONCE at the grid's global max shape and slices them per size
    bucket, so bucketed dispatches consume exactly the uniforms the
    max-padded single dispatch would.  ``B=None`` drops the batch axis
    and ``seed`` may be a PRNG key — the executor's ``per_element``
    scheme draws one unbatched set per grid element that way."""
    import jax

    from repro.core import baselines as BL

    B, N, M, U, H = dims
    key = jax.random.PRNGKey(seed) if isinstance(seed, int) else seed
    k_coc, k_spr, k_bl = jax.random.split(key, 3)
    u_cat, u_phi = draw_rounding_uniforms(k_coc, n_seeds * max(best_of, 1),
                                          N, M, U, H, batch=B)
    u_cat_s, u_phi_s = draw_rounding_uniforms(k_spr, n_seeds, N, M, U, H,
                                              batch=B)
    u_perm, u_h, u_route = BL.draw_baseline_uniforms(k_bl, N, M, U,
                                                     n_seeds=n_seeds,
                                                     batch=B)
    return (u_cat, u_phi, u_cat_s, u_phi_s, u_perm, u_h, u_route)


def gat_grid_policies(stacked: StackedWindows, seed: int = 0,
                      episodes: int = 150):
    """Host-side GatMARL training for every window in the stack (cached
    per topology/catalog shape), stacked for the vmapped rollout: a
    params pytree with a leading batch axis + padded features/adjacency.
    """
    from repro.core import baselines as BL

    n_pad = stacked.data.R.shape[1]
    params, feats, adjs = [], [], []
    for inst in stacked.insts:
        params.append(BL.gat_policy(inst, seed, episodes))
        feats.append(BL.gat_features(inst, n_pad=n_pad))
        adjs.append(BL.gat_adj(inst, n_pad=n_pad))
    stacked_params = {k: np.stack([p[k] for p in params])
                      for k in params[0]}
    return stacked_params, np.stack(feats), np.stack(adjs)


def policy_grid_device(stacked: StackedWindows, seed: int = 0,
                       pdhg_iters: int = 4000, best_of: int = 8,
                       n_seeds: int = 1, episodes: int = 150,
                       uniforms=None, gat=None,
                       lp_backend: str = "reference",
                       diagnostics: bool = False):
    """CoCaR + the four baselines over (windows × seeds) in ONE jitted/
    vmapped f64 dispatch (GatMARL training excepted — host-side, cached).

    Returns nested numpy: ``out[policy] = {x (B,S,...), A (B,S,...),
    metrics {k: (B,S)}}`` plus ``lp_obj (B,)`` and SPR³'s fractional
    solution (``spr3_frac``) for the host oracle — plus CoCaR's batched
    solver curves under ``lp_diag`` when ``diagnostics`` is on.
    """
    from jax.experimental import enable_x64

    uniforms = uniforms if uniforms is not None else \
        policy_uniforms(stacked, seed, n_seeds, best_of)
    gat = gat if gat is not None else \
        gat_grid_policies(stacked, seed, episodes)
    gat_params, gat_feats, gat_adj = gat
    with enable_x64():
        out = _policy_jitted(lp_backend, bool(diagnostics))(
            stacked.data, *uniforms, gat_params, gat_feats, gat_adj,
            int(pdhg_iters), int(n_seeds))

    def to_np(tree):
        if isinstance(tree, dict):
            return {k: to_np(v) for k, v in tree.items()}
        return np.asarray(tree)

    return to_np(out)


def policy_grid_host(stacked: StackedWindows, uniforms, gat,
                     x_frac, A_frac, spr3_frac, n_seeds: int = 1):
    """NumPy reference of ``policy_grid_device``: per-(window, seed)
    Python loops over the *same* fractional LP solutions, rounding
    uniforms, and trained GatMARL params.  This is both the correctness
    oracle and (driven per-instance) the host-loop path
    ``benchmarks/bench_baselines.py`` measures against.

    Returns ``results[policy][b][s] = (x, A, metrics)`` at true shapes.
    """
    from repro.core import baselines as BL

    u_cat, u_phi, u_cat_s, u_phi_s, u_perm, u_h, u_route = uniforms
    gat_params, gat_feats, gat_adj = gat
    results = {p: [] for p in OFFLINE_POLICIES}

    coc = offline_pipeline_host(stacked, x_frac, A_frac, u_cat, u_phi,
                                n_seeds=n_seeds)
    spr_fracs = stacked.unstack(spr3_frac["x"], spr3_frac["A"])
    for i, inst in enumerate(stacked.insts):
        N, U = inst.N, inst.U
        results["cocar"].append([
            (x, A, info["metrics"]) for x, A, info in coc[i]])

        xs_f, As_f = spr_fracs[i]
        xs, As = BL.spr3_from_fractional(
            inst, xs_f, As_f, u_cat_s[i, :, :N], u_phi_s[i, :, :N, :U])
        results["spr3"].append([
            (xs[s], As[s], MET.window_metrics(inst, xs[s], As[s]))
            for s in range(n_seeds)])

        xg, Ag = BL.greedy(inst)
        mg = MET.window_metrics(inst, xg, Ag)
        results["greedy"].append([(xg, Ag, mg)] * n_seeds)

        per_rand = []
        for s in range(n_seeds):
            xr, Ar = BL.random_from_uniforms(
                inst, u_perm[i, s, :N], u_h[i, s, :N], u_route[i, s, :U])
            per_rand.append((xr, Ar, MET.window_metrics(inst, xr, Ar)))
        results["random"].append(per_rand)

        params_i = {k: v[i] for k, v in gat_params.items()}
        xm, Am = BL.gat_rollout_host(inst, params_i, feats=gat_feats[i],
                                     adj=gat_adj[i])
        mm = MET.window_metrics(inst, xm, Am)
        results["gatmarl"].append([(xm, Am, mm)] * n_seeds)
    return results


def export_cache_plans(out, stacked: StackedWindows, seed_idx: int = 0):
    """Slice a ``policy_grid_device`` output into per-policy, per-window
    decision arrays at true (unpadded) shapes — the control-plane export
    the serving bridge (``repro.serving.plan.plan_from_offline``)
    consumes.

    Returns ``{policy: [{"x": (N, M, H+1), "A": (N, U, H),
    "metrics": {...}} per window]}`` for one rounding seed — the actual
    integral caching/routing decisions each policy committed to, never a
    hand-constructed residency profile.
    """
    plans = {}
    for p in OFFLINE_POLICIES:
        per_window = []
        for i, inst in enumerate(stacked.insts):
            per_window.append({
                "x": np.asarray(out[p]["x"][i, seed_idx, :inst.N]),
                "A": np.asarray(out[p]["A"][i, seed_idx,
                                            :inst.N, :inst.U]),
                "metrics": {k: float(v[i, seed_idx])
                            for k, v in out[p]["metrics"].items()}})
        plans[p] = per_window
    return plans


def improvement_ratio(metrics_by_policy, key: str = "avg_precision"):
    """The paper's headline number (Sec. VII-B): grid-mean CoCaR ``key``
    over the best baseline's.  ``metrics_by_policy[p]`` is any array of
    per-(window, seed) values."""
    means = {p: float(np.mean(np.asarray(v, dtype=np.float64)))
             for p, v in metrics_by_policy.items()}
    best_val = max(v for p, v in means.items() if p != "cocar")
    best = next(p for p, v in means.items()
                if p != "cocar" and v == best_val)
    return {"ratio": means["cocar"] / max(best_val, 1e-12),
            "best_baseline": best, "means": means}


def _unstack_device(stacked: StackedWindows, out, n_seeds: int):
    """Slice the padded device pipeline outputs back into the
    ``results[b][s] = (x, A, info)`` shape of the host reference.  When
    the dispatch carried the diagnostics tap, each info dict gains the
    window's ``lp_diag``: the sampled curves plus their host summary
    (curves are per-window, so every seed shares the same record)."""
    results = []
    for i, inst in enumerate(stacked.insts):
        lp_diag = None
        if "lp_diag" in out:
            curves = {k: np.asarray(v[i]) for k, v in out["lp_diag"].items()}
            lp_diag = {**curves, "summary": lp_diag_summary(curves)}
        per_seed = []
        for s in range(n_seeds):
            info = {"lp_obj": float(out["lp_obj"][i]),
                    "obj": float(out["trial_objs"][i, s,
                                                   out["best_t"][i, s]]),
                    "best_t": int(out["best_t"][i, s]),
                    "trial_objs": out["trial_objs"][i, s],
                    "metrics": {k: float(v[i, s])
                                for k, v in out["metrics"].items()}}
            if lp_diag is not None:
                info["lp_diag"] = lp_diag
            per_seed.append((out["x"][i, s, :inst.N],
                             out["A"][i, s, :inst.N, :inst.U], info))
        results.append(per_seed)
    return results


def cocar_grid(insts, seed: int = 0, pdhg_iters: int = 4000,
               best_of: int = 8, n_seeds: int = 1, backend: str = "device",
               devices: int = None, chunk_size: int = 0,
               max_buckets: int = 1, lp_backend: str = "reference",
               diagnostics: bool = False):
    """CoCaR over a grid of independent windows × rounding seeds.

    ``backend="device"``: the fused LP → rounding → repair → metrics
    pipeline through the ``repro.scale`` grid executor on one device;
    ``backend="sharded"``: the same executor partitioning the grid
    across a ``devices``-wide host mesh (decision-identical — see
    ``repro.scale.executor``).  ``devices``/``chunk_size``/``max_buckets``
    tune the executor's mesh width, streaming chunk, and size-bucket
    count (the default ``max_buckets=1`` is the classic one-padded-shape
    dispatch).  ``backend="host"``: the NumPy reference — batched LP
    dispatch, then per-(window, seed, trial) NumPy rounding + repair.
    ``lp_backend`` independently picks the window LP solver ("reference"
    or "pallas" — the fused mixed-precision kernel, decision-identical).
    ``diagnostics`` threads the jit-safe solver tap through the device /
    sharded executors (the host reference has no tap — it checks
    feasibility directly).  Returns ``results[b][s] = (x, A, info)``.
    """
    insts = list(insts)
    if backend in ("device", "sharded"):
        from repro.scale import GridSpec, run_grid

        spec = GridSpec(
            kind="offline", insts=insts, seed=seed, n_seeds=n_seeds,
            best_of=best_of, pdhg_iters=pdhg_iters,
            backend="vmap" if backend == "device" else "sharded",
            devices=devices, chunk_size=chunk_size,
            max_buckets=max_buckets, lp_backend=lp_backend,
            diagnostics=diagnostics)
        return run_grid(spec).results
    if backend != "host":
        raise ValueError(f"unknown backend {backend!r}")
    stacked = stack_instances(insts)
    u_cat, u_phi = offline_uniforms(stacked, seed, n_seeds, best_of)
    res = LP.solve_lp_pdhg_batched(stacked.data, iters=pdhg_iters,
                                   backend=lp_backend)
    return offline_pipeline_host(stacked, res.x, res.A, u_cat, u_phi,
                                 n_seeds=n_seeds)


def cocar_windows_batched(insts, seed: int = 0, pdhg_iters: int = 4000,
                          best_of: int = 8, backend: str = "device",
                          lp_backend: str = "reference"):
    """CoCaR over a stack of independent windows (scenario-grid variants,
    seeds, parallel traces) — one rounding seed per window, aligned with
    ``insts``.  Returns a list of (x, A, info) triples.

    Instances may differ in N and U (padded inside ``stack_instances``)
    but must share the catalog shape (M, H).
    """
    grid = cocar_grid(insts, seed=seed, pdhg_iters=pdhg_iters,
                      best_of=best_of, n_seeds=1, backend=backend,
                      lp_backend=lp_backend)
    return [per_seed[0] for per_seed in grid]


def lr_window(inst: JDCRInstance):
    """The LR upper bound (fractional optimum, paper's 'LR')."""
    _, _, obj = LP.solve_lp_scipy(inst)
    return obj


def run_offline(cfg: MECConfig, algo: str = "cocar", solver: str = "scipy",
                seed: int = 0, scenario: Scenario = None):
    """Runs `algo` over cfg.n_windows windows; returns aggregate metrics.

    algo in {cocar, lr, greedy, random, spr3, gatmarl}.
    """
    from repro.core import baselines as BL

    sc = scenario or Scenario(cfg)
    x_prev = sc.empty_cache()
    results, lr_objs = [], []
    for w in range(cfg.n_windows):
        inst = sc.instance(w, x_prev)
        if algo == "cocar":
            x, A, _ = cocar_window(inst, seed=seed * 1000 + w, solver=solver)
        elif algo == "lr":
            lr_objs.append(lr_window(inst) / inst.U)
            # LR is an upper bound, not a deployable policy: carry greedy
            # caching forward so later windows stay comparable
            x, A, _ = cocar_window(inst, seed=seed * 1000 + w, solver=solver)
        elif algo == "greedy":
            x, A = BL.greedy(inst)
        elif algo == "random":
            x, A = BL.random_policy(inst, seed=seed * 1000 + w)
        elif algo == "spr3":
            x, A = BL.spr3(inst, seed=seed * 1000 + w)
        elif algo == "gatmarl":
            x, A = BL.gatmarl(inst, seed=seed)
        else:
            raise ValueError(algo)
        results.append(MET.window_metrics(inst, x, A))
        x_prev = x
    agg = MET.aggregate(results)
    if algo == "lr":
        agg["lr_bound"] = float(np.mean(lr_objs))
    return agg
