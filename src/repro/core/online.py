"""CoCaR-OL — the online extension (paper Sec. VI, Alg. 2) and the online
baselines (LFU, LFU-MAD, Random), with and without dynamic-DNN partitioning.

Implements faithfully:
  * the download state machine (Eqs. 35–37): submodel components download
    sequentially from the cloud at W_n, across slot boundaries; the cache
    switches to a submodel the slot after its Δ finishes;
  * QoE (Eq. 40) and argmax-QoE routing (Eq. 41);
  * expected-future-gain caching (Eqs. 45–47) with a memory-constrained
    multi-choice knapsack per adjusted BS (Alg. 2 lines 15–21);
  * eviction/shrink is immediate (Eq. 49).

Workloads come from ``repro.traces``: demand is a
:class:`~repro.traces.workloads.Workload` — per-slot ``(n_bs, n_models)``
request-count tensors (exact for dense/log families, sampled for the
streaming Poisson family) — and every random number the policies consume
(``DecisionStream``) is pre-drawn, so all four policies replay
byte-identical inputs — no policy's RNG consumption can perturb
another's stream.  The QoE sum (Eq. 40) and the caching updates
(Eqs. 45-49) only ever see users through their (home BS, model) pair, so
the aggregation is exact; only the optional per-user reference replay
(``run_online_trace``) touches dense tensors.

``run_online(workload, policy, *, cfg=..., ocfg=..., engine=...)`` is the
single entry point every caller (sweep, grid executor, examples, benches)
routes through; ``engine="scan"`` dispatches to the vectorized
``jax.lax.scan`` engine (``repro.traces.engine``), which matches this
NumPy state machine slot-for-slot.  ``record_states=True`` additionally
exports the per-slot serving cache states (level / download-in-flight /
target) that ``repro.serving.plan`` turns into per-pod residency
schedules.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.mec.scenario import MECConfig, Scenario
from repro.traces.generators import DecisionStream, Trace, default_stream
from repro.traces.registry import default_trace
from repro.traces.workloads import DenseWorkload, Workload, as_workload, check_workload


@dataclass
class OnlineConfig:
    slot_s: float = 0.5
    n_slots: int = 100
    rounds: int = 3              # BSs adjusted per slot
    dT_past: int = 10
    dT_future: int = 5
    alpha: float = 0.9           # QoE smoothing (Eq. 40)
    gamma: float = 0.9           # future-gain discount (Eq. 46)
    partition: bool = True       # dynamic-DNN submodel switching enabled
    pop_change_every: int = 20   # slots
    pop_warmup: int = 5
    knap_units: int = 64         # V: discrete capacity states


class OnlineSim:
    """Download/cache state machine replaying a precomputed workload.

    Demand arrives as a :class:`~repro.traces.workloads.Workload` (or a
    per-user ``Trace``, wrapped on the way in); the stream is drawn up
    front from its own PRNG key, so it is identical for every policy run
    against the same (cfg, workload).  ``self.trace`` is the dense
    per-user view when the workload has one (the reference replay needs
    it) and ``None`` for aggregated-only families.
    """

    def __init__(self, cfg: MECConfig, ocfg: OnlineConfig,
                 trace: Trace = None, workload: Workload = None,
                 scenario: Scenario = None):
        self.cfg, self.ocfg = cfg, ocfg
        self.sc = scenario or Scenario(cfg)
        N, M, H = cfg.n_bs, cfg.n_models, self.sc.sizes.shape[1] - 1
        self.N, self.M, self.H = N, M, H
        if workload is not None:
            wl = as_workload(workload, cfg=cfg)
        else:
            wl = DenseWorkload(trace or default_trace(cfg, ocfg), N, M)
        self.workload = check_workload(wl, cfg, ocfg)
        self.trace = wl.trace if isinstance(wl, DenseWorkload) else None
        # state
        self.X = np.zeros((N, M, H + 1))
        self.X[:, :, 0] = 1
        self.O = np.zeros((N, M, H))            # remaining download bytes->MB
        self.target = np.zeros((N, M), dtype=int)   # download target submodel
        self.hist = deque(maxlen=ocfg.dT_past)      # (N, M) request counts
        self.W = np.full(N, cfg.cloud_mbps / 8.0)   # MB/s cloud->BS
        # θ: minimum achievable end-to-end latency (Eq. 40 normalizer)
        self.theta = self._theta()

    def _theta(self):
        d = self.cfg.data_mb
        comm = d / self.sc.phi.min()
        infer = (self.sc.flops[:, 1] * d / self.sc.C.max()).min()
        return comm + 2 * self.cfg.hop_latency_s + infer

    # ---------------- request stream ----------------
    def draw_slot_requests(self, t):
        """Slot t's (m_u, home) from the precomputed trace."""
        if self.trace is None:
            raise ValueError(
                f"workload {self.workload.name!r} (family "
                f"{self.workload.family!r}) is aggregated-only — no "
                f"per-user tensors exist; use route_counts / the "
                f"counts-driven replay instead")
        return self.trace.requests(t)

    # ---------------- Eqs. 35–37: routine update ----------------
    def routine_update(self):
        """Each BS spends its slot budget W_n·Δt on its download queue in
        (m, h) order — sequential, smaller submodels first; every finished
        Δ switches the cache to h+1 (Eq. 37).  Vectorized: the per-queue
        prefix sum of remaining bytes tells how much of each entry the
        budget reaches, no Python loop over (n, m, h)."""
        N, M, H = self.N, self.M, self.H
        budget = self.W * self.ocfg.slot_s                      # (N,)
        O = self.O.reshape(N, M * H)
        before = np.cumsum(O, axis=1) - O                       # bytes queued ahead
        take = np.clip(budget[:, None] - before, 0.0, O)
        O_new = O - take
        finished = (O > 0) & (O_new <= 1e-12)
        O_new[finished] = 0.0
        self.O = O_new.reshape(N, M, H)
        fin = finished.reshape(N, M, H)
        done = fin.any(-1)
        # the LAST finished Δ per (n, m) wins, exactly like the loop did
        h_top = (H - 1) - np.argmax(fin[:, :, ::-1], axis=-1)   # (N, M)
        nn, mm = np.nonzero(done)
        self.X[nn, mm, :] = 0.0
        self.X[nn, mm, h_top[nn, mm] + 1] = 1.0
        return self.X

    # ---------------- Eq. 39/40: latency & QoE (vectorized) ----------------
    def qoe_matrix(self, X=None):
        """(N_home, N_target, M) QoE and latency with cache state X."""
        sc, cfg = self.sc, self.cfg
        X = self.X if X is None else X
        d = cfg.data_mb
        h_cached = np.argmax(X, axis=-1)                  # (N, M)
        P = np.take_along_axis(sc.prec[None].repeat(self.N, 0),
                               h_cached[:, :, None], axis=2)[:, :, 0]
        c = np.take_along_axis(sc.flops[None].repeat(self.N, 0),
                               h_cached[:, :, None], axis=2)[:, :, 0]
        infer = c * d / sc.C[:, None]                     # (N, M)
        comm = (d / sc.phi)[:, None] \
            + np.where(np.eye(self.N, dtype=bool), 0.0,
                       d / (cfg.wired_mbps / 8.0)) + sc.lam   # (N_home, N_tgt)
        lat = comm[:, :, None] + infer[None, :, :]        # (Nh, Nt, M)
        q = P[None] * np.clip(1.0 - (lat - self.theta) * self.ocfg.alpha,
                              0.0, None)
        q = np.where((P[None] > 0) & (lat <= cfg.ddl_s), q, 0.0)
        return q, lat

    def route(self, m_u, home):
        """Eq. 41: argmax-QoE routing. Returns (total_qoe, hits)."""
        q, _ = self.qoe_matrix()
        best = q.max(axis=1)                              # (N_home, M)
        vals = best[home, m_u]
        return float(vals.sum()), int((vals > 0).sum())

    def route_counts(self, counts):
        """Eq. 41 over aggregated demand: ``counts`` is the slot's (N, M)
        request-count tensor.  Exact — every user at (home n, model m)
        receives the same argmax-QoE value, so the per-user sum IS the
        count-weighted sum (summation order differs, hence ~1e-16
        relative float drift vs. :meth:`route`; hits are integers and
        match exactly)."""
        q, _ = self.qoe_matrix()
        best = q.max(axis=1)                              # (N_home, M)
        return (float((counts * best).sum()),
                float((counts * (best > 0)).sum()))

    def state(self):
        """Export the cache/download state in the scan engine's
        ``OnlineState`` layout (lvl/O/target/hist, history zero-padded at
        the front) — the currency of the decision-identity certificates."""
        from repro.traces.engine import OnlineState

        P = self.ocfg.dT_past
        hist = [np.asarray(h, np.float64) for h in self.hist]
        pad = [np.zeros((self.N, self.M))] * (P - len(hist))
        return OnlineState(
            lvl=np.argmax(self.X, axis=-1).astype(np.int32),
            O=self.O.copy(),
            target=self.target.astype(np.int32),
            hist=(np.stack(pad + hist) if (pad or hist)
                  else np.zeros((0, self.N, self.M))))

    # ---------------- Eqs. 45–47: expected future gain ----------------
    def freq(self):
        """(N, M) proportion of requests per (home BS, model)."""
        if not self.hist:
            return np.full((self.N, self.M), 1.0 / self.M / self.N)
        tot = sum(h.sum() for h in self.hist)
        return sum(self.hist) / max(tot, 1)

    def slot_qoe(self, X):
        """Expected one-slot total QoE under cache state X (Eq. 46 term)."""
        q, _ = self.qoe_matrix(X)
        best = q.max(axis=1)                              # (N_home, M)
        return float((self.freq() * best).sum()) * self.cfg.n_users

    def future_gain(self, n, m, h_tgt, X_hyp, X_during):
        """Expected discounted QoE gain of the switch vs. keeping the
        current state, over a matched horizon of (download delay + ΔT^F)
        slots (Eq. 46/47; horizons must match or long downloads are
        spuriously favoured by their extra discount terms)."""
        cur = int(np.argmax(self.X[n, m]))
        if h_tgt > cur:
            if self.ocfg.partition:
                delta = self.sc.sizes[m, h_tgt] - self.sc.sizes[m, cur]
            else:
                delta = self.sc.sizes[m, h_tgt]
            delay = int(np.ceil(delta / (self.W[n] * self.ocfg.slot_s)))
        else:
            delay = 0
        g_dur = self.slot_qoe(X_during) if delay else 0.0
        g_hyp = self.slot_qoe(X_hyp)
        g_cur = self.slot_qoe(self.X)
        gam = self.ocfg.gamma
        g = 0.0
        for k in range(1, delay + self.ocfg.dT_future + 1):
            q_k = g_dur if k <= delay else g_hyp
            g += gam ** k * (q_k - g_cur)
        return g

    # ---------------- Alg. 2 lines 15–21: caching decision ----------------
    def _action_space(self, n, m):
        """Paper Sec. VI-B: enlargements from the cached submodel up to (and
        including) the first whose cumulative Δ cannot be fully downloaded
        within one time slot; all shrinks are allowed."""
        sc, ocfg = self.sc, self.ocfg
        cur = int(np.argmax(self.X[n, m]))
        acts = list(range(0, cur))                        # shrinks / evict
        if not ocfg.partition:
            return acts + ([self.H] if cur < self.H else [])
        budget = self.W[n] * ocfg.slot_s
        cum = 0.0
        for h in range(cur + 1, self.H + 1):
            acts.append(h)
            cum += sc.sizes[m, h] - sc.sizes[m, h - 1]
            if cum > budget:
                break                                     # first over-budget:
        return acts                                       # included, then stop

    def adjust_bs(self, n):
        sc, ocfg = self.sc, self.ocfg
        M, H = self.M, self.H
        best = (1e-9, None)
        for m in range(M):
            if self.O[n, m].sum() > 0:
                continue                                  # downloading: frozen
            cur = int(np.argmax(self.X[n, m]))
            for h_tgt in self._action_space(n, m):
                if h_tgt == cur or h_tgt == 0:
                    continue
                X_hyp, shrunk = self._fit(n, m, h_tgt)
                if X_hyp is None:
                    continue
                X_during = X_hyp.copy()                   # shrinks immediate,
                X_during[n, m, :] = 0                     # upgrade pending
                X_during[n, m, cur] = 1
                gain = self.future_gain(n, m, h_tgt, X_hyp, X_during)
                if gain > best[0]:
                    best = (gain, (m, h_tgt, shrunk))
        if best[1] is None:
            return
        m, h_tgt, shrunk = best[1]
        cur = int(np.argmax(self.X[n, m]))
        for (m2, h2) in shrunk:                           # evict/shrink (Eq. 49)
            self.X[n, m2, :] = 0
            self.X[n, m2, h2] = 1
        if h_tgt < cur:
            self.X[n, m, :] = 0
            self.X[n, m, h_tgt] = 1                       # shrink: immediate
        else:
            if self.ocfg.partition:
                # enqueue Δ downloads for each intermediate submodel (Eq. 48);
                # sizes[:, 0] == 0 so delta is uniform
                for h in range(cur + 1, h_tgt + 1):
                    self.O[n, m, h - 1] = sc.sizes[m, h] - sc.sizes[m, h - 1]
            else:
                # no partitioning: the complete model must be downloaded
                self.O[n, m, h_tgt - 1] = sc.sizes[m, h_tgt]
            self.target[n, m] = h_tgt

    def _fit(self, n, m, h_tgt):
        """Multi-choice knapsack (quantized): shrink other models so that
        (m -> h_tgt) fits; maximizes retained immediate QoE-weight."""
        sc = self.sc
        M, H = self.M, self.H
        R = sc.R[n]
        need = sc.sizes[m, h_tgt]
        others = [m2 for m2 in range(M) if m2 != m]
        f = self.freq().sum(0)                            # (M,) demand weight
        budget = R - need
        choice = {}
        # models mid-download are LOCKED at their target size: shrinking them
        # now would be undone (over capacity) when the download lands
        free_others = []
        for m2 in others:
            if self.O[n, m2].sum() > 0:
                budget -= sc.sizes[m2, self.target[n, m2]]
                choice[m2] = int(np.argmax(self.X[n, m2]))
            else:
                free_others.append(m2)
        if budget < 0:
            return None, None
        # greedy multi-choice knapsack: keep high-demand models as large as
        # the remaining budget allows, shrink/evict the rest
        allowed = range(0, H + 1) if self.ocfg.partition else (0, H)
        for m2 in sorted(free_others, key=lambda mm: -f[mm]):
            cur2 = int(np.argmax(self.X[n, m2]))
            choice[m2] = 0
            for h2 in sorted((h for h in allowed if h <= cur2), reverse=True):
                if sc.sizes[m2, h2] <= budget + 1e-9:
                    choice[m2] = h2
                    budget -= sc.sizes[m2, h2]
                    break
        X_hyp = self.X.copy()
        shrunk = []
        for m2, h2 in choice.items():
            cur2 = int(np.argmax(self.X[n, m2]))
            if h2 != cur2:
                shrunk.append((m2, h2))
            X_hyp[n, m2, :] = 0
            X_hyp[n, m2, h2] = 1
        X_hyp[n, m, :] = 0
        X_hyp[n, m, h_tgt] = 1
        return X_hyp, shrunk


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def run_online(workload, policy: str = "cocar-ol", *,
               cfg: MECConfig = None, ocfg: OnlineConfig = None,
               engine: str = "scan", seed: int = 0,
               stream: DecisionStream = None, chunk_slots: int = 0,
               diagnostics: bool = False, record_states: bool = False,
               scenario: Scenario = None):
    """Run one (scenario, workload, policy) online episode — the unified
    entry point every online caller routes through.

    ``workload`` is anything ``repro.traces.as_workload`` accepts (a
    ``Workload``, a per-user ``Trace``, or a ``(T, N, M)`` count tensor);
    ``engine="scan"`` is the jit-compiled ``lax.scan`` engine (one XLA
    dispatch per chunk, O(chunk) memory for streaming workloads),
    ``engine="numpy"`` this module's per-slot state machine — identical
    decisions either way.  Returns a summary dict with ``avg_qoe``/
    ``hit_rate``, per-slot arrays, and the final cache state.

    ``record_states=True`` adds ``out["states"]`` — per-slot serving
    cache states ``{"lvl", "dl", "target"}``, each ``(T, N, M)``,
    snapshotted right after the routine download update (i.e. exactly
    the state requests are routed against, Eqs. 35–37): ``lvl`` the
    cached submodel level (0 = not cached), ``dl`` whether a download is
    in flight, ``target`` its target level.  This is the input of
    ``repro.serving.plan.plans_from_online_states`` — a submodel
    mid-download is NOT in ``lvl`` at its target and therefore never
    serves.  ``scenario`` injects a prebuilt :class:`Scenario` (e.g. one
    carrying a measured catalog) instead of deriving one from ``cfg``.
    """
    if cfg is None or ocfg is None:
        raise TypeError(
            "run_online(workload, policy, ...) needs cfg= and ocfg=")
    workload = check_workload(as_workload(workload, cfg=cfg), cfg, ocfg)
    if stream is None:
        stream = default_stream(cfg, ocfg, seed)
    if engine == "scan":
        from repro.traces.engine import make_params, run_workload
        out = run_workload(make_params(cfg, ocfg, sc=scenario), workload,
                           stream, policy, dT_past=ocfg.dT_past,
                           diagnostics=diagnostics,
                           chunk_slots=chunk_slots,
                           record_states=record_states)
    elif engine == "numpy":
        slot_qoe, slot_hits, sim = replay_workload(
            cfg, ocfg, policy, workload, stream, chunk_slots=chunk_slots,
            record_states=record_states, scenario=scenario)
        total = workload.total()
        out = {"avg_qoe": float(slot_qoe.sum()) / max(total, 1.0),
               "hit_rate": float(slot_hits.sum()) / max(total, 1.0),
               "slot_qoe": slot_qoe, "slot_hits": slot_hits,
               "final_state": sim.state()}
        if record_states:
            out["states"] = sim.recorded_states
    else:
        raise ValueError(
            f"unknown engine {engine!r}; one of ('scan', 'numpy')")
    out["workload"] = workload.name
    return out


def _policy_step(sim: OnlineSim, algo: str, t: int,
                 stream: DecisionStream, ocfg: OnlineConfig):
    """One slot's caching decision — shared by every NumPy replay."""
    if algo == "cocar-ol":
        for n in stream.adjust_ns[t]:
            sim.adjust_bs(n)
    elif algo in ("lfu", "lfu-mad"):
        _lfu_step(sim, stream.adjust_ns[t], ocfg, mad=(algo == "lfu-mad"))
    elif algo == "random":
        _random_step(sim, stream.adjust_ns[t], stream.u_model[t],
                     stream.perms[t], stream.u_shrink[t], ocfg)
    else:
        raise ValueError(algo)


def replay_workload(cfg: MECConfig, ocfg: OnlineConfig, algo: str,
                    workload, stream: DecisionStream,
                    per_user: bool = False, chunk_slots: int = 0,
                    record_states: bool = False,
                    scenario: Scenario = None):
    """The NumPy per-slot loop over aggregated demand, with per-slot
    recording.

    This is THE reference slot ordering (downloads -> routing -> history
    push -> policy).  The policies consume only the count history, so
    decisions are bit-identical for any workload representation; routing
    QoE is count-weighted (:meth:`OnlineSim.route_counts`).  With
    ``per_user`` (dense workloads only) the slot QoE/hits are instead
    re-derived from the per-user tensors in the original per-user
    summation order — the bit-reference the equivalence certificates
    compare against.  Streams the workload chunk-by-chunk (O(chunk)
    memory).  Returns ``(slot_qoe (T,), slot_hits (T,), sim)``; with
    ``record_states`` the per-slot serving states (post-download-update
    lvl/dl/target, the routing snapshot) land on ``sim.recorded_states``.
    """
    workload = as_workload(workload, cfg=cfg)
    if per_user and not isinstance(workload, DenseWorkload):
        raise ValueError(
            f"per-user replay needs a dense workload, got "
            f"{workload.name!r} (family {workload.family!r})")
    sim = OnlineSim(cfg, ocfg, workload=workload, scenario=scenario)
    slot_qoe, slot_hits = [], []
    recs = [] if record_states else None
    for t0, t1, chunk in workload.iter_chunks(chunk_slots):
        for k in range(t1 - t0):
            t = t0 + k
            sim.routine_update()
            if record_states:
                recs.append((np.argmax(sim.X, -1).astype(np.int32),
                             sim.O.sum(-1) > 0,
                             sim.target.astype(np.int32).copy()))
            if per_user:
                m_u, home = sim.draw_slot_requests(t)
                q, hits = sim.route(m_u, home)
            else:
                q, hits = sim.route_counts(chunk[k])
            slot_qoe.append(q)
            slot_hits.append(hits)
            sim.hist.append(np.asarray(chunk[k], np.float64))
            _policy_step(sim, algo, t, stream, ocfg)
    if record_states:
        sim.recorded_states = {
            key: np.stack([r[i] for r in recs])
            for i, key in enumerate(("lvl", "dl", "target"))}
    return np.asarray(slot_qoe), np.asarray(slot_hits), sim


def run_online_trace(cfg: MECConfig, ocfg: OnlineConfig, algo: str,
                     trace: Trace, stream: DecisionStream):
    """Per-user reference replay of a dense trace: same slot ordering as
    ``replay_workload``, with QoE/hits summed user-by-user (Eq. 40's
    original form).  The scan-engine equivalence checks
    (``tests/test_traces.py``, ``benchmarks/bench_online.py``) compare
    against it directly.  Returns ``(slot_qoe (T,), slot_hits (T,),
    sim)``.
    """
    return replay_workload(cfg, ocfg, algo,
                           DenseWorkload(trace, cfg.n_bs, cfg.n_models),
                           stream, per_user=True)


def _freq_weighted(sim: OnlineSim, mad: bool):
    if not sim.hist:
        return np.zeros((sim.N, sim.M))
    if not mad:
        return sum(sim.hist)
    w = [0.8 ** (len(sim.hist) - 1 - i) for i in range(len(sim.hist))]
    return sum(wi * h for wi, h in zip(w, sim.hist))


def _lfu_step(sim: OnlineSim, ns, ocfg: OnlineConfig, mad=False):
    """LFU / LFU-MAD: enlarge the most frequent model at the BS (+1-hop
    neighbours' demand), shrink the least frequent until memory fits.
    Sorts are stable so the scan engine reproduces identical tie-breaks."""
    freq = _freq_weighted(sim, mad)
    adj = sim.sc.hops <= 1
    for n in ns:
        f = freq[adj[n]].sum(0)                           # (M,)
        order = np.argsort(-f, kind="stable")
        sc = sim.sc
        top = next((m for m in order if sim.O[n, m].sum() == 0), None)
        if top is None:
            continue
        cur = int(np.argmax(sim.X[n, top]))
        tgt = min(cur + 1, sim.H) if ocfg.partition else sim.H
        if tgt == cur:
            continue
        # shrink least-frequent models until the enlargement fits
        used = sum(sc.sizes[m2, int(np.argmax(sim.X[n, m2]))]
                   for m2 in range(sim.M))
        used += max(sc.sizes[top, tgt] - sc.sizes[top, cur] * (cur > 0), 0)
        for m2 in np.argsort(f, kind="stable"):
            if used <= sc.R[n]:
                break
            if m2 == top:
                continue
            c2 = int(np.argmax(sim.X[n, m2]))
            if c2 == 0:
                continue
            new2 = c2 - 1 if ocfg.partition else 0
            used -= sc.sizes[m2, c2] - sc.sizes[m2, new2]
            sim.X[n, m2, :] = 0
            sim.X[n, m2, new2] = 1
        if used <= sc.R[n]:
            delta = sc.sizes[top, tgt] - (sc.sizes[top, cur] if (cur and ocfg.partition) else 0.0)
            sim.O[n, top, tgt - 1] = max(delta, 0.0)
            sim.target[n, top] = tgt


def _random_step(sim: OnlineSim, ns, u_model, perms, u_shrink,
                 ocfg: OnlineConfig):
    """Random baseline driven by the pre-drawn uniforms, so its RNG
    consumption is fixed-shape (state-independent) and replayable."""
    sc = sim.sc
    for j, n in enumerate(ns):
        candidates = [m for m in range(sim.M) if sim.O[n, m].sum() == 0]
        if not candidates:
            continue
        m = candidates[min(int(u_model[j] * len(candidates)),
                           len(candidates) - 1)]
        cur = int(np.argmax(sim.X[n, m]))
        tgt = min(cur + 1, sim.H) if ocfg.partition else sim.H
        if tgt == cur:
            continue
        used = sum(sc.sizes[m2, int(np.argmax(sim.X[n, m2]))]
                   for m2 in range(sim.M))
        used += sc.sizes[m, tgt] - (sc.sizes[m, cur] if cur else 0.0)
        for m2 in perms[j]:
            if m2 == m:
                continue
            if used <= sc.R[n]:
                break
            c2 = int(np.argmax(sim.X[n, m2]))
            if c2 == 0:
                continue
            new2 = min(int(u_shrink[j, m2] * c2), c2 - 1) \
                if ocfg.partition else 0
            used -= sc.sizes[m2, c2] - sc.sizes[m2, new2]
            sim.X[n, m2, :] = 0
            sim.X[n, m2, new2] = 1
        if used <= sc.R[n]:
            delta = sc.sizes[m, tgt] - (sc.sizes[m, cur] if (cur and ocfg.partition) else 0.0)
            sim.O[n, m, tgt - 1] = max(delta, 0.0)
            sim.target[n, m] = tgt
