"""Offline baselines (paper Sec. VII-B).

* SPR³  [22] — random-rounding joint caching/routing, but complete models
  only (no dynamic submodels) and loading time ignored in decisions.
* Greedy — popularity-ordered caching, highest precision first, home-BS
  routing only.
* Random — random submodel choices under memory + random routing.
* GatMARL [55] — compact graph-attention multi-agent RL: a 2-layer GAT over
  the BS graph encodes per-BS demand; per-BS policy heads pick a submodel
  per model type; trained with REINFORCE on average served precision.
  (Loading time ignored in decisions, as in the paper's comparison.)

All baselines are *evaluated* under the same feasibility enforcement as
CoCaR (mec.metrics.enforce).
"""
from __future__ import annotations

import numpy as np

from repro.core.jdcr import JDCRInstance


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _route_home(inst: JDCRInstance, x):
    """Route every user to its home BS if the model is cached there."""
    A = np.zeros((inst.N, inst.U, inst.H))
    for u in range(inst.U):
        n = inst.home[u]
        h = int(np.argmax(x[n, inst.m_u[u]]))
        if h > 0:
            A[n, u, h - 1] = 1.0
    return A


def _route_best(inst: JDCRInstance, x, rng=None, random_route=False):
    """Route to a BS caching m_u (random or best precision), else cloud."""
    A = np.zeros((inst.N, inst.U, inst.H))
    cached_h = np.argmax(x, axis=-1)                     # (N, M)
    for u in range(inst.U):
        m = inst.m_u[u]
        options = [(n, cached_h[n, m]) for n in range(inst.N)
                   if cached_h[n, m] > 0]
        if not options:
            continue
        if random_route:
            n, h = options[rng.integers(len(options))]
        else:
            n, h = max(options, key=lambda nh: inst.prec[m, nh[1]])
        A[n, u, h - 1] = 1.0
    return A


# ---------------------------------------------------------------------------
# Greedy
# ---------------------------------------------------------------------------

def greedy(inst: JDCRInstance):
    counts = np.bincount(inst.m_u, minlength=inst.M)
    order = np.argsort(-counts)
    x = np.zeros((inst.N, inst.M, inst.H + 1))
    x[:, :, 0] = 1.0
    for n in range(inst.N):
        free = inst.R[n]
        for m in order:
            for h in range(inst.H, 0, -1):               # high precision first
                if inst.sizes[m, h] <= free:
                    x[n, m, :] = 0
                    x[n, m, h] = 1
                    free -= inst.sizes[m, h]
                    break
    return x, _route_home(inst, x)


# ---------------------------------------------------------------------------
# Random
# ---------------------------------------------------------------------------

def random_policy(inst: JDCRInstance, seed=0):
    rng = np.random.default_rng(seed)
    x = np.zeros((inst.N, inst.M, inst.H + 1))
    x[:, :, 0] = 1.0
    for n in range(inst.N):
        free = inst.R[n]
        for m in rng.permutation(inst.M):
            h = rng.integers(0, inst.H + 1)
            if h > 0 and inst.sizes[m, h] <= free:
                x[n, m, :] = 0
                x[n, m, h] = 1
                free -= inst.sizes[m, h]
    # paper: "user requests are randomly routed to a BS" — any BS; it is a
    # miss if that BS does not cache the model
    A = np.zeros((inst.N, inst.U, inst.H))
    cached_h = np.argmax(x, axis=-1)
    for u in range(inst.U):
        n = rng.integers(inst.N)
        h = cached_h[n, inst.m_u[u]]
        if h > 0:
            A[n, u, h - 1] = 1.0
    return x, A


# ---------------------------------------------------------------------------
# SPR³ — complete models only, loading time ignored
# ---------------------------------------------------------------------------

def spr3(inst: JDCRInstance, seed=0):
    import dataclasses

    from repro.core import lp as LP
    from repro.core.rounding import repair, round_solution

    # complete-model variant: shrink the catalog to {h0, hH} by making the
    # intermediate submodels as large as the full model (the LP then never
    # prefers them) and neutralize the load constraint (s_u = window end).
    sizes = inst.sizes.copy()
    prec = inst.prec.copy()
    for m in range(inst.M):
        for h in range(1, inst.H):
            sizes[m, h] = sizes[m, inst.H]
            prec[m, h] = 0.0
    relaxed = dataclasses.replace(
        inst, sizes=sizes, prec=prec,
        s_u=np.full(inst.U, 1e9))                        # ignore load time
    x_f, A_f, _ = LP.solve_lp_scipy(relaxed)
    x_i, A_i = round_solution(relaxed, x_f, A_f, seed)
    x, A = repair(relaxed, x_i, A_i)
    return x, A


# ---------------------------------------------------------------------------
# GatMARL-lite: GAT over the BS graph + REINFORCE
# ---------------------------------------------------------------------------

def _gat_forward(params, feats, adj):
    """One graph-attention layer + policy logits.

    feats: (N, F); adj: (N, N) with self-loops. Returns (N, M, H+1) logits."""
    import jax.numpy as jnp

    h = jnp.tanh(feats @ params["w_in"])                     # (N, d)
    att_src = h @ params["a_src"]                            # (N,)
    att_dst = h @ params["a_dst"]
    scores = att_src[:, None] + att_dst[None, :]
    scores = jnp.where(adj > 0, scores, -1e9)
    alpha = jnp.exp(scores - scores.max(1, keepdims=True))
    alpha = alpha * (adj > 0)
    alpha = alpha / jnp.maximum(alpha.sum(1, keepdims=True), 1e-9)
    h2 = jnp.tanh(alpha @ h @ params["w_msg"] + h)
    return (h2 @ params["w_out"]).reshape(h.shape[0], -1)


_GAT_CACHE = {}


def _train_gatmarl(inst: JDCRInstance, seed: int, episodes: int = 150):
    import jax
    import jax.numpy as jnp

    N, M, H = inst.N, inst.M, inst.H
    d = 32
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    params = {
        "w_in": jax.random.normal(ks[0], (M + 1, d)) * 0.3,
        "a_src": jax.random.normal(ks[1], (d,)) * 0.3,
        "a_dst": jax.random.normal(ks[2], (d,)) * 0.3,
        "w_msg": jax.random.normal(ks[3], (d, d)) * 0.3,
        "w_out": jax.random.normal(ks[4], (d, M * (H + 1))) * 0.3,
    }
    adj = np.asarray(inst.wired < 1e11, dtype=np.float64)
    np.fill_diagonal(adj, 1.0)
    adj = jnp.asarray(adj)

    def feats_of(m_u, home):
        f = np.zeros((N, M + 1))
        for u in range(len(m_u)):
            f[home[u], m_u[u]] += 1.0
        f[:, M] = inst.R / inst.R.max()
        f[:, :M] /= max(len(m_u) / N, 1)
        return jnp.asarray(f)

    def reward_of(actions, inst):
        x = np.zeros((N, M, H + 1))
        for n in range(N):
            free = inst.R[n]
            for m in range(M):
                h = int(actions[n, m])
                if h > 0 and inst.sizes[m, h] <= free:
                    x[n, m, h] = 1
                    free -= inst.sizes[m, h]
                else:
                    x[n, m, 0] = 1
        A = _route_best(inst, x)
        from repro.mec import metrics as MET
        return MET.window_metrics(inst, x, A)["avg_precision"], x, A

    feats = feats_of(inst.m_u, inst.home)
    lr = 0.05
    baseline = 0.0

    def logp_of(p, actions):
        lg = _gat_forward(p, feats, adj).reshape(N, M, H + 1)
        logp = jax.nn.log_softmax(lg, axis=-1)
        return jnp.take_along_axis(logp, actions[..., None], -1).sum()

    grad_fn = jax.jit(jax.grad(logp_of))
    for ep in range(episodes):
        key, k1 = jax.random.split(key)
        lg = _gat_forward(params, feats, adj).reshape(N, M, H + 1)
        a = jax.random.categorical(k1, lg, axis=-1)          # (N, M)
        r, _, _ = reward_of(np.asarray(a), inst)
        adv = r - baseline
        baseline = 0.9 * baseline + 0.1 * r
        grads = grad_fn(params, a)
        params = jax.tree.map(lambda p, g: p + lr * adv * g, params, grads)
    return params, feats, adj


def gatmarl(inst: JDCRInstance, seed=0, episodes: int = 150):
    import jax
    import jax.numpy as jnp

    cache_key = (inst.N, inst.M, inst.H, seed)
    if cache_key not in _GAT_CACHE:
        _GAT_CACHE[cache_key] = _train_gatmarl(inst, seed, episodes)
    params, _, adj = _GAT_CACHE[cache_key]
    # greedy (argmax) rollout on the current window's features
    N, M, H = inst.N, inst.M, inst.H
    f = np.zeros((N, M + 1))
    for u in range(inst.U):
        f[inst.home[u], inst.m_u[u]] += 1.0
    f[:, M] = inst.R / inst.R.max()
    f[:, :M] /= max(inst.U / N, 1)
    logits = _gat_forward(params, jnp.asarray(f), adj).reshape(N, M, H + 1)
    actions = np.asarray(jnp.argmax(logits, -1))
    x = np.zeros((N, M, H + 1))
    for n in range(N):
        free = inst.R[n]
        for m in range(M):
            h = int(actions[n, m])
            if h > 0 and inst.sizes[m, h] <= free:
                x[n, m, h] = 1
                free -= inst.sizes[m, h]
            else:
                x[n, m, 0] = 1
    A = _route_best(inst, x)
    return x, A
