"""Offline baselines (paper Sec. VII-B) — twice each, PR-3 style: a NumPy
reference (the oracle, closest to the paper's prose) and a pure-jnp device
kernel riding on the same :class:`~repro.core.lp.PDHGData` pytree,
engineered to make *identical decisions* (``docs/algorithms.md`` Sec. 8).

* SPR³  [22] — random-rounding joint caching/routing, but complete models
  only (no dynamic submodels) and loading time ignored in decisions.
  Device path: the CoCaR pipeline stages (PDHG → Alg. 1 rounding → repair)
  on a *relaxed* pytree (``spr3_relax_device``), sharing the LP kernel.
* Greedy — popularity-ordered caching, highest precision first, home-BS
  routing only.  Deterministic: a per-BS ``lax.scan`` fill on device.
* Random — random submodel choices under memory + random routing.  All
  randomness is pre-drawn (``draw_baseline_uniforms``) and consumed
  verbatim by both engines, so every cache/route choice coincides.
* GatMARL [55] — compact graph-attention multi-agent RL: a 2-layer GAT over
  the BS graph encodes per-BS demand; per-BS policy heads pick a submodel
  per model type; trained with REINFORCE on average served precision.
  Training stays host-side (``gat_policy``, cached); the learned policy's
  *rollout* (forward → argmax actions → sequential fill → best-precision
  routing) is a vmappable kernel (``gat_rollout_device``) with
  ``gat_rollout_host`` as its oracle.  (Loading time ignored in decisions,
  as in the paper's comparison.)

All baselines are *evaluated* under the same feasibility enforcement as
CoCaR (``mec.metrics.enforce`` / ``enforce_device``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.jdcr import JDCRInstance, _jnp, tree_sum


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _route_home(inst: JDCRInstance, x):
    """Route every user to its home BS if the model is cached there."""
    A = np.zeros((inst.N, inst.U, inst.H))
    for u in range(inst.U):
        n = inst.home[u]
        h = int(np.argmax(x[n, inst.m_u[u]]))
        if h > 0:
            A[n, u, h - 1] = 1.0
    return A


def _route_best(inst: JDCRInstance, x, rng=None, random_route=False):
    """Route to a BS caching m_u (random or best precision), else cloud.

    Best-precision ties resolve to the smallest BS index (``max`` keeps the
    first maximal option) — the device twin resolves its argmax the same
    way.
    """
    A = np.zeros((inst.N, inst.U, inst.H))
    cached_h = np.argmax(x, axis=-1)                     # (N, M)
    for u in range(inst.U):
        m = inst.m_u[u]
        options = [(n, cached_h[n, m]) for n in range(inst.N)
                   if cached_h[n, m] > 0]
        if not options:
            continue
        if random_route:
            n, h = options[rng.integers(len(options))]
        else:
            n, h = max(options, key=lambda nh: inst.prec[m, nh[1]])
        A[n, u, h - 1] = 1.0
    return A


def _route_home_device(data, lvl):
    """``_route_home`` on cached levels ``lvl (N, M)``: one gathered route
    per real user at its home BS, if the home BS caches its model."""
    jnp = _jnp()
    N, M = lvl.shape
    H = data.T.shape[2]
    onehot_mu = jnp.asarray(data.onehot_mu)
    user_mask = tree_sum(onehot_mu, -1) > 0                 # (U,)
    m_u = jnp.argmax(onehot_mu, axis=-1)                    # (U,)
    home = jnp.argmax(jnp.asarray(data.home_onehot), axis=-1)  # (U,)
    h_u = lvl[home, m_u]                                    # (U,)
    hit_n = jnp.arange(N)[:, None] == home[None, :]         # (N, U)
    hit_h = jnp.arange(H)[None, :] == (h_u - 1)[:, None]    # (U, H)
    on = user_mask & (h_u > 0)
    return jnp.where(on[None, :, None] & hit_n[:, :, None]
                     & hit_h[None, :, :], 1.0, 0.0)


def _route_best_device(data, lvl):
    """``_route_best`` on cached levels: per user, the real BS caching its
    model with the highest precision (argmax-first on exact ties)."""
    jnp = _jnp()
    N, U, H = data.T.shape
    onehot_mu = jnp.asarray(data.onehot_mu)
    user_mask = tree_sum(onehot_mu, -1) > 0
    m_u = jnp.argmax(onehot_mu, axis=-1)
    h_sel = lvl[:, m_u]                                     # (N, U)
    hm1 = jnp.maximum(h_sel - 1, 0)
    prec_g = jnp.asarray(data.prec_u)[jnp.arange(U)[None, :], hm1]  # (N, U)
    ok = (h_sel > 0) & (jnp.asarray(data.bs_mask)[:, None] > 0)
    score = jnp.where(ok, prec_g, -jnp.inf)
    n_best = jnp.argmax(score, axis=0)                      # (U,)
    assign = user_mask & ok.any(axis=0)
    h_best = jnp.take_along_axis(h_sel, n_best[None, :], axis=0)[0]
    hit_n = jnp.arange(N)[:, None] == n_best[None, :]
    hit_h = jnp.arange(H)[None, :] == (h_best - 1)[:, None]
    return jnp.where(assign[None, :, None] & hit_n[:, :, None]
                     & hit_h[None, :, :], 1.0, 0.0)


def _levels_to_onehot(lvl, Hp1):
    xp = np if isinstance(lvl, np.ndarray) else _jnp()
    return (lvl[..., None] == xp.arange(Hp1)).astype(xp.float64)


# ---------------------------------------------------------------------------
# Greedy — popularity order, largest fitting submodel, home routing
# ---------------------------------------------------------------------------

def greedy(inst: JDCRInstance):
    counts = np.bincount(inst.m_u, minlength=inst.M)
    order = np.argsort(-counts, kind="stable")
    x = np.zeros((inst.N, inst.M, inst.H + 1))
    x[:, :, 0] = 1.0
    for n in range(inst.N):
        free = inst.R[n]
        for m in order:
            for h in range(inst.H, 0, -1):               # high precision first
                if inst.sizes[m, h] <= free:
                    x[n, m, :] = 0
                    x[n, m, h] = 1
                    free -= inst.sizes[m, h]
                    break
    return x, _route_home(inst, x)


def greedy_device(data):
    """``greedy`` as a pure jnp function of one padded window: the per-BS
    fill is a ``lax.scan`` over the (stable) popularity order, subtracting
    sizes in exactly the host loop's sequence so every fit test sees the
    same float budget.  Padded BSs carry ``R = 0``, so nothing fits."""
    import jax
    jnp = _jnp()

    sizes = jnp.asarray(data.sizes)
    M, Hp1 = sizes.shape
    counts = tree_sum(jnp.asarray(data.onehot_mu), 0)       # (M,) exact ints
    order = jnp.argsort(-counts, stable=True)
    hh = jnp.arange(Hp1)

    def fill_bs(R_n):
        def step(free, m):
            fits = (hh >= 1) & (sizes[m] <= free)
            h = jnp.max(jnp.where(fits, hh, 0))             # largest fitting
            return free - sizes[m, h], h
        _, lvls = jax.lax.scan(step, R_n, order)
        return jnp.zeros((M,), lvls.dtype).at[order].set(lvls)

    lvl = jax.vmap(fill_bs)(jnp.asarray(data.R))            # (N, M)
    x = _levels_to_onehot(lvl, Hp1)
    return x, _route_home_device(data, lvl)


# ---------------------------------------------------------------------------
# Random — uniform-driven on both engines
# ---------------------------------------------------------------------------

def draw_baseline_uniforms(key, N, M, U, n_seeds=1, batch=None):
    """All the randomness of ``n_seeds`` Random-policy draws, as three
    float64 uniform tensors both engines consume verbatim:

      u_perm  (S, N, M)  per-BS model visiting order (argsort of the row)
      u_h     (S, N, M)  submodel pick: h = floor(u · (H+1))
      u_route (S, U)     routing pick: n = floor(u · N_real)

    With ``batch`` given, every tensor gains a leading batch axis.
    """
    import jax
    from jax.experimental import enable_x64

    lead = (n_seeds,) if batch is None else (batch, n_seeds)
    with enable_x64():
        k = jax.random.PRNGKey(key) if isinstance(key, int) else key
        k1, k2, k3 = jax.random.split(k, 3)
        u_perm = jax.random.uniform(k1, lead + (N, M), dtype=np.float64)
        u_h = jax.random.uniform(k2, lead + (N, M), dtype=np.float64)
        u_route = jax.random.uniform(k3, lead + (U,), dtype=np.float64)
    return np.asarray(u_perm), np.asarray(u_h), np.asarray(u_route)


def random_from_uniforms(inst: JDCRInstance, u_perm, u_h, u_route):
    """One Random-policy draw as a deterministic function of pre-drawn
    uniforms (``u_perm/u_h (N, M)``, ``u_route (U,)``) — the NumPy oracle
    of ``random_device``."""
    H = inst.H
    x = np.zeros((inst.N, inst.M, H + 1))
    x[:, :, 0] = 1.0
    for n in range(inst.N):
        free = inst.R[n]
        for m in np.argsort(u_perm[n], kind="stable"):
            h = min(int(u_h[n, m] * (H + 1)), H)
            if h > 0 and inst.sizes[m, h] <= free:
                x[n, m, :] = 0
                x[n, m, h] = 1
                free -= inst.sizes[m, h]
    # paper: "user requests are randomly routed to a BS" — any BS; it is a
    # miss if that BS does not cache the model
    A = np.zeros((inst.N, inst.U, H))
    cached_h = np.argmax(x, axis=-1)
    for u in range(inst.U):
        n = min(int(u_route[u] * inst.N), inst.N - 1)
        h = cached_h[n, inst.m_u[u]]
        if h > 0:
            A[n, u, h - 1] = 1.0
    return x, A


def random_policy(inst: JDCRInstance, seed=0):
    u_perm, u_h, u_route = draw_baseline_uniforms(seed, inst.N, inst.M,
                                                  inst.U)
    return random_from_uniforms(inst, u_perm[0], u_h[0], u_route[0])


def random_device(data, u_perm, u_h, u_route):
    """``random_from_uniforms`` as a pure jnp function of one padded
    window.  The visiting order, the floor-scaled submodel picks, and the
    routing picks all come from the same uniforms the oracle consumes;
    routing scales by the number of *real* BSs, so padded rows are never
    drawn."""
    import jax
    jnp = _jnp()

    sizes = jnp.asarray(data.sizes)
    M, Hp1 = sizes.shape
    H = Hp1 - 1
    N, U = data.T.shape[0], data.T.shape[1]
    hh = jnp.arange(Hp1)

    def fill_bs(R_n, u_perm_n, u_h_n):
        order = jnp.argsort(u_perm_n, stable=True)
        def step(free, m):
            h_pick = jnp.minimum((u_h_n[m] * (H + 1)).astype(jnp.int32), H)
            ok = (h_pick > 0) & (sizes[m, h_pick] <= free)
            h = jnp.where(ok, h_pick, 0)
            return free - sizes[m, h], h
        _, lvls = jax.lax.scan(step, R_n, order)
        return jnp.zeros((M,), lvls.dtype).at[order].set(lvls)

    lvl = jax.vmap(fill_bs)(jnp.asarray(data.R),
                            jnp.asarray(u_perm), jnp.asarray(u_h))
    x = _levels_to_onehot(lvl, Hp1)

    onehot_mu = jnp.asarray(data.onehot_mu)
    user_mask = tree_sum(onehot_mu, -1) > 0
    m_u = jnp.argmax(onehot_mu, axis=-1)
    n_real = tree_sum(jnp.asarray(data.bs_mask), -1)
    n_pick = jnp.minimum((jnp.asarray(u_route) * n_real).astype(jnp.int32),
                         (n_real - 1).astype(jnp.int32))    # (U,)
    h_u = lvl[n_pick, m_u]
    hit_n = jnp.arange(N)[:, None] == n_pick[None, :]
    hit_h = jnp.arange(H)[None, :] == (h_u - 1)[:, None]
    on = user_mask & (h_u > 0)
    A = jnp.where(on[None, :, None] & hit_n[:, :, None] & hit_h[None, :, :],
                  1.0, 0.0)
    return x, A


# ---------------------------------------------------------------------------
# SPR³ — complete models only, loading time ignored
# ---------------------------------------------------------------------------

def spr3_relaxed(inst: JDCRInstance) -> JDCRInstance:
    """The complete-model relaxation SPR³ optimizes: intermediate submodels
    as large as the full model with zero precision (the LP then never
    prefers them) and a neutralized load constraint (s_u = window end)."""
    sizes = inst.sizes.copy()
    prec = inst.prec.copy()
    for m in range(inst.M):
        for h in range(1, inst.H):
            sizes[m, h] = sizes[m, inst.H]
            prec[m, h] = 0.0
    return dataclasses.replace(inst, sizes=sizes, prec=prec,
                               s_u=np.full(inst.U, 1e9))


def spr3_relax_device(data):
    """``spr3_relaxed`` on the :class:`~repro.core.lp.PDHGData` pytree —
    the transformed pytree feeds the *same* PDHG/round/repair kernels
    CoCaR uses (the LP solve is shared, only its inputs change)."""
    jnp = _jnp()
    Hp1 = data.sizes.shape[1]
    H = Hp1 - 1
    mid = (jnp.arange(Hp1) >= 1) & (jnp.arange(Hp1) < H)
    sizes = jnp.where(mid[None, :], data.sizes[:, H:H + 1], data.sizes)
    prec = jnp.where(mid[None, :], 0.0, data.prec)
    prec_u = jnp.where(jnp.arange(H)[None, :] < H - 1, 0.0, data.prec_u)
    s_u = jnp.full_like(data.s_u, 1e9)
    return data._replace(sizes=sizes, prec=prec, prec_u=prec_u, s_u=s_u)


def spr3(inst: JDCRInstance, seed=0):
    from repro.core import lp as LP
    from repro.core.rounding import repair, round_solution

    relaxed = spr3_relaxed(inst)
    x_f, A_f, _ = LP.solve_lp_scipy(relaxed)
    x_i, A_i = round_solution(relaxed, x_f, A_f, seed)
    x, A = repair(relaxed, x_i, A_i)
    return x, A


def spr3_from_fractional(inst: JDCRInstance, x_f, A_f, u_cat, u_phi):
    """The NumPy reference of the device SPR³ stages downstream of the LP:
    Alg. 1 rounding (trial axis from the uniforms) + repair, all against
    the relaxed instance.  Returns per-trial ``(x (T,...), A (T,...))``."""
    from repro.core.rounding import repair, round_from_uniforms

    relaxed = spr3_relaxed(inst)
    x_r, A_r = round_from_uniforms(np.asarray(x_f, np.float64),
                                   np.asarray(A_f, np.float64),
                                   relaxed.onehot_mu(), u_cat, u_phi)
    outs = [repair(relaxed, x_t, A_t) for x_t, A_t in zip(x_r, A_r)]
    return (np.stack([x for x, _ in outs]), np.stack([A for _, A in outs]))


# ---------------------------------------------------------------------------
# GatMARL-lite: GAT over the BS graph + REINFORCE
# ---------------------------------------------------------------------------

def _gat_forward(params, feats, adj):
    """One graph-attention layer + policy logits.

    feats: (N, F); adj: (N, N) with self-loops. Returns (N, M·(H+1))
    logits.  Zero adj rows/columns (padded BSs) contribute exactly-zero
    attention mass, so real rows' logits equal their unpadded values."""
    import jax.numpy as jnp

    h = jnp.tanh(feats @ params["w_in"])                     # (N, d)
    att_src = h @ params["a_src"]                            # (N,)
    att_dst = h @ params["a_dst"]
    scores = att_src[:, None] + att_dst[None, :]
    scores = jnp.where(adj > 0, scores, -1e9)
    alpha = jnp.exp(scores - scores.max(1, keepdims=True))
    alpha = alpha * (adj > 0)
    alpha = alpha / jnp.maximum(alpha.sum(1, keepdims=True), 1e-9)
    h2 = jnp.tanh(alpha @ h @ params["w_msg"] + h)
    return h2 @ params["w_out"]


_GAT_CACHE = {}


def gat_features(inst: JDCRInstance, n_pad: int = None):
    """Per-BS demand features for one window, optionally zero-padded to
    ``n_pad`` rows (the stacked grid shape)."""
    N = inst.N if n_pad is None else n_pad
    f = np.zeros((N, inst.M + 1))
    for u in range(inst.U):
        f[inst.home[u], inst.m_u[u]] += 1.0
    f[:inst.N, inst.M] = inst.R / inst.R.max()
    f[:, :inst.M] /= max(inst.U / inst.N, 1)
    return f


def gat_adj(inst: JDCRInstance, n_pad: int = None):
    """BS adjacency with self-loops, zero-padded to ``n_pad``."""
    adj = np.asarray(inst.wired < 1e11, dtype=np.float64)
    np.fill_diagonal(adj, 1.0)
    if n_pad is not None and n_pad > inst.N:
        dn = n_pad - inst.N
        adj = np.pad(adj, ((0, dn), (0, dn)))
    return adj


def _train_gatmarl(inst: JDCRInstance, seed: int, episodes: int = 150):
    """REINFORCE training, pinned to float64 (``enable_x64``) so the
    learned params — and therefore the gated comparison ratio — are
    identical whether or not the process runs under JAX_ENABLE_X64."""
    from jax.experimental import enable_x64

    with enable_x64():
        return _train_gatmarl_x64(inst, seed, episodes)


def _train_gatmarl_x64(inst: JDCRInstance, seed: int, episodes: int):
    import jax
    import jax.numpy as jnp

    N, M, H = inst.N, inst.M, inst.H
    d = 32
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    params = {
        "w_in": jax.random.normal(ks[0], (M + 1, d)) * 0.3,
        "a_src": jax.random.normal(ks[1], (d,)) * 0.3,
        "a_dst": jax.random.normal(ks[2], (d,)) * 0.3,
        "w_msg": jax.random.normal(ks[3], (d, d)) * 0.3,
        "w_out": jax.random.normal(ks[4], (d, M * (H + 1))) * 0.3,
    }
    adj = jnp.asarray(gat_adj(inst))

    def reward_of(actions, inst):
        x = np.zeros((N, M, H + 1))
        for n in range(N):
            free = inst.R[n]
            for m in range(M):
                h = int(actions[n, m])
                if h > 0 and inst.sizes[m, h] <= free:
                    x[n, m, h] = 1
                    free -= inst.sizes[m, h]
                else:
                    x[n, m, 0] = 1
        A = _route_best(inst, x)
        from repro.mec import metrics as MET
        return MET.window_metrics(inst, x, A)["avg_precision"], x, A

    feats = jnp.asarray(gat_features(inst))
    lr = 0.05
    baseline = 0.0

    def logp_of(p, actions):
        lg = _gat_forward(p, feats, adj).reshape(N, M, H + 1)
        logp = jax.nn.log_softmax(lg, axis=-1)
        return jnp.take_along_axis(logp, actions[..., None], -1).sum()

    grad_fn = jax.jit(jax.grad(logp_of))
    for ep in range(episodes):
        key, k1 = jax.random.split(key)
        lg = _gat_forward(params, feats, adj).reshape(N, M, H + 1)
        a = jax.random.categorical(k1, lg, axis=-1)          # (N, M)
        r, _, _ = reward_of(np.asarray(a), inst)
        adv = r - baseline
        baseline = 0.9 * baseline + 0.1 * r
        grads = grad_fn(params, a)
        params = jax.tree.map(lambda p, g: p + lr * adv * g, params, grads)
    return params


def _gat_cache_key(inst: JDCRInstance, seed: int, episodes: int):
    """Content-derived cache key: repeated calls on an *identical* window
    reuse the training run, but every distinct scenario variant (capacity,
    skew, requests, …) trains its own policy — the paper's per-scenario
    protocol."""
    import hashlib

    h = hashlib.sha1()
    for a in (inst.m_u, inst.home, inst.R, inst.C, inst.sizes, inst.prec,
              inst.wired):
        h.update(np.ascontiguousarray(a).tobytes())
    return (inst.N, inst.M, inst.H, seed, episodes, h.hexdigest())


def gat_policy(inst: JDCRInstance, seed: int = 0, episodes: int = 150):
    """Train (or fetch the cached) GatMARL policy for this window's
    scenario; returns float64 params so both rollout engines run the
    forward pass on identical numbers."""
    cache_key = _gat_cache_key(inst, seed, episodes)
    if cache_key not in _GAT_CACHE:
        params = _train_gatmarl(inst, seed, episodes)
        _GAT_CACHE[cache_key] = {k: np.asarray(v, np.float64)
                                 for k, v in params.items()}
    return _GAT_CACHE[cache_key]


def _gat_fill(inst: JDCRInstance, actions):
    """Greedy sequential fill of the argmax actions (host reference)."""
    x = np.zeros((inst.N, inst.M, inst.H + 1))
    for n in range(inst.N):
        free = inst.R[n]
        for m in range(inst.M):
            h = int(actions[n, m])
            if h > 0 and inst.sizes[m, h] <= free:
                x[n, m, h] = 1
                free -= inst.sizes[m, h]
            else:
                x[n, m, 0] = 1
    return x


def gat_rollout_host(inst: JDCRInstance, params, feats=None, adj=None):
    """The learned policy's greedy rollout, host path: f64 forward on the
    (possibly padded) features, then the NumPy fill + best-precision route.
    ``feats``/``adj`` default to the window's own unpadded arrays; pass the
    stacked grid's padded versions to oracle the device kernel."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    feats = gat_features(inst) if feats is None else feats
    adj = gat_adj(inst, n_pad=len(feats)) if adj is None else adj
    with enable_x64():
        logits = np.asarray(_gat_forward(params, jnp.asarray(feats),
                                         jnp.asarray(adj)))
    actions = np.argmax(
        logits.reshape(len(feats), inst.M, inst.H + 1), -1)[:inst.N]
    x = _gat_fill(inst, actions)
    return x, _route_best(inst, x)


def gat_rollout_device(data, params, feats, adj):
    """``gat_rollout_host`` as a pure jnp function of one padded window:
    forward → argmax actions → per-BS ``lax.scan`` fill → masked-argmax
    best-precision routing.  vmappable over stacked windows (stack the
    params pytree alongside ``feats``/``adj``)."""
    import jax
    jnp = _jnp()

    sizes = jnp.asarray(data.sizes)
    M, Hp1 = sizes.shape
    N = data.T.shape[0]
    params = {k: jnp.asarray(v) for k, v in params.items()}
    logits = _gat_forward(params, jnp.asarray(feats),
                          jnp.asarray(adj)).reshape(N, M, Hp1)
    actions = jnp.argmax(logits, -1)                        # (N, M)

    def fill_bs(R_n, act_n):
        def step(free, ma):
            m, h_a = ma
            ok = (h_a > 0) & (sizes[m, h_a] <= free)
            h = jnp.where(ok, h_a, 0)
            return free - sizes[m, h], h
        _, lvls = jax.lax.scan(step, R_n, (jnp.arange(M), act_n))
        return lvls

    lvl = jax.vmap(fill_bs)(jnp.asarray(data.R), actions)   # (N, M)
    x = _levels_to_onehot(lvl, Hp1)
    return x, _route_best_device(data, lvl)


def gatmarl(inst: JDCRInstance, seed=0, episodes: int = 150):
    params = gat_policy(inst, seed, episodes)
    return gat_rollout_host(inst, params)
