"""JDCR problem instance (paper Sec. IV): joint dynamic-model caching and
request routing within one observation window.

Array conventions (rectangular: every model type has the same number of
submodels H; the empty submodel h0 is slot 0 of the caching variable only):

  x      (N, M, H+1)   caching one-hot over {h0, h1..hH}   (paper x_{n,h})
  A      (N, U, H)     routing to real submodels h1..hH    (paper A_{n,u,h})
  sizes  (M, H+1)      r_h bytes-like units (slot 0 = 0)
  prec   (M, H+1)      p_h (slot 0 = 0)
  flops  (M, H+1)      c_h per data unit (slot 0 = 0)
  loadD  (M, H+1, H+1) D_m(h', h) switching latency, rows = previous state
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class JDCRInstance:
    # catalog
    sizes: np.ndarray      # (M, H+1)
    prec: np.ndarray       # (M, H+1)
    flops: np.ndarray      # (M, H+1)
    loadD: np.ndarray      # (M, H+1, H+1)
    # infrastructure
    R: np.ndarray          # (N,) memory capacity
    C: np.ndarray          # (N,) compute capacity (flops/s)
    phi: np.ndarray        # (N,) wireless rate (data units/s)
    wired: np.ndarray      # (N, N) wired rate
    lam: np.ndarray        # (N, N) propagation latency home->target (s)
    # requests (one observation window)
    m_u: np.ndarray        # (U,) requested model type
    d_u: np.ndarray        # (U,) data size
    ddl: np.ndarray        # (U,) latency budget
    s_u: np.ndarray        # (U,) initiation time in window
    home: np.ndarray       # (U,) home BS
    # previous window caching state
    x_prev: np.ndarray     # (N, M, H+1) one-hot

    @property
    def N(self):
        return len(self.R)

    @property
    def M(self):
        return self.sizes.shape[0]

    @property
    def H(self):
        return self.sizes.shape[1] - 1

    @property
    def U(self):
        return len(self.m_u)

    # ------------------------------------------------------------------
    def comm_latency(self) -> np.ndarray:
        """(U, N): T^off term for routing user u to BS n (excl. inference)."""
        up = self.d_u / self.phi[self.home]                       # (U,)
        wired = self.d_u[:, None] / self.wired[self.home, :]      # (U, N)
        wired[self.wired[self.home, :] <= 0] = 0.0
        lam = self.lam[self.home, :]                              # (U, N)
        return up[:, None] + wired + lam

    def e2e_latency(self) -> np.ndarray:
        """(N, U, H): T̂_{n,u,h} = comm + inference (paper Eq. 15)."""
        comm = self.comm_latency()                                # (U, N)
        infer = (self.flops[self.m_u, 1:][None, :, :]
                 * self.d_u[None, :, None] / self.C[:, None, None])
        return comm.T[:, :, None] + infer                         # (N,U,H)

    def load_latency(self) -> np.ndarray:
        """(N, U, H): model-m_u load time at BS n (paper Eq. 16), determined
        by the previous window's caching state."""
        # T[n, m, h] = sum_h' x_prev[n,m,h'] * loadD[m, h', h]
        T = np.einsum("nmp,mph->nmh", self.x_prev, self.loadD)
        return T[:, self.m_u, 1:]                                 # (N,U,H)

    def objective(self, A) -> float:
        return float(np.sum(A * self.prec[self.m_u, 1:][None]))


def check_feasible(inst: JDCRInstance, x, A, atol=1e-6):
    """Constraint residuals for integer/fractional (x, A)."""
    res = {}
    res["one_submodel"] = np.max(np.abs(x.sum(-1) - 1.0))
    res["memory"] = np.max(np.sum(x * inst.sizes[None], axis=(1, 2)) - inst.R)
    res["route"] = np.max(A.sum(axis=(0, 2)) - 1.0)
    xa = x[:, inst.m_u, 1:]                                       # (N,U,H)
    res["A_le_x"] = np.max(A - xa)
    res["latency"] = np.max((A * inst.e2e_latency()).sum(axis=(0, 2)) - inst.ddl)
    res["load"] = np.max((A * inst.load_latency()).sum(axis=(0, 2)) - inst.s_u)
    res["ok"] = all(v <= atol for k, v in res.items() if k != "ok")
    return res
