"""JDCR problem instance (paper Sec. IV): joint dynamic-model caching and
request routing within one observation window.

Array conventions (rectangular: every model type has the same number of
submodels H; the empty submodel h0 is slot 0 of the caching variable only):

  x      (N, M, H+1)   caching one-hot over {h0, h1..hH}   (paper x_{n,h})
  A      (N, U, H)     routing to real submodels h1..hH    (paper A_{n,u,h})
  sizes  (M, H+1)      r_h bytes-like units (slot 0 = 0)
  prec   (M, H+1)      p_h (slot 0 = 0)
  flops  (M, H+1)      c_h per data unit (slot 0 = 0)
  loadD  (M, H+1, H+1) D_m(h', h) switching latency, rows = previous state

Also home of the *deterministic reductions* the NumPy reference and the
device round+repair pipeline share (``tree_sum``, ``objective_sel``): the
offline equivalence story (PR-2 style, see ``docs/algorithms.md`` Sec. 7)
hinges on decision-critical sums producing bit-identical float64 values on
both paths.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def tree_sum(v, axis=-1):
    """Balanced-tree reduction over one axis — bit-identical in NumPy and
    JAX, and invariant to trailing zero padding.

    Both engines fold the same explicit sequence of pairwise adds (no
    library reduction, whose association is backend-defined), so any two
    arrays with equal elements reduce to the *same float*, not merely a
    close one.  The axis is zero-padded to the next power of two and folded
    in halves; appending zeros only ever adds exact ``+0.0`` terms, so a
    padded batch row reduces to the same value as its unpadded original —
    the property that makes host-vs-device threshold and argmin/argmax
    decisions coincide.
    """
    xp = np if isinstance(v, np.ndarray) else _jnp()
    v = xp.moveaxis(v, axis, -1)
    n = v.shape[-1]
    if n == 0:
        return xp.zeros(v.shape[:-1], dtype=v.dtype)
    p = 1
    while p < n:
        p *= 2
    if p != n:
        pad = [(0, 0)] * (v.ndim - 1) + [(0, p - n)]
        v = xp.pad(v, pad)
    while p > 1:
        p //= 2
        v = v[..., :p] + v[..., p:2 * p]
    return v[..., 0]


def _jnp():
    import jax.numpy as jnp
    return jnp


def objective_sel(prec_u, A):
    """Total routed precision Σ A·p, as a pure tree of adds over selected
    (not multiplied) precision values — the trial-argmax key of the
    ``best_of`` selection, computed identically on host and device so tied
    trials resolve to the same index on both.  ``A`` must be 0/1-valued."""
    xp = np if isinstance(A, np.ndarray) else _jnp()
    v = xp.where(A > 0, prec_u[None], 0.0)          # (N, U, H)
    return tree_sum(tree_sum(tree_sum(v, -1), -1), -1)


@dataclass
class JDCRInstance:
    # catalog
    sizes: np.ndarray      # (M, H+1)
    prec: np.ndarray       # (M, H+1)
    flops: np.ndarray      # (M, H+1)
    loadD: np.ndarray      # (M, H+1, H+1)
    # infrastructure
    R: np.ndarray          # (N,) memory capacity
    C: np.ndarray          # (N,) compute capacity (flops/s)
    phi: np.ndarray        # (N,) wireless rate (data units/s)
    wired: np.ndarray      # (N, N) wired rate
    lam: np.ndarray        # (N, N) propagation latency home->target (s)
    # requests (one observation window)
    m_u: np.ndarray        # (U,) requested model type
    d_u: np.ndarray        # (U,) data size
    ddl: np.ndarray        # (U,) latency budget
    s_u: np.ndarray        # (U,) initiation time in window
    home: np.ndarray       # (U,) home BS
    # previous window caching state
    x_prev: np.ndarray     # (N, M, H+1) one-hot

    @property
    def N(self):
        return len(self.R)

    @property
    def M(self):
        return self.sizes.shape[0]

    @property
    def H(self):
        return self.sizes.shape[1] - 1

    @property
    def U(self):
        return len(self.m_u)

    def onehot_mu(self) -> np.ndarray:
        """(U, M) one-hot of each user's requested model type — the
        encoding the LP, rounding, and repair kernels all consume."""
        onehot = np.zeros((self.U, self.M))
        onehot[np.arange(self.U), self.m_u] = 1.0
        return onehot

    # ------------------------------------------------------------------
    def comm_latency(self) -> np.ndarray:
        """(U, N): T^off term for routing user u to BS n (excl. inference)."""
        up = self.d_u / self.phi[self.home]                       # (U,)
        wired = self.d_u[:, None] / self.wired[self.home, :]      # (U, N)
        wired[self.wired[self.home, :] <= 0] = 0.0
        lam = self.lam[self.home, :]                              # (U, N)
        return up[:, None] + wired + lam

    def e2e_latency(self) -> np.ndarray:
        """(N, U, H): T̂_{n,u,h} = comm + inference (paper Eq. 15)."""
        comm = self.comm_latency()                                # (U, N)
        infer = (self.flops[self.m_u, 1:][None, :, :]
                 * self.d_u[None, :, None] / self.C[:, None, None])
        return comm.T[:, :, None] + infer                         # (N,U,H)

    def load_latency(self) -> np.ndarray:
        """(N, U, H): model-m_u load time at BS n (paper Eq. 16), determined
        by the previous window's caching state."""
        # T[n, m, h] = sum_h' x_prev[n,m,h'] * loadD[m, h', h]
        T = np.einsum("nmp,mph->nmh", self.x_prev, self.loadD)
        return T[:, self.m_u, 1:]                                 # (N,U,H)

    def objective(self, A) -> float:
        return float(np.sum(A * self.prec[self.m_u, 1:][None]))


def check_feasible(inst: JDCRInstance, x, A, atol=1e-6):
    """Constraint residuals for integer/fractional (x, A)."""
    res = {}
    res["one_submodel"] = np.max(np.abs(x.sum(-1) - 1.0))
    res["memory"] = np.max(np.sum(x * inst.sizes[None], axis=(1, 2)) - inst.R)
    res["route"] = np.max(A.sum(axis=(0, 2)) - 1.0)
    xa = x[:, inst.m_u, 1:]                                       # (N,U,H)
    res["A_le_x"] = np.max(A - xa)
    res["latency"] = np.max((A * inst.e2e_latency()).sum(axis=(0, 2)) - inst.ddl)
    res["load"] = np.max((A * inst.load_latency()).sum(axis=(0, 2)) - inst.s_u)
    res["ok"] = all(v <= atol for k, v in res.items() if k != "ok")
    return res


def check_feasible_device(data, x, A):
    """``check_feasible`` as a pure jnp function of a PDHGData-shaped
    pytree — residuals the fused offline pipeline can assert *inside* the
    dispatch (vmappable over windows and trials).

    Padded base stations / users carry zero capacity and zero routes, so
    their residual contributions are masked rather than penalised.
    Returns a dict of scalar residuals (same keys as ``check_feasible``,
    minus ``ok``).
    """
    jnp = _jnp()
    sizes, prec_u, T, L, onehot_mu = (data.sizes, data.prec_u, data.T,
                                      data.L, data.onehot_mu)
    bs = data.bs_mask > 0                                         # (N,)
    um = tree_sum(onehot_mu, -1) > 0                              # (U,)
    mem = tree_sum(tree_sum(jnp.where(x > 0, sizes[None], 0.0), -1), -1)
    xa = jnp.einsum("nmh,um->nuh", x[:, :, 1:], onehot_mu)
    lat = tree_sum(tree_sum(jnp.where(A > 0, T, 0.0), -1), 0)     # (U,)
    load = tree_sum(tree_sum(jnp.where(A > 0, L, 0.0), -1), 0)
    routes = tree_sum(tree_sum(A, -1), 0)                         # (U,)
    return {
        "one_submodel": jnp.max(jnp.where(bs[:, None],
                                          jnp.abs(tree_sum(x, -1) - 1.0),
                                          0.0)),
        "memory": jnp.max(jnp.where(bs, mem - data.R, -jnp.inf)),
        "route": jnp.max(jnp.where(um, routes - 1.0, -jnp.inf)),
        "A_le_x": jnp.max(A - xa),
        "latency": jnp.max(jnp.where(um, lat - data.ddl, -jnp.inf)),
        "load": jnp.max(jnp.where(um, load - data.s_u, -jnp.inf)),
    }
