"""CoCaR randomized rounding (paper Alg. 1) + feasibility repair (Sec. V-D).

Rounding is fully vectorized JAX:
  * caching: one multinoulli draw per (BS, model type) with probabilities
    x†[n,m,:]  (Lines 2–6),
  * routing: Bernoulli φ̃ with success probability A†/x† (Lines 7–13),
    Ã = x̃ · φ̃, ỹ = 1(Σ_h Ã > 0).

``round_solution_batch`` draws *all* ``best_of`` trials as two batched RNG
ops (one categorical, one bernoulli) instead of a Python loop — every trial
is iid, so the max over trials keeps Thm 1's guarantee.

Repair (host-side numpy, Sec. V-D "Extension to Practice"):
  1. memory violations: repeatedly shrink the least-beneficial cached
     submodel (or evict to h0), redirecting now-unserved users to the cloud;
  2. latency / load violations: send the offending routes to the cloud;
  3. multiple routes: keep the highest-precision one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jdcr import JDCRInstance


def round_solution_batch(inst: JDCRInstance, x_frac, A_frac, key,
                         n_trials: int = 1):
    """Alg. 1, ``n_trials`` iid draws in one RNG dispatch.

    Returns integer (x̃ (T,N,M,H+1), Ã (T,N,U,H)) as numpy arrays.
    """
    N, M, H, U = inst.N, inst.M, inst.H, inst.U
    xf = jnp.asarray(x_frac)
    Af = jnp.asarray(A_frac)
    k1, k2 = jax.random.split(jax.random.PRNGKey(key) if isinstance(key, int)
                              else key)

    probs = jnp.clip(xf, 0.0, 1.0)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-12)
    logits = jnp.log(probs + 1e-12)                                 # (N,M,H+1)
    cat = jax.random.categorical(k1, logits[None], axis=-1,
                                 shape=(n_trials, N, M))
    x_int = jax.nn.one_hot(cat, H + 1)                              # (T,N,M,H+1)

    xa = xf[:, inst.m_u, 1:]                                        # (N,U,H)
    phi_p = jnp.where(xa > 1e-12, Af / jnp.maximum(xa, 1e-12), 0.0)
    phi = jax.random.bernoulli(k2, jnp.clip(phi_p, 0.0, 1.0)[None],
                               shape=(n_trials, N, U, H))
    x_sel = x_int[:, :, inst.m_u, 1:]                               # (T,N,U,H)
    A_int = x_sel * phi.astype(x_sel.dtype)
    return np.asarray(x_int), np.asarray(A_int)


def round_solution(inst: JDCRInstance, x_frac, A_frac, key):
    """Vectorized Alg. 1. Returns integer (x̃ (N,M,H+1), Ã (N,U,H))."""
    x_int, A_int = round_solution_batch(inst, x_frac, A_frac, key, n_trials=1)
    return x_int[0], A_int[0]


def _dedupe_routes(inst: JDCRInstance, A):
    """Keep at most one route per user — the highest-precision one."""
    N, U, H = A.shape
    prec_u = inst.prec[inst.m_u, 1:]                        # (U,H)
    for u in range(U):
        nz = np.argwhere(A[:, u, :] > 0)
        if len(nz) <= 1:
            continue
        best = max(nz, key=lambda nh: prec_u[u, nh[1]])
        A[:, u, :] = 0
        A[best[0], u, best[1]] = 1
    return A


def repair(inst: JDCRInstance, x, A):
    """Sec. V-D heuristic: convert rounded (x̃, Ã) into feasible (x, y)."""
    x = np.array(x, dtype=np.float64)
    A = np.array(A, dtype=np.float64)
    N, M, H = inst.N, inst.M, inst.H
    prec_u = inst.prec[inst.m_u, 1:]                        # (U,H)

    A = _dedupe_routes(inst, A)

    # ---- 1. memory -----------------------------------------------------
    for n in range(N):
        def used():
            return float(np.sum(x[n] * inst.sizes))
        while used() > inst.R[n] + 1e-9:
            # benefit per cached (m, h>0): routed users × precision
            cached = [(m, int(np.argmax(x[n, m]))) for m in range(M)]
            benefits = []
            for m, h in cached:
                if h == 0:
                    continue
                users = [u for u in range(inst.U)
                         if inst.m_u[u] == m and A[n, u, h - 1] > 0]
                benefits.append((sum(prec_u[u, h - 1] for u in users), m, h))
            if not benefits:
                break
            benefits.sort()
            _, m, h = benefits[0]
            # try the largest smaller submodel that fits
            slack = inst.R[n] - (used() - inst.sizes[m, h])
            new_h = 0
            for hh in range(h - 1, 0, -1):
                if inst.sizes[m, hh] <= slack + 1e-9:
                    new_h = hh
                    break
            x[n, m, :] = 0
            x[n, m, new_h] = 1
            for u in range(inst.U):
                if inst.m_u[u] == m and A[n, u, h - 1] > 0:
                    A[n, u, h - 1] = 0
                    # downgraded service if a smaller submodel remains
                    if new_h > 0:
                        A[n, u, new_h - 1] = 1

    # routes must point at cached submodels
    x_sel = x[:, inst.m_u, 1:].transpose(0, 1, 2)           # (N,U,H)
    A = A * (x_sel > 0)

    # ---- 2. latency & load ----------------------------------------------
    T = inst.e2e_latency()
    L = inst.load_latency()
    lat_u = np.einsum("nuh->u", A * T)
    load_u = np.einsum("nuh->u", A * L)
    bad = (lat_u > inst.ddl + 1e-9) | (load_u > inst.s_u + 1e-9)
    A[:, bad, :] = 0.0

    # ---- 3. route repair (beyond Sec. V-D, routing-only and constraint-
    # safe): unserved users whose model IS cached at some feasible BS are
    # routed there instead of the cloud (contention-free model: adding a
    # route violates nothing)
    cached_h = np.argmax(x, axis=-1)                        # (N, M)
    unserved = np.nonzero(A.sum(axis=(0, 2)) == 0)[0]
    for u in unserved:
        m = inst.m_u[u]
        best = None
        for n in range(N):
            h = cached_h[n, m]
            if h == 0:
                continue
            if T[n, u, h - 1] > inst.ddl[u] + 1e-9:
                continue
            if L[n, u, h - 1] > inst.s_u[u] + 1e-9:
                continue
            p = prec_u[u, h - 1]
            if best is None or p > best[0]:
                best = (p, n, h - 1)
        if best is not None:
            A[best[1], u, best[2]] = 1.0

    return x, A
