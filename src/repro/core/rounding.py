"""CoCaR randomized rounding (paper Alg. 1) + feasibility repair (Sec. V-D)
— twice: a NumPy reference and a pure-JAX device kernel, engineered to make
*identical decisions* (PR-2 style, see ``docs/algorithms.md`` Sec. 7).

Rounding (Alg. 1) is a deterministic function of pre-drawn uniforms:

  * caching: inverse-CDF multinoulli per (BS, model type) with
    probabilities x†[n,m,:] (Lines 2–6) against ``u_cat``,
  * routing: Bernoulli φ̃ with success probability A†/x† (Lines 7–13)
    against ``u_phi``; Ã = x̃ · φ̃.

``draw_rounding_uniforms`` draws *all* ``best_of × seeds`` trials as two
batched RNG ops; both engines then consume the same numbers, so every
threshold crossing — and therefore every rounded decision — coincides.

Repair (Sec. V-D "Extension to Practice") turns a rounded draw into a
feasible integral solution:

  1. route dedupe: at most one route per user, highest precision wins;
  2. memory violations: repeatedly shrink the least-beneficial cached
     submodel (or evict to h0), redirecting now-unserved users;
  3. latency / load violations: send the offending routes to the cloud;
  4. route re-repair (routing-only, constraint-safe): re-route unserved
     users to the best feasible cached replica.

``repair`` is the NumPy oracle (per-BS Python loop, closest to the paper's
pseudocode); ``repair_device`` is the same state machine as masked argmax /
select ops with the eviction loop as a bounded ``lax.while_loop`` (each
eviction strictly lowers some cached level, so M·H iterations reach the
fixpoint).  Decision-critical sums go through ``jdcr.tree_sum`` on both
paths and comparisons select (never multiply) precision values, so the two
implementations agree on the *decision* level, not merely to a tolerance —
asserted in ``tests/test_offline_batched.py`` and
``benchmarks/bench_offline.py``.
"""
from __future__ import annotations

import numpy as np

from repro.core.jdcr import JDCRInstance, tree_sum

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Alg. 1 rounding — deterministic in pre-drawn uniforms
# ---------------------------------------------------------------------------

def draw_rounding_uniforms(key, n_trials, N, M, U, H, batch=None):
    """All the randomness of ``n_trials`` Alg. 1 draws, as two float64
    uniform tensors (one categorical inverse-CDF, one Bernoulli):
    ``u_cat (T, N, M)`` and ``u_phi (T, N, U, H)`` — with a leading
    ``batch`` axis when given.  Both engines consume these *same* numbers.
    """
    import jax
    from jax.experimental import enable_x64

    shape = (n_trials, N, M) if batch is None else (batch, n_trials, N, M)
    shape_phi = shape[:-2] + (N, U, H) if batch is None \
        else (batch, n_trials, N, U, H)
    with enable_x64():
        k = jax.random.PRNGKey(key) if isinstance(key, int) else key
        k1, k2 = jax.random.split(k)
        u_cat = jax.random.uniform(k1, shape, dtype=np.float64)
        u_phi = jax.random.uniform(k2, shape_phi, dtype=np.float64)
    return np.asarray(u_cat), np.asarray(u_phi)


def round_from_uniforms(x_frac, A_frac, onehot_mu, u_cat, u_phi):
    """Alg. 1 as a pure function of the fractional LP solution and the
    pre-drawn uniforms.  Works on NumPy *and* JAX arrays (same ops, same
    float results); ``u_cat``/``u_phi`` may carry leading trial axes that
    broadcast against the unbatched (N, M, H+1) / (N, U, H) solution.

    Returns 0/1-valued (x̃ ..., N, M, H+1) and (Ã ..., N, U, H).
    """
    xp = np if isinstance(x_frac, np.ndarray) else _jnp()
    Hp1 = x_frac.shape[-1]
    probs = xp.clip(x_frac, 0.0, 1.0)
    den = xp.maximum(tree_sum(probs, -1), 1e-12)
    probs = probs / den[..., None]
    # inverse CDF: smallest k with u < Σ_{j<=k} p_j; partial sums are
    # accumulated left-to-right (static loop) identically on both engines
    cum = probs[..., 0]
    cat = xp.zeros(u_cat.shape, dtype=xp.int32)
    for k in range(Hp1 - 1):
        cat = cat + (u_cat >= cum).astype(xp.int32)
        if k < Hp1 - 2:
            cum = cum + probs[..., k + 1]
    x_int = (cat[..., None] == xp.arange(Hp1)).astype(xp.float64)
    # Bernoulli routing: P[φ=1] = A†/x† at the user's model row
    xa = xp.einsum("nmh,um->nuh", x_frac[..., :, :, 1:], onehot_mu)
    phi_p = xp.where(xa > 1e-12, A_frac / xp.maximum(xa, 1e-12), 0.0)
    phi_p = xp.clip(phi_p, 0.0, 1.0)
    x_sel = xp.einsum("...nmh,um->...nuh", x_int[..., :, :, 1:], onehot_mu)
    A_int = xp.where((x_sel > 0) & (u_phi < phi_p), 1.0, 0.0)
    return x_int, A_int


def round_solution_batch(inst: JDCRInstance, x_frac, A_frac, key,
                         n_trials: int = 1):
    """Alg. 1, ``n_trials`` iid draws from one batched RNG dispatch.

    Returns integer (x̃ (T,N,M,H+1), Ã (T,N,U,H)) as numpy arrays.
    """
    N, M, H, U = inst.N, inst.M, inst.H, inst.U
    u_cat, u_phi = draw_rounding_uniforms(key, max(n_trials, 1), N, M, U, H)
    x_int, A_int = round_from_uniforms(
        np.asarray(x_frac, np.float64), np.asarray(A_frac, np.float64),
        inst.onehot_mu(), u_cat, u_phi)
    return x_int, A_int


def round_solution(inst: JDCRInstance, x_frac, A_frac, key):
    """Vectorized Alg. 1. Returns integer (x̃ (N,M,H+1), Ã (N,U,H))."""
    x_int, A_int = round_solution_batch(inst, x_frac, A_frac, key, n_trials=1)
    return x_int[0], A_int[0]


def _jnp():
    import jax.numpy as jnp
    return jnp


# ---------------------------------------------------------------------------
# Sec. V-D repair — NumPy reference (the oracle)
# ---------------------------------------------------------------------------

def _dedupe_routes(prec_u, A):
    """Keep at most one route per user — highest precision; exact ties go
    to the smallest (n, h) in row-major order (both engines argmax-first)."""
    N, U, H = A.shape
    score = np.where(A > 0, np.broadcast_to(prec_u[None], A.shape), -np.inf)
    flat = np.moveaxis(score, 1, 0).reshape(U, N * H)
    k = np.argmax(flat, axis=1)
    served = (flat > -np.inf).any(axis=1)
    keep = (np.arange(N * H)[None, :] == k[:, None]) & served[:, None]
    return np.moveaxis(keep.reshape(U, N, H), 0, 1).astype(np.float64)


def repair(inst: JDCRInstance, x, A):
    """Sec. V-D heuristic: convert rounded (x̃, Ã) into feasible (x, y).

    The reference implementation: per-BS Python eviction loop, mirroring
    the paper's prose.  Decision sums use ``tree_sum`` so the device kernel
    (``repair_device``) reproduces every eviction/kick-out choice exactly.
    """
    x = np.array(x, dtype=np.float64)
    A = np.array(A, dtype=np.float64)
    N, M, H = inst.N, inst.M, inst.H
    prec_u = inst.prec[inst.m_u, 1:]                        # (U,H)
    onehot_mu = inst.onehot_mu()

    A = _dedupe_routes(prec_u, A)

    # ---- 1. memory -----------------------------------------------------
    hh = np.arange(H + 1)
    ms = np.arange(M)
    for n in range(N):
        while True:
            used = tree_sum(tree_sum(
                np.where(x[n] > 0, inst.sizes, 0.0), -1), -1)
            cached = np.argmax(x[n], axis=-1)               # (M,)
            if used <= inst.R[n] + _EPS or not (cached > 0).any():
                break
            # benefit of each cached (m, h>0): Σ routed users' precision.
            # Every routed user of model m contributes the same catalog
            # p_{m,h}, so this is an exact integer count times one float —
            # bit-identical on host and device whatever the summation order
            cnt = np.einsum("um,uh->mh", onehot_mu,
                            (A[n] > 0).astype(np.float64))
            hm1 = np.maximum(cached - 1, 0)
            benefit = inst.prec[ms, cached] * cnt[ms, hm1]
            m_e = int(np.argmin(np.where(cached > 0, benefit, np.inf)))
            h = cached[m_e]
            # largest smaller submodel that fits the freed budget
            slack = inst.R[n] - (used - inst.sizes[m_e, h])
            fits = (hh >= 1) & (hh < h) & (inst.sizes[m_e] <= slack + _EPS)
            new_h = int(np.max(np.where(fits, hh, 0)))
            x[n, m_e] = 0.0
            x[n, m_e, new_h] = 1.0
            moved = (onehot_mu[:, m_e] > 0) & (A[n, :, h - 1] > 0)
            A[n, moved, h - 1] = 0.0
            if new_h > 0:                  # downgraded service survives
                A[n, moved, new_h - 1] = 1.0

    # routes must point at cached submodels
    x_sel = np.einsum("nmh,um->nuh", x[:, :, 1:], onehot_mu)
    A = np.where(x_sel > 0, A, 0.0)

    # ---- 2. latency & load ---------------------------------------------
    T = inst.e2e_latency()
    L = inst.load_latency()
    lat_u = tree_sum(tree_sum(np.where(A > 0, T, 0.0), -1), 0)
    load_u = tree_sum(tree_sum(np.where(A > 0, L, 0.0), -1), 0)
    bad = (lat_u > inst.ddl + _EPS) | (load_u > inst.s_u + _EPS)
    A[:, bad, :] = 0.0

    # ---- 3. route repair (beyond Sec. V-D, routing-only and constraint-
    # safe): unserved users whose model IS cached at some feasible BS are
    # routed there instead of the cloud (contention-free model: adding a
    # route violates nothing)
    cached_h = np.argmax(x, axis=-1)                        # (N, M)
    h_sel = cached_h[:, inst.m_u]                           # (N, U)
    hm1 = np.maximum(h_sel - 1, 0)
    T_g = np.take_along_axis(T, hm1[:, :, None], axis=-1)[..., 0]
    L_g = np.take_along_axis(L, hm1[:, :, None], axis=-1)[..., 0]
    prec_g = prec_u[np.arange(inst.U)[None, :], hm1]        # (N, U)
    feas = (h_sel > 0) & (T_g <= inst.ddl[None] + _EPS) \
        & (L_g <= inst.s_u[None] + _EPS)
    score = np.where(feas, prec_g, -np.inf)
    n_best = np.argmax(score, axis=0)                       # (U,)
    unserved = ~(A > 0).any(axis=(0, 2))
    assign = unserved & feas.any(axis=0)
    uu = np.nonzero(assign)[0]
    A[n_best[uu], uu, h_sel[n_best[uu], uu] - 1] = 1.0
    return x, A


# ---------------------------------------------------------------------------
# Sec. V-D repair — device kernel (pure jnp, one padded window)
# ---------------------------------------------------------------------------

def _dedupe_device(prec_u, A):
    jnp = _jnp()
    N, U, H = A.shape
    score = jnp.where(A > 0, jnp.broadcast_to(prec_u[None], A.shape),
                      -jnp.inf)
    flat = jnp.moveaxis(score, 1, 0).reshape(U, N * H)
    k = jnp.argmax(flat, axis=1)
    served = (flat > -jnp.inf).any(axis=1)
    keep = (jnp.arange(N * H)[None, :] == k[:, None]) & served[:, None]
    return jnp.moveaxis(keep.reshape(U, N, H), 0, 1).astype(jnp.float64)


def _mem_repair_bs(sizes, prec, onehot_mu, R_n, x_n, A_n):
    """The per-BS eviction loop at one base station, as a bounded
    ``lax.while_loop`` (each eviction strictly lowers some cached level,
    so at most M·H iterations reach the fixpoint; under ``vmap`` the
    batched loop runs only as long as the slowest station still
    overflows — finished stations' updates are masked to exact no-ops)."""
    import jax
    jnp = _jnp()

    M, Hp1 = x_n.shape
    H = Hp1 - 1
    hh = jnp.arange(Hp1)
    ms = jnp.arange(M)

    def overflowing(carry):
        x_n, _, it = carry
        used = tree_sum(tree_sum(jnp.where(x_n > 0, sizes, 0.0), -1), -1)
        cached = jnp.argmax(x_n, axis=-1)
        return (used > R_n + _EPS) & (cached > 0).any() & (it < M * H)

    def body(carry):
        x_n, A_n, it = carry
        used = tree_sum(tree_sum(jnp.where(x_n > 0, sizes, 0.0), -1), -1)
        cached = jnp.argmax(x_n, axis=-1)                   # (M,)
        act = (used > R_n + _EPS) & (cached > 0).any()
        # exact routed-user count per (m, h) times the catalog precision —
        # see the NumPy reference for why this matches Σ user precision
        cnt = jnp.einsum("um,uh->mh", onehot_mu,
                         (A_n > 0).astype(jnp.float64))
        hm1 = jnp.maximum(cached - 1, 0)
        benefit = prec[ms, cached] * cnt[ms, hm1]
        m_e = jnp.argmin(jnp.where(cached > 0, benefit, jnp.inf))
        h = cached[m_e]
        slack = R_n - (used - sizes[m_e, h])
        fits = (hh >= 1) & (hh < h) & (sizes[m_e] <= slack + _EPS)
        new_h = jnp.max(jnp.where(fits, hh, 0))
        new_row = (hh == new_h).astype(x_n.dtype)
        x_n = jnp.where(act, x_n.at[m_e].set(new_row), x_n)
        hs = jnp.maximum(h, 1)
        moved = act & (onehot_mu[:, m_e] > 0) & (A_n[:, hs - 1] > 0)
        col = jnp.arange(H)[None, :]
        A_n = jnp.where(moved[:, None] & (col == hs - 1), 0.0, A_n)
        A_n = jnp.where((moved & (new_h > 0))[:, None]
                        & (col == jnp.maximum(new_h, 1) - 1), 1.0, A_n)
        return x_n, A_n, it + 1

    x_n, A_n, _ = jax.lax.while_loop(overflowing, body, (x_n, A_n, 0))
    return x_n, A_n


def repair_device(data, x, A):
    """``repair`` as a pure jnp function of one padded window.

    ``data`` is a :class:`~repro.core.lp.PDHGData`; padded base stations
    (``bs_mask`` 0) and padded users (zero ``onehot_mu`` row) are excluded
    from the re-route step, and their zero routes / capacities make every
    other stage inert for them.  Decisions match the NumPy ``repair`` of
    the unpadded instance exactly (same tree sums, same argmin/argmax
    tie-breaking).
    """
    import jax
    jnp = _jnp()

    sizes, prec, prec_u, T, L, onehot_mu, R, ddl, s_u, bs_mask = (
        jnp.asarray(v) for v in
        (data.sizes, data.prec, data.prec_u, data.T, data.L,
         data.onehot_mu, data.R, data.ddl, data.s_u, data.bs_mask))
    x = jnp.asarray(x)
    A = jnp.asarray(A)
    N, U, H = T.shape

    A = _dedupe_device(prec_u, A)

    x, A = jax.vmap(_mem_repair_bs, in_axes=(None, None, None, 0, 0, 0))(
        sizes, prec, onehot_mu, R, x, A)

    x_sel = jnp.einsum("nmh,um->nuh", x[:, :, 1:], onehot_mu)
    A = jnp.where(x_sel > 0, A, 0.0)

    lat_u = tree_sum(tree_sum(jnp.where(A > 0, T, 0.0), -1), 0)
    load_u = tree_sum(tree_sum(jnp.where(A > 0, L, 0.0), -1), 0)
    bad = (lat_u > ddl + _EPS) | (load_u > s_u + _EPS)
    A = jnp.where(bad[None, :, None], 0.0, A)

    user_mask = tree_sum(onehot_mu, -1) > 0                 # (U,)
    m_u = jnp.argmax(onehot_mu, axis=-1)
    cached_h = jnp.argmax(x, axis=-1)                       # (N, M)
    h_sel = cached_h[:, m_u]                                # (N, U)
    hm1 = jnp.maximum(h_sel - 1, 0)
    T_g = jnp.take_along_axis(T, hm1[:, :, None], axis=-1)[..., 0]
    L_g = jnp.take_along_axis(L, hm1[:, :, None], axis=-1)[..., 0]
    prec_g = prec_u[jnp.arange(U)[None, :], hm1]            # (N, U)
    feas = (h_sel > 0) & (T_g <= ddl[None] + _EPS) \
        & (L_g <= s_u[None] + _EPS) & (bs_mask[:, None] > 0)
    score = jnp.where(feas, prec_g, -jnp.inf)
    n_best = jnp.argmax(score, axis=0)                      # (U,)
    unserved = ~(A > 0).any(axis=(0, 2))
    assign = unserved & feas.any(axis=0) & user_mask
    h_best = jnp.take_along_axis(h_sel, n_best[None, :], axis=0)[0]
    hit_n = jnp.arange(N)[:, None] == n_best[None, :]       # (N, U)
    hit_h = jnp.arange(H)[None, :] == (h_best - 1)[:, None]  # (U, H)
    A = jnp.where(assign[None, :, None] & hit_n[:, :, None]
                  & hit_h[None, :, :], 1.0, A)
    return x, A
