"""Fused PDHG inner loop for the P1-LR window solver.

``repro.core.lp._pdhg_kernel`` — the bit-compared reference — materializes
the full primal/dual state through HBM every iteration: four dense one-hot
einsums, separate strided reductions per dual family, and a dozen
elementwise passes.  This module is the fused production path behind
``solve_lp_pdhg(..., backend="pallas")``:

  * **one step, restructured** (``_fused_step``): the cache↔route coupling
    ``x_a`` and its transpose each become a single real GEMM against the
    one-hot user→model matrix (bit-identical to the reference's gather —
    one-hot rows contract exactly one term per output), the three per-user
    dual reductions run contiguously over a ``(U, N·H)`` relayout, and the
    routing prox folds ``tau_A`` into precomputed ``tau_A·T`` / ``tau_A·L``
    tensors — the same Chambolle–Pock math (docs/algorithms.md Sec. 3),
    ~3x fewer memory passes;
  * **two engines over the same step**: ``engine="scan"`` wraps the step
    in ``lax.scan`` (the XLA path CPU CI measures), ``engine="pallas"``
    keeps the whole state resident in VMEM scratch across a *block* of
    iterations per grid step (``ssm_scan``-style sequential grid), so the
    primal/dual tensors never round-trip HBM between iterations.  Both
    engines execute the identical jnp expressions on the identical state
    layout; what separates them is only XLA's per-compilation FMA
    contraction, so interpret-mode Pallas agrees with the scan engine to
    ≤1e-12 in pure f64 and to f32-ulp noise (~1e-7) through the mixed
    sweep — and the *decisions* derived from either are bit-identical,
    the conformance contract ``tests/test_pdhg_fused.py`` enforces;
  * **mixed precision** (``polish``): the inner sweep runs in float32,
    then the last ``polish`` iterations re-run the same fused step in
    float64 on the carried state.  Decisions downstream (rounding, repair,
    winning trials) are gated on ~1e-15-scale comparisons of *uniforms vs
    thresholds*; the fused path preserves them because (a) the float64
    tail pins every saturated coordinate back to the exact 0/1 the
    reference reaches, and (b) the residual fractional gap is orders of
    magnitude below the rounding-threshold margins, which
    ``tests/harness.py::decision_margin`` certifies per run.

Padding is *stronger* than the reference's inertness: ``tau_A`` carries
both the ``bs_mask`` row mask and a per-user column mask (users with an
all-zero ``onehot_mu`` row), so padded base-station rows AND padded user
columns of ``A`` stay exactly 0.0 through both precision phases.
"""
from __future__ import annotations

import functools

import numpy as np

#: float64 polish-tail length (iterations) of the mixed-precision schedule.
POLISH_TAIL = 64

#: iterations per Pallas grid step (state stays in VMEM within a block).
PALLAS_BLOCK = 8


def _f(v, dtype):
    import jax.numpy as jnp

    return jnp.asarray(v, dtype)


def _constants(data, dtype):
    """Precomputed step-size / operator tensors in the fused (N, H, U)
    layout, all cast to ``dtype``.  Pure function of the PDHGData pytree;
    shared verbatim by the scan and Pallas engines."""
    import jax.numpy as jnp

    sizes = _f(data.sizes, dtype)                      # (M, H+1)
    onehot_mu = _f(data.onehot_mu, dtype)              # (U, M)
    R = _f(data.R, dtype)
    ddl = _f(data.ddl, dtype)
    s_u = _f(data.s_u, dtype)
    bs_mask = _f(data.bs_mask, dtype)
    T = jnp.swapaxes(_f(data.T, dtype), 1, 2)          # (N, H, U)
    L = jnp.swapaxes(_f(data.L, dtype), 1, 2)
    prec_hu = jnp.swapaxes(_f(data.prec_u, dtype), 0, 1)   # (H, U)
    N, H, U = T.shape
    M = sizes.shape[0]
    NH = N * H

    u_mask = onehot_mu.sum(-1)                         # 0.0 on padded users
    T_t = T.reshape(NH, U).T                           # (U, NH) contiguous
    L_t = L.reshape(NH, U).T

    # Pock–Chambolle diagonal step sizes (alpha = 1), exactly the
    # reference's row/column sums
    sig_eq = jnp.full((N, M), 1.0, dtype) / jnp.maximum(
        jnp.full((N, M), float(H + 1), dtype), 1e-9)
    sig_mem = 1.0 / jnp.maximum(jnp.ones((N,), dtype) * sizes.sum(), 1e-9)
    sig_route = 1.0 / jnp.maximum(
        jnp.ones((U,), dtype) * bs_mask.sum() * H, 1e-9)
    sig_lat = 1.0 / jnp.maximum(T.sum(axis=(0, 1)), 1e-9)
    sig_load = 1.0 / jnp.maximum(L.sum(axis=(0, 1)), 1e-9)
    sig_ax = 0.5  # Python float: weak-typed, exact in both precisions

    cx = jnp.ones((N, M, H + 1), dtype) + sizes[None]
    cx = cx.at[:, :, 1:].add(onehot_mu.sum(0)[None, :, None])
    tau_x = 1.0 / jnp.maximum(cx, 1e-9)
    # row mask (padded BSs) AND column mask (padded users): masked entries
    # get a zero step, so A stays exactly 0.0 there for the whole solve
    tau_A = (bs_mask[:, None, None] * u_mask[None, None, :]) \
        / jnp.maximum(2.0 + T + L, 1e-9)
    tau_prec = tau_A * prec_hu[None]                   # objective gradient
    tAT = tau_A * T                                    # folded prox tensors
    tAL = tau_A * L

    # bs_mask / u_mask / prec_hu are read only by the diagnostics sampler
    # (not listed in the Pallas const_keys — _fused_step never touches them)
    return dict(sizes=sizes, onehot_mu=onehot_mu, R=R, ddl=ddl, s_u=s_u,
                T=T, L=L, T_t=T_t, L_t=L_t,
                sig_eq=sig_eq, sig_mem=sig_mem, sig_route=sig_route,
                sig_lat=sig_lat, sig_load=sig_load, sig_ax=sig_ax,
                tau_x=tau_x, tau_A=tau_A, tau_prec=tau_prec,
                tAT=tAT, tAL=tAL, bs_mask=bs_mask, u_mask=u_mask,
                prec_hu=prec_hu, dims=(N, M, H, U))


def _apply_K(c, x, A):
    """The forward operator K in the fused layout: per-family residuals
    of (x (N,M,H+1), A (N,H,U))."""
    import jax
    import jax.numpy as jnp

    N, M, H, U = c["dims"]
    y_eq = x.sum(-1) - 1.0                                       # (N, M)
    y_mem = (x * c["sizes"][None]).sum((-2, -1)) - c["R"]        # (N,)
    A_t = A.reshape(N * H, U).T                                  # (U, NH)
    y_route = A_t.sum(-1) - 1.0                                  # (U,)
    y_lat = (A_t * c["T_t"]).sum(-1) - c["ddl"]
    y_load = (A_t * c["L_t"]).sum(-1) - c["s_u"]
    xg = jnp.swapaxes(x[:, :, 1:], 1, 2)                         # (N, H, M)
    # one-hot GEMM over M: exactly one term per output, so bit-identical
    # to the gather xg[:, :, m_u] it replaces — and faster, M is tiny and
    # the contraction vectorizes where the gather's index plumbing won't
    xa = jax.lax.dot_general(
        xg, c["onehot_mu"], (((2,), (1,)), ((), ())),
        preferred_element_type=x.dtype)                          # (N, H, U)
    return y_eq, y_mem, y_route, y_lat, y_load, A - xa


def _init_state(data, dtype):
    """The reference's cold start (x = 1/(H+1), A = 0, y = K applied
    once... the reference initializes y = 0 and we match it exactly:
    zeros_like of one K application)."""
    import jax.numpy as jnp

    c = _constants(data, dtype)
    N, M, H, U = c["dims"]
    x = jnp.full((N, M, H + 1), 1.0 / (H + 1), dtype)
    A = jnp.zeros((N, H, U), dtype)
    y = tuple(jnp.zeros_like(v) for v in _apply_K(c, x, A))
    return c, (x, A) + y


def _fused_step(c, state):
    """One PDHG iteration (prox-primal → over-relax → dual ascent) on the
    fused state layout.  This is the single source of truth both engines
    execute — identical expressions, identical float results."""
    import jax
    import jax.numpy as jnp

    x, A, y_eq, y_mem, y_route, y_lat, y_load, y_ax = state
    dtype = x.dtype
    N, M, H, U = c["dims"]

    # KT(y) for x, as one broadcast sum + one real GEMM over users
    gx = y_eq[:, :, None] + y_mem[:, None, None] * c["sizes"][None]
    gx_sub = jax.lax.dot_general(
        y_ax, c["onehot_mu"], (((2,), (0,)), ((), ())),
        preferred_element_type=dtype)                            # (N, H, M)
    gx = gx.at[:, :, 1:].add(-jnp.swapaxes(gx_sub, 1, 2))
    x_new = jnp.clip(x - c["tau_x"] * gx, 0.0, 1.0)
    # routing prox with tau_A folded into the operator tensors; tau_prec
    # carries the (negated) objective gradient
    A_new = jnp.clip(
        A - c["tau_A"] * (y_route[None, None, :] + y_ax)
        - c["tAT"] * y_lat[None, None, :] - c["tAL"] * y_load[None, None, :]
        + c["tau_prec"], 0.0, 1.0)
    xb = 2 * x_new - x                                           # over-relax
    Ab = 2 * A_new - A
    k_eq, k_mem, k_route, k_lat, k_load, k_ax = _apply_K(c, xb, Ab)
    return (x_new, A_new,
            y_eq + c["sig_eq"] * k_eq,
            jnp.maximum(y_mem + c["sig_mem"] * k_mem, 0.0),
            jnp.maximum(y_route + c["sig_route"] * k_route, 0.0),
            jnp.maximum(y_lat + c["sig_lat"] * k_lat, 0.0),
            jnp.maximum(y_load + c["sig_load"] * k_load, 0.0),
            jnp.maximum(y_ax + c["sig_ax"] * k_ax, 0.0))


def _cast_state(state, dtype):
    import jax.numpy as jnp

    return tuple(jnp.asarray(v, dtype) for v in state)


def _diag_sample(c, state):
    """(primal residual, dual displacement, objective) of the current
    fused state, cast to float64 — the same masked residual contract as
    the reference tap in ``repro.core.lp._pdhg_kernel``, evaluated in
    the (N, H, U) layout.  Pure: never perturbs the carried state."""
    import jax.numpy as jnp

    f64 = _f64()
    x, A = state[0], state[1]
    y_eq, y_mem, y_route, _, _, y_ax = _apply_K(c, x, A)
    bs = c["bs_mask"] > 0
    um = c["u_mask"] > 0
    r_eq = jnp.max(jnp.where(bs[:, None], jnp.abs(y_eq), 0.0))
    r_mem = jnp.max(jnp.where(bs, y_mem, -jnp.inf)) \
        / jnp.maximum(c["R"].max(), 1e-9)
    r_route = jnp.max(jnp.where(um, y_route, -jnp.inf))
    primal = jnp.maximum(
        jnp.maximum(jnp.maximum(r_eq, r_mem),
                    jnp.maximum(r_route, jnp.max(y_ax))), 0.0)
    x2, A2 = _fused_step(c, state)[:2]
    dual = jnp.maximum(jnp.abs(x2 - x).max(), jnp.abs(A2 - A).max())
    obj = (jnp.asarray(A, f64) * jnp.asarray(c["prec_hu"], f64)[None]).sum()
    return jnp.asarray(primal, f64), jnp.asarray(dual, f64), obj


def _f64():
    """float64, degraded to float32 when x64 is disabled (matching what
    the reference kernel would silently compute under the same config)."""
    import jax
    import jax.numpy as jnp

    return jax.dtypes.canonicalize_dtype(jnp.float64)


def _finalize(state, dims):
    """Fused state → the reference's (x (N,M,H+1), A (N,U,H)) float64."""
    import jax.numpy as jnp

    N, M, H, U = dims
    x, A = state[0], state[1]
    return (jnp.asarray(x, _f64()),
            jnp.swapaxes(jnp.asarray(A, _f64()), 1, 2))


# ---------------------------------------------------------------------------
# engine: lax.scan (the XLA realization; production path off-TPU)
# ---------------------------------------------------------------------------

def _scan_phase(data, state, iters, dtype):
    import jax

    c = _constants(data, dtype)

    def body(carry, _):
        return _fused_step(c, carry), None

    state, _ = jax.lax.scan(body, _cast_state(state, dtype), None,
                            length=int(iters))
    return state


# ---------------------------------------------------------------------------
# engine: Pallas (state resident in VMEM across an iteration block)
# ---------------------------------------------------------------------------

def _pallas_phase(data, state, iters, dtype, block=PALLAS_BLOCK,
                  interpret=None):
    """``iters`` fused iterations as Pallas grid steps of ``block``
    iterations each.  The eight state tensors live in VMEM scratch for the
    whole call: loaded from the inputs at grid step 0, advanced in-place
    ``block`` steps per grid step, and emitted on the last step — one
    kernel invocation per iteration block, zero HBM round-trips inside.

    The kernel body executes ``_fused_step`` verbatim; output matches
    ``_scan_phase`` at the same dtype up to XLA FMA contraction (dtype
    ulp per step, asserted in interpret mode by
    tests/test_pdhg_fused.py)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    iters = int(iters)
    if iters <= 0:
        return _cast_state(state, dtype)
    block = max(1, min(int(block), iters))
    n_blocks, rem = divmod(iters, block)

    c = _constants(data, dtype)
    state = _cast_state(state, dtype)
    shapes = [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in state]
    n_state = len(state)
    # constants the step reads, as kernel inputs (whole-array blocks)
    const_keys = ("sizes", "onehot_mu", "R", "ddl", "s_u", "T_t", "L_t",
                  "sig_eq", "sig_mem", "sig_route", "sig_lat",
                  "sig_load", "tau_x", "tau_A", "tau_prec", "tAT", "tAL")
    consts = [c[k] for k in const_keys]

    def run(state, n_steps, n_blk):
        def kernel(*refs):
            in_refs = refs[:n_state + len(consts)]
            out_refs = refs[n_state + len(consts):
                            n_state + len(consts) + n_state]
            scratch = refs[n_state + len(consts) + n_state:]
            cc = {k: v[...] for k, v in zip(const_keys, in_refs[n_state:])}
            cc["sig_ax"] = c["sig_ax"]
            cc["dims"] = c["dims"]

            j = pl.program_id(0)

            @pl.when(j == 0)
            def _load():
                for s, r in zip(scratch, in_refs[:n_state]):
                    s[...] = r[...]

            cur = tuple(s[...] for s in scratch)
            for _ in range(n_steps):
                cur = _fused_step(cc, cur)
            for s, v in zip(scratch, cur):
                s[...] = v

            @pl.when(j == n_blk - 1)
            def _emit():
                for o, s in zip(out_refs, scratch):
                    o[...] = s[...]

        return pl.pallas_call(
            kernel,
            grid=(n_blk,),
            in_specs=[pl.BlockSpec(v.shape, lambda j, sh=v.shape:
                                   (0,) * len(sh))
                      for v in list(state) + consts],
            out_specs=[pl.BlockSpec(s.shape, lambda j, sh=s.shape:
                                    (0,) * len(sh))
                       for s in shapes],
            out_shape=shapes,
            scratch_shapes=[_vmem(v.shape, v.dtype) for v in state],
            interpret=interpret,
        )(*state, *consts)

    if n_blocks:
        state = tuple(run(state, block, n_blocks))
    if rem:
        state = tuple(run(state, rem, 1))
    return state


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def pdhg_fused(data, iters: int, polish: int = POLISH_TAIL,
               engine: str = "auto", block: int = PALLAS_BLOCK,
               interpret=None, diagnostics: bool = False,
               diag_stride: int = 50):
    """The fused mixed-precision PDHG solve of one (padded) window.

    Runs ``iters - polish`` float32 sweep iterations then ``polish``
    float64 iterations of the same fused step, and returns float64
    ``(x (N,M,H+1), A (N,U,H))`` in the reference layout.  ``engine``:

      * ``"auto"``  — Pallas on TPU, ``lax.scan`` elsewhere (the fast
        realization per platform; both run the identical step);
      * ``"scan"``  — force the XLA scan realization;
      * ``"pallas"`` — force the Pallas kernel (interpret mode is
        auto-selected off-TPU, or pass ``interpret=`` explicitly).

    ``diagnostics=True`` re-expresses each precision phase as the same
    phase calls segmented at ``diag_stride`` boundaries (pure function
    composition — the scan engine composes bit-exactly, which
    tests/test_obs.py asserts; the Pallas engine is exact whenever
    ``diag_stride`` is a multiple of ``block``, else remainder blocks
    compile separately and may regroup FMAs at dtype-ulp scale) and
    returns ``(x, A, diag)`` where ``diag`` carries float64 residual /
    objective curves plus ``polish_delta``, the max coordinate movement
    of the f32→f64 polish tail.

    Traceable (jit/vmap-safe) for fixed static ``iters``/``polish``.
    """
    import jax

    if engine == "auto":
        engine = "pallas" if jax.devices()[0].platform == "tpu" else "scan"
    if engine not in ("scan", "pallas"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "one of ('auto', 'scan', 'pallas')")
    import jax.numpy as jnp

    iters = int(iters)
    polish = max(0, min(int(polish), iters))
    sweep = iters - polish

    phase = _scan_phase if engine == "scan" else functools.partial(
        _pallas_phase, block=block, interpret=interpret)

    f64 = _f64()
    if not diagnostics:
        if sweep:
            _, state = _init_state(data, jnp.float32)
            state = phase(data, state, sweep, jnp.float32)
        else:
            _, state = _init_state(data, f64)
        state = phase(data, state, polish, f64)
        N, M, H, U = _constants(data, f64)["dims"]
        return _finalize(state, (N, M, H, U))

    stride = max(1, int(diag_stride))
    c64 = _constants(data, f64)
    samples = []  # (sampled iteration, primal, dual, obj)
    if sweep:
        c32 = _constants(data, jnp.float32)
        _, state = _init_state(data, jnp.float32)
        n1, r1 = divmod(sweep, stride)
        for s in range(n1):
            state = phase(data, state, stride, jnp.float32)
            samples.append(((s + 1) * stride,) + _diag_sample(c32, state))
        if r1:
            state = phase(data, state, r1, jnp.float32)
            samples.append((sweep,) + _diag_sample(c32, state))
    else:
        _, state = _init_state(data, f64)
    x_sw, A_sw = _finalize(state, c64["dims"])
    n2, r2 = divmod(polish, stride)
    for s in range(n2):
        state = phase(data, state, stride, f64)
        samples.append((sweep + (s + 1) * stride,) + _diag_sample(c64, state))
    # unconditional, mirroring the diag-off path: a zero-length phase
    # call still applies the f64 cast
    state = phase(data, state, r2, f64)
    if r2 or not samples:
        samples.append((iters,) + _diag_sample(c64, state))
    x, A = _finalize(state, c64["dims"])
    polish_delta = jnp.maximum(jnp.abs(x - x_sw).max(),
                               jnp.abs(A - A_sw).max())
    diag = {"iters": jnp.asarray([s[0] for s in samples], jnp.int32),
            "primal_res": jnp.stack([s[1] for s in samples]),
            "dual_res": jnp.stack([s[2] for s in samples]),
            "obj": jnp.stack([s[3] for s in samples]),
            "polish_delta": polish_delta}
    return x, A, diag


def fused_vs_reference_gap(data, iters: int, polish: int = POLISH_TAIL):
    """Max abs fractional gap between the fused scan solve and the f64
    reference — the number the bench reports next to the decision gap."""
    import jax.numpy as jnp

    from repro.core import lp as LP

    x_r, A_r = LP._pdhg_kernel(data, iters)
    x_f, A_f = pdhg_fused(data, iters, polish=polish, engine="scan")
    return float(jnp.maximum(jnp.abs(x_f - x_r).max(),
                             jnp.abs(A_f - A_r).max()))
