"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """q: (B, H, S, E); k, v: (B, K, T, E)."""
    B, H, S, E = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, S, E).astype(jnp.float32)
    s = jnp.einsum("bkgse,bkte->bkgst", qg, k.astype(jnp.float32)) * E ** -0.5
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(T)
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = kpos[None, :] <= qpos[:, None]
    if window:
        ok = ok & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bkte->bkgse", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, E).astype(q.dtype)


def decode_attention_ref(q, k, v, valid_len):
    """q: (B, H, E); k, v: (B, T, K, E)."""
    B, H, E = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, E).astype(jnp.float32)
    s = jnp.einsum("bkge,btke->bkgt", qg, k.astype(jnp.float32)) * E ** -0.5
    ok = jnp.arange(T) < valid_len
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btke->bkge", p, v.astype(jnp.float32))
    return o.reshape(B, H, E).astype(q.dtype)


def ssm_chunk_scan_ref(xbar, Bc, Cc, cum):
    """Sequential-scan oracle. xbar: (B,H,NC,c,P); Bc/Cc: (B,NC,c,N);
    cum: (B,H,NC,c) inclusive log-decay cumsum (per chunk)."""
    B, H, NC, c, P = xbar.shape
    N = Bc.shape[-1]

    def per_bh(xb, cumh, Bb, Cb):
        # xb (NC,c,P), cumh (NC,c), Bb/Cb (NC,c,N)
        def chunk(state, inp):
            x, cu, Bi, Ci = inp
            seg = cu[:, None] - cu[None, :]
            L = jnp.where(jnp.tril(jnp.ones((c, c), bool)), jnp.exp(seg), 0.0)
            CB = Ci @ Bi.T
            y_intra = (CB * L) @ x
            y_inter = jnp.exp(cu)[:, None] * (Ci @ state.T)
            total = cu[-1]
            Sc = (x * jnp.exp(total - cu)[:, None]).T @ Bi
            state = jnp.exp(total) * state + Sc
            return state, y_intra + y_inter

        st0 = jnp.zeros((P, N), jnp.float32)
        st, ys = jax.lax.scan(chunk, st0, (xb, cumh, Bb, Cb))
        return ys, st

    f = jax.vmap(jax.vmap(per_bh, in_axes=(0, 0, None, None)),
                 in_axes=(0, 0, 0, 0))
    return f(xbar, cum, Bc, Cc)


def moe_gmm_ref(x, w):
    """x: (E, C, D); w: (E, D, F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def early_exit_head_ref(h, norm_w, head_w, eps=1e-5):
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=1, keepdims=True)
    hn = hf * jax.lax.rsqrt(var + eps) * norm_w.astype(jnp.float32)[None]
    logits = hn @ head_w.astype(jnp.float32)
    tok = jnp.argmax(logits, axis=1).astype(jnp.int32)
    p = jax.nn.softmax(logits, axis=1)
    return tok, jnp.max(p, axis=1)
