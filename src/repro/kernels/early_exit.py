"""Pallas TPU fused early-exit head — the paper-specific kernel.

A dynamic-DNN serving stack evaluates an ExtNet head per request to get the
predicted token AND a confidence signal (max softmax probability, used by
exit policies / the precision ladder).  Done naively this materializes the
(T, V) logits to HBM (hundreds of MB per batch).  This kernel fuses

    RMSNorm(h) @ W  ->  online (max, argmax, sum-exp) over vocab tiles

so only (T,) token ids and (T,) confidences ever leave VMEM — turning a
V-wide memory-bound pass into a single streaming reduction.

Grid (nt, nv): vocab tiles iterate sequentially per token tile; scratch
carries the running max/argmax/sumexp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(h_ref, w_ref, head_ref, tok_ref, conf_ref, m_s, l_s, a_s, *,
            bt, bv, nv, eps):
    jv = pl.program_id(1)

    @pl.when(jv == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        a_s[...] = jnp.zeros_like(a_s)

    h = h_ref[...].astype(jnp.float32)                   # (bt, D)
    var = jnp.mean(h * h, axis=1, keepdims=True)
    hn = h * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None]
    logits = jax.lax.dot_general(hn, head_ref[...].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (bt,bv)

    blk_max = jnp.max(logits, axis=1)
    blk_arg = jnp.argmax(logits, axis=1).astype(jnp.int32) + jv * bv
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, blk_max)
    l_s[...] = jnp.exp(m_prev - m_new) * l_s[...] + \
        jnp.sum(jnp.exp(logits - m_new[:, None]), axis=1)
    a_s[...] = jnp.where(blk_max > m_prev, blk_arg, a_s[...])
    m_s[...] = m_new

    @pl.when(jv == nv - 1)
    def _finalize():
        tok_ref[...] = a_s[...]
        conf_ref[...] = (1.0 / jnp.maximum(l_s[...], 1e-30)).astype(
            conf_ref.dtype)          # p_max = exp(m - logsumexp) = 1/l


def early_exit_head(h, norm_w, head_w, *, block_t=256, block_v=1024,
                    eps=1e-5, interpret=None):
    """h: (T, D); norm_w: (D,); head_w: (D, V) ->
    (token_ids (T,) int32, p_max (T,) float32)."""
    T, D = h.shape
    V = head_w.shape[1]
    bt = min(block_t, T)
    bv = min(block_v, V)
    assert T % bt == 0 and V % bv == 0, (T, bt, V, bv)
    nt, nv = T // bt, V // bv
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    kern = functools.partial(_kernel, bt=bt, bv=bv, nv=nv, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt, D), lambda it, jv: (it, 0)),
            pl.BlockSpec((D,), lambda it, jv: (0,)),
            pl.BlockSpec((D, bv), lambda it, jv: (0, jv)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda it, jv: (it,)),
            pl.BlockSpec((bt,), lambda it, jv: (it,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.float32),
            pltpu.VMEM((bt,), jnp.int32),
        ],
        interpret=interpret,
    )(h, norm_w, head_w)
