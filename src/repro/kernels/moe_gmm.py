"""Pallas TPU grouped expert matmul (MoE "gmm").

Computes out[e] = x[e] @ w[e] for every expert in one kernel: the dispatched
token buffers (E, C, D) never round-trip HBM between experts, and tiles are
MXU-aligned.  Grid (E, nc, nf, nd) — the contraction dim iterates minor so
the f32 accumulator tile stays in VMEM scratch across D-blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc, *, nd):
    jd = pl.program_id(3)

    @pl.when(jd == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(jd == nd - 1)
    def _emit():
        o_ref[0] = acc[...].astype(o_ref.dtype)


def moe_gmm(x, w, *, block_c=128, block_f=128, block_d=512, interpret=None):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[2]
    bc = min(block_c, C)
    bf = min(block_f, F)
    bd = min(block_d, D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0, (C, F, D)
    nc, nf, nd = C // bc, F // bf, D // bd
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    kern = functools.partial(_kernel, nd=nd)
    return pl.pallas_call(
        kern,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, jd: (e, ic, jd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, jd: (e, jd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, jf, jd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
