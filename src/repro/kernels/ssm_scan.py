"""Pallas TPU Mamba2 (SSD) chunked scan.

Grid (B, H, NC): the chunk dim iterates sequentially, carrying the (P, N)
state in VMEM scratch — HBM sees each input exactly once (the CUDA
selective-scan's shared-memory recurrence re-thought as a grid-carried VMEM
resident).  All chunk-local compute is three MXU matmuls:
  CB = C·Bᵀ (c×c), y_intra = (CB∘L)·x̄, state update/readout (c×N)·(N×P).
Chunk c = 128 aligns every matmul dim to the 128-lane MXU.

Layouts: xbar (B, H, NC, c, P) f32, Bc/Cc (B, NC, c, N) f32 (shared across
heads), cum (B, H, NC, c) f32 (inclusive cumsum of log-decay).
Output: y (B, H, NC, c, P) f32 (+ final state (B, H, P, N)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xb_ref, B_ref, C_ref, cum_ref, y_ref, st_ref, state, *, c, nc):
    jc = pl.program_id(2)

    @pl.when(jc == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    xb = xb_ref[0, 0, 0]                                  # (c, P)
    Bc = B_ref[0, 0]                                      # (c, N)
    Cc = C_ref[0, 0]
    cum = cum_ref[0, 0, 0]                                # (c,)

    # intra-chunk
    seg = cum[:, None] - cum[None, :]                     # (c, c) log decay
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot_general(CB * L, xb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_inter = exp(cum) * C @ state^T ; state (P, N)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cc, state[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (c, P)

    y_ref[0, 0, 0] = y_intra + y_inter

    # state update: S = exp(total) * S + sum_j decay_end_j * xb_j B_j^T
    total = cum[c - 1]
    decay_end = jnp.exp(total - cum)                      # (c,)
    Sc = jax.lax.dot_general(xb * decay_end[:, None], Bc,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    state[...] = jnp.exp(total) * state[...] + Sc

    @pl.when(jc == nc - 1)
    def _emit_state():
        st_ref[0, 0] = state[...]


def ssm_chunk_scan(xbar, Bc, Cc, cum, *, interpret=None):
    """xbar: (B,H,NC,c,P); Bc/Cc: (B,NC,c,N); cum: (B,H,NC,c).

    Returns (y (B,H,NC,c,P), final_state (B,H,P,N)), all float32."""
    B, H, NC, c, P = xbar.shape
    N = Bc.shape[-1]
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    kern = functools.partial(_kernel, c=c, nc=NC)
    y, st = pl.pallas_call(
        kern,
        grid=(B, H, NC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, c, P), lambda b, h, jc: (b, h, jc, 0, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, jc: (b, jc, 0, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, jc: (b, jc, 0, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda b, h, jc: (b, h, jc, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, c, P), lambda b, h, jc: (b, h, jc, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, jc: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, NC, c, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xbar, Bc, Cc, cum)
    return y, st
