"""Pallas TPU flash attention (prefill): online softmax over KV blocks.

Grid (B, H, nq, nk); the last grid dim iterates sequentially on TPU so the
(acc, m, l) scratch persists across KV blocks of one query tile.  Tiles are
MXU-aligned (block_q × block_k ≥ 128×128, E a multiple of 8/128 lanes), all
accumulation f32 in VMEM.  Causal tiles above the diagonal are skipped with
``pl.when`` (the grid-level causal skip a fused XLA softmax cannot do).

GQA layouts: q (B, H, S, E); k, v (B, K, T, E) with H = G·K — the kv-head
index map (h -> h // G) reads each KV tile once per query-head group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            causal, window, q_offset, bq, bk, nk, scale):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    q_start = q_offset + iq * bq
    k_start = jk * bk

    @pl.when(jk == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    # grid-level tile skip: dead tiles (fully above the causal diagonal or
    # fully outside the sliding window) never touch the MXU
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, E)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, E)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = kpos <= qpos
        if window:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = corr * l_s[...] + jnp.sum(p, axis=1)
        acc[...] = corr[:, None] * acc[...] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] /
                       jnp.maximum(l_s[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_k=128, interpret=None):
    """q: (B, H, S, E); k, v: (B, K, T, E) -> (B, H, S, E)."""
    B, H, S, E = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    kern = functools.partial(
        _kernel, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, nk=nk, scale=E ** -0.5)

    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, E), lambda b, h, iq, jk: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, E), lambda b, h, iq, jk: (b, h // G, jk, 0)),
            pl.BlockSpec((1, 1, bk, E), lambda b, h, iq, jk: (b, h // G, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, E), lambda b, h, iq, jk: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, E), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, E), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
