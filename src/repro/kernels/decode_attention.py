"""Pallas TPU decode attention: one query token vs. a long KV cache.

Memory-bound by design: the KV cache streams HBM->VMEM in S-blocks while the
(H, E) query tile and f32 accumulators stay resident in VMEM.  GQA is kept
honest — each query head group reduces against its own kv head, no
materialized head repetition.  The valid length (current decode position,
or the full ring for wrapped SWA caches) arrives as a scalar-prefetch
argument in SMEM.

Layouts: q (B, H, E); k, v (B, T, K, E); out (B, H, E).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            bk, nk, G, scale):
    jk = pl.program_id(1)
    k_start = jk * bk

    @pl.when(jk == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    valid_len = len_ref[0]

    @pl.when(k_start < valid_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (H, E)
        k = k_ref[0].astype(jnp.float32)                    # (bk, K, E)
        v = v_ref[0].astype(jnp.float32)
        H, E = q.shape
        K = k.shape[1]
        qg = q.reshape(K, G, E)
        # scores (K, G, bk)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (K, G, bk), 2)
        s = jnp.where(kpos < valid_len, s, NEG_INF)

        m_prev = m_s[...]                                   # (K, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[:, :, None])
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = corr * l_s[...] + jnp.sum(p, axis=2)
        # pv: (K, G, E)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                 preferred_element_type=jnp.float32)
        acc[...] = corr[:, :, None] * acc[...] + pv
        m_s[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        K, G, E = acc.shape
        out = acc[...] / jnp.maximum(l_s[...], 1e-30)[:, :, None]
        o_ref[0] = out.reshape(K * G, E).astype(o_ref.dtype)


def decode_attention(q, k, v, valid_len, *, block_k=512, interpret=None):
    """q: (B, H, E); k, v: (B, T, K, E); valid_len: () int32 -> (B, H, E)."""
    B, H, E = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bk = min(block_k, T)
    assert T % bk == 0
    nk = T // bk
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    kern = functools.partial(_kernel, bk=bk, nk=nk, G=G, scale=E ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, H, E), lambda b, jk, L: (b, 0, 0)),
            pl.BlockSpec((1, bk, K, E), lambda b, jk, L: (b, jk, 0, 0)),
            pl.BlockSpec((1, bk, K, E), lambda b, jk, L: (b, jk, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, E), lambda b, jk, L: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G, E), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, E), q.dtype),
        interpret=interpret,
    )(jnp.asarray(valid_len, jnp.int32).reshape(1), q, k, v)
