"""Jitted public wrappers for the Pallas kernels.

Each op auto-selects interpret mode off-TPU (validated against ref.py) and
picks hardware-aligned block sizes.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import early_exit as _ee
from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import ssm_scan as _ssm


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, causal=True, window=0, q_offset=0,
                    block_q=128, block_k=128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k)


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, valid_len, block_k=512):
    return _dec.decode_attention(q, k, v, valid_len, block_k=block_k)


@jax.jit
def ssm_chunk_scan(xbar, Bc, Cc, cum):
    return _ssm.ssm_chunk_scan(xbar, Bc, Cc, cum)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v"))
def early_exit_head(h, norm_w, head_w, block_t=256, block_v=1024):
    return _ee.early_exit_head(h, norm_w, head_w, block_t=block_t,
                               block_v=block_v)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d"))
def moe_gmm(x, w, block_c=128, block_f=128, block_d=512):
    return _gmm.moe_gmm(x, w, block_c=block_c, block_f=block_f,
                        block_d=block_d)
