"""Named trace families for sweeps.

``make_trace("flash_crowd", cfg, n_slots, seed=3, intensity=0.9)`` builds a
replayable workload for a scenario config; ``default_trace`` reproduces the
legacy ``OnlineSim`` workload (popularity drift when
``ocfg.pop_change_every`` is set, stationary Zipf otherwise) so the
refactored online driver is a drop-in.
"""
from __future__ import annotations

from repro.traces import generators as G
from repro.traces.generators import Trace

REGISTRY = {
    "stationary": G.stationary,
    "drift": G.drift,
    "diurnal": G.diurnal,
    "flash_crowd": G.flash_crowd,
    "mmpp": G.mmpp,
    "mobility": G.mobility,
}


def available():
    return sorted(REGISTRY)


def make_trace(name: str, cfg, n_slots: int, seed: int = 0, **kw) -> Trace:
    """Build trace ``name`` for a :class:`~repro.mec.scenario.MECConfig`.

    ``cfg`` only needs ``n_users``/``n_bs``/``n_models``/``zipf``
    attributes; extra ``kw`` are family parameters (see
    ``repro.traces.generators``).
    """
    try:
        gen = REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown trace family {name!r}; available: {available()}")
    kw.setdefault("zipf", cfg.zipf)
    return gen(seed, n_slots, cfg.n_users, cfg.n_bs, cfg.n_models, **kw)


def default_trace(cfg, ocfg, seed: int | None = None) -> Trace:
    """The legacy online workload: drift when the config asks for
    popularity changes, stationary Zipf otherwise.  Seeded from
    ``cfg.seed`` so every policy sharing a config replays one stream."""
    seed = cfg.seed if seed is None else seed
    if getattr(ocfg, "pop_change_every", 0):
        return make_trace("drift", cfg, ocfg.n_slots, seed=seed,
                          change_every=ocfg.pop_change_every,
                          warmup=ocfg.pop_warmup)
    return make_trace("stationary", cfg, ocfg.n_slots, seed=seed)
