"""Named trace and workload families for sweeps.

``make_trace("flash_crowd", cfg, n_slots, seed=3, intensity=0.9)`` builds a
replayable per-user workload for a scenario config; ``default_trace``
reproduces the legacy ``OnlineSim`` workload (popularity drift when
``ocfg.pop_change_every`` is set, stationary Zipf otherwise) so the
refactored online driver is a drop-in.

``make_workload`` is the aggregated-demand counterpart: every per-user
family is available as an exact :class:`~repro.traces.workloads
.DenseWorkload`, plus the streaming families that never materialize a
``(T, U)`` tensor — ``"poisson_zipf"`` (sampled Poisson + Zipf arrivals,
the million-user family) and ``"request_log"`` (exact replay of measured
``(slot, home, model)`` request-log arrays).
"""
from __future__ import annotations

from repro.traces import generators as G
from repro.traces.generators import Trace
from repro.traces.workloads import (DenseWorkload, PoissonWorkload,
                                    TraceLogWorkload, Workload)

REGISTRY = {
    "stationary": G.stationary,
    "drift": G.drift,
    "diurnal": G.diurnal,
    "flash_crowd": G.flash_crowd,
    "mmpp": G.mmpp,
    "mobility": G.mobility,
}

#: workload families beyond the per-user traces: family -> kind
STREAMING = {
    "poisson_zipf": "sampled Poisson + Zipf arrivals (streaming, O(chunk))",
    "request_log": "exact replay of (slot, home, model) request-log arrays",
}


def available():
    return sorted(REGISTRY)


def available_workloads():
    """Every name ``make_workload`` accepts: the per-user trace families
    (exact aggregation) plus the streaming families."""
    return sorted(REGISTRY) + sorted(STREAMING)


def make_trace(name: str, cfg, n_slots: int, seed: int = 0, **kw) -> Trace:
    """Build trace ``name`` for a :class:`~repro.mec.scenario.MECConfig`.

    ``cfg`` only needs ``n_users``/``n_bs``/``n_models``/``zipf``
    attributes; extra ``kw`` are family parameters (see
    ``repro.traces.generators``).
    """
    try:
        gen = REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown trace family {name!r}; available: {available()}")
    kw.setdefault("zipf", cfg.zipf)
    tr = gen(seed, n_slots, cfg.n_users, cfg.n_bs, cfg.n_models, **kw)
    tr.meta.setdefault("family", name)
    return tr


def make_workload(name: str, cfg, n_slots: int, seed: int = 0,
                  **kw) -> Workload:
    """Build workload ``name`` for a config as aggregated demand.

    Per-user families come back as exact :class:`DenseWorkload`\\ s (their
    ``kw`` are the trace family's parameters).  ``"poisson_zipf"`` takes
    ``users_per_slot`` (default ``cfg.n_users``) and ``zipf``/
    ``chunk_slots``; ``"request_log"`` takes ``slot``/``home``/``model``
    arrays (one entry per request).
    """
    if name in REGISTRY:
        return DenseWorkload(make_trace(name, cfg, n_slots, seed=seed, **kw),
                             cfg.n_bs, cfg.n_models)
    if name == "poisson_zipf":
        kw.setdefault("zipf", cfg.zipf)
        kw.setdefault("users_per_slot", cfg.n_users)
        return PoissonWorkload(n_slots, cfg.n_bs, cfg.n_models,
                               seed=seed, **kw)
    if name == "request_log":
        return TraceLogWorkload(kw.pop("slot"), kw.pop("home"),
                                kw.pop("model"), n_slots=n_slots,
                                n_bs=cfg.n_bs, n_models=cfg.n_models, **kw)
    raise KeyError(
        f"unknown workload family {name!r}; available: "
        f"{available_workloads()}")


def default_trace(cfg, ocfg, seed: int | None = None) -> Trace:
    """The legacy online workload: drift when the config asks for
    popularity changes, stationary Zipf otherwise.  Seeded from
    ``cfg.seed`` so every policy sharing a config replays one stream."""
    seed = cfg.seed if seed is None else seed
    if getattr(ocfg, "pop_change_every", 0):
        return make_trace("drift", cfg, ocfg.n_slots, seed=seed,
                          change_every=ocfg.pop_change_every,
                          warmup=ocfg.pop_warmup)
    return make_trace("stationary", cfg, ocfg.n_slots, seed=seed)


def default_workload(cfg, ocfg, seed: int | None = None) -> Workload:
    """The legacy workload wrapped as aggregated demand (exact)."""
    return DenseWorkload(default_trace(cfg, ocfg, seed=seed),
                         cfg.n_bs, cfg.n_models)
