"""Device-resident online engine: CoCaR-OL (Alg. 2) and the online
baselines as one ``jax.lax.scan`` over slots.

The NumPy ``repro.core.online.OnlineSim`` runs one (scenario, policy) at a
time in Python — a per-slot state machine.  This module re-implements the
same math as a pure function of a state pytree:

  * :class:`OnlineState` — ``lvl (N, M)`` cached-submodel index (the
    one-hot ``X`` of Eqs. 35–37 stored as its argmax), ``O (N, M, H)``
    remaining download MB per Δ component, ``target (N, M)`` in-flight
    download targets, ``hist (P, N, M)`` request-count ring buffer
    (the ΔT^P window of Eq. 45);
  * ``_routine_update`` — the download state machine (Eqs. 35–37);
  * ``_qoe_best`` — QoE (Eq. 40) + argmax-QoE routing (Eq. 41);
  * ``_adjust_bs`` — expected-future-gain caching (Eqs. 45–47) with the
    greedy multi-choice knapsack fit and immediate shrink (Eq. 49),
    evaluated for the whole (M, H+1) candidate grid at once;
  * ``_lfu_step`` / ``_random_step`` — the online baselines.

Every slot consumes only aggregated tensors (the workload's per-slot
``(N, M)`` request counts and the pre-drawn
:class:`~repro.traces.generators.DecisionStream`), so a whole run is ONE
``lax.scan`` dispatch, and ``run_online_grid`` vmaps it across
(scenario × workload × seed × policy) — a 64-element online grid is a
single XLA program instead of 64 Python slot loops.  ``run_workload``
streams a :class:`~repro.traces.workloads.Workload` through the scan in
bounded chunks, carrying ``OnlineState`` across chunk boundaries: the
scan is a strict fold over slots, so chunking cannot change any decision,
and peak memory is O(chunk) — a million-user Poisson workload runs
without ever materializing a ``(T, U)`` or even full ``(T, N, M)``
tensor.

Numerics: the engine mirrors ``OnlineSim`` op-for-op (same stable sort
orders, same thresholds) and runs in float64 (``jax.experimental
.enable_x64``), so per-slot QoE and final cache state match the NumPy
engine to ~1e-12 — asserted in ``tests/test_traces.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

from repro.traces.generators import DecisionStream, check_trace, default_stream

POLICIES = ("cocar-ol", "lfu", "lfu-mad", "random")
LFU_MAD_DECAY = 0.8              # matches online._freq_weighted


class OnlineParams(NamedTuple):
    """Static per-scenario arrays (all float64/int — vmappable leading
    batch axis in ``run_online_grid``)."""
    sizes: object                # (M, H+1) MB
    prec: object                 # (M, H+1)
    flops: object                # (M, H+1) GFLOP per MB (c_h)
    comm: object                 # (N, N) comm latency home->target (Eq. 39)
    C: object                    # (N,) GFLOPS
    R: object                    # (N,) MB
    W: object                    # (N,) MB/s cloud->BS
    adj1: object                 # (N, N) 1.0 where hops <= 1 (LFU pooling)
    theta: object                # () Eq. 40 normalizer
    ddl: object                  # ()
    alpha: object                # ()
    gamma: object                # ()
    dT_future: object            # ()
    data_mb: object              # ()
    slot_s: object               # ()
    n_users: object              # () QoE scale of Eq. 46
    partition: object            # () bool — dynamic-DNN switching enabled


class OnlineState(NamedTuple):
    lvl: object                  # (N, M) int32 cached submodel index
    O: object                    # (N, M, H) remaining download MB
    target: object               # (N, M) int32 download target
    hist: object                 # (P, N, M) request-count ring buffer


def make_params(cfg, ocfg, sc=None) -> OnlineParams:
    """Extract the engine's arrays from a scenario (host numpy, float64)."""
    from repro.mec.scenario import Scenario

    sc = sc or Scenario(cfg)
    N = cfg.n_bs
    d = cfg.data_mb
    comm = (d / sc.phi)[:, None] \
        + np.where(np.eye(N, dtype=bool), 0.0, d / (cfg.wired_mbps / 8.0)) \
        + sc.lam
    infer_min = (sc.flops[:, 1] * d / sc.C.max()).min()
    theta = d / sc.phi.min() + 2 * cfg.hop_latency_s + infer_min
    return OnlineParams(
        sizes=np.asarray(sc.sizes, np.float64),
        prec=np.asarray(sc.prec, np.float64),
        flops=np.asarray(sc.flops, np.float64),
        comm=np.asarray(comm, np.float64),
        C=np.asarray(sc.C, np.float64),
        R=np.asarray(sc.R, np.float64),
        W=np.full(N, cfg.cloud_mbps / 8.0),
        adj1=(sc.hops <= 1).astype(np.float64),
        theta=np.float64(theta),
        ddl=np.float64(cfg.ddl_s),
        alpha=np.float64(ocfg.alpha),
        gamma=np.float64(ocfg.gamma),
        dT_future=np.float64(ocfg.dT_future),
        data_mb=np.float64(d),
        slot_s=np.float64(ocfg.slot_s),
        n_users=np.float64(cfg.n_users),
        partition=np.bool_(ocfg.partition))


def init_state(params: OnlineParams, dT_past: int) -> OnlineState:
    M, Hp1 = np.shape(params.sizes)[-2:]
    N = np.shape(params.R)[-1]
    return OnlineState(
        lvl=np.zeros((N, M), np.int32),
        O=np.zeros((N, M, Hp1 - 1), np.float64),
        target=np.zeros((N, M), np.int32),
        hist=np.zeros((dT_past, N, M), np.float64))


# ---------------------------------------------------------------------------
# kernels (pure jnp functions of (params, state))
# ---------------------------------------------------------------------------

def _routine_update(p, st):
    """Eqs. 35–37: each BS spends W_n·Δt on its (m, h)-ordered download
    queue; every finished Δ switches the cache to h+1."""
    import jax.numpy as jnp

    N, M, H = st.O.shape
    budget = p.W * p.slot_s
    O = st.O.reshape(N, M * H)
    before = jnp.cumsum(O, axis=1) - O
    take = jnp.clip(budget[:, None] - before, 0.0, O)
    O_new = O - take
    finished = (O > 0) & (O_new <= 1e-12)
    O_new = jnp.where(finished, 0.0, O_new)
    fin = finished.reshape(N, M, H)
    done = fin.any(-1)
    h_top = (H - 1) - jnp.argmax(fin[:, :, ::-1], axis=-1)
    lvl = jnp.where(done, h_top.astype(jnp.int32) + 1, st.lvl)
    return st._replace(lvl=lvl, O=O_new.reshape(N, M, H))


def _qoe_best(p, lvl):
    """Eqs. 39–41: per-(home BS, model) best QoE over routing targets."""
    import jax.numpy as jnp

    M = lvl.shape[-1]
    ms = jnp.arange(M)
    P = p.prec[ms[None, :], lvl]                       # (N, M)
    c = p.flops[ms[None, :], lvl]
    infer = c * p.data_mb / p.C[:, None]               # (N_tgt, M)
    lat = p.comm[:, :, None] + infer[None]             # (Nh, Nt, M)
    q = P[None] * jnp.clip(1.0 - (lat - p.theta) * p.alpha, 0.0, None)
    q = jnp.where((P[None] > 0) & (lat <= p.ddl), q, 0.0)
    return q.max(axis=1)                               # (Nh, M)


def _seq_sum(rows, mask=None):
    """Left-to-right sequential accumulation (static Python loop).

    Decision-critical sums are accumulated in exactly the order the NumPy
    engine uses — identical f64 values added in identical order are
    bit-exact, so threshold/sort decisions cannot diverge between the two
    engines.  ``mask`` rows contribute an exact +0.0 (a no-op), matching
    NumPy's boolean-subset sums.
    """
    acc = rows[0] * (mask[0] if mask is not None else 1.0)
    for i in range(1, rows.shape[0]):
        acc = acc + rows[i] * (mask[i] if mask is not None else 1.0)
    return acc


def _freq(st):
    """Eq. 45: request proportions over the ΔT^P window."""
    import jax.numpy as jnp

    tot = st.hist.sum()
    return st.hist.sum(0) / jnp.maximum(tot, 1.0)


def _slot_qoe(p, freqNM, lvl):
    """Expected one-slot total QoE under cache state ``lvl`` (Eq. 46)."""
    return (freqNM * _qoe_best(p, lvl)).sum() * p.n_users


def _adjust_bs(p, st, n):
    """Alg. 2 lines 15–21 at BS n: evaluate the whole (M, H+1) candidate
    grid — action-space filter, knapsack fit, expected future gain — and
    apply the argmax candidate (first-wins on ties, like the Python loop).
    """
    import jax
    import jax.numpy as jnp

    N, M = st.lvl.shape
    H = st.O.shape[-1]
    K = M * (H + 1)
    ms = jnp.arange(M)
    freqNM = _freq(st)
    fM = _seq_sum(freqNM)                              # (M,) demand weight
    cur = st.lvl[n]                                    # (M,)
    dl = st.O[n].sum(-1) > 0                           # (M,)
    dlbudget = p.W[n] * p.slot_s

    cand_m = jnp.repeat(ms, H + 1)                     # (K,)
    cand_h = jnp.tile(jnp.arange(H + 1), M).astype(jnp.int32)
    cur_k = cur[cand_m]
    shrink = cand_h < cur_k
    enlarge = cand_h > cur_k
    # Sec. VI-B action space: enlargements up to (and incl.) the first
    # whose cumulative Δ overruns one slot budget
    sz_prev = p.sizes[cand_m, jnp.maximum(cand_h - 1, 0)]
    enl_ok = jnp.where(p.partition,
                       sz_prev - p.sizes[cand_m, cur_k] <= dlbudget,
                       cand_h == H)
    valid = (~dl[cand_m]) & (cand_h >= 1) & (shrink | (enlarge & enl_ok))

    # ---- _fit: greedy multi-choice knapsack, all candidates at once ----
    need = p.sizes[cand_m, cand_h]
    locked = dl[None, :] & (ms[None, :] != cand_m[:, None])      # (K, M)
    locked_sz = p.sizes[ms, st.target[n]]
    budget0 = p.R[n] - need
    for m2 in range(M):                                # sequential, like _fit
        budget0 = budget0 - jnp.where(locked[:, m2], locked_sz[m2], 0.0)
    feasible = budget0 >= 0
    order = jnp.argsort(-fM)                           # stable, high f first

    choice0 = jnp.where(locked, cur[None, :], 0)

    def knap_step(carry, m2):
        budget, choice = carry
        is_free = (m2 != cand_m) & (~dl[m2])           # (K,)
        cur2 = cur[m2]
        fits = p.sizes[m2][None, :] <= budget[:, None] + 1e-9
        h2_part = jnp.clip(jnp.minimum(cur2, fits.sum(-1) - 1), 0)
        h2_full = jnp.where((cur2 == H) & (p.sizes[m2, H] <= budget + 1e-9),
                            H, 0)
        h2 = jnp.where(p.partition, h2_part, h2_full)
        h2 = jnp.where(is_free, h2, choice[:, m2]).astype(jnp.int32)
        budget = budget - jnp.where(is_free, p.sizes[m2, h2], 0.0)
        return (budget, choice.at[:, m2].set(h2)), None

    (_, choice), _ = jax.lax.scan(knap_step, (budget0, choice0), order)

    k_idx = jnp.arange(K)
    lvl_hyp = choice.at[k_idx, cand_m].set(cand_h)     # (K, M) rows at n
    lvl_dur = choice.at[k_idx, cand_m].set(cur_k)      # upgrade pending

    # Eq. 46/47 matched-horizon discounted gain
    delta = jnp.where(p.partition,
                      p.sizes[cand_m, cand_h] - p.sizes[cand_m, cur_k],
                      p.sizes[cand_m, cand_h])
    delay = jnp.where(enlarge, jnp.ceil(delta / dlbudget), 0.0)

    full = jnp.broadcast_to(st.lvl, (K, N, M))
    g_cur = _slot_qoe(p, freqNM, st.lvl)
    g_hyp = jax.vmap(lambda L: _slot_qoe(p, freqNM, L))(
        full.at[k_idx, n].set(lvl_hyp))
    g_dur = jax.vmap(lambda L: _slot_qoe(p, freqNM, L))(
        full.at[k_idx, n].set(lvl_dur))
    gam = p.gamma
    geo = lambda D: gam * (1 - gam ** D) / (1 - gam)   # sum_{k=1}^D gam^k
    gain = geo(delay) * (g_dur - g_cur) \
        + gam ** delay * geo(p.dT_future) * (g_hyp - g_cur)

    gains = jnp.where(valid & feasible, gain, -jnp.inf)
    k_best = jnp.argmax(gains)
    act = gains[k_best] > 1e-9
    mb, hb = cand_m[k_best], cand_h[k_best]
    curb = cur[mb]
    row = choice[k_best].at[mb].set(jnp.where(hb < curb, hb, curb))
    lvl = st.lvl.at[n].set(jnp.where(act, row, st.lvl[n]))

    enl = act & (hb > curb)                            # Eq. 48 downloads
    h_axis = jnp.arange(1, H + 1)
    Orow = jnp.where(p.partition,
                     jnp.where((h_axis > curb) & (h_axis <= hb),
                               p.sizes[mb, 1:] - p.sizes[mb, :-1], 0.0),
                     jnp.where(h_axis == hb, p.sizes[mb, hb], 0.0))
    O = st.O.at[n, mb].set(jnp.where(enl, Orow, st.O[n, mb]))
    target = st.target.at[n, mb].set(
        jnp.where(enl, hb, st.target[n, mb]))
    return st._replace(lvl=lvl, O=O, target=target)


def _lfu_step(p, st, n, mad):
    """LFU / LFU-MAD at BS n: enlarge the most frequent non-downloading
    model (pooling 1-hop neighbour demand), shrink least-frequent to fit."""
    import jax
    import jax.numpy as jnp

    N, M = st.lvl.shape
    H = st.O.shape[-1]
    P = st.hist.shape[0]
    ms = jnp.arange(M)
    if mad:
        w = LFU_MAD_DECAY ** (P - 1 - jnp.arange(P))
        fW = _seq_sum(st.hist * w[:, None, None])
    else:
        fW = st.hist.sum(0)                            # integer-exact
    f = _seq_sum(fW, mask=p.adj1[n])                   # (M,) 1-hop pooling
    order = jnp.argsort(-f)                            # stable
    dl = st.O[n].sum(-1) > 0
    free_in_order = ~dl[order]
    exists = free_in_order.any()
    top = order[jnp.argmax(free_in_order)]
    cur = st.lvl[n, top]
    tgt = jnp.where(p.partition, jnp.minimum(cur + 1, H), H)
    act0 = exists & (tgt != cur)
    used = _seq_sum(p.sizes[ms, st.lvl[n]]) + jnp.maximum(
        p.sizes[top, tgt] - p.sizes[top, cur] * (cur > 0), 0.0)

    def shrink_step(carry, m2):
        used, lvln = carry
        c2 = lvln[m2]
        cond = act0 & (used > p.R[n]) & (m2 != top) & (c2 > 0)
        new2 = jnp.where(p.partition, c2 - 1, 0)
        used = used - jnp.where(cond,
                                p.sizes[m2, c2] - p.sizes[m2, new2], 0.0)
        return (used, lvln.at[m2].set(jnp.where(cond, new2, c2))), None

    (used, lvln), _ = jax.lax.scan(shrink_step, (used, st.lvl[n]),
                                   jnp.argsort(f))
    fin = act0 & (used <= p.R[n])
    delta = p.sizes[top, tgt] - jnp.where(p.partition & (cur > 0),
                                          p.sizes[top, cur], 0.0)
    O = st.O.at[n, top, tgt - 1].set(
        jnp.where(fin, jnp.maximum(delta, 0.0), st.O[n, top, tgt - 1]))
    target = st.target.at[n, top].set(
        jnp.where(fin, tgt.astype(jnp.int32), st.target[n, top]))
    return st._replace(lvl=st.lvl.at[n].set(lvln), O=O, target=target)


def _random_step(p, st, n, u_m, perm, u_shr):
    """Random baseline at BS n, driven by the pre-drawn uniforms."""
    import jax
    import jax.numpy as jnp

    N, M = st.lvl.shape
    H = st.O.shape[-1]
    ms = jnp.arange(M)
    dl = st.O[n].sum(-1) > 0
    free = ~dl
    n_free = free.sum()
    idx = jnp.minimum((u_m * n_free).astype(jnp.int32),
                      jnp.maximum(n_free - 1, 0))
    m = jnp.argmax((jnp.cumsum(free) - 1 == idx) & free)
    cur = st.lvl[n, m]
    tgt = jnp.where(p.partition, jnp.minimum(cur + 1, H), H)
    act0 = (n_free > 0) & (tgt != cur)
    used = _seq_sum(p.sizes[ms, st.lvl[n]]) + p.sizes[m, tgt] \
        - jnp.where(cur > 0, p.sizes[m, cur], 0.0)

    def shrink_step(carry, m2):
        used, lvln = carry
        c2 = lvln[m2]
        cond = act0 & (m2 != m) & (used > p.R[n]) & (c2 > 0)
        new2 = jnp.where(p.partition,
                         jnp.minimum((u_shr[m2] * c2).astype(jnp.int32),
                                     jnp.maximum(c2 - 1, 0)), 0)
        used = used - jnp.where(cond,
                                p.sizes[m2, c2] - p.sizes[m2, new2], 0.0)
        return (used, lvln.at[m2].set(jnp.where(cond, new2, c2))), None

    (used, lvln), _ = jax.lax.scan(shrink_step, (used, st.lvl[n]), perm)
    fin = act0 & (used <= p.R[n])
    delta = p.sizes[m, tgt] - jnp.where(p.partition & (cur > 0),
                                        p.sizes[m, cur], 0.0)
    O = st.O.at[n, m, tgt - 1].set(
        jnp.where(fin, jnp.maximum(delta, 0.0), st.O[n, m, tgt - 1]))
    target = st.target.at[n, m].set(
        jnp.where(fin, tgt.astype(jnp.int32), st.target[n, m]))
    return st._replace(lvl=st.lvl.at[n].set(lvln), O=O, target=target)


# ---------------------------------------------------------------------------
# the scan
# ---------------------------------------------------------------------------

def _slot_step(p, policy, st, xs, diagnostics: bool = False,
               record_states: bool = False):
    """One slot: downloads -> routing/QoE -> history push -> policy.

    With ``diagnostics`` (static) the emission grows a per-slot telemetry
    dict — cache-hit rate, downloads in flight, evictions this slot,
    cached MB — computed purely from values the step already produces, so
    the state trajectory (and every decision) is bit-identical either
    way; off, the dict is empty and compiles out entirely.

    With ``record_states`` (static) the emission additionally carries
    the slot's *serving* cache state — ``(lvl, dl, target)`` right after
    the download update, i.e. exactly the state Eq. 41 routes against
    this slot.  This is the per-slot export the serving bridge
    (``repro.serving.plan``) turns into residency schedules; a submodel
    mid-download (``dl`` true) is still at its pre-download ``lvl``, so
    it can never be exposed as resident at its target.  Decision-inert,
    like diagnostics: off, nothing extra is compiled or carried."""
    import jax
    import jax.numpy as jnp

    counts, ns, u_model, perms, u_shrink = xs
    st = _routine_update(p, st)
    rec = ()
    if record_states:
        rec = (st.lvl, st.O.sum(-1) > 0, st.target)
    best = _qoe_best(p, st.lvl)
    qoe = (counts * best).sum()
    hits = (counts * (best > 0)).sum()
    st = st._replace(hist=jnp.concatenate([st.hist[1:], counts[None]]))
    lvl_before = st.lvl
    rounds = ns.shape[0]
    js = jnp.arange(rounds)

    def rounds_scan(step_fn):
        def run(s):
            return jax.lax.scan(lambda s_, j: (step_fn(s_, j), None),
                                s, js)[0]
        return run

    st = jax.lax.switch(policy, [
        rounds_scan(lambda s, j: _adjust_bs(p, s, ns[j])),
        rounds_scan(lambda s, j: _lfu_step(p, s, ns[j], mad=False)),
        rounds_scan(lambda s, j: _lfu_step(p, s, ns[j], mad=True)),
        rounds_scan(lambda s, j: _random_step(p, s, ns[j], u_model[j],
                                              perms[j], u_shrink[j])),
    ], st)
    diag = {}
    if diagnostics:
        ms = jnp.arange(st.lvl.shape[-1])
        diag = {
            "hit_rate": hits / jnp.maximum(counts.sum(), 1.0),
            "dl_in_flight": (st.O.sum(-1) > 0).sum(),
            "evictions": (st.lvl < lvl_before).sum(),
            "cache_mb": p.sizes[ms[None, :], st.lvl].sum(),
        }
    return st, (qoe, hits, diag, rec)


def _scan_run(p, st0, counts, ns, u_model, perms, u_shrink, policy,
              diagnostics: bool = False, record_states: bool = False):
    """Whole-trace scan.  Always returns ``(stF, qoe, hits, diag, rec)``;
    ``diag`` is a dict of per-slot curves when ``diagnostics`` (static)
    is on and ``rec`` the per-slot ``(lvl, dl, target)`` trajectory when
    ``record_states`` is on — otherwise both are empty (nothing extra
    compiled or carried)."""
    import jax

    def step(st, xs):
        return _slot_step(p, policy, st, xs, diagnostics=diagnostics,
                          record_states=record_states)

    stF, (qoe, hits, diag, rec) = jax.lax.scan(
        step, st0, (counts, ns, u_model, perms, u_shrink))
    return stF, qoe, hits, diag, rec


@functools.cache
def _compiled(diagnostics: bool = False, record_states: bool = False):
    """The single-scenario scan (``run_scan``).  Grid runs go through the
    ``repro.scale`` executor, which jits its own vmapped ``_scan_run``."""
    import jax

    from repro.obs.tracing import register_jit

    fn = functools.partial(_scan_run, diagnostics=diagnostics,
                           record_states=record_states)
    return register_jit(f"online:scan:diag={int(bool(diagnostics))}"
                        f":rec={int(bool(record_states))}",
                        jax.jit(fn))


def _policy_id(algo: str) -> int:
    try:
        return POLICIES.index(algo)
    except ValueError:
        raise ValueError(f"unknown online policy {algo!r}; "
                         f"one of {POLICIES}")


def run_scan(params: OnlineParams, counts, stream: DecisionStream,
             algo: str = "cocar-ol", dT_past: int = 10,
             diagnostics: bool = False, record_states: bool = False):
    """One scenario through the compiled scan.  Returns the summary dict of
    ``run_online`` plus per-slot arrays and the final state — and, with
    ``diagnostics``, the engine's per-slot telemetry curves (decision-
    inert: same compiled step math, extra emissions only), and, with
    ``record_states``, the per-slot serving cache states under
    ``"states"`` (the serving bridge's input)."""
    from jax.experimental import enable_x64

    st0 = init_state(params, dT_past)
    with enable_x64():
        stF, qoe, hits, diag, rec = _compiled(
            bool(diagnostics), bool(record_states))(
            params, st0, np.asarray(counts, np.float64),
            stream.adjust_ns, stream.u_model, stream.perms, stream.u_shrink,
            _policy_id(algo))
    # pull to host BEFORE reducing: np.sum on a device array would
    # re-enter jnp outside the x64 context and downcast to f32
    qoe, hits = np.asarray(qoe), np.asarray(hits)
    total = float(np.asarray(counts).sum())
    out = {
        "avg_qoe": float(qoe.sum()) / max(total, 1.0),
        "hit_rate": float(hits.sum()) / max(total, 1.0),
        "slot_qoe": qoe,
        "slot_hits": hits,
        "final_state": OnlineState(*(np.asarray(x) for x in stF)),
    }
    if diagnostics:
        out["diagnostics"] = {k: np.asarray(v) for k, v in diag.items()}
    if record_states:
        out["states"] = {"lvl": np.asarray(rec[0]),
                         "dl": np.asarray(rec[1]),
                         "target": np.asarray(rec[2])}
    return out


def run_workload(params: OnlineParams, workload, stream: DecisionStream,
                 algo: str = "cocar-ol", dT_past: int = 10,
                 diagnostics: bool = False, chunk_slots: int = 0,
                 record_states: bool = False):
    """Stream a :class:`~repro.traces.workloads.Workload` through the
    compiled scan in bounded chunks.

    ``chunk_slots`` <= 0 defers to the workload's own preference (whole
    horizon for small exact families, a bounded default for streaming
    ones).  The ``OnlineState`` carry crosses chunk boundaries, so the
    slot trajectory — and every cache decision — is identical to the
    one-shot scan; at most two chunk lengths (full + tail) ever compile.
    Returns the ``run_scan`` summary dict.
    """
    from jax.experimental import enable_x64

    st = init_state(params, dT_past)
    fn = _compiled(bool(diagnostics), bool(record_states))
    pid = _policy_id(algo)
    qoes, hitss, diags, recs, total = [], [], [], [], 0.0
    with enable_x64():
        for t0, t1, counts in workload.iter_chunks(chunk_slots):
            counts = np.asarray(counts, np.float64)
            total += float(counts.sum())
            st, qoe, hits, diag, rec = fn(
                params, st, counts, stream.adjust_ns[t0:t1],
                stream.u_model[t0:t1], stream.perms[t0:t1],
                stream.u_shrink[t0:t1], pid)
            qoes.append(np.asarray(qoe))
            hitss.append(np.asarray(hits))
            if diagnostics:
                diags.append({k: np.asarray(v) for k, v in diag.items()})
            if record_states:
                recs.append(tuple(np.asarray(r) for r in rec))
    qoe, hits = np.concatenate(qoes), np.concatenate(hitss)
    out = {
        "avg_qoe": float(qoe.sum()) / max(total, 1.0),
        "hit_rate": float(hits.sum()) / max(total, 1.0),
        "slot_qoe": qoe,
        "slot_hits": hits,
        "final_state": OnlineState(*(np.asarray(x) for x in st)),
    }
    if diagnostics:
        out["diagnostics"] = {
            k: np.concatenate([d[k] for d in diags]) for k in diags[0]}
    if record_states:
        out["states"] = {
            key: np.concatenate([r[i] for r in recs])
            for i, key in enumerate(("lvl", "dl", "target"))}
    return out


def grid_payloads(jobs, ocfg):
    """Per-job engine arrays for a grid run: the (params, counts, stream,
    policy id, request total) each scan consumes, derived exactly as
    ``run_online`` derives them (same default seeds and streams).

    This is the online grid's ingestion stage; the ``repro.scale``
    executor buckets the payloads by shape, stacks each bucket, and
    dispatches them sharded/chunked.
    """
    from dataclasses import replace

    from repro.traces.registry import default_trace
    from repro.traces.workloads import as_workload, check_workload

    payloads = []
    for j in jobs:
        seed = j.get("seed", 0)        # same default as run_online
        cfg = replace(j["cfg"], seed=seed)
        if j.get("workload") is not None:
            wl = check_workload(as_workload(j["workload"], cfg=cfg),
                                cfg, ocfg)
            counts = wl.counts()
        else:
            trace = j.get("trace") or default_trace(cfg, ocfg)
            check_trace(trace, cfg, ocfg)
            counts = trace.counts(cfg.n_bs, cfg.n_models)
        stream = j.get("stream") or default_stream(cfg, ocfg, seed)
        payloads.append({
            "params": make_params(cfg, ocfg),
            "counts": counts,
            "stream": stream,
            "policy": _policy_id(j["algo"]),
            "total": float(counts.sum()),
        })
    return payloads


def run_online_grid(jobs, ocfg, backend: str = "vmap",
                    devices: int = None, chunk_size: int = 0,
                    diagnostics: bool = False):
    """Run many (cfg, trace, algo, seed) scenarios in one vmapped scan
    dispatch per shape bucket, via the ``repro.scale`` grid executor.

    ``jobs`` is a list of dicts with keys ``cfg`` (MECConfig), ``algo``
    (policy name), and optionally ``workload`` (anything ``as_workload``
    accepts) or ``trace`` (a Trace; the default workload when neither is
    given) and ``seed``.  Heterogeneous (n_bs, n_models, n_slots) grids
    are bucketed by shape — each bucket is one dispatch.
    ``backend="sharded"`` partitions every bucket's batch across a
    ``devices``-wide host mesh; ``chunk_size`` streams it in bounded
    chunks.  Returns one summary dict per job, in order.
    """
    from repro.scale import GridSpec, run_grid

    spec = GridSpec(kind="online", jobs=list(jobs), ocfg=ocfg,
                    backend=backend, devices=devices,
                    chunk_size=chunk_size, diagnostics=diagnostics)
    return run_grid(spec).results
