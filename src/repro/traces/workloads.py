"""The ``Workload`` protocol: aggregated per-(BS, model) demand tensors.

Eq. 40 QoE and the Eq. 45-49 caching updates are sums over the users that
share a (home BS, requested model) pair, so the per-slot ``(n_bs,
n_models)`` request-count tensor is an *exact* representation of demand —
the engines never need the dense per-user ``(n_slots, n_users)`` tensors.
This module puts that representation behind one small protocol:

  * :class:`Workload` — the abstract surface every online caller consumes:
    ``counts_chunk(t0, t1) -> (t1-t0, N, M)`` float64 counts, plus
    ``counts()``/``iter_chunks()``/``total()`` conveniences and the
    ``exact`` flag (True when counts are an exact aggregation of a
    per-user stream, False when they are sampled directly);
  * :class:`DenseWorkload` — wraps a per-user :class:`Trace` (exact; the
    only family that can also replay per-user, which the equivalence
    certificates use as the bit-reference);
  * :class:`AggregatedWorkload` — wraps a precomputed ``(T, N, M)`` count
    tensor (exact; e.g. replayed from a previous run's aggregation);
  * :class:`PoissonWorkload` — streaming Poisson + Zipf arrivals generated
    chunk-by-chunk (sampled; the million-user family: memory is O(chunk),
    and per-slot counter-based keys make the draw independent of the
    chunk layout);
  * :class:`TraceLogWorkload` — fed from request-log arrays ``(slot, home
    BS, model)`` (exact; the trace-driven family — icarus-style replay of
    measured logs without materializing ``(T, U)`` tensors).

``as_workload`` coerces the legacy currencies (a ``Trace``, a raw count
tensor) and ``check_workload`` validates shapes against a run's
``(cfg, ocfg)`` the way ``check_trace`` does for dense traces.
"""
from __future__ import annotations

import numpy as np

from repro.traces.generators import (Trace, _key, _per_bs_pop, check_trace)


class Workload:
    """Aggregated demand over ``n_slots`` slots of an online run.

    Subclasses set ``name``, ``family``, ``n_slots``, ``n_bs``,
    ``n_models``, ``exact``, ``meta`` and implement
    :meth:`counts_chunk`.  ``chunk_slots`` is the family's preferred
    streaming granularity (0 = materialize the whole horizon at once,
    right for small exact families; streaming families set a bounded
    default so no caller accidentally materializes the full horizon).
    """

    name: str = "workload"
    family: str = "workload"
    n_slots: int = 0
    n_bs: int = 0
    n_models: int = 0
    exact: bool = True
    chunk_slots: int = 0

    def __init__(self):
        self.meta: dict = {}
        self._total = None

    # -- the protocol ------------------------------------------------------
    def counts_chunk(self, t0: int, t1: int) -> np.ndarray:
        """Per-slot request counts for slots ``[t0, t1)`` as a
        ``(t1 - t0, n_bs, n_models)`` float64 array.  Must be a pure
        function of ``(self, t0, t1)`` and independent of how the horizon
        is chunked."""
        raise NotImplementedError

    # -- conveniences ------------------------------------------------------
    def counts(self) -> np.ndarray:
        """The full ``(n_slots, n_bs, n_models)`` tensor (fine for grid
        payloads and small runs; streaming callers use iter_chunks)."""
        return self.counts_chunk(0, self.n_slots)

    def iter_chunks(self, chunk_slots: int = 0):
        """Yield ``(t0, t1, counts)`` covering ``[0, n_slots)`` in order.

        ``chunk_slots`` <= 0 falls back to the family's own
        ``chunk_slots`` default (whole horizon when that is 0 too).
        """
        step = int(chunk_slots) if chunk_slots and chunk_slots > 0 \
            else (self.chunk_slots or self.n_slots)
        for t0 in range(0, self.n_slots, max(step, 1)):
            t1 = min(t0 + step, self.n_slots)
            yield t0, t1, self.counts_chunk(t0, t1)

    def total(self) -> float:
        """Total requests over the horizon (normalizes avg QoE)."""
        if self._total is None:
            self._total = float(sum(
                float(c.sum()) for _, _, c in self.iter_chunks()))
        return self._total

    def __repr__(self):
        return (f"{type(self).__name__}(name={self.name!r}, "
                f"family={self.family!r}, n_slots={self.n_slots}, "
                f"n_bs={self.n_bs}, n_models={self.n_models}, "
                f"exact={self.exact})")


class DenseWorkload(Workload):
    """Exact aggregation of a per-user :class:`Trace`.

    Keeps the trace around: this is the only family that can also replay
    per-user (``OnlineSim.route``), which the decision-identity
    certificates use as the bit-reference at small U.
    """

    exact = True

    def __init__(self, trace: Trace, n_bs: int, n_models: int):
        super().__init__()
        self.trace = trace
        self.name = trace.name
        self.family = str(trace.meta.get("family", trace.name))
        self.n_slots = trace.n_slots
        self.n_bs = int(n_bs)
        self.n_models = int(n_models)
        self.meta = dict(trace.meta, n_users=trace.n_users)
        self._counts = None

    @property
    def n_users(self) -> int:
        return self.trace.n_users

    def counts(self) -> np.ndarray:
        if self._counts is None:
            self._counts = self.trace.counts(self.n_bs, self.n_models)
        return self._counts

    def counts_chunk(self, t0, t1):
        return self.counts()[t0:t1]


class AggregatedWorkload(Workload):
    """A precomputed ``(T, N, M)`` count tensor, taken as-is."""

    exact = True
    family = "aggregated"

    def __init__(self, counts: np.ndarray, name: str = "aggregated",
                 meta: dict | None = None):
        super().__init__()
        counts = np.asarray(counts, dtype=np.float64)
        if counts.ndim != 3:
            raise ValueError(
                f"aggregated workload {name!r} needs a (n_slots, n_bs, "
                f"n_models) count tensor, got shape {counts.shape}")
        self._counts = counts
        self.name = name
        self.n_slots, self.n_bs, self.n_models = counts.shape
        self.meta = dict(meta or {})

    def counts_chunk(self, t0, t1):
        return self._counts[t0:t1]


class PoissonWorkload(Workload):
    """Streaming Poisson + Zipf arrivals — the million-user family.

    Per slot, the request count at (BS n, model m) is Poisson with mean
    ``users_per_slot / n_bs * pop[n, m]`` where ``pop`` is the same
    per-BS-permuted Zipf popularity the dense families use (each user
    picks a home uniformly and a model from its home's popularity; at
    large U the multinomial cell counts are Poisson to within O(1/U)).
    Counts are drawn with a counter-based generator keyed on
    ``(seed, slot)``, so ``counts_chunk`` is a pure function of the slot
    range — chunk layout cannot change the stream.  Memory is O(chunk):
    no per-user tensor ever exists at any U.
    """

    exact = False
    family = "poisson_zipf"

    def __init__(self, n_slots: int, n_bs: int, n_models: int,
                 users_per_slot: float, *, zipf: float = 0.8, seed: int = 0,
                 chunk_slots: int = 64, name: str = "poisson_zipf"):
        super().__init__()
        import jax

        self.name = name
        self.n_slots = int(n_slots)
        self.n_bs = int(n_bs)
        self.n_models = int(n_models)
        self.users_per_slot = float(users_per_slot)
        self.seed = int(seed)
        self.chunk_slots = int(chunk_slots)
        # same popularity derivation as generators.stationary: split the
        # family key and permute the Zipf ranks independently per BS
        k_pop, _ = jax.random.split(_key(self.seed))
        self.pop = _per_bs_pop(k_pop, self.n_bs, self.n_models, zipf)
        self._lam = self.users_per_slot / self.n_bs * self.pop
        self.meta = {"zipf": zipf, "users_per_slot": self.users_per_slot,
                     "seed": self.seed}

    def counts_chunk(self, t0, t1):
        out = np.empty((t1 - t0, self.n_bs, self.n_models))
        for k, t in enumerate(range(t0, t1)):
            rng = np.random.Generator(np.random.Philox(key=[self.seed, t]))
            out[k] = rng.poisson(self._lam)
        return out

    def total(self) -> float:
        if self._total is None:
            self._total = float(sum(
                float(c.sum()) for _, _, c in self.iter_chunks()))
        return self._total


class TraceLogWorkload(Workload):
    """Exact aggregation of request-log arrays ``(slot, home, model)``.

    The log is sorted by slot once at construction; ``counts_chunk`` then
    touches only the O(requests-in-chunk) span via ``searchsorted``
    boundaries, so replaying a measured log never materializes a
    ``(T, U)`` tensor either.
    """

    exact = True
    family = "request_log"

    def __init__(self, slot, home, model, *, n_slots: int, n_bs: int,
                 n_models: int, name: str = "request_log",
                 meta: dict | None = None):
        super().__init__()
        slot = np.asarray(slot, dtype=np.int64).ravel()
        home = np.asarray(home, dtype=np.int64).ravel()
        model = np.asarray(model, dtype=np.int64).ravel()
        if not (slot.shape == home.shape == model.shape):
            raise ValueError(
                f"request log {name!r}: slot/home/model arrays must have "
                f"one entry per request, got shapes {slot.shape}, "
                f"{home.shape}, {model.shape}")
        self.name = name
        self.n_slots = int(n_slots)
        self.n_bs = int(n_bs)
        self.n_models = int(n_models)
        self.meta = dict(meta or {}, n_requests=int(slot.size))
        for arr, what, hi in ((slot, "slot", self.n_slots),
                              (home, "home BS", self.n_bs),
                              (model, "model", self.n_models)):
            if arr.size and (arr.min() < 0 or arr.max() >= hi):
                raise ValueError(
                    f"request log {name!r}: {what} indexes outside "
                    f"[0, {hi})")
        order = np.argsort(slot, kind="stable")
        self._slot = slot[order]
        self._flat = home[order] * self.n_models + model[order]
        self._starts = np.searchsorted(self._slot,
                                       np.arange(self.n_slots + 1))
        self._total = float(slot.size)

    def counts_chunk(self, t0, t1):
        lo, hi = self._starts[t0], self._starts[t1]
        out = np.zeros((t1 - t0, self.n_bs * self.n_models))
        np.add.at(out, (self._slot[lo:hi] - t0, self._flat[lo:hi]), 1.0)
        return out.reshape(t1 - t0, self.n_bs, self.n_models)


def as_workload(obj, cfg=None, *, n_bs=None, n_models=None) -> Workload:
    """Coerce the legacy currencies into a :class:`Workload`.

    Accepts a ``Workload`` (returned as-is), a per-user :class:`Trace`
    (wrapped in :class:`DenseWorkload` — needs ``cfg`` or explicit
    ``n_bs``/``n_models`` for the aggregation shape) or a ``(T, N, M)``
    array (wrapped in :class:`AggregatedWorkload`).
    """
    if isinstance(obj, Workload):
        return obj
    if isinstance(obj, Trace):
        if cfg is not None:
            n_bs = cfg.n_bs if n_bs is None else n_bs
            n_models = cfg.n_models if n_models is None else n_models
        if n_bs is None or n_models is None:
            raise ValueError(
                "wrapping a Trace needs the aggregation shape: pass cfg= "
                "or n_bs=/n_models=")
        return DenseWorkload(obj, n_bs, n_models)
    if isinstance(obj, np.ndarray):
        return AggregatedWorkload(obj)
    raise TypeError(
        f"cannot interpret {type(obj).__name__} as a workload; expected "
        f"Workload, Trace, or a (n_slots, n_bs, n_models) count array")


def check_workload(wl: Workload, cfg, ocfg) -> Workload:
    """Validate a workload against the run's shape, mirroring
    ``check_trace`` (and delegating to it for dense families so the
    per-user tensors are vetted too)."""
    hint = (f"build one for this config with make_workload("
            f"{wl.family!r}, cfg, n_slots={ocfg.n_slots}) — see "
            f"repro.traces.available_workloads()")
    if wl.n_slots != ocfg.n_slots:
        raise ValueError(
            f"workload {wl.name!r} (family {wl.family!r}) covers "
            f"{wl.n_slots} slots but the run needs "
            f"ocfg.n_slots={ocfg.n_slots}; {hint}")
    if wl.n_bs != cfg.n_bs or wl.n_models != cfg.n_models:
        raise ValueError(
            f"workload {wl.name!r} (family {wl.family!r}) aggregates over "
            f"(n_bs={wl.n_bs}, n_models={wl.n_models}) but the config has "
            f"(n_bs={cfg.n_bs}, n_models={cfg.n_models}); {hint}")
    if isinstance(wl, DenseWorkload):
        check_trace(wl.trace, cfg, ocfg)
    return wl
