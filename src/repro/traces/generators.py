"""Request-trace generators: precomputed (n_slots, n_users) workload tensors.

Every generator is a pure function of a JAX PRNG key — the same key always
yields the same trace, so every policy (and both online engines, the NumPy
``OnlineSim`` and the ``lax.scan`` engine) replays an *identical* request
stream.  Traces are materialized as host numpy arrays: the NumPy engine
slices them per slot, the scan engine consumes the per-slot
``(N, M)`` request-count tensor (``Trace.counts``) in one device array.

Families (paper Sec. VI "dynamic and unpredictable online request
patterns", plus the arrival models of the related online-caching work):

  * ``stationary``   — fixed per-BS Zipf popularity (the legacy workload);
  * ``drift``        — popularity re-drawn every ``change_every`` slots with
                       a warm-up blend (the paper's ``pop_change_every``
                       regime, Fig. 13);
  * ``diurnal``      — sinusoidal load: the active-user fraction follows a
                       day/night curve (inactive users are masked out);
  * ``flash_crowd``  — sudden hot-model spikes: for short windows a single
                       model absorbs most of the probability mass;
  * ``mmpp``         — Markov-modulated bursts: a 2-state (calm/burst)
                       chain modulates both load and popularity skew;
  * ``mobility``     — user handover: each user's home BS performs a lazy
                       random walk over the slots.

The policy side of the replayed randomness lives here too:
``draw_decision_stream`` pre-draws every random number the online policies
consume (which BSs to adjust, the Random baseline's picks), so no policy
can perturb another's stream.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def _key(seed_or_key):
    """Accept an int seed or a jax PRNG key."""
    import jax

    if isinstance(seed_or_key, (int, np.integer)):
        return jax.random.PRNGKey(int(seed_or_key))
    return seed_or_key


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Trace:
    """A precomputed request stream.

    ``model[t, u]``/``home[t, u]`` give user u's requested model type and
    home BS in slot t; ``mask[t, u]`` is False when the user is inactive
    that slot (diurnal/MMPP load modulation).
    """
    name: str
    model: np.ndarray            # (T, U) int32
    home: np.ndarray             # (T, U) int32
    mask: np.ndarray             # (T, U) bool
    meta: dict = field(default_factory=dict)

    @property
    def n_slots(self) -> int:
        return self.model.shape[0]

    @property
    def n_users(self) -> int:
        return self.model.shape[1]

    def requests(self, t):
        """Slot t's active requests: (m_u, home) 1-D arrays."""
        sel = self.mask[t]
        return self.model[t][sel], self.home[t][sel]

    def counts(self, n_bs: int, n_models: int) -> np.ndarray:
        """(T, N, M) per-slot request counts — the scan engine's input."""
        T = self.n_slots
        out = np.zeros((T, n_bs * n_models))
        t_idx, u_idx = np.nonzero(self.mask)
        flat = self.home[t_idx, u_idx] * n_models + self.model[t_idx, u_idx]
        np.add.at(out, (t_idx, flat), 1.0)
        return out.reshape(T, n_bs, n_models)


# ---------------------------------------------------------------------------
# shared sampling helpers (all jax.random, converted to host numpy)
# ---------------------------------------------------------------------------

def _zipf_pmf(n_models: int, a: float) -> np.ndarray:
    p = np.ones(n_models) if a <= 0 else 1.0 / np.arange(1, n_models + 1) ** a
    return p / p.sum()


def _per_bs_pop(key, n_bs: int, n_models: int, a: float):
    """(N, M): the Zipf pmf with an independent rank permutation per BS
    (matches the legacy ``OnlineSim._draw_pop`` workload)."""
    import jax

    base = np.asarray(_zipf_pmf(n_models, a))
    perms = jax.vmap(lambda k: jax.random.permutation(k, n_models))(
        jax.random.split(key, n_bs))
    return base[np.asarray(perms)]


def _sample_requests(key, pops, n_users: int):
    """Draw homes uniformly and models from per-(slot, BS) popularity.

    ``pops`` is (T, N, M); returns (model, home) as (T, U) int32.
    """
    import jax
    import jax.numpy as jnp

    T, N, M = pops.shape
    k_home, k_model = jax.random.split(key)
    home = jax.random.randint(k_home, (T, n_users), 0, N)
    logits = jnp.log(jnp.take_along_axis(
        jnp.asarray(pops), home[:, :, None] % N, axis=1) + 1e-30)
    model = jax.random.categorical(k_model, logits, axis=-1)
    return (np.asarray(model, dtype=np.int32),
            np.asarray(home, dtype=np.int32))


def _full_mask(T, U):
    return np.ones((T, U), dtype=bool)


# ---------------------------------------------------------------------------
# generator families
# ---------------------------------------------------------------------------

def stationary(key, n_slots, n_users, n_bs, n_models, *, zipf=0.8):
    """Fixed per-BS Zipf popularity — today's single hard-coded workload."""
    import jax

    key = _key(key)
    k_pop, k_req = jax.random.split(key)
    pop = _per_bs_pop(k_pop, n_bs, n_models, zipf)
    pops = np.broadcast_to(pop, (n_slots, n_bs, n_models))
    model, home = _sample_requests(k_req, np.asarray(pops), n_users)
    return Trace("stationary", model, home, _full_mask(n_slots, n_users),
                 {"zipf": zipf})


def drift(key, n_slots, n_users, n_bs, n_models, *, zipf=0.8,
          change_every=20, warmup=5):
    """Popularity re-drawn every ``change_every`` slots; over the last
    ``warmup`` slots of each period the stream blends toward the next
    popularity (the legacy ``pop_change_every``/``pop_warmup`` regime)."""
    import jax

    key = _key(key)
    ce = int(change_every)
    if ce <= 0:
        return stationary(key, n_slots, n_users, n_bs, n_models, zipf=zipf)
    n_periods = n_slots // ce + 2
    k_pop, k_req = jax.random.split(key)
    pop_seq = np.stack([
        _per_bs_pop(k, n_bs, n_models, zipf)
        for k in jax.random.split(k_pop, n_periods)])    # (P, N, M)
    pops = np.empty((n_slots, n_bs, n_models))
    for t in range(n_slots):
        p, k = t // ce, t % ce
        ph = pop_seq[p]
        if warmup and k >= ce - warmup:
            w = (k - (ce - warmup) + 1) / warmup
            ph = (1 - w) * ph + w * pop_seq[p + 1]
            ph = ph / ph.sum(-1, keepdims=True)
        pops[t] = ph
    model, home = _sample_requests(k_req, pops, n_users)
    return Trace("drift", model, home, _full_mask(n_slots, n_users),
                 {"zipf": zipf, "change_every": ce, "warmup": warmup})


def diurnal(key, n_slots, n_users, n_bs, n_models, *, zipf=0.8,
            period=50, min_load=0.2, phase=0.0):
    """Sinusoidal load: the active-user fraction oscillates between
    ``min_load`` and 1 with the given period (slots)."""
    import jax

    key = _key(key)
    k_pop, k_req, k_act = jax.random.split(key, 3)
    pop = _per_bs_pop(k_pop, n_bs, n_models, zipf)
    pops = np.broadcast_to(pop, (n_slots, n_bs, n_models))
    model, home = _sample_requests(k_req, np.asarray(pops), n_users)
    t = np.arange(n_slots)
    frac = min_load + (1 - min_load) * 0.5 * (
        1 + np.sin(2 * np.pi * (t + phase) / period))
    u = np.asarray(jax.random.uniform(k_act, (n_slots, n_users)))
    mask = u < frac[:, None]
    return Trace("diurnal", model, home, mask,
                 {"zipf": zipf, "period": period, "min_load": min_load})


def flash_crowd(key, n_slots, n_users, n_bs, n_models, *, zipf=0.8,
                n_events=2, duration=10, intensity=0.8):
    """Sudden hot-model spikes: during each event a single model absorbs
    ``intensity`` of the probability mass at every BS."""
    import jax

    key = _key(key)
    k_pop, k_start, k_hot, k_req = jax.random.split(key, 4)
    pop = _per_bs_pop(k_pop, n_bs, n_models, zipf)
    pops = np.tile(pop[None], (n_slots, 1, 1))
    starts = np.asarray(jax.random.randint(
        k_start, (n_events,), 0, max(n_slots - duration, 1)))
    hot = np.asarray(jax.random.randint(k_hot, (n_events,), 0, n_models))
    events = []
    for s, m in zip(starts, hot):
        e = min(int(s) + duration, n_slots)
        # blend from the *current* pops so overlapping events compose
        # (both hot models stay elevated, the later one dominant) instead
        # of the later event erasing the earlier one
        pops[int(s):e] = (1 - intensity) * pops[int(s):e]
        pops[int(s):e, :, int(m)] += intensity
        events.append({"start": int(s), "end": e, "model": int(m)})
    model, home = _sample_requests(k_req, pops, n_users)
    return Trace("flash_crowd", model, home, _full_mask(n_slots, n_users),
                 {"zipf": zipf, "events": events, "intensity": intensity})


def mmpp(key, n_slots, n_users, n_bs, n_models, *, zipf=0.8,
         p_stay_calm=0.9, p_stay_burst=0.7, calm_load=0.4, burst_load=1.0,
         burst_sharpen=2.0):
    """Markov-modulated arrivals: a 2-state (calm/burst) chain modulates
    the active-user fraction and, in bursts, sharpens the popularity skew
    (``pop**burst_sharpen`` renormalized)."""
    import jax

    key = _key(key)
    k_pop, k_chain, k_act, k_req = jax.random.split(key, 4)
    pop = _per_bs_pop(k_pop, n_bs, n_models, zipf)
    sharp = pop ** burst_sharpen
    sharp = sharp / sharp.sum(-1, keepdims=True)
    u = np.asarray(jax.random.uniform(k_chain, (n_slots,)))
    state = np.zeros(n_slots, dtype=np.int32)
    s = 0
    for t in range(n_slots):
        stay = p_stay_calm if s == 0 else p_stay_burst
        s = s if u[t] < stay else 1 - s
        state[t] = s
    pops = np.where(state[:, None, None] == 1, sharp[None], pop[None])
    model, home = _sample_requests(k_req, pops, n_users)
    frac = np.where(state == 1, burst_load, calm_load)
    ua = np.asarray(jax.random.uniform(k_act, (n_slots, n_users)))
    mask = ua < frac[:, None]
    return Trace("mmpp", model, home, mask,
                 {"zipf": zipf, "burst_slots": int(state.sum())})


def mobility(key, n_slots, n_users, n_bs, n_models, *, zipf=0.8,
             p_move=0.05):
    """User handover: each user's home BS re-draws uniformly with
    probability ``p_move`` per slot (a lazy random walk); popularity is
    stationary per BS, so demand *composition* at each BS drifts with the
    users."""
    import jax

    key = _key(key)
    k_pop, k_h0, k_move, k_new, k_req = jax.random.split(key, 5)
    pop = _per_bs_pop(k_pop, n_bs, n_models, zipf)
    h0 = np.asarray(jax.random.randint(k_h0, (n_users,), 0, n_bs))
    moves = np.asarray(jax.random.uniform(
        k_move, (n_slots, n_users))) < p_move
    new = np.asarray(jax.random.randint(
        k_new, (n_slots, n_users), 0, n_bs))
    home = np.empty((n_slots, n_users), dtype=np.int32)
    cur = h0.astype(np.int32)
    for t in range(n_slots):
        cur = np.where(moves[t], new[t], cur).astype(np.int32)
        home[t] = cur
    # models from each user's *current* home popularity
    import jax.numpy as jnp
    logits = jnp.log(jnp.asarray(pop)[home] + 1e-30)      # (T, U, M)
    model = np.asarray(jax.random.categorical(k_req, logits, axis=-1),
                       dtype=np.int32)
    return Trace("mobility", model, home.astype(np.int32),
                 _full_mask(n_slots, n_users),
                 {"zipf": zipf, "p_move": p_move,
                  "handovers": int(moves.sum())})


# ---------------------------------------------------------------------------
# the policies' pre-drawn randomness
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DecisionStream:
    """Every random number the online policies consume, drawn up front.

    All four policies index the *same* stream, so no policy's consumption
    can perturb another's (nor the request trace, which has its own key):

      * ``adjust_ns[t, j]`` — the j-th BS adjusted in slot t (all policies);
      * ``u_model[t, j]``   — Random baseline's model pick (uniform in [0,1),
                              mapped onto the candidate list);
      * ``perms[t, j]``     — Random baseline's eviction scan order;
      * ``u_shrink[t, j, m]`` — Random baseline's shrink level for model m.
    """
    adjust_ns: np.ndarray        # (T, rounds) int
    u_model: np.ndarray          # (T, rounds) float64
    perms: np.ndarray            # (T, rounds, M) int
    u_shrink: np.ndarray         # (T, rounds, M) float64


def default_stream(cfg, ocfg, seed: int) -> DecisionStream:
    """The run's policy randomness for (cfg, ocfg): keyed off ``seed + 99``
    so it is independent of the trace key (``cfg.seed``).  The single
    derivation shared by ``run_online`` and ``run_online_grid`` — it is
    load-bearing for NumPy==scan replay."""
    return draw_decision_stream(ocfg.n_slots, ocfg.rounds, cfg.n_bs,
                                cfg.n_models, seed + 99)


def check_trace(trace: Trace, cfg, ocfg) -> Trace:
    """Validate a user-supplied trace against the run's shape (a silent
    mismatch would mis-normalize avg QoE or crash deep in the engines).

    Errors name the trace *and* its registry family and show the
    ``make_trace`` call that rebuilds it for this config — a registry-
    built grid mixes many (name, cfg) pairs and "has 60 users" alone does
    not say which entry to regenerate.
    """
    family = str(trace.meta.get("family", trace.name))
    hint = (f"rebuild it for this config with make_trace({family!r}, cfg, "
            f"n_slots={ocfg.n_slots}, seed=...) or pick a family from "
            f"repro.traces.available()")
    if trace.n_slots != ocfg.n_slots:
        raise ValueError(
            f"trace {trace.name!r} (family {family!r}) has "
            f"{trace.n_slots} slots but the run needs "
            f"ocfg.n_slots={ocfg.n_slots}; {hint}")
    if trace.n_users != cfg.n_users:
        raise ValueError(
            f"trace {trace.name!r} (family {family!r}) was generated for "
            f"{trace.n_users} users but cfg.n_users={cfg.n_users}; {hint}")
    if trace.home.max() >= cfg.n_bs or trace.model.max() >= cfg.n_models:
        raise ValueError(
            f"trace {trace.name!r} (family {family!r}) indexes BS/model "
            f"outside (n_bs={cfg.n_bs}, n_models={cfg.n_models}); {hint}")
    return trace


def draw_decision_stream(n_slots: int, rounds: int, n_bs: int,
                         n_models: int, seed: int) -> DecisionStream:
    rng = np.random.default_rng(seed)
    adjust_ns = rng.integers(0, n_bs, size=(n_slots, rounds))
    u_model = rng.random((n_slots, rounds))
    perms = np.stack([
        np.stack([rng.permutation(n_models) for _ in range(rounds)])
        for _ in range(n_slots)])
    u_shrink = rng.random((n_slots, rounds, n_models))
    return DecisionStream(adjust_ns=adjust_ns.astype(np.int32),
                          u_model=u_model,
                          perms=perms.astype(np.int32),
                          u_shrink=u_shrink)
