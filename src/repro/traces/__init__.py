"""Trace workload subsystem: precomputed request streams + the
device-resident online engine.

- ``repro.traces.generators`` — workload families as pure functions of a
  PRNG key (``Trace`` tensors every policy replays identically);
- ``repro.traces.registry`` — names them for sweeps;
- ``repro.traces.engine`` — the ``jax.lax.scan`` online engine (imported
  lazily: ``from repro.traces import engine``) that runs CoCaR-OL and the
  online baselines slot-by-slot on device, vmappable across
  (scenario, trace, seed, policy).
"""
from repro.traces.generators import (DecisionStream, Trace, check_trace,
                                     default_stream, draw_decision_stream)
from repro.traces.registry import available, default_trace, make_trace

__all__ = ["Trace", "DecisionStream", "check_trace", "default_stream",
           "draw_decision_stream", "available", "default_trace",
           "make_trace"]
