"""Trace workload subsystem: aggregated demand tensors + the
device-resident online engine.

- ``repro.traces.generators`` — per-user workload families as pure
  functions of a PRNG key (``Trace`` tensors every policy replays
  identically);
- ``repro.traces.workloads`` — the ``Workload`` protocol: per-slot
  ``(n_bs, n_models)`` request-count tensors (dense/aggregated/streaming
  Poisson/request-log families) that the engines consume, so no
  ``(n_slots, n_users)`` tensor is ever required;
- ``repro.traces.registry`` — names both for sweeps (``make_trace``,
  ``make_workload``);
- ``repro.traces.engine`` — the ``jax.lax.scan`` online engine (imported
  lazily: ``from repro.traces import engine``) that runs CoCaR-OL and the
  online baselines slot-by-slot on device, vmappable across
  (scenario, workload, seed, policy).
"""
from repro.traces.generators import (DecisionStream, Trace, check_trace,
                                     default_stream, draw_decision_stream)
from repro.traces.registry import (available, available_workloads,
                                   default_trace, default_workload,
                                   make_trace, make_workload)
from repro.traces.workloads import (AggregatedWorkload, DenseWorkload,
                                    PoissonWorkload, TraceLogWorkload,
                                    Workload, as_workload, check_workload)

__all__ = ["Trace", "DecisionStream", "check_trace", "default_stream",
           "draw_decision_stream", "available", "available_workloads",
           "default_trace", "default_workload", "make_trace",
           "make_workload", "Workload", "DenseWorkload",
           "AggregatedWorkload", "PoissonWorkload", "TraceLogWorkload",
           "as_workload", "check_workload"]
