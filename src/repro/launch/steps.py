"""Step functions: train_step (multi-exit loss + AdamW) and serve steps.

These are the functions lowered by the dry-run and executed by the examples.
The multi-exit weighted CE is the paper's dynamic-DNN joint training — every
submodel's ExtNet head learns simultaneously (Sec. III / MSDNet-style).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig, build_plan
from repro.training.optim import AdamWConfig, adamw_init, adamw_update

AUX_WEIGHT = 0.01   # MoE load-balance loss weight


def cross_entropy(logits, labels):
    """logits (B,S,V) any float dtype; labels (B,S) int32 (−1 = ignore).

    The gold logit is selected with an iota comparison instead of
    ``take_along_axis`` so the reduction partitions cleanly when V is sharded
    over "model" (a sharded-gather here replicates the batch under GSPMD)."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, len(lg.shape) - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], lg, 0.0), axis=-1)
    tok_loss = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(tok_loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _pick_chunk(n: int, target: int) -> int:
    for d in range(min(n, target), 0, -1):
        if n % d == 0:
            return d
    return n


def chunked_exit_ce(cfg, head_params, h, labels, chunk=1024):
    """CE from hidden states, head matmul rematerialized per sequence chunk —
    the (B, S, V) logits tensor is never materialized (MaxText-style)."""
    from repro.distribution.sharding import hint
    from repro.models.layers import exit_head_fwd
    if cfg.family == "vlm":
        h = h[:, cfg.frontend_len:, :]
    B, S, D = h.shape
    c = _pick_chunk(S, chunk)
    nc = S // c
    if nc == 1:
        lg = hint(exit_head_fwd(cfg, head_params, h), "batch", None, "model")
        return cross_entropy(lg, labels)
    hc = h.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, c).transpose(1, 0, 2)

    def body(carry, xs):
        s, n = carry
        hcb, lab = xs
        lg = hint(exit_head_fwd(cfg, head_params, hcb), "batch", None, "model")
        lgf = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lgf, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, lgf.shape, 2)
        gold = jnp.sum(jnp.where(iota == lab[..., None], lgf, 0.0), axis=-1)
        mask = (lab >= 0).astype(jnp.float32)
        return (s + jnp.sum((lse - gold) * mask), n + jnp.sum(mask)), None

    body = jax.checkpoint(body, prevent_cse=False)
    (s, n), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hc, lc))
    return s / jnp.maximum(n, 1.0)


def make_loss_fn(cfg: ModelConfig, plan=None):
    plan = plan or build_plan(cfg)
    wsum = sum(cfg.exit_loss_weights)

    def loss_fn(params, batch):
        labels = batch["labels"]

        def consume(j, h):
            return chunked_exit_ce(cfg, params["exits"][j], h, labels)

        per_exit, aux = M.apply_train(cfg, params, batch, plan, consume=consume)
        total = sum(w * ce for w, ce in zip(cfg.exit_loss_weights, per_exit))
        loss = total / wsum + AUX_WEIGHT * aux
        return loss, {"ce_per_exit": jnp.stack(per_exit), "aux": aux}

    return loss_fn


def init_train_state(cfg: ModelConfig, key):
    params = M.init(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(cfg: ModelConfig, oc: AdamWConfig = AdamWConfig(),
                    plan=None, microbatches: int = 0):
    plan = plan or build_plan(cfg)
    loss_fn = make_loss_fn(cfg, plan)
    mb = microbatches or cfg.train_microbatches

    def grad_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(state, batch):
        params = state["params"]
        if mb <= 1:
            (loss, extras), grads = grad_of(params, batch)
        else:
            # gradient accumulation: scan over microbatch indices, slicing
            # the (integer) batch per step — slicing raw tokens keeps GSPMD
            # away from re-partitioning hoisted embedding gathers
            B = jax.tree.leaves(batch)[0].shape[0]
            size = B // mb
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def body(carry, i):
                gsum, lsum, esum = carry
                micro = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * size, size, axis=0), batch)
                (l, e), g = grad_of(params, micro)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                esum = jax.tree.map(lambda a, b: a + b, esum, e)
                return (gsum, lsum + l, esum), None

            e0 = {"ce_per_exit": jnp.zeros((cfg.n_exits,), jnp.float32),
                  "aux": jnp.float32(0)}
            (gsum, lsum, esum), _ = jax.lax.scan(
                body, (g0, jnp.float32(0), e0), jnp.arange(mb))
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = lsum / mb
            extras = jax.tree.map(lambda e: e / mb, esum)
        params, opt, om = adamw_update(oc, state["params"], grads, state["opt"])
        metrics = {"loss": loss, **extras, **om}
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, exit_idx: int = -1, plan=None):
    plan = plan or build_plan(cfg)

    def prefill_step(params, batch, cache):
        return M.prefill(cfg, params, batch, cache, exit_idx=exit_idx,
                         plan=plan)

    return prefill_step


def make_decode_step(cfg: ModelConfig, exit_idx: int = -1, plan=None):
    plan = plan or build_plan(cfg)

    def decode_step(params, tokens, pos, cache):
        logits, cache = M.decode(cfg, params, tokens, pos, cache,
                                 exit_idx=exit_idx, plan=plan)
        return logits, cache

    return decode_step
