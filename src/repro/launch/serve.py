"""Serving launcher: CoCaR-OL control plane driving the edge cluster.

  PYTHONPATH=src python -m repro.launch.serve --pods 3 --slots 20

Each slot: requests arrive (Zipf over the model catalog), the engine routes
and executes real token generation with the cached submodels, and the
control plane adjusts submodel residency by expected future gain — with a
pod failure injected mid-run to exercise re-routing.
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, default=3)
    ap.add_argument("--slots", type=int, default=20)
    ap.add_argument("--rps", type=int, default=8, help="requests per slot")
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro import configs
    from repro.models import partition
    from repro.serving import EdgeCluster, Request, WeightStore

    rng = np.random.default_rng(args.seed)
    models = {"qwen-edge": configs.get_smoke("qwen1.5-0.5b"),
              "glm-edge": configs.get_smoke("chatglm3-6b"),
              "mix-edge": configs.get_smoke("mixtral-8x7b")}
    store = WeightStore(models, seed=args.seed)
    cap = int(1.1 * max(partition.submodel_bytes(c, c.n_exits - 1)
                        for c in models.values()))
    cluster = EdgeCluster(store, n_pods=args.pods, capacity_bytes=cap,
                          bandwidth_Bps=2e8)
    names = list(models)
    # initial placement: spread smallest submodels
    cluster.apply_caching({i: {names[i % len(names)]: 0,
                               names[(i + 1) % len(names)]: 0}
                           for i in range(args.pods)})
    cluster.tick(2.0)
    pop = np.asarray([0.6, 0.3, 0.1])
    served = missed = 0
    psum = 0.0
    for slot in range(args.slots):
        if slot == args.fail_at:
            cluster.fail_pod(0)
            print(f"== slot {slot}: pod0 failed ==")
        if slot == args.slots // 2:
            pop = pop[::-1].copy()
            print(f"== slot {slot}: popularity flipped ==")
        reqs = [Request(rid=slot * 100 + i,
                        model=names[rng.choice(len(names), p=pop)],
                        tokens=list(rng.integers(1, 100, 4)), max_new=4,
                        home=int(rng.integers(args.pods)),
                        deadline=cluster.now + 60)
                for i in range(args.rps)]
        s = cluster.submit(reqs)
        served += s
        missed += len(reqs) - s
        psum += sum(r.precision for r in reqs)
        # greedy control step: upgrade the most-requested model wherever
        # there is capacity (stand-in for the CoCaR-OL gain computation at
        # this scale; examples/online_adaptation.py runs the real one)
        hot = names[int(np.argmax(pop))]
        for pod in cluster.pods:
            if pod.failed:
                continue
            cur = pod.cache.serveable(hot)
            cfg = models[hot]
            if cur < cfg.n_exits - 1:
                try:
                    pod.cache.request_load(hot, cur + 1, cluster.now)
                except MemoryError:
                    for other in names:
                        if other != hot and pod.cache.serveable(other) > 0:
                            pod.cache.evict(other)
                            break
        cluster.tick(1.0)
        res = {p.idx: dict(p.cache.resident) for p in cluster.pods}
        print(f"slot {slot:3d}: served {s}/{len(reqs)} resident={res}")
    total = served + missed
    print(f"\nserved {served}/{total} ({served/total:.1%}); "
          f"avg precision {psum/total:.3f}")


if __name__ == "__main__":
    main()
