"""Loop-aware analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so any scanned
program (stacked-layer scans, flash-attention block loops, SSD chunk scans)
is under-counted by the trip count.  This walker parses the HLO module,
recovers trip counts from loop conditions, and multiplies through:

  * flops        — exact for dot ops (2 · prod(out) · prod(contracting));
                   elementwise excluded (VPU, not the MXU roofline term)
  * coll         — collective bytes by op kind (output-shape proxy)
  * hbm_bytes    — HBM traffic proxy: Σ over top-level ops of operand+output
                   bytes (fusions are single ops, so internals don't count;
                   parameter/tuple/gte/bitcast/constant are free)

All numbers are per-device (the HLO is the partitioned per-device module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List

_DT = {"pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "f8e4m3fn": 1,
       "f8e5m2": 1, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
       "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8,
       "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\([^)]*\)|\S+)\s+"
    r"(?P<kind>[\w\-]+)\((?P<rest>.*)$")
_COMP = re.compile(r"^(?:ENTRY\s+)?%(?P<name>[\w.\-]+)\s*\(")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_FREE = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
         "after-all", "partition-id", "replica-id", "iota"}


def _bytes_of(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_text):
        if dt not in _DT:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += int(n * _DT[dt])
    return total


def _dims_of(shape_text: str):
    m = _SHAPE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[dict]] = {}
        self.entry = None
        self._parse(text)
        self._memo: Dict[str, Cost] = {}
        self._slice_memo: Dict[str, tuple] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            if line.startswith("}"):
                cur = None
                continue
            mc = _COMP.match(line)
            if mc and line.rstrip().endswith("{") and "->" in line:
                cur = mc.group("name")
                self.comps[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None:
                continue
            mo = _OP.match(line)
            if not mo:
                continue
            rest = mo.group("rest")
            close = rest.find(")")
            operand_text = rest[:close if close >= 0 else len(rest)]
            self.comps[cur].append({
                "name": mo.group("name"),
                "shape": mo.group("shape"),
                "kind": mo.group("kind"),
                "operands": re.findall(r"%([\w.\-]+)", operand_text),
                "attrs": rest[close + 1:] if close >= 0 else "",
                "line": line,
            })

    # ------------------------------------------------------------------
    def _trip_count(self, cond_name: str) -> int:
        ops = self.comps.get(cond_name, [])
        consts = []
        for op in ops:
            consts += [int(c) for c in _CONST.findall(op["line"])]
        return max(consts) if consts else 1

    def _dot_flops(self, op, symtab) -> float:
        out = 1
        for d in _dims_of(op["shape"]):
            out *= d
        lhs_shape = symtab.get(op["operands"][0]) if op["operands"] else None
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op["line"])
        contract = 1
        if lhs_shape and m:
            ld = _dims_of(lhs_shape)
            for i in m.group(1).split(","):
                if i and int(i) < len(ld):
                    contract *= ld[int(i)]
        return 2.0 * out * contract

    def _slice_kinds(self, comp_name: str):
        """(has_dynamic_slice, has_dynamic_update_slice) incl. nested calls."""
        if comp_name in self._slice_memo:
            return self._slice_memo[comp_name]
        self._slice_memo[comp_name] = (False, False)
        ds = dus = False
        for op in self.comps.get(comp_name, []):
            if op["kind"] in ("dynamic-slice", "gather"):
                ds = True
            if op["kind"] in ("dynamic-update-slice", "scatter"):
                dus = True
            if op["kind"] in ("fusion", "call"):
                m = _CALLS.search(op["line"])
                if m and m.group(1) in self.comps:
                    d2, u2 = self._slice_kinds(m.group(1))
                    ds, dus = ds or d2, dus or u2
        self._slice_memo[comp_name] = (ds, dus)
        return ds, dus

    def _op_hbm_bytes(self, op, symtab) -> float:
        """Traffic model for one top-level op.

        Slice-aware: a dynamic-slice/gather reads only ~its output; an
        in-place dynamic-update-slice (cache write) moves ~2x the update,
        not the whole aliased buffer.  Everything else: operands + output.
        """
        kind = op["kind"]
        if kind == "convert":
            # XLA:CPU materializes bf16<->f32 upcasts of whole buffers; on
            # TPU bf16 is native and converts fuse into consumers — free.
            return 0.0
        out_b = _bytes_of(op["shape"])
        in_bs = [_bytes_of(symtab.get(o, "")) for o in op["operands"]]
        ds = kind in ("dynamic-slice", "gather")
        dus = kind in ("dynamic-update-slice", "scatter")
        if kind in ("fusion", "call"):
            m = _CALLS.search(op["line"])
            if m:
                d2, u2 = self._slice_kinds(m.group(1))
                ds, dus = ds or d2, dus or u2
        if dus and any(b == out_b for b in in_bs):
            # in-place update of an aliased buffer: count the small operands
            # twice (read-modify-write of the touched region)
            return 2.0 * sum(b for b in in_bs if b != out_b)
        if ds:
            # sliced read: the big source is touched only output-wide
            return out_b + sum(b for b in in_bs if b <= 4 * out_b)
        return out_b + sum(in_bs)

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Cost()
        self._memo[comp_name] = total          # break cycles defensively
        ops = self.comps.get(comp_name, [])
        symtab = {op["name"]: op["shape"] for op in ops}
        for op in ops:
            kind = op["kind"]
            attrs = op["attrs"] + op["line"]
            if kind == "while":
                body = None
                mb = re.search(r"body=%?([\w.\-]+)", op["line"])
                mcnd = _COND.search(op["line"])
                if mb:
                    body = mb.group(1)
                trip = self._trip_count(mcnd.group(1)) if mcnd else 1
                if body in self.comps:
                    total.add(self.cost_of(body), mult=trip)
                continue
            if kind in ("fusion", "call", "async-start"):
                mcall = _CALLS.search(op["line"])
                if mcall and mcall.group(1) in self.comps:
                    sub = self.cost_of(mcall.group(1))
                    total.flops += sub.flops
                    for k, v in sub.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                # HBM: the fusion op itself moves operands+output
            if kind == "dot":
                total.flops += self._dot_flops(op, symtab)
            base = kind.replace("-start", "")
            if base in COLLECTIVES:
                b = _bytes_of(op["shape"])
                total.coll[base] = total.coll.get(base, 0.0) + b
            if kind in _FREE or kind.endswith("-done"):
                continue
            total.hbm_bytes += self._op_hbm_bytes(op, symtab)
        self._memo[comp_name] = total
        return total


def analyse_hlo(text: str) -> dict:
    mod = HloModule(text)
    if mod.entry is None:
        return {"error": "no ENTRY computation found"}
    c = mod.cost_of(mod.entry)
    return {"flops": c.flops, "hbm_bytes": c.hbm_bytes,
            "collectives": {k: int(v) for k, v in sorted(c.coll.items())},
            "collective_bytes": int(sum(c.coll.values()))}
