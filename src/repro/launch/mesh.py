"""Production mesh construction.

Single pod:  (16, 16) = 256 chips, axes ("data", "model")   — TPU v5e pod.
Multi-pod:   (2, 16, 16) = 512 chips, axes ("pod", "data", "model");
             the "pod" axis is pure data-parallel (DCN-friendly: only the
             gradient all-reduce crosses pods).

Defined as functions so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    import numpy as np
    n = data * model
    dev = np.asarray(jax.devices()[:n]).reshape((data, model))
    return jax.sharding.Mesh(dev, ("data", "model"))


# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
