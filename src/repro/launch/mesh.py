"""Production mesh construction.

Single pod:  (16, 16) = 256 chips, axes ("data", "model")   — TPU v5e pod.
Multi-pod:   (2, 16, 16) = 512 chips, axes ("pod", "data", "model");
             the "pod" axis is pure data-parallel (DCN-friendly: only the
             gradient all-reduce crosses pods).

Defined as functions so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small ("data", "model") mesh for host-device runs (CPU tests, the
    ``repro.scale`` grid executor).  Validates the device count up front:
    a short mesh would otherwise surface as an inscrutable reshape or
    shard_map error far from the cause."""
    import numpy as np
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got ({data}, {model})")
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh ({data}, {model}) needs {n} devices, but only "
            f"{len(devices)} exist — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before the first "
            "jax import (see benchmarks/bench_scale.py), or shrink the mesh")
    dev = np.asarray(devices[:n]).reshape((data, model))
    return jax.sharding.Mesh(dev, ("data", "model"))


# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
