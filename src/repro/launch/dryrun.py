"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh and extract roofline inputs (FLOPs, bytes, collective bytes, memory).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all                 # 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod     # 2x16x16

Results are cached as JSON under results/dryrun/.
"""
# The very first lines — before ANY other import, jax locks the device count
# on first init.  512 placeholder host devices back the production meshes.
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                                   # noqa: E402
from repro.distribution import sharding as shd              # noqa: E402
from repro.launch import specs as SP                        # noqa: E402
from repro.launch.hlo_analysis import analyse_hlo           # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.launch.steps import (init_train_state,           # noqa: E402
                                make_decode_step,
                                make_prefill_step, make_train_step)
from repro.models import model as M                         # noqa: E402
from repro.models.config import build_plan                  # noqa: E402

def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh, exit_idx: int = -1):
    """Returns the lowered computation for one (arch, shape, mesh) cell."""
    cfg = configs.get_config(arch)
    seq, batch, mode = SP.SHAPES[shape_name]
    plan = build_plan(cfg)
    pshapes = jax.eval_shape(lambda: M.init(cfg, jax.random.key(0)))
    # sharding regime per workload (§Perf iteration): weight-stationary TP
    # only pays off when activations are tiny (decode); train AND prefill
    # (1M-token batches) want FSDP×TP — serve-mode MoE sharding at prefill
    # made GSPMD replicate the dispatch einsums 16x (measured, reverted)
    pspec = shd.param_specs(cfg, mesh, pshapes,
                            mode="serve" if mode == "decode" else "train")
    psh = named(mesh, pspec)
    bd = shd.batch_dim_spec(mesh, batch)
    ins = SP.input_specs(cfg, shape_name)

    if mode == "train":
        state_shapes = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.key(0)))
        opt_sh = {"master": psh, "m": psh, "v": psh,
                  "step": NamedSharding(mesh, P())}
        state_sh = {"params": psh, "opt": opt_sh}
        batch_sh = named(mesh, shd.batch_specs(cfg, mesh, batch, mode))
        fn = make_train_step(cfg, plan=plan)
        jfn = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                      donate_argnums=(0,))
        return jfn.lower(state_shapes, ins["batch"])

    csh = named(mesh, shd.cache_specs(cfg, mesh, batch, plan))
    if mode == "prefill":
        batch_sh = named(mesh, shd.batch_specs(cfg, mesh, batch, mode))
        fn = make_prefill_step(cfg, exit_idx=exit_idx, plan=plan)
        jfn = jax.jit(fn, in_shardings=(psh, batch_sh, csh),
                      donate_argnums=(2,))
        return jfn.lower(pshapes, ins["batch"], ins["cache"])

    # decode
    tok_sh = NamedSharding(mesh, P(bd, None))
    pos_sh = NamedSharding(mesh, P())
    fn = make_decode_step(cfg, exit_idx=exit_idx, plan=plan)
    jfn = jax.jit(fn, in_shardings=(psh, tok_sh, pos_sh, csh),
                  donate_argnums=(3,))
    return jfn.lower(pshapes, ins["tokens"], ins["pos"], ins["cache"])


def analyse(lowered, dump_hlo: str = None):
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    res = {"compile_s": round(compile_s, 1)}

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        # NOTE: XLA counts while bodies once -> raw values under-count scans;
        # the loop-aware numbers below are the roofline inputs.
        res["flops_per_device_raw"] = float(ca.get("flops", -1.0))
        res["bytes_per_device_raw"] = float(ca.get("bytes accessed", -1.0))
    except Exception as e:   # pragma: no cover
        res["cost_analysis_error"] = str(e)

    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                res[k] = int(v)
        if "argument_size_in_bytes" in res:
            res["peak_bytes_per_device"] = (
                res.get("argument_size_in_bytes", 0)
                + res.get("temp_size_in_bytes", 0)
                + res.get("output_size_in_bytes", 0))
    except Exception as e:   # pragma: no cover
        res["memory_analysis_error"] = str(e)

    hlo = compiled.as_text()
    la = analyse_hlo(hlo)
    res["flops_per_device"] = la.get("flops")
    res["hbm_bytes_per_device"] = la.get("hbm_bytes")
    res["collectives"] = la.get("collectives", {})
    res["collective_bytes_per_device"] = la.get("collective_bytes", 0)
    if dump_hlo:
        pathlib.Path(dump_hlo).write_text(hlo)
        res["hlo_path"] = dump_hlo
    return res


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             force: bool = False, dump_hlo: bool = False):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    out = out_dir / mesh_name / f"{arch}__{shape_name}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    if out.exists() and not force:
        rec = json.loads(out.read_text())
        print(f"[cached] {mesh_name} {arch} {shape_name}: ok={rec.get('ok')}")
        return rec

    cfg = configs.get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not SP.supports_cell(cfg, shape_name):
        rec.update(ok=None, skipped=SP.skip_reason(cfg, shape_name))
        out.write_text(json.dumps(rec, indent=1))
        print(f"[skip]   {mesh_name} {arch} {shape_name}: {rec['skipped']}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        with mesh:
            t0 = time.time()
            lowered = lower_cell(arch, shape_name, mesh)
            rec["lower_s"] = round(time.time() - t0, 1)
            hlo_path = (str(out)[:-5] + ".hlo") if dump_hlo else None
            rec.update(analyse(lowered, dump_hlo=hlo_path))
            rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out.write_text(json.dumps(rec, indent=1))
    status = "ok" if rec["ok"] else "FAIL"
    print(f"[{status}]   {mesh_name} {arch} {shape_name} "
          f"lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s "
          f"coll={rec.get('collective_bytes_per_device', 0)/1e6:.0f}MB"
          + ("" if rec["ok"] else f"  {rec.get('error', '')[:200]}"))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    cells = []
    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SP.SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    n_fail = 0
    for a, s in cells:
        rec = run_cell(a, s, args.multi_pod, out_dir, force=args.force,
                       dump_hlo=args.dump_hlo)
        if rec.get("ok") is False:
            n_fail += 1
    print(f"done: {len(cells)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
