"""Input shape cells: ShapeDtypeStruct stand-ins for every (arch × shape).

Weak-type-correct, shardable, no device allocation — consumed by
``jax.jit(...).lower()`` in the dry-run and by the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig, build_plan

S = jax.ShapeDtypeStruct

SHAPES = {
    #                 seq      global_batch  mode
    "train_4k":     (4_096,    256,          "train"),
    "prefill_32k":  (32_768,   32,           "prefill"),
    "decode_32k":   (32_768,   128,          "decode"),
    "long_500k":    (524_288,  1,            "decode"),
}


def supports_cell(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k only for sub-quadratic-decode archs (DESIGN.md §5)."""
    if shape_name != "long_500k":
        return True
    if cfg.family in ("hybrid_mamba", "xlstm"):
        return True
    return cfg.sliding_window > 0          # SWA ring cache bounds KV


def skip_reason(cfg: ModelConfig, shape_name: str) -> str:
    if supports_cell(cfg, shape_name):
        return ""
    if cfg.family == "encdec":
        return "enc-dec: architecture context << 500k"
    return "pure full attention: 500k decode KV is quadratic-era; skipped per assignment"


def batch_structs(cfg: ModelConfig, seq: int, batch: int, mode: str):
    """ShapeDtypeStructs for the model input batch."""
    emb_dt = jnp.dtype(cfg.dtype)
    d = {}
    if cfg.family == "vlm":
        d["tokens"] = S((batch, seq - cfg.frontend_len), jnp.int32)
        d["patches"] = S((batch, cfg.frontend_len, cfg.d_model), emb_dt)
    else:
        d["tokens"] = S((batch, seq), jnp.int32)
    if cfg.family == "encdec":
        d["frames"] = S((batch, cfg.encoder_len, cfg.d_model), emb_dt)
    if mode == "train":
        d["labels"] = S(d["tokens"].shape, jnp.int32)
    return d


def cache_structs(cfg: ModelConfig, batch: int, max_len: int, plan=None):
    plan = plan or build_plan(cfg)
    return jax.eval_shape(lambda: M.cache_init(cfg, batch, max_len, plan))


def input_specs(cfg: ModelConfig, shape_name: str):
    """Full abstract inputs for the step function of this cell.

    train  -> {"batch": ...}
    prefill-> {"batch": ..., "cache": ...}
    decode -> {"tokens": (B,1), "pos": scalar, "cache": ...}
    """
    seq, batch, mode = SHAPES[shape_name]
    plan = build_plan(cfg)
    if mode == "train":
        return {"batch": batch_structs(cfg, seq, batch, mode)}
    if mode == "prefill":
        return {"batch": batch_structs(cfg, seq, batch, mode),
                "cache": cache_structs(cfg, batch, seq, plan)}
    if mode == "decode":
        return {"tokens": S((batch, 1), jnp.int32),
                "pos": S((), jnp.int32),
                "cache": cache_structs(cfg, batch, seq, plan)}
    raise ValueError(mode)
