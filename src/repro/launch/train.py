"""Training launcher.

Smoke scale (CPU, default):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 200 --ckpt results/ckpt_run

Production scale (TPU pod; the same code path the dry-run compiles):
  python -m repro.launch.train --arch mixtral-8x7b --full --mesh 16x16

The loop is fault-tolerant: checkpoints are atomic and the launcher
auto-resumes from the latest complete one, so preempted jobs just re-run
the same command.
"""
from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="full-size config on a production mesh (TPU)")
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16"])
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    from repro import configs
    from repro.distribution import sharding as shd
    from repro.launch.steps import init_train_state
    from repro.training.data import markov_stream
    from repro.training.loop import TrainConfig, train
    from repro.training.optim import AdamWConfig

    cfg = (configs.get_config(args.arch) if args.full
           else configs.get_smoke(args.arch))
    oc = AdamWConfig(lr=args.lr, total_steps=args.steps)

    if args.full:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "2x16x16")
        shapes = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.key(args.seed)))
        psh = shd.named(mesh, shd.param_specs(cfg, mesh, shapes["params"]))
        print(f"mesh {mesh.shape}; params sharded FSDPxTP; "
              f"microbatches={cfg.train_microbatches}")
        with mesh:
            _run(cfg, oc, args)
        return
    _run(cfg, oc, args)


def _run(cfg, oc, args):
    from repro.training.data import markov_stream
    from repro.training.loop import TrainConfig, train

    tc = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
                     log_every=max(args.steps // 20, 1), seed=args.seed)
    data = markov_stream(cfg.vocab_size, args.batch, args.seq,
                         args.steps + 8, seed=args.seed)
    state, hist = train(cfg, tc, data, oc=oc)
    print(f"done: final loss {hist[-1]['loss']:.4f}; "
          f"per-exit CE {[round(c, 3) for c in hist[-1]['ce_per_exit']]}")


if __name__ == "__main__":
    main()
