"""Dynamic-DNN catalog: submodel attributes (r_h, p_h, c_h, D_m).

Three sources behind one registry (``make_catalog(source=...)``, the
catalog counterpart of ``repro.traces.make_workload``):

  * ``paper`` — the paper's own measurements (ViT, Tables II & III);
    model type 0 is ViT exactly, types 1..M-1 are deterministic
    size-jittered variants (the paper uses 8 ViT/Swin-class types but
    publishes only ViT's table);
  * ``zoo`` — derived from the real architecture zoo via
    ``models.partition.catalog_entry`` (sizes/FLOPs from the actual
    configs), used by the framework-scale serving examples;
  * ``measured`` — like ``zoo`` but with the loading-latency matrix D_m
    computed from the *actual parameter-tree bytes* each submodel
    transition transfers (``models.partition.delta_bytes`` — the exact
    byte math ``serving.loader.PodCache`` executes) over an explicit
    load bandwidth, cross-checkable against Table III via
    :func:`table3_mem_rate`.  This is the catalog the closed-loop
    serving bench (``benchmarks/bench_serving.py``) optimizes and then
    *executes*.

Every source returns a :class:`Catalog` — a named, frozen view of the
four arrays.  Positional ``(sizes, prec, flops, loadD)`` unpacking is
gone: call sites read fields by name.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.configs.vit_edge import VIT_LOAD_S, VIT_SUBMODELS


@dataclass(frozen=True)
class Catalog:
    """The model catalog the JDCR instances and the serving data plane
    share.  Index 0 of the submodel axis is "not cached" (zero size/
    precision); index j >= 1 is submodel h_j (serving exit ``j - 1``)."""
    sizes: np.ndarray            # (M, H+1) MB
    prec: np.ndarray             # (M, H+1) delivered precision
    flops: np.ndarray            # (M, H+1) GFLOP per request
    loadD: np.ndarray            # (M, H+1, H+1) switch seconds [from, to]
    source: str = "paper"
    names: tuple = ()            # model names ("" entries for paper types)
    bandwidth_MBps: float = 0.0  # load bandwidth behind loadD (0 = assumed)
    meta: dict = field(default_factory=dict)

    @property
    def n_models(self) -> int:
        return self.sizes.shape[0]

    @property
    def H(self) -> int:
        return self.sizes.shape[1] - 1

    def load_seconds(self, m: int, lvl_from: int, lvl_to: int) -> float:
        """D_m for one transition, catalog-level indexed (0 = empty)."""
        return float(self.loadD[m, lvl_from, lvl_to])


def paper_catalog(n_models: int = 8, seed: int = 7) -> Catalog:
    """The paper's measured ViT tables, jittered into ``n_models`` types."""
    H = len(VIT_SUBMODELS)
    rng = np.random.default_rng(seed)
    # 0.5..1.4: the catalog spans ~87..480 MB submodels, so the smallest
    # submodels fit the paper's 100 MB low-capacity operating point (Fig 12)
    factors = np.concatenate([[1.0], rng.uniform(0.5, 1.4, n_models - 1)])

    sizes = np.zeros((n_models, H + 1))
    prec = np.zeros((n_models, H + 1))
    flops = np.zeros((n_models, H + 1))
    loadD = np.zeros((n_models, H + 1, H + 1))
    base_load = np.asarray(VIT_LOAD_S)                     # (H+1, H)

    for m, f in enumerate(factors):
        for j, sub in enumerate(VIT_SUBMODELS):
            sizes[m, j + 1] = sub["memory_mb"] * f
            flops[m, j + 1] = sub["gflops"] * f
            dp = rng.uniform(-0.015, 0.015) if m else 0.0
            prec[m, j + 1] = min(sub["precision"] + dp, 0.999)
        # loading/switch times scale with the transferred bytes
        loadD[m, :, 1:] = base_load * f
        # switching down / evicting is (nearly) free (paper Sec. VI)
        loadD[m, 1:, 0] = 0.0
    return Catalog(sizes=sizes, prec=prec, flops=flops, loadD=loadD,
                   source="paper", names=("vit",) + ("",) * (n_models - 1))


def zoo_catalog(arch_ids, ctx: int = 2048,
                mem_rate_mbps: float = 2024.0) -> Catalog:
    """Catalog derived from the real architecture zoo (framework scale).

    mem_rate is the secondary-storage->memory load rate implied by the
    paper's Table III (~253 MB/s)."""
    from repro import configs

    cfgs = {a: configs.get_config(a) for a in arch_ids}
    return _derived_catalog(cfgs, ctx=ctx, source="zoo",
                            bandwidth_MBps=mem_rate_mbps / 8.0,
                            measured_loadD=False)


def measured_catalog(cfgs: dict, tokens: int = 64,
                     bandwidth_MBps: float = None) -> Catalog:
    """Catalog whose loading latencies are *measured*, not assumed.

    ``cfgs`` maps model names to real ``ModelConfig``s.  Sizes and the
    D_m matrix come from the actual parameter-tree bytes each submodel
    transition moves (``partition.submodel_bytes`` / ``delta_bytes`` via
    ``jax.eval_shape`` — no weights materialize), divided by
    ``bandwidth_MBps`` (default: the storage->memory rate the paper's
    Table III implies, :func:`table3_mem_rate`).  FLOPs are per
    ``tokens``-token request, so a request's inference time agrees
    between the LP's latency model and the queue simulator's
    ``service_time`` when both use the same compute figure.
    """
    if bandwidth_MBps is None:
        bandwidth_MBps = table3_mem_rate()["median"]
    return _derived_catalog(dict(cfgs), ctx=tokens, source="measured",
                            bandwidth_MBps=float(bandwidth_MBps),
                            measured_loadD=True, tokens=tokens)


def _derived_catalog(cfgs: dict, ctx: int, source: str,
                     bandwidth_MBps: float, measured_loadD: bool,
                     tokens: int = None) -> Catalog:
    from repro.models import partition

    names = tuple(cfgs)
    H = max(c.n_exits for c in cfgs.values())
    M = len(cfgs)
    sizes = np.zeros((M, H + 1))
    prec = np.zeros((M, H + 1))
    flops = np.zeros((M, H + 1))
    loadD = np.zeros((M, H + 1, H + 1))
    rate = bandwidth_MBps * 1e6                             # bytes/s
    for m, cfg in enumerate(cfgs.values()):
        entries = partition.catalog_entry(cfg, ctx)
        # depth-quality curve: saturating toward a per-arch ceiling
        for j, e in enumerate(entries):
            frac = cfg.exit_layers[j] / cfg.n_layers
            sizes[m, j + 1] = e["r_h"] / 1e6                # MB
            prec[m, j + 1] = 0.99 * (1 - 0.45 * (1 - frac) ** 1.5)
            if tokens is None:
                flops[m, j + 1] = e["c_h"] / 1e9            # GFLOP/token
            else:
                flops[m, j + 1] = tokens * e["c_h"] / 1e9   # GFLOP/request
        for prev in range(H + 1):
            for tgt in range(1, H + 1):
                if measured_loadD:
                    # the serving loader's exact byte math: an upgrade
                    # transfers only the Delta segments + new exit head,
                    # a shrink is an instant slice (PodCache semantics)
                    if tgt > prev:
                        nbytes = partition.delta_bytes(cfg, prev - 1,
                                                       tgt - 1)
                        loadD[m, prev, tgt] = nbytes / rate
                    else:
                        loadD[m, prev, tgt] = 0.0
                elif tgt >= prev:
                    delta = sizes[m, tgt] - (sizes[m, prev] if prev else 0.0)
                    loadD[m, prev, tgt] = delta * 1e6 / rate + 0.01
                else:
                    loadD[m, prev, tgt] = 0.042             # prune overhead
    return Catalog(sizes=sizes, prec=prec, flops=flops, loadD=loadD,
                   source=source, names=names,
                   bandwidth_MBps=float(bandwidth_MBps),
                   meta={"ctx": ctx, "measured_loadD": measured_loadD})


def table3_mem_rate() -> dict:
    """The storage->memory load rates the paper's Table III implies.

    Each upgrade (from submodel i to j) in ``VIT_LOAD_S`` moves
    ``size[j] - size[i]`` MB in the listed seconds; the implied MB/s
    band is the cross-check a measured catalog's bandwidth must land in
    (Table III's rates are not constant — per-transition overheads make
    small transfers look slower — so this is a band, not one number).
    """
    sz = np.array([0.0] + [s["memory_mb"] for s in VIT_SUBMODELS])
    load = np.asarray(VIT_LOAD_S)                           # (H+1, H)
    rates = []
    for i in range(load.shape[0]):
        for j in range(1, load.shape[1] + 1):
            if j > i and load[i, j - 1] > 0:
                rates.append((sz[j] - sz[i]) / load[i, j - 1])
    rates = np.asarray(rates)
    return {"min": float(rates.min()), "max": float(rates.max()),
            "median": float(np.median(rates)),
            "rates_MBps": rates.tolist()}


def crosscheck_table3(catalog: Catalog, slack: float = 0.10) -> dict:
    """Does a measured catalog's load bandwidth sit inside the rate band
    Table III implies (within ``slack`` relative tolerance at the band
    edges)?  Returns the verdict plus both sides of the comparison —
    the gated provenance record in ``BENCH_serving.json``."""
    band = table3_mem_rate()
    bw = float(catalog.bandwidth_MBps)
    ok = (band["min"] * (1 - slack)) <= bw <= (band["max"] * (1 + slack))
    return {"ok": bool(ok), "bandwidth_MBps": bw,
            "table3_min_MBps": band["min"], "table3_max_MBps": band["max"],
            "table3_median_MBps": band["median"]}


#: registry: catalog source name -> constructor
CATALOG_SOURCES = {
    "paper": paper_catalog,
    "zoo": zoo_catalog,
    "measured": measured_catalog,
}


def make_catalog(source: str = "paper", **kw) -> Catalog:
    """Build a named catalog — ``make_catalog("paper", n_models=8)``,
    ``make_catalog("zoo", arch_ids=[...])``, or
    ``make_catalog("measured", cfgs={...}, bandwidth_MBps=...)``."""
    try:
        fn = CATALOG_SOURCES[source]
    except KeyError:
        raise ValueError(f"unknown catalog source {source!r}; one of "
                         f"{tuple(CATALOG_SOURCES)}") from None
    return fn(**kw)
