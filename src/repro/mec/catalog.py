"""Dynamic-DNN catalog: submodel attributes (r_h, p_h, c_h, D_m).

Two sources:
  * the paper's own measurements (ViT, Tables II & III) — model type 0 is
    ViT exactly; types 1..M-1 are deterministic size-jittered variants
    (the paper uses 8 ViT/Swin-class types but publishes only ViT's table);
  * derived catalogs from the real architecture zoo via
    ``models.partition.catalog_entry`` (sizes/FLOPs from the actual configs),
    used by the framework-scale serving examples.
"""
from __future__ import annotations

import numpy as np

from repro.configs.vit_edge import VIT_LOAD_S, VIT_SUBMODELS


def paper_catalog(n_models: int = 8, seed: int = 7):
    """Returns (sizes (M,H+1) MB, prec (M,H+1), flops (M,H+1) GFLOP/request,
    loadD (M,H+1,H+1) seconds)."""
    H = len(VIT_SUBMODELS)
    rng = np.random.default_rng(seed)
    # 0.5..1.4: the catalog spans ~87..480 MB submodels, so the smallest
    # submodels fit the paper's 100 MB low-capacity operating point (Fig 12)
    factors = np.concatenate([[1.0], rng.uniform(0.5, 1.4, n_models - 1)])

    sizes = np.zeros((n_models, H + 1))
    prec = np.zeros((n_models, H + 1))
    flops = np.zeros((n_models, H + 1))
    loadD = np.zeros((n_models, H + 1, H + 1))
    base_load = np.asarray(VIT_LOAD_S)                     # (H+1, H)

    for m, f in enumerate(factors):
        for j, sub in enumerate(VIT_SUBMODELS):
            sizes[m, j + 1] = sub["memory_mb"] * f
            flops[m, j + 1] = sub["gflops"] * f
            dp = rng.uniform(-0.015, 0.015) if m else 0.0
            prec[m, j + 1] = min(sub["precision"] + dp, 0.999)
        # loading/switch times scale with the transferred bytes
        loadD[m, :, 1:] = base_load * f
        # switching down / evicting is (nearly) free (paper Sec. VI)
        loadD[m, 1:, 0] = 0.0
    return sizes, prec, flops, loadD


def zoo_catalog(arch_ids, ctx: int = 2048, mem_rate_mbps: float = 2024.0):
    """Catalog derived from the real architecture zoo (framework scale).

    mem_rate is the secondary-storage->memory load rate implied by the
    paper's Table III (~253 MB/s)."""
    from repro import configs
    from repro.models import partition

    cfgs = [configs.get_config(a) for a in arch_ids]
    H = max(c.n_exits for c in cfgs)
    M = len(cfgs)
    sizes = np.zeros((M, H + 1))
    prec = np.zeros((M, H + 1))
    flops = np.zeros((M, H + 1))
    loadD = np.zeros((M, H + 1, H + 1))
    rate = mem_rate_mbps / 8.0 * 1e6                        # bytes/s
    for m, cfg in enumerate(cfgs):
        entries = partition.catalog_entry(cfg, ctx)
        # depth-quality curve: saturating toward a per-arch ceiling
        for j, e in enumerate(entries):
            frac = cfg.exit_layers[j] / cfg.n_layers
            sizes[m, j + 1] = e["r_h"] / 1e6                # MB
            prec[m, j + 1] = 0.99 * (1 - 0.45 * (1 - frac) ** 1.5)
            flops[m, j + 1] = e["c_h"] / 1e9                # GFLOP/token
        for prev in range(H + 1):
            for tgt in range(1, H + 1):
                if tgt >= prev:
                    delta = sizes[m, tgt] - (sizes[m, prev] if prev else 0.0)
                    loadD[m, prev, tgt] = delta * 1e6 / rate * 8.0 + 0.01
                else:
                    loadD[m, prev, tgt] = 0.042             # prune overhead
    return sizes, prec, flops, loadD
