"""MEC scenario: topology + request traces + window-by-window instances
(paper Sec. VII-A settings by default).

Also the batching layer for the vmapped PDHG solver: ``config_grid``
expands a base :class:`MECConfig` into a cross-product of variants, and
``stack_instances`` pads a heterogeneous list of :class:`JDCRInstance`
windows into one :class:`~repro.core.lp.PDHGData` stack that
``repro.core.lp.solve_lp_pdhg_batched`` solves in a single dispatch.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.jdcr import JDCRInstance
from repro.mec.catalog import Catalog, make_catalog


@dataclass
class MECConfig:
    n_bs: int = 5
    n_users: int = 600
    n_models: int = 8
    window_s: float = 3.0
    n_windows: int = 10
    zipf: float = 0.8
    mem_capacity_mb: float = 500.0
    compute_gflops: float = 70.0
    wireless_mbps: float = 20.0        # user -> home BS
    wired_mbps: float = 100.0          # BS <-> BS
    cloud_mbps: float = 800.0          # cloud -> BS (online downloads)
    hop_latency_s: float = 0.01
    er_prob: float = 0.5
    data_mb: float = 0.144
    ddl_s: float = 0.3
    popularity_change_every: int = 0   # in windows; 0 = static popularity
    seed: int = 0


def _er_connected(n, p, rng):
    """Erdős–Rényi graph, re-drawn until connected."""
    while True:
        adj = rng.random((n, n)) < p
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        # BFS hop counts
        hops = np.full((n, n), np.inf)
        for s in range(n):
            hops[s, s] = 0
            frontier = [s]
            d = 0
            while frontier:
                d += 1
                nxt = []
                for v in frontier:
                    for w in np.nonzero(adj[v])[0]:
                        if hops[s, w] == np.inf:
                            hops[s, w] = d
                            nxt.append(w)
                frontier = nxt
        if np.isfinite(hops).all():
            return adj, hops.astype(int)


def zipf_popularity(n, a, rng):
    if a <= 0:
        p = np.ones(n)
    else:
        p = 1.0 / np.arange(1, n + 1) ** a
    p = p / p.sum()
    return p[rng.permutation(n)]


class Scenario:
    """Holds the static topology and generates per-window JDCR instances."""

    def __init__(self, cfg: MECConfig, catalog: Catalog = None):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.rng = rng
        N, M = cfg.n_bs, cfg.n_models
        cat = catalog or make_catalog("paper", n_models=M,
                                      seed=cfg.seed + 7)
        if cat.n_models != M:
            raise ValueError(f"catalog has {cat.n_models} models, "
                             f"config wants n_models={M}")
        self.catalog = cat
        self.sizes, self.prec = cat.sizes, cat.prec
        self.flops_req, self.loadD = cat.flops, cat.loadD
        # flops per data unit (paper c_h): Table II is GFLOP per request of
        # size d_u, so c_h = GFLOP / d_u per MB
        self.flops = self.flops_req / cfg.data_mb
        self.adj, self.hops = _er_connected(N, cfg.er_prob, rng)
        mbps = 1.0 / 8.0                                    # Mb -> MB
        self.phi = np.full(N, cfg.wireless_mbps * mbps)     # MB/s
        self.wired = np.where(np.eye(N, dtype=bool), np.inf,
                              cfg.wired_mbps * mbps)
        # propagation: round trip = 2 wireless legs + 2 * hops wired legs
        self.lam = cfg.hop_latency_s * (2.0 + 2.0 * self.hops)
        self.R = np.full(N, cfg.mem_capacity_mb)
        self.C = np.full(N, cfg.compute_gflops)
        self.pop = zipf_popularity(M, cfg.zipf, rng)

    def empty_cache(self):
        x = np.zeros((self.cfg.n_bs, self.cfg.n_models,
                      self.sizes.shape[1]))
        x[:, :, 0] = 1.0
        return x

    def maybe_reshuffle_popularity(self, window: int):
        ce = self.cfg.popularity_change_every
        if ce and window > 0 and window % ce == 0:
            self.pop = self.pop[self.rng.permutation(len(self.pop))]

    def trace(self, name: str, n_slots: int, seed: int = None, **kw):
        """Build a named online workload (``repro.traces``) for this
        scenario's config — e.g. ``sc.trace("flash_crowd", 100)``."""
        from repro.traces.registry import make_trace
        seed = self.cfg.seed if seed is None else seed
        return make_trace(name, self.cfg, n_slots, seed=seed, **kw)

    def draw_requests(self, n_users=None):
        cfg = self.cfg
        U = n_users or cfg.n_users
        m_u = self.rng.choice(cfg.n_models, size=U, p=self.pop)
        home = self.rng.integers(0, cfg.n_bs, size=U)
        s_u = self.rng.uniform(0.0, cfg.window_s, size=U)
        return m_u, home, s_u

    def instance(self, window: int, x_prev, n_users=None) -> JDCRInstance:
        cfg = self.cfg
        self.maybe_reshuffle_popularity(window)
        m_u, home, s_u = self.draw_requests(n_users)
        U = len(m_u)
        wired = np.where(np.isinf(self.wired), 1e12, self.wired)
        return JDCRInstance(
            sizes=self.sizes, prec=self.prec, flops=self.flops,
            loadD=self.loadD, R=self.R, C=self.C, phi=self.phi,
            wired=wired, lam=self.lam,
            m_u=m_u, d_u=np.full(U, cfg.data_mb),
            ddl=np.full(U, cfg.ddl_s), s_u=s_u, home=home,
            x_prev=np.asarray(x_prev, dtype=np.float64))


# ---------------------------------------------------------------------------
# batching: config grids and stacked instances for the vmapped solver
# ---------------------------------------------------------------------------

def config_grid(base: MECConfig, axes: dict) -> list:
    """Cross-product of MECConfig variants.

    ``axes`` maps field names to value lists, e.g.
    ``{"n_bs": (4, 6), "zipf": (0.4, 0.8)}`` -> 4 configs.  Order is the
    itertools.product order of ``axes`` (insertion-ordered).
    """
    names = list(axes)
    cfgs = []
    for combo in itertools.product(*(axes[k] for k in names)):
        cfgs.append(replace(base, **dict(zip(names, combo))))
    return cfgs


@dataclass
class StackedWindows:
    """A padded stack of JDCR windows ready for one vmapped PDHG dispatch.

    ``data`` is a PDHGData pytree with a leading batch axis (padded to the
    max N and U in the stack); ``n_bs[i]``/``n_users[i]`` are element i's
    true sizes, used by :meth:`unstack` to slice solutions back out.
    """
    data: object                 # PDHGData, batched
    n_bs: np.ndarray             # (B,)
    n_users: np.ndarray          # (B,)
    insts: list = field(default_factory=list)

    def __len__(self):
        return len(self.n_bs)

    @property
    def signature(self):
        """Stable, hashable shape key ``(B, N_pad, U_pad, M, H)``.

        Two stacks with the same signature trace to the same jitted
        executables — the static bucket key the ``repro.scale`` executor
        (and any caller managing its own jit cache) keys on, instead of
        re-deriving shapes from the pytree per call.
        """
        B, N, U, H = self.data.T.shape
        M = self.data.sizes.shape[1]
        return (int(B), int(N), int(U), int(M), int(H))

    def unstack(self, x, A):
        """Slice padded batch solutions (B,N,M,H+1), (B,N,U,H) back into
        per-instance (x_i, A_i) at their true shapes."""
        out = []
        for i, (N_i, U_i) in enumerate(zip(self.n_bs, self.n_users)):
            out.append((np.asarray(x[i, :N_i]), np.asarray(A[i, :N_i, :U_i])))
        return out


def stack_instances(insts: list, pad_to: tuple = None) -> StackedWindows:
    """Pad + stack JDCR windows into one PDHGData batch.

    All instances must share the catalog shape (M, H).  N and U may differ:
    padded base stations are masked out of the kernel entirely (bs_mask
    zeroes their routing step, so their A stays exactly 0), padded users
    get zero precision and a zero one-hot row (nothing pulls routing mass
    toward them, and A <= x pins them at 0).  All pads are zeros, so the
    real rows see the same preconditioner sums and the same per-iteration
    updates as a solo solve of their own instance.

    ``pad_to=(N_pad, U_pad)`` pads to an explicit shape instead of the
    stack's own max — how the ``repro.scale`` executor pins every stack
    of a size bucket to the bucket's one compiled shape.  Since pads are
    exactly inert, the padding target never changes real rows' results.
    """
    from repro.core.lp import PDHGData, pdhg_data

    if not insts:
        raise ValueError("stack_instances needs at least one instance")
    M, H = insts[0].M, insts[0].H
    for inst in insts:
        if (inst.M, inst.H) != (M, H):
            raise ValueError(
                f"heterogeneous catalog shapes: ({inst.M},{inst.H}) vs "
                f"({M},{H}); stack only varies N/U")
    N_max = max(inst.N for inst in insts)
    U_max = max(inst.U for inst in insts)
    if pad_to is not None:
        pN, pU = int(pad_to[0]), int(pad_to[1])
        if pN < N_max or pU < U_max:
            raise ValueError(
                f"pad_to {pad_to} smaller than the stack's own max "
                f"({N_max}, {U_max})")
        N_max, U_max = pN, pU

    fields = {k: [] for k in PDHGData._fields}
    for inst in insts:
        d = pdhg_data(inst)
        dn, du = N_max - inst.N, U_max - inst.U
        fields["sizes"].append(d.sizes)
        fields["prec"].append(d.prec)
        fields["prec_u"].append(np.pad(d.prec_u, ((0, du), (0, 0))))
        fields["T"].append(np.pad(d.T, ((0, dn), (0, du), (0, 0))))
        fields["L"].append(np.pad(d.L, ((0, dn), (0, du), (0, 0))))
        fields["onehot_mu"].append(np.pad(d.onehot_mu, ((0, du), (0, 0))))
        fields["R"].append(np.pad(d.R, (0, dn)))
        fields["ddl"].append(np.pad(d.ddl, (0, du)))
        fields["s_u"].append(np.pad(d.s_u, (0, du)))
        fields["bs_mask"].append(np.pad(d.bs_mask, (0, dn)))
        fields["home_onehot"].append(np.pad(d.home_onehot,
                                            ((0, du), (0, dn))))
    data = PDHGData(**{k: np.stack(v) for k, v in fields.items()})
    return StackedWindows(
        data=data,
        n_bs=np.array([inst.N for inst in insts]),
        n_users=np.array([inst.U for inst in insts]),
        insts=list(insts))
