"""Evaluation metrics (paper Sec. VII-B) + post-hoc feasibility enforcement.

All algorithms are evaluated identically: a routed request only counts as a
hit if its end-to-end latency fits ddl_u AND the model finished loading
before the request's initiation time s_u — baselines that ignored loading
time in their decisions lose those requests here (exactly the paper's
evaluation protocol).
"""
from __future__ import annotations

import numpy as np

from repro.core.jdcr import JDCRInstance


def enforce(inst: JDCRInstance, x, A):
    """Zero out routes that are infeasible at execution time."""
    A = np.array(A, dtype=np.float64)
    x_sel = x[:, inst.m_u, 1:]
    A = A * (x_sel > 0)
    # one route per user, best precision
    prec_u = inst.prec[inst.m_u, 1:]
    for u in np.nonzero(A.sum(axis=(0, 2)) > 1)[0]:
        nz = np.argwhere(A[:, u, :] > 0)
        best = max(nz, key=lambda nh: prec_u[u, nh[1]])
        A[:, u, :] = 0
        A[best[0], u, best[1]] = 1
    lat = np.einsum("nuh,nuh->u", A, inst.e2e_latency())
    load = np.einsum("nuh,nuh->u", A, inst.load_latency())
    bad = (lat > inst.ddl + 1e-9) | (load > inst.s_u + 1e-9)
    A[:, bad, :] = 0.0
    return A


def window_metrics(inst: JDCRInstance, x, A):
    A = enforce(inst, x, A)
    prec_u = inst.prec[inst.m_u, 1:]
    served = A.sum(axis=(0, 2)) > 0
    precision = float(np.sum(A * prec_u[None]))
    mem_used = np.sum(x * inst.sizes[None], axis=(1, 2))
    return {
        "precision_sum": precision,
        "hits": int(served.sum()),
        "users": inst.U,
        "avg_precision": precision / inst.U,
        "hit_rate": served.mean(),
        "mem_util": float(np.mean(mem_used / inst.R)),
    }


def aggregate(window_results):
    users = sum(r["users"] for r in window_results)
    return {
        "avg_precision": sum(r["precision_sum"] for r in window_results) / users,
        "hit_rate": sum(r["hits"] for r in window_results) / users,
        "mem_util": float(np.mean([r["mem_util"] for r in window_results])),
    }


def qoe(prec, latency, theta, alpha=0.9):
    """Paper Eq. 40."""
    return prec * max(0.0, 1.0 - (latency - theta) * alpha)


def window_metrics_device(data, x, A):
    """``window_metrics`` as a pure jnp function of one padded window —
    the last stage of the fused offline pipeline (``repro.core.cocar``).

    Valid for *repaired* solutions, where ``enforce`` is an identity:
    repair already dedupes routes, pins them to cached submodels, and
    kicks out latency/load violators with the same thresholds — asserted
    in ``tests/test_offline_batched.py``.  Padded base stations and users
    are masked out of every aggregate, so the numbers equal the host
    ``window_metrics`` of the unpadded instance.
    """
    import jax.numpy as jnp

    from repro.core.jdcr import objective_sel, tree_sum

    user_mask = tree_sum(data.onehot_mu, -1) > 0
    bs_mask = data.bs_mask > 0
    users = tree_sum(user_mask.astype(jnp.float64), -1)
    served = (A > 0).any(axis=(0, 2)) & user_mask
    precision = objective_sel(data.prec_u, A)
    used = tree_sum(tree_sum(jnp.where(x > 0, data.sizes[None], 0.0),
                             -1), -1)                       # (N,)
    util = jnp.where(bs_mask, used / jnp.maximum(data.R, 1e-12), 0.0)
    n_bs = tree_sum(bs_mask.astype(jnp.float64), -1)
    return {
        "precision_sum": precision,
        "hits": tree_sum(served.astype(jnp.float64), -1),
        "users": users,
        "avg_precision": precision / jnp.maximum(users, 1.0),
        "hit_rate": tree_sum(served.astype(jnp.float64), -1)
        / jnp.maximum(users, 1.0),
        "mem_util": tree_sum(util, -1) / jnp.maximum(n_bs, 1.0),
    }
