"""Evaluation metrics (paper Sec. VII-B) + post-hoc feasibility enforcement.

All algorithms are evaluated identically: a routed request only counts as a
hit if its end-to-end latency fits ddl_u AND the model finished loading
before the request's initiation time s_u — baselines that ignored loading
time in their decisions lose those requests here (exactly the paper's
evaluation protocol).

Enforcement and metrics exist twice (PR-3 style): the NumPy path
(``enforce`` / ``window_metrics``) and the pure-jnp path
(``enforce_device`` / ``window_metrics_device``) the fused policy grid
vmaps over.  Decision-critical sums go through ``jdcr.tree_sum`` and
comparisons select (never multiply) precision values, so the two paths
kick out the *same* routes and report numbers within 1e-9.
"""
from __future__ import annotations

import numpy as np

from repro.core.jdcr import JDCRInstance, _jnp, objective_sel, tree_sum

#: Eq. 40 QoE decay rate (matches ``OnlineConfig.alpha``).
QOE_ALPHA = 0.9

_TOL = 1e-9


def enforce(inst: JDCRInstance, x, A):
    """Zero out routes that are infeasible at execution time."""
    from repro.core.rounding import _dedupe_routes

    A = np.array(A, dtype=np.float64)
    x_sel = x[:, inst.m_u, 1:]
    A = np.where(x_sel > 0, A, 0.0)
    # one route per user, best precision (exact ties -> smallest (n, h))
    prec_u = inst.prec[inst.m_u, 1:]
    A = _dedupe_routes(prec_u, A)
    T = inst.e2e_latency()
    L = inst.load_latency()
    lat = tree_sum(tree_sum(np.where(A > 0, T, 0.0), -1), 0)
    load = tree_sum(tree_sum(np.where(A > 0, L, 0.0), -1), 0)
    bad = (lat > inst.ddl + _TOL) | (load > inst.s_u + _TOL)
    A[:, bad, :] = 0.0
    return A


def enforce_device(data, x, A):
    """``enforce`` as a pure jnp function of one padded window — the
    uniform evaluation stage of the fused policy grid.  Identity on
    repaired CoCaR solutions; for baselines that ignored latency or
    loading time in their decisions, this is where those routes die (on
    exactly the same threshold sums as the host path)."""
    import jax.numpy as jnp

    from repro.core.rounding import _dedupe_device

    x_sel = jnp.einsum("nmh,um->nuh", x[:, :, 1:], data.onehot_mu)
    A = jnp.where(x_sel > 0, A, 0.0)
    A = _dedupe_device(data.prec_u, A)
    lat = tree_sum(tree_sum(jnp.where(A > 0, data.T, 0.0), -1), 0)
    load = tree_sum(tree_sum(jnp.where(A > 0, data.L, 0.0), -1), 0)
    bad = (lat > data.ddl + _TOL) | (load > data.s_u + _TOL)
    return jnp.where(bad[None, :, None], 0.0, A)


def _qoe_per_user(prec_sel, lat, theta, served):
    """Eq. 40 per served user: p · max(0, 1 − (latency − θ_u) · α), with
    θ_u the user's minimum achievable latency (the online engine's
    normalizer, per-user here).  Same elementwise float ops on both
    engines."""
    xp = np if isinstance(lat, np.ndarray) else _jnp()
    decay = xp.maximum(1.0 - (lat - theta) * QOE_ALPHA, 0.0)
    return xp.where(served, prec_sel * decay, 0.0)


def window_metrics(inst: JDCRInstance, x, A):
    A = enforce(inst, x, A)
    prec_u = inst.prec[inst.m_u, 1:]
    served = A.sum(axis=(0, 2)) > 0
    precision = float(np.sum(A * prec_u[None]))
    mem_used = np.sum(x * inst.sizes[None], axis=(1, 2))
    T = inst.e2e_latency()
    lat_u = tree_sum(tree_sum(np.where(A > 0, T, 0.0), -1), 0)
    theta = T.min(axis=(0, 2))
    prec_sel = tree_sum(tree_sum(np.where(A > 0, prec_u[None], 0.0), -1), 0)
    qoe_u = _qoe_per_user(prec_sel, lat_u, theta, served)
    return {
        "precision_sum": precision,
        "hits": int(served.sum()),
        "users": inst.U,
        "avg_precision": precision / inst.U,
        "hit_rate": served.mean(),
        "avg_qoe": float(tree_sum(qoe_u, -1) / inst.U),
        "mem_util": float(np.mean(mem_used / inst.R)),
    }


def aggregate(window_results):
    users = sum(r["users"] for r in window_results)
    return {
        "avg_precision": sum(r["precision_sum"] for r in window_results) / users,
        "hit_rate": sum(r["hits"] for r in window_results) / users,
        "mem_util": float(np.mean([r["mem_util"] for r in window_results])),
    }


def qoe(prec, latency, theta, alpha=0.9):
    """Paper Eq. 40."""
    return prec * max(0.0, 1.0 - (latency - theta) * alpha)


def window_metrics_device(data, x, A):
    """``window_metrics`` as a pure jnp function of one padded window —
    the last stage of the fused offline pipeline (``repro.core.cocar``).

    Valid for *enforced* solutions, where ``enforce`` is an identity:
    repair already dedupes routes, pins them to cached submodels, and
    kicks out latency/load violators with the same thresholds — asserted
    in ``tests/test_offline_batched.py``; the policy grid applies
    ``enforce_device`` first.  Padded base stations and users are masked
    out of every aggregate, so the numbers equal the host
    ``window_metrics`` of the unpadded instance.
    """
    import jax.numpy as jnp

    user_mask = tree_sum(data.onehot_mu, -1) > 0
    bs_mask = data.bs_mask > 0
    users = tree_sum(user_mask.astype(jnp.float64), -1)
    served = (A > 0).any(axis=(0, 2)) & user_mask
    precision = objective_sel(data.prec_u, A)
    used = tree_sum(tree_sum(jnp.where(x > 0, data.sizes[None], 0.0),
                             -1), -1)                       # (N,)
    util = jnp.where(bs_mask, used / jnp.maximum(data.R, 1e-12), 0.0)
    n_bs = tree_sum(bs_mask.astype(jnp.float64), -1)
    lat_u = tree_sum(tree_sum(jnp.where(A > 0, data.T, 0.0), -1), 0)
    theta = jnp.min(jnp.where(bs_mask[:, None, None], data.T, jnp.inf),
                    axis=(0, 2))
    prec_sel = tree_sum(tree_sum(
        jnp.where(A > 0, data.prec_u[None], 0.0), -1), 0)
    qoe_u = _qoe_per_user(prec_sel, lat_u, theta, served)
    return {
        "precision_sum": precision,
        "hits": tree_sum(served.astype(jnp.float64), -1),
        "users": users,
        "avg_precision": precision / jnp.maximum(users, 1.0),
        "hit_rate": tree_sum(served.astype(jnp.float64), -1)
        / jnp.maximum(users, 1.0),
        "avg_qoe": tree_sum(qoe_u, -1) / jnp.maximum(users, 1.0),
        "mem_util": tree_sum(util, -1) / jnp.maximum(n_bs, 1.0),
    }
