"""Evaluation metrics (paper Sec. VII-B) + post-hoc feasibility enforcement.

All algorithms are evaluated identically: a routed request only counts as a
hit if its end-to-end latency fits ddl_u AND the model finished loading
before the request's initiation time s_u — baselines that ignored loading
time in their decisions lose those requests here (exactly the paper's
evaluation protocol).
"""
from __future__ import annotations

import numpy as np

from repro.core.jdcr import JDCRInstance


def enforce(inst: JDCRInstance, x, A):
    """Zero out routes that are infeasible at execution time."""
    A = np.array(A, dtype=np.float64)
    x_sel = x[:, inst.m_u, 1:]
    A = A * (x_sel > 0)
    # one route per user, best precision
    prec_u = inst.prec[inst.m_u, 1:]
    for u in np.nonzero(A.sum(axis=(0, 2)) > 1)[0]:
        nz = np.argwhere(A[:, u, :] > 0)
        best = max(nz, key=lambda nh: prec_u[u, nh[1]])
        A[:, u, :] = 0
        A[best[0], u, best[1]] = 1
    lat = np.einsum("nuh,nuh->u", A, inst.e2e_latency())
    load = np.einsum("nuh,nuh->u", A, inst.load_latency())
    bad = (lat > inst.ddl + 1e-9) | (load > inst.s_u + 1e-9)
    A[:, bad, :] = 0.0
    return A


def window_metrics(inst: JDCRInstance, x, A):
    A = enforce(inst, x, A)
    prec_u = inst.prec[inst.m_u, 1:]
    served = A.sum(axis=(0, 2)) > 0
    precision = float(np.sum(A * prec_u[None]))
    mem_used = np.sum(x * inst.sizes[None], axis=(1, 2))
    return {
        "precision_sum": precision,
        "hits": int(served.sum()),
        "users": inst.U,
        "avg_precision": precision / inst.U,
        "hit_rate": served.mean(),
        "mem_util": float(np.mean(mem_used / inst.R)),
    }


def aggregate(window_results):
    users = sum(r["users"] for r in window_results)
    return {
        "avg_precision": sum(r["precision_sum"] for r in window_results) / users,
        "hit_rate": sum(r["hits"] for r in window_results) / users,
        "mem_util": float(np.mean([r["mem_util"] for r in window_results])),
    }


def qoe(prec, latency, theta, alpha=0.9):
    """Paper Eq. 40."""
    return prec * max(0.0, 1.0 - (latency - theta) * alpha)
