from repro.mec.scenario import MECConfig, Scenario  # noqa: F401
