"""Structured per-request event log for the serving data plane.

``QueueSim`` (``repro.serving.simulator``) emits one event per request
lifecycle phase:

  ``arrival``  the request enters the system;
  ``route``    the routing decision, with the full candidate set the
               router scored (pod, exit, precision, projected finish,
               deadline feasibility);
  ``queue``    time spent waiting for the chosen pod's server to free;
  ``stall``    additional time waiting for the submodel's bytes to load
               (the plan's ``available_at`` — the paper's Eq. 37
               loading-time constraint made visible per request);
  ``service``  the generation itself;
  ``finish`` | ``miss`` | ``drop``  exactly one terminal event per
               arrival — served within the deadline, served late
               (``admit_late``), or rejected at admission.

The conservation law — every ``arrival`` matched by exactly one
terminal event within its run — is checked by :meth:`EventLog
.conservation` and asserted over the full BENCH_serving run.  Events
are plain dicts (JSONL on disk) so the log is greppable and
tool-agnostic; a log spans many simulator runs, disambiguated by the
``run`` id handed out by :meth:`EventLog.new_run`.

Like every ``repro.obs`` module this imports no jax and no ``repro``
sibling; the tap is decision-inert — the simulator computes the same
quantities with or without a log attached.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

#: Exactly one of these closes each arrival (conservation law).
TERMINAL_KINDS = ("finish", "miss", "drop")
#: Full phase vocabulary, in lifecycle order.
PHASE_KINDS = ("arrival", "route", "queue", "stall",
               "service") + TERMINAL_KINDS


@dataclass
class Event:
    run: str
    rid: int
    kind: str
    t: float
    attrs: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"run": self.run, "rid": self.rid, "kind": self.kind,
                "t": self.t, **self.attrs}


class EventLog:
    """Append-only event collector shared across simulator runs."""

    def __init__(self):
        self.events: list = []
        self._run_no = 0
        self.run_id = ""

    def __len__(self):
        return len(self.events)

    def new_run(self, label: str = "") -> str:
        """Open a new run scope; subsequent emits are stamped with the
        returned id so request ids never collide across runs."""
        self.run_id = f"{self._run_no:04d}:{label}"
        self._run_no += 1
        return self.run_id

    def emit(self, kind: str, rid: int, t: float, **attrs):
        if kind not in PHASE_KINDS:
            raise ValueError(f"unknown event kind {kind!r}")
        self.events.append(Event(self.run_id, int(rid), kind, float(t),
                                 attrs))

    def by_kind(self) -> dict:
        out: dict = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def conservation(self) -> dict:
        """Every arrival appears exactly once as finish, miss, or drop
        (within its run).  Returns the verdict plus the failure counts:
        ``unterminated`` arrivals with no terminal, ``orphans``
        terminals with no arrival, ``duplicates`` arrivals terminated
        more than once."""
        arrivals: dict = {}
        terminals: dict = {}
        for e in self.events:
            key = (e.run, e.rid)
            if e.kind == "arrival":
                arrivals[key] = arrivals.get(key, 0) + 1
            elif e.kind in TERMINAL_KINDS:
                terminals[key] = terminals.get(key, 0) + 1
        unterminated = sum(1 for k in arrivals if k not in terminals)
        orphans = sum(1 for k in terminals if k not in arrivals)
        duplicates = sum(1 for k, c in terminals.items()
                         if c > 1 and k in arrivals)
        return {"ok": not (unterminated or orphans or duplicates),
                "n_arrivals": sum(arrivals.values()),
                "n_terminals": sum(terminals.values()),
                "unterminated": unterminated, "orphans": orphans,
                "duplicates": duplicates,
                "by_kind": self.by_kind()}

    def export_jsonl(self, path):
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e.to_dict()) + "\n")
        return path

    @classmethod
    def read_jsonl(cls, path) -> "EventLog":
        log = cls()
        with open(path) as f:
            for line in f:
                d = json.loads(line)
                log.events.append(Event(
                    d.pop("run"), d.pop("rid"), d.pop("kind"),
                    d.pop("t"), d))
        log._run_no = len({e.run for e in log.events})
        return log
