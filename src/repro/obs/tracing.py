"""Host-side tracing: nested spans on the monotonic clock + retrace
accounting for jitted entry points.

``time.time()`` is not monotonic (NTP can step it backwards mid-run),
so every duration here comes from ``time.perf_counter``.  A ``Tracer``
records a tree of :class:`Span`\\ s — one per ``with tracer.span(...)``
block — and exports them as JSONL (one span per line) or in the
chrome://tracing ``traceEvents`` format (load the file in
``chrome://tracing`` / Perfetto to see the dispatch timeline).

Retrace accounting: dispatch sites register their jitted callables
under stable names (:func:`register_jit`); each span snapshots the
per-entry-point compile-cache sizes (``fn._cache_size()``) on entry and
records the delta on exit as ``Span.retraces``.  A warm dispatch spans
``retraces == 0``; a span that compiled records how many new
executables it cost — which is how the report separates compile time
from execute time, and how ``tests/test_obs.py`` turns "repeat sweeps
retrace nothing" into an enforced invariant.

No jax import happens at module load (or ever, unless a registered jit
is inspected) — safe to import from anywhere, including before
``XLA_FLAGS`` is set.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# jitted entry point registry (retrace accounting)
# ---------------------------------------------------------------------------

_JIT_REGISTRY: dict = {}


def register_jit(name: str, fn):
    """Register a jitted callable under a stable name so its compile
    cache can be watched for retraces.  Idempotent; returns ``fn``."""
    _JIT_REGISTRY[str(name)] = fn
    return fn


def jit_cache_sizes() -> dict:
    """{registered name: current compile-cache size}.  Entries whose
    callable does not expose ``_cache_size`` report -1."""
    out = {}
    for name, fn in _JIT_REGISTRY.items():
        size = fn._cache_size() if hasattr(fn, "_cache_size") else -1
        out[name] = int(size)
    return out


def retrace_snapshot() -> dict:
    """A point-in-time copy of :func:`jit_cache_sizes` — pass it to
    :func:`retraces_since` after the work you want to account."""
    return jit_cache_sizes()


def retraces_since(snapshot: dict) -> dict:
    """{name: newly compiled executables since ``snapshot``} — only
    positive deltas; entry points registered after the snapshot count
    their full cache size."""
    now = jit_cache_sizes()
    out = {}
    for name, size in now.items():
        delta = size - snapshot.get(name, 0)
        if delta > 0 and size >= 0:
            out[name] = delta
    return out


def total_retraces_since(snapshot: dict) -> int:
    return sum(retraces_since(snapshot).values())


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

@dataclass
class Span:
    """One timed block: name, perf_counter start/duration, nesting
    (depth + parent index into the tracer's span list), free-form
    attrs, and the retrace count its work caused."""
    name: str
    t0: float
    seconds: float = 0.0
    depth: int = 0
    parent: int = -1
    attrs: dict = field(default_factory=dict)
    retraces: int = 0

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0,
                "seconds": self.seconds, "depth": self.depth,
                "parent": self.parent, "retraces": self.retraces,
                "attrs": self.attrs}


class Tracer:
    """Records a tree of spans; export as JSONL or chrome://tracing."""

    def __init__(self):
        self._spans: list[Span] = []
        self._stack: list[int] = []

    @contextmanager
    def span(self, name: str, **attrs):
        """Time a block.  Yields the (open) :class:`Span`; its
        ``seconds`` and ``retraces`` are filled when the block exits."""
        sp = Span(name=str(name), t0=time.perf_counter(),
                  depth=len(self._stack),
                  parent=self._stack[-1] if self._stack else -1,
                  attrs=dict(attrs))
        idx = len(self._spans)
        self._spans.append(sp)
        self._stack.append(idx)
        snap = retrace_snapshot()
        try:
            yield sp
        finally:
            sp.seconds = time.perf_counter() - sp.t0
            sp.retraces = total_retraces_since(snap)
            self._stack.pop()

    @property
    def spans(self) -> list:
        return list(self._spans)

    def reset(self):
        self._spans.clear()
        self._stack.clear()

    def summary(self, top: int = 10) -> dict:
        """Aggregate by span name (count / total / max seconds /
        retraces) plus the ``top`` slowest individual spans."""
        agg: dict = {}
        for sp in self._spans:
            a = agg.setdefault(sp.name, {"count": 0, "total_s": 0.0,
                                         "max_s": 0.0, "retraces": 0})
            a["count"] += 1
            a["total_s"] += sp.seconds
            a["max_s"] = max(a["max_s"], sp.seconds)
            a["retraces"] += sp.retraces
        slowest = sorted(self._spans, key=lambda s: -s.seconds)[:top]
        return {"by_name": agg,
                "slowest": [s.to_dict() for s in slowest]}

    def export_jsonl(self, path):
        """One span per line, in start order."""
        lines = [json.dumps(sp.to_dict()) for sp in self._spans]
        with open(path, "w") as f:
            f.write("\n".join(lines) + ("\n" if lines else ""))
        return path

    def export_chrome(self, path):
        """chrome://tracing ``traceEvents`` (complete "X" events,
        microsecond timestamps relative to the first span)."""
        epoch = self._spans[0].t0 if self._spans else 0.0
        events = [{"name": sp.name, "cat": "obs", "ph": "X", "pid": 0,
                   "tid": sp.depth,
                   "ts": (sp.t0 - epoch) * 1e6,
                   "dur": sp.seconds * 1e6,
                   "args": {**sp.attrs, "retraces": sp.retraces}}
                  for sp in self._spans]
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
        return path


#: The process-wide default tracer every dispatch site records into.
TRACER = Tracer()


@contextmanager
def span(name: str, **attrs):
    """``with obs.span("solve"): ...`` — sugar for ``TRACER.span``."""
    with TRACER.span(name, **attrs) as sp:
        yield sp
