"""Run manifests: provenance JSON written next to every results file.

A manifest answers "what produced this JSON?" without re-running
anything: git SHA + dirty flag, jax version / backend / devices / x64
flag (only if jax is already imported — building a manifest never
triggers device initialization), python/numpy/platform, the argv that
launched the run, seeds, and the run config with a canonical sha256
hash so two runs can be compared by a single string.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import subprocess
import sys
import time


def _git(*args):
    try:
        out = subprocess.run(("git",) + args, capture_output=True,
                             text=True, timeout=10,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def _git_info() -> dict:
    status = _git("status", "--porcelain")
    return {"sha": _git("rev-parse", "HEAD"),
            "branch": _git("rev-parse", "--abbrev-ref", "HEAD"),
            "dirty": bool(status) if status is not None else None}


def _jax_info() -> dict:
    # read-only: report on jax only when the run already imported it,
    # so writing a manifest never initializes a backend itself
    if "jax" not in sys.modules:
        return {"imported": False}
    jax = sys.modules["jax"]
    try:
        devices = jax.devices()
        return {"imported": True,
                "version": jax.__version__,
                "backend": devices[0].platform if devices else None,
                "device_count": len(devices),
                "devices": [str(d) for d in devices],
                "x64": bool(jax.config.jax_enable_x64)}
    except Exception as e:  # backend init can fail in odd environments
        return {"imported": True, "version": getattr(jax, "__version__", None),
                "error": repr(e)}


def config_hash(config) -> str:
    """sha256 of the canonical (sorted-keys, default=str) JSON encoding
    — a stable fingerprint for "same run config"."""
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()


def run_manifest(config=None, seeds=None, extra=None) -> dict:
    """Build the provenance record for one run."""
    try:
        import numpy as np
        np_version = np.__version__
    except ImportError:  # pragma: no cover
        np_version = None
    man = {
        "schema": "repro.obs.manifest/v1",
        "created_unix": time.time(),
        "created": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "git": _git_info(),
        "jax": _jax_info(),
        "python": sys.version.split()[0],
        "numpy": np_version,
        "platform": platform.platform(),
        "argv": list(sys.argv),
        "env": {k: os.environ[k]
                for k in ("JAX_ENABLE_X64", "XLA_FLAGS", "REPRO_BENCH_FULL")
                if k in os.environ},
        "seeds": seeds,
        "config": config,
        "config_hash": config_hash(config) if config is not None else None,
    }
    if extra:
        man["extra"] = dict(extra)
    return man


def write_manifest(results_path, config=None, seeds=None, extra=None) -> str:
    """Write ``<results stem>.manifest.json`` next to ``results_path``
    and return the manifest path."""
    results_path = os.fspath(results_path)
    stem, _ = os.path.splitext(results_path)
    path = stem + ".manifest.json"
    with open(path, "w") as f:
        json.dump(run_manifest(config=config, seeds=seeds, extra=extra),
                  f, indent=2, default=str)
        f.write("\n")
    return path
