"""Host-side summaries of the jit-safe diagnostics pytrees.

The kernels (``repro.core.lp``, ``repro.kernels.pdhg_fused``,
``repro.traces.engine``) emit raw device curves — residuals, objective
trajectories, per-slot cache stats — sampled every ``diag_stride``
iterations.  This module turns those curves into the JSON-safe
convergence records that sweeps, benches, ``scripts/report.py`` and
``check_bench.py`` consume.  Pure numpy/stdlib; imports no jax.

``DEFAULT_TOL`` is calibrated against the production sweep grid: at the
default 4000 PDHG iterations the worst window's final scaled primal
residual is ~4.2e-3, so 1e-2 converges everywhere with ~2.4x headroom
while still catching a solver that stalls.  Truncated bench budgets
(200–500 iterations) intentionally do *not* reach it; those are gated
by residual-drift checks in ``check_bench.py`` instead of a flag.
"""
from __future__ import annotations

import numpy as np

#: Convergence tolerance on the scaled primal residual (see module doc).
DEFAULT_TOL = 1e-2


def _to_np(x):
    return np.asarray(x)


def lp_diag_summary(diag, tol: float = DEFAULT_TOL) -> dict:
    """Summarize one window's PDHG diagnostics pytree (1-D curves).

    Returns ``final_residual``, ``converged`` (final residual <= tol),
    ``iters_to_tol`` (first *sampled* iteration whose primal residual
    is <= tol, -1 if never — the curve is sampled at ``diag_stride``,
    so this is an upper bound on the true crossing), ``tol`` and
    ``n_samples``.  Curves that exist in the pytree (``polish_delta``,
    final objective) are passed through.
    """
    pr = _to_np(diag["primal_res"]).ravel()
    iters = _to_np(diag["iters"]).ravel()
    final = float(pr[-1]) if pr.size else float("nan")
    hit = np.nonzero(pr <= tol)[0]
    out = {
        "final_residual": final,
        "converged": bool(final <= tol),
        "iters_to_tol": int(iters[hit[0]]) if hit.size else -1,
        "tol": float(tol),
        "n_samples": int(pr.size),
    }
    if "dual_res" in diag:
        dr = _to_np(diag["dual_res"]).ravel()
        if dr.size:
            out["final_dual_residual"] = float(dr[-1])
    if "obj" in diag:
        ob = _to_np(diag["obj"]).ravel()
        if ob.size:
            out["final_obj"] = float(ob[-1])
    if "polish_delta" in diag:
        out["polish_delta"] = float(_to_np(diag["polish_delta"]))
    return out


def convergence_table(residuals, tol: float = DEFAULT_TOL) -> dict:
    """Aggregate per-window final residuals into the convergence record
    sweeps publish (and ``report.py --check-converged`` gates on)."""
    res = [float(r) for r in residuals]
    not_conv = [i for i, r in enumerate(res) if not (r <= tol)]
    return {
        "n_windows": len(res),
        "n_not_converged": len(not_conv),
        "all_converged": not not_conv,
        "max_final_residual": max(res) if res else float("nan"),
        "tol": float(tol),
        "per_window": res,
    }
