"""Streaming metrics: mergeable fixed-bucket histograms, counters,
gauges, and the exporters that turn one serving/sweep run into a
Prometheus textfile plus a JSON snapshot.

Design constraints (docs/algorithms.md Sec. 14):

  * **Fixed buckets, mergeable.** A :class:`Histogram` owns an immutable
    tuple of upper bucket edges chosen at construction.  Observations
    only increment integer bucket counts (plus ``n``/``sum``/min/max
    accumulators), so merging two histograms with the same edges is
    element-wise integer addition — associative and commutative by
    construction, which is what lets per-run registries from a bench
    grid be folded together in any order (property-tested in
    ``tests/test_metrics.py``).
  * **No jax at module load.** Like the rest of ``repro.obs`` this
    module imports only stdlib + numpy; :func:`memory_snapshot` talks to
    jax solely through ``sys.modules`` so importing the metrics layer
    never initializes a device backend.
  * **Decision-inert taps.** Nothing here is called from inside a jitted
    computation; adapters (:func:`observe_queue_sim`,
    :func:`observe_online_diag`) read results that already exist, so
    enabling metrics cannot perturb cache/routing decisions.

Exposition: :meth:`MetricsRegistry.export_prometheus` writes the
Prometheus textfile format (cumulative ``_bucket{le=...}`` lines,
``_sum``/``_count``, counter/gauge samples) validated by
``scripts/check_metrics.py``; :meth:`MetricsRegistry.export_json` writes
the full mergeable state for offline analysis.
"""
from __future__ import annotations

import bisect
import json
import sys
from dataclasses import dataclass, field

import numpy as np

#: Default latency bucket upper edges (seconds) — log-ish spaced from
#: 1 ms to 60 s, matching the QueueSim latency scales in BENCH_serving.
DEFAULT_LATENCY_EDGES = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                         0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 60.0)
#: Edges for unit-interval quantities (hit rates, fractions).
UNIT_EDGES = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)
#: Edges for small nonnegative counts (downloads in flight, evictions).
COUNT_EDGES = (0.0, 1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
               500.0, 1000.0)


class Histogram:
    """Fixed-bucket streaming histogram.

    ``counts[i]`` counts observations ``v <= edges[i]`` (first matching
    bucket); ``counts[-1]`` is the overflow bucket ``v > edges[-1]``.
    ``merge`` requires identical edges and adds counts — order never
    matters.
    """

    def __init__(self, name: str, edges=DEFAULT_LATENCY_EDGES):
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"edges must be sorted and non-empty: {edges}")
        self.name = name
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.n = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, value: float, count: int = 1):
        v = float(value)
        self.counts[bisect.bisect_left(self.edges, v)] += count
        self.n += count
        self.total += v * count
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def observe_many(self, values):
        for v in np.asarray(values, float).ravel():
            self.observe(float(v))

    def merge(self, other: "Histogram") -> "Histogram":
        """In-place merge; returns self.  Requires identical edges."""
        if other.edges != self.edges:
            raise ValueError(f"bucket mismatch: {self.name} has "
                             f"{len(self.edges)} edges, merge source has "
                             f"{len(other.edges)}")
        self.counts = [a + b for a, b in zip(self.counts, other.counts)]
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, q: float) -> float:
        """Approximate percentile by linear interpolation inside the
        containing bucket, clamped to the observed [vmin, vmax]."""
        if self.n == 0:
            return 0.0
        target = (q / 100.0) * self.n
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo = self.edges[i - 1] if i > 0 else self.vmin
                hi = self.edges[i] if i < len(self.edges) else self.vmax
                lo = min(max(lo, self.vmin), self.vmax)
                hi = min(max(hi, self.vmin), self.vmax)
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self.vmax

    def to_dict(self) -> dict:
        return {"name": self.name, "edges": list(self.edges),
                "counts": list(self.counts), "n": self.n,
                "sum": self.total,
                "min": self.vmin if self.n else None,
                "max": self.vmax if self.n else None}

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls(d["name"], d["edges"])
        h.counts = [int(c) for c in d["counts"]]
        h.n = int(d["n"])
        h.total = float(d["sum"])
        h.vmin = float("inf") if d.get("min") is None else float(d["min"])
        h.vmax = float("-inf") if d.get("max") is None else float(d["max"])
        return h


@dataclass
class Counter:
    name: str
    value: float = 0.0

    def inc(self, amount: float = 1.0):
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount


@dataclass
class Gauge:
    """Last-value gauge with a high-water mark (merge takes the max, the
    right fold for memory watermarks)."""
    name: str
    value: float = 0.0
    hwm: float = float("-inf")

    def set(self, value: float):
        self.value = float(value)
        self.hwm = max(self.hwm, self.value)


class MetricsRegistry:
    """Named histograms/counters/gauges with get-or-create accessors,
    registry-level merge, and Prometheus/JSON exporters.

    Metric names use Prometheus conventions (``snake_case``, unit
    suffix); the exporters prepend ``repro_``.
    """

    def __init__(self):
        self.histograms: dict = {}
        self.counters: dict = {}
        self.gauges: dict = {}

    def histogram(self, name: str, edges=DEFAULT_LATENCY_EDGES) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, edges)
        elif h.edges != tuple(float(e) for e in edges):
            raise ValueError(f"histogram {name} re-declared with "
                             "different edges")
        return h

    def counter(self, name: str) -> Counter:
        return self.counters.setdefault(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self.gauges.setdefault(name, Gauge(name))

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry into this one (histogram counts add,
        counters add, gauges keep the high-water mark).  Returns self."""
        for name, h in other.histograms.items():
            self.histogram(name, h.edges).merge(h)
        for name, c in other.counters.items():
            self.counter(name).inc(c.value)
        for name, g in other.gauges.items():
            mine = self.gauge(name)
            mine.set(max(mine.value, g.value) if mine.hwm > float("-inf")
                     else g.value)
            mine.hwm = max(mine.hwm, g.hwm)
        return self

    def to_dict(self) -> dict:
        return {
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self.histograms.items())},
            "counters": {k: c.value
                         for k, c in sorted(self.counters.items())},
            "gauges": {k: {"value": g.value,
                           "max": None if g.hwm == float("-inf") else g.hwm}
                       for k, g in sorted(self.gauges.items())},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MetricsRegistry":
        reg = cls()
        for k, hd in d.get("histograms", {}).items():
            reg.histograms[k] = Histogram.from_dict(hd)
        for k, v in d.get("counters", {}).items():
            reg.counters[k] = Counter(k, float(v))
        for k, gd in d.get("gauges", {}).items():
            g = reg.gauge(k)
            g.value = float(gd["value"])
            g.hwm = (float("-inf") if gd.get("max") is None
                     else float(gd["max"]))
        return reg

    # -- exporters --------------------------------------------------

    def render_prometheus(self, prefix: str = "repro_") -> str:
        lines = []
        for name, h in sorted(self.histograms.items()):
            full = prefix + name
            lines.append(f"# HELP {full} repro streaming histogram")
            lines.append(f"# TYPE {full} histogram")
            cum = 0
            for e, c in zip(h.edges, h.counts):
                cum += c
                lines.append(f'{full}_bucket{{le="{format(e, "g")}"}} {cum}')
            lines.append(f'{full}_bucket{{le="+Inf"}} {h.n}')
            lines.append(f"{full}_sum {format(h.total, '.17g')}")
            lines.append(f"{full}_count {h.n}")
        for name, c in sorted(self.counters.items()):
            full = prefix + name
            lines.append(f"# HELP {full} repro counter")
            lines.append(f"# TYPE {full} counter")
            lines.append(f"{full} {format(c.value, '.17g')}")
        for name, g in sorted(self.gauges.items()):
            full = prefix + name
            lines.append(f"# HELP {full} repro gauge")
            lines.append(f"# TYPE {full} gauge")
            lines.append(f"{full} {format(g.value, '.17g')}")
        return "\n".join(lines) + "\n"

    def export_prometheus(self, path, prefix: str = "repro_"):
        with open(path, "w") as f:
            f.write(self.render_prometheus(prefix))
        return path

    def export_json(self, path):
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
        return path


# -- stack adapters: one metrics schema for serving + online runs ------

def observe_queue_sim(registry: MetricsRegistry, sim) -> MetricsRegistry:
    """Fold one finished ``QueueSim`` run into the shared schema:
    per-request latency + its exact attribution phases (queue wait,
    loading stall, service) as histograms, outcome counters.  Reads the
    simulator after the fact — cannot perturb its decisions."""
    lat = registry.histogram("request_latency_seconds")
    que = registry.histogram("request_queue_seconds")
    stl = registry.histogram("request_stall_seconds")
    svc = registry.histogram("request_service_seconds")
    for r in sim.done:
        lat.observe(r.latency)
        que.observe(r.queue_s)
        stl.observe(r.stall_s)
        svc.observe(r.service_s)
    registry.counter("requests_served_total").inc(len(sim.done))
    registry.counter("requests_dropped_total").inc(sim.dropped)
    registry.counter("deadline_misses_total").inc(
        sim.dropped + sum(not r.met_slo for r in sim.done))
    return registry


def observe_online_diag(registry: MetricsRegistry, diag: dict
                        ) -> MetricsRegistry:
    """Fold one online run's per-slot telemetry (the ``diagnostics=True``
    curves from ``repro.traces.engine``: hit_rate, dl_in_flight,
    evictions, cache_mb) into the same histogram types the serving plane
    uses, so one textfile carries both planes."""
    if "hit_rate" in diag:
        registry.histogram("online_hit_rate", UNIT_EDGES).observe_many(
            diag["hit_rate"])
    if "dl_in_flight" in diag:
        registry.histogram("online_dl_in_flight", COUNT_EDGES
                           ).observe_many(diag["dl_in_flight"])
    if "evictions" in diag:
        ev = np.asarray(diag["evictions"], float).ravel()
        registry.histogram("online_evictions", COUNT_EDGES
                           ).observe_many(ev)
        registry.counter("online_evictions_total").inc(float(ev.sum()))
    if "cache_mb" in diag:
        cm = np.asarray(diag["cache_mb"], float).ravel()
        if cm.size:
            g = registry.gauge("online_cache_mb")
            g.set(float(cm[-1]))
            g.hwm = max(g.hwm, float(cm.max()))
    return registry


# -- memory watermarks -------------------------------------------------

def _host_rss_kb() -> float:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return float(line.split()[1])
    except OSError:
        pass
    return _host_maxrss_kb()


def _host_maxrss_kb() -> float:
    try:
        import resource
        return float(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)
    except Exception:  # pragma: no cover - non-POSIX fallback
        return 0.0


def memory_snapshot() -> dict:
    """Host RSS (current + peak, kB) and — when jax is already imported
    by the caller — live device-array bytes via ``jax.live_arrays()``
    (falling back to the backend's ``live_buffers``).  Importing this
    module never pulls in jax; a process that never touched jax gets
    host numbers only."""
    snap = {"host_rss_kb": _host_rss_kb(),
            "host_maxrss_kb": _host_maxrss_kb()}
    jax = sys.modules.get("jax")
    if jax is not None:
        try:
            arrs = jax.live_arrays()
        except Exception:
            try:
                arrs = jax.devices()[0].client.live_buffers()
            except Exception:
                arrs = None
        if arrs is not None:
            snap["device_live_bytes"] = int(
                sum(int(getattr(a, "nbytes", 0)) for a in arrs))
            snap["device_live_arrays"] = len(arrs)
    return snap
