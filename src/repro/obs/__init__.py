"""``repro.obs`` — the observability layer: host-side span tracing,
retrace accounting, run manifests, and jit-safe solver/engine
diagnostics summaries.

Three parts (docs/algorithms.md Sec. 11):

  * :mod:`repro.obs.tracing` — ``Span``/``Tracer`` built on the
    monotonic ``time.perf_counter``, with JSONL + chrome://tracing
    export and a registry of jitted entry points whose compile-cache
    sizes turn into per-span retrace counts;
  * :mod:`repro.obs.manifest` — ``run_manifest``/``write_manifest``:
    git SHA, jax/device info, x64 flags, seeds, and a config hash next
    to every emitted results file;
  * :mod:`repro.obs.diagnostics` — host-side summaries of the jit-safe
    diagnostics pytrees the kernels emit (``diagnostics=True`` through
    ``repro.core.lp``, ``repro.kernels.pdhg_fused``,
    ``repro.traces.engine`` and the ``repro.scale`` executor).

This package imports neither jax nor any ``repro`` sibling at module
load, so every dispatch site can depend on it without import cycles or
early device initialization.
"""
from repro.obs.diagnostics import (DEFAULT_TOL, convergence_table,
                                   lp_diag_summary)
from repro.obs.manifest import config_hash, run_manifest, write_manifest
from repro.obs.tracing import (TRACER, Span, Tracer, jit_cache_sizes,
                               register_jit, retrace_snapshot,
                               retraces_since, span, total_retraces_since)
