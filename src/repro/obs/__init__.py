"""``repro.obs`` — the observability layer: host-side span tracing,
retrace accounting, run manifests, streaming metrics, per-request
events, and jit-safe solver/engine diagnostics summaries.

Five parts (docs/algorithms.md Sec. 11 and 14):

  * :mod:`repro.obs.tracing` — ``Span``/``Tracer`` built on the
    monotonic ``time.perf_counter``, with JSONL + chrome://tracing
    export and a registry of jitted entry points whose compile-cache
    sizes turn into per-span retrace counts;
  * :mod:`repro.obs.manifest` — ``run_manifest``/``write_manifest``:
    git SHA, jax/device info, x64 flags, seeds, and a config hash next
    to every emitted results file;
  * :mod:`repro.obs.diagnostics` — host-side summaries of the jit-safe
    diagnostics pytrees the kernels emit (``diagnostics=True`` through
    ``repro.core.lp``, ``repro.kernels.pdhg_fused``,
    ``repro.traces.engine`` and the ``repro.scale`` executor);
  * :mod:`repro.obs.metrics` — mergeable fixed-bucket streaming
    histograms, counters, gauges; Prometheus-textfile + JSON exporters;
    adapters folding QueueSim runs and online engine telemetry into one
    shared schema; :func:`memory_snapshot` device/host watermarks;
  * :mod:`repro.obs.events` — the structured per-request event log the
    queue simulator emits (arrival/route/queue/stall/service +
    finish|miss|drop terminals, with a conservation check).

This package imports neither jax nor any ``repro`` sibling at module
load, so every dispatch site can depend on it without import cycles or
early device initialization.
"""
from repro.obs.diagnostics import (DEFAULT_TOL, convergence_table,
                                   lp_diag_summary)
from repro.obs.events import PHASE_KINDS, TERMINAL_KINDS, Event, EventLog
from repro.obs.manifest import config_hash, run_manifest, write_manifest
from repro.obs.metrics import (COUNT_EDGES, DEFAULT_LATENCY_EDGES,
                               UNIT_EDGES, Counter, Gauge, Histogram,
                               MetricsRegistry, memory_snapshot,
                               observe_online_diag, observe_queue_sim)
from repro.obs.tracing import (TRACER, Span, Tracer, jit_cache_sizes,
                               register_jit, retrace_snapshot,
                               retraces_since, span, total_retraces_since)
