"""CoCaR-OL vs the online baselines across workloads (paper Sec. VI).

Everything routes through the unified API introduced with the Workload
protocol: ``run_online(workload, policy, cfg=..., ocfg=..., engine=...)``.
Demand is aggregated per-(BS, model) request counts — the engines never
see a per-user tensor.

Part 1 replays the paper's popularity-shift regime (Fig. 13) on the NumPy
engine: the whole request stream is pre-drawn (``repro.traces``), so
every policy replays the identical workload.

Part 2 hits the policies with a *flash crowd* — a model nobody cached
suddenly absorbs 90% of the traffic — and shows the expected-future-gain
policy pre-positioning submodel upgrades while LFU chases stale counts.
All (workload x policy) runs go through the vectorized scan engine in ONE
vmapped dispatch.

Part 3 streams a *million users per slot* through the scan engine: the
``poisson_zipf`` family samples per-slot (BS, model) counts chunk-by-
chunk, so memory stays O(chunk) no matter how large U grows.

Run:  PYTHONPATH=src python examples/online_adaptation.py
"""
from repro.core.online import OnlineConfig, run_online
from repro.mec.scenario import MECConfig
from repro.traces import default_workload, make_workload
from repro.traces.engine import run_online_grid

ALGOS = ("cocar-ol", "lfu", "lfu-mad", "random")

cfg = MECConfig(n_users=300, seed=1)
ocfg = OnlineConfig(n_slots=80, pop_change_every=20)

print("part 1 — popularity drift (5 BSs, 300 users/slot, shift every "
      "20 slots), NumPy engine:\n")
wl = default_workload(cfg, ocfg)
for algo in ALGOS:
    r = run_online(wl, algo, cfg=cfg, ocfg=ocfg, engine="numpy")
    print(f"  {algo:10s}  avg QoE {r['avg_qoe']:.3f}   "
          f"hit rate {r['hit_rate']:.3f}")

print("\nwithout dynamic-DNN partitioning (complete models only):")
ocfg_np = OnlineConfig(n_slots=80, pop_change_every=20, partition=False)
wl_np = default_workload(cfg, ocfg_np)
for algo in ("cocar-ol", "lfu"):
    r = run_online(wl_np, algo, cfg=cfg, ocfg=ocfg_np, engine="numpy")
    print(f"  {algo:10s}  avg QoE {r['avg_qoe']:.3f}   "
          f"hit rate {r['hit_rate']:.3f}")

print("\npart 2 — flash crowd (two 12-slot spikes, hot model takes 90% "
      "of traffic),\nall runs in one vmapped scan dispatch:\n")
flash = make_workload("flash_crowd", cfg, ocfg.n_slots, seed=cfg.seed,
                      n_events=2, duration=12, intensity=0.9)
calm = make_workload("stationary", cfg, ocfg.n_slots, seed=cfg.seed)
jobs = [dict(cfg=cfg, algo=a, workload=w)
        for w in (calm, flash) for a in ALGOS]
res = run_online_grid(jobs, ocfg)
for (job, r) in zip(jobs, res):
    print(f"  {job['workload'].name:12s} {job['algo']:10s}  "
          f"avg QoE {r['avg_qoe']:.3f}   hit rate {r['hit_rate']:.3f}")
spikes = ", ".join(f"t={e['start']}..{e['end']} model {e['model']}"
                   for e in flash.meta["events"])
print(f"\n  (spikes: {spikes})")

print("\npart 3 — one million users per slot, streamed through the scan "
      "engine\nin 20-slot chunks (no per-user tensor ever exists):\n")
mega = make_workload("poisson_zipf", cfg, ocfg.n_slots, seed=1,
                     users_per_slot=1_000_000, chunk_slots=20)
r = run_online(mega, "cocar-ol", cfg=cfg, ocfg=ocfg, engine="scan",
               chunk_slots=20)
print(f"  cocar-ol    avg QoE {r['avg_qoe']:.3f}   "
      f"hit rate {r['hit_rate']:.3f}   "
      f"({mega.total():.2e} requests over {ocfg.n_slots} slots)")
