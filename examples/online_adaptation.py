"""CoCaR-OL vs the online baselines across trace workloads (paper Sec. VI).

Part 1 replays the paper's popularity-shift regime (Fig. 13) through the
trace API: the whole request stream is pre-drawn (``repro.traces``), so
every policy replays the identical workload.

Part 2 hits the policies with a *flash crowd* — a model nobody cached
suddenly absorbs 90% of the traffic — and shows the expected-future-gain
policy pre-positioning submodel upgrades while LFU chases stale counts.
All (trace x policy) runs go through the vectorized scan engine in ONE
vmapped dispatch (``backend``/grid switch introduced with the trace
subsystem).

Run:  PYTHONPATH=src python examples/online_adaptation.py
"""
from repro.core.online import OnlineConfig, run_online
from repro.mec.scenario import MECConfig
from repro.traces import make_trace
from repro.traces.engine import run_online_grid

ALGOS = ("cocar-ol", "lfu", "lfu-mad", "random")

cfg = MECConfig(n_users=300, seed=1)
ocfg = OnlineConfig(n_slots=80, pop_change_every=20)

print("part 1 — popularity drift (5 BSs, 300 users/slot, shift every "
      "20 slots), NumPy engine:\n")
for algo in ALGOS:
    r = run_online(cfg, ocfg, algo)
    print(f"  {algo:10s}  avg QoE {r['avg_qoe']:.3f}   "
          f"hit rate {r['hit_rate']:.3f}")

print("\nwithout dynamic-DNN partitioning (complete models only):")
ocfg_np = OnlineConfig(n_slots=80, pop_change_every=20, partition=False)
for algo in ("cocar-ol", "lfu"):
    r = run_online(cfg, ocfg_np, algo)
    print(f"  {algo:10s}  avg QoE {r['avg_qoe']:.3f}   "
          f"hit rate {r['hit_rate']:.3f}")

print("\npart 2 — flash crowd (two 12-slot spikes, hot model takes 90% "
      "of traffic),\nall runs in one vmapped scan dispatch:\n")
flash = make_trace("flash_crowd", cfg, ocfg.n_slots, seed=cfg.seed,
                   n_events=2, duration=12, intensity=0.9)
calm = make_trace("stationary", cfg, ocfg.n_slots, seed=cfg.seed)
jobs = [dict(cfg=cfg, algo=a, trace=t)
        for t in (calm, flash) for a in ALGOS]
res = run_online_grid(jobs, ocfg)
for (job, r) in zip(jobs, res):
    print(f"  {job['trace'].name:12s} {job['algo']:10s}  "
          f"avg QoE {r['avg_qoe']:.3f}   hit rate {r['hit_rate']:.3f}")
spikes = ", ".join(f"t={e['start']}..{e['end']} model {e['model']}"
                   for e in flash.meta["events"])
print(f"\n  (spikes: {spikes})")
