"""CoCaR-OL vs LFU under a popularity shift (paper Sec. VI / Fig. 13).

Watch the expected-future-gain policy pre-position submodel upgrades while
LFU chases the old distribution.

Run:  PYTHONPATH=src python examples/online_adaptation.py
"""
from repro.core.online import OnlineConfig, run_online
from repro.mec.scenario import MECConfig

cfg = MECConfig(n_users=300, seed=1)
ocfg = OnlineConfig(n_slots=80, pop_change_every=20)

print("online scenario: 5 BSs, 300 users/slot, popularity shifts every "
      "20 slots\n")
for algo in ("cocar-ol", "lfu", "lfu-mad", "random"):
    r = run_online(cfg, ocfg, algo)
    print(f"  {algo:10s}  avg QoE {r['avg_qoe']:.3f}   "
          f"hit rate {r['hit_rate']:.3f}")

print("\nwithout dynamic-DNN partitioning (complete models only):")
ocfg_np = OnlineConfig(n_slots=80, pop_change_every=20, partition=False)
for algo in ("cocar-ol", "lfu"):
    r = run_online(cfg, ocfg_np, algo)
    print(f"  {algo:10s}  avg QoE {r['avg_qoe']:.3f}   "
          f"hit rate {r['hit_rate']:.3f}")
