"""Quickstart: the paper's pipeline in one page.

1. Take a real architecture, disassemble it into dynamic-DNN submodels.
2. Build a MEC scenario (paper Sec. VII-A settings, reduced).
3. Run CoCaR for one observation window (LP -> rounding -> repair).
4. Inspect the caching/routing decisions and metrics.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import configs
from repro.core.cocar import cocar_window
from repro.core.jdcr import check_feasible
from repro.mec import metrics as MET
from repro.mec.scenario import MECConfig, Scenario
from repro.models import partition

# -- 1. dynamic-DNN partitioning of a real architecture ----------------------
cfg = configs.get_config("qwen1.5-0.5b")
print(f"{cfg.name}: {cfg.n_layers} layers, exits at {cfg.exit_layers}")
for j, entry in enumerate(partition.catalog_entry(cfg)):
    print(f"  submodel h{j+1}: {entry['r_h']/1e9:6.2f} GB "
          f"(Δ download {entry['delta_r']/1e9:5.2f} GB), "
          f"{entry['c_h']/1e9:6.2f} GFLOP/token")

# -- 2. MEC scenario ----------------------------------------------------------
mec = MECConfig(n_bs=5, n_users=300, n_models=8, seed=0)
sc = Scenario(mec)
inst = sc.instance(0, sc.empty_cache())
print(f"\nMEC: {inst.N} BSs, {inst.U} users, {inst.M} model types x "
      f"{inst.H} submodels, R={mec.mem_capacity_mb:.0f} MB")

# -- 3. CoCaR ------------------------------------------------------------------
x, A, info = cocar_window(inst, seed=0)
print(f"\nLP optimum: {info['lp_obj']:.1f} total precision")
print("feasible after rounding+repair:", check_feasible(inst, x, A)["ok"])

# -- 4. decisions & metrics ----------------------------------------------------
for n in range(inst.N):
    cached = [f"m{m}:h{np.argmax(x[n, m])}" for m in range(inst.M)
              if np.argmax(x[n, m]) > 0]
    print(f"  BS{n}: {', '.join(cached) or '(empty)'}")
m = MET.window_metrics(inst, x, A)
print(f"\navg precision {m['avg_precision']:.3f}  hit rate "
      f"{m['hit_rate']:.3f}  memory util {m['mem_util']:.3f}")
