"""Train a multi-exit (dynamic-DNN) LM and measure its precision ladder.

The paper assumes each submodel h_j has a precision p_h; here we *earn* that
table: a small early-exit transformer is trained on character data with the
weighted multi-exit CE (all ExtNet heads jointly), then each exit's held-out
CE is reported — deeper exits win, giving the catalog its p_h ordering.
Checkpoints are atomic + resumable (kill it mid-run and re-run to see).

Run:  PYTHONPATH=src python examples/train_submodels.py [steps]
"""
import sys

import numpy as np

from repro import configs
from repro.training.data import char_stream, char_vocab
from repro.training.loop import TrainConfig, eval_exit_ce, train

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 300
_, V = char_vocab()
cfg = configs.get_smoke("qwen1.5-0.5b").replace(
    name="edge-lm-multi-exit", vocab_size=max(V, 64),
    n_layers=6, d_model=128, d_ff=256, exit_layers=(2, 4, 6))

print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model}, "
      f"exits at {cfg.exit_layers}, {steps} steps")
tc = TrainConfig(steps=steps, batch=16, seq=96, ckpt_dir="results/ckpt_demo",
                 ckpt_every=100, log_every=max(steps // 10, 1))
state, hist = train(cfg, tc, char_stream(16, 96, steps + 10))

ces = eval_exit_ce(cfg, state, char_stream(16, 96, 8, seed=123))
print("\nheld-out CE per exit (lower is better):")
prec = np.exp(-ces)          # a monotone precision proxy in [0, 1]
for j, (d, ce, p) in enumerate(zip(cfg.exit_layers, ces, prec)):
    print(f"  submodel h{j+1} (depth {d}): CE={ce:.3f}  precision~{p:.3f}")
assert ces[-1] < ces[0], "deeper exit should be better"
print("\nthe ladder above is what the MEC catalog's p_h column encodes")
