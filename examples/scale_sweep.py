"""A sharded many-scenario sweep: 256 variants x 8 rounding seeds x all
5 offline policies, streamed across an 8-device host mesh.

The grid executor (``repro.scale``) buckets the heterogeneous windows
into a few padded shapes, partitions every chunk across the mesh with
``shard_map``, and streams chunks with donated buffers — peak live
memory is one chunk, not the grid, and the decisions are bit-identical
to the one-device dispatch (see ``docs/algorithms.md`` Sec. 9).

The default run is a reduced 32 x 2 x 5 grid (~a minute on a laptop);
``--full`` runs the headline 256 x 8 x 5 (GatMARL trains once per
topology, host-side and cached, so the full grid is dominated by the
fused LP+rounding dispatches).

Run:  PYTHONPATH=src python examples/scale_sweep.py [--full]
"""
# must precede the first jax import: the device count locks on init
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse                                             # noqa: E402
import resource                                             # noqa: E402
from dataclasses import replace                             # noqa: E402

import numpy as np                                          # noqa: E402

from repro.core.cocar import OFFLINE_POLICIES, improvement_ratio  # noqa: E402
from repro.experiments.sweep import DEFAULT_AXES            # noqa: E402
from repro.mec.scenario import MECConfig, Scenario, config_grid  # noqa: E402
from repro.scale import GridSpec, run_grid                  # noqa: E402

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("--full", action="store_true",
                help="the full 256x8x5 grid (default: 32x2x5)")
args = ap.parse_args()

N_VARIANTS = 256 if args.full else 32
N_SEEDS = 8 if args.full else 2
EPISODES = 20 if args.full else 8

# 256 scenario variants: the four paper axes crossed, then cycled with
# fresh seeds and alternating user counts (heterogeneous shapes on
# purpose — the executor buckets them)
base_cfgs = config_grid(MECConfig(n_users=40), DEFAULT_AXES)
insts = []
for i in range(N_VARIANTS):
    cfg = replace(base_cfgs[i % len(base_cfgs)], seed=i,
                  n_users=40 - (10 if i % 2 else 0))
    sc = Scenario(cfg)
    insts.append(sc.instance(0, sc.empty_cache()))


def progress(ev):
    print(f"  bucket (N={ev['bucket'][0]}, U={ev['bucket'][1]}) "
          f"chunk {ev['chunk'] + 1}/{ev['n_chunks']}: "
          f"{ev['batch']} windows, {ev['in_bytes'] / 1e6:.1f} MB in, "
          f"{ev['seconds']:.2f}s")


spec = GridSpec(kind="policy", insts=insts, seed=0, n_seeds=N_SEEDS,
                best_of=8, pdhg_iters=1200, episodes=EPISODES,
                backend="sharded", chunk_size=max(N_VARIANTS // 8, 8),
                max_buckets=4, progress=progress)

print(f"{N_VARIANTS} variants x {N_SEEDS} seeds x {len(OFFLINE_POLICIES)} "
      f"policies, sharded across the host mesh:\n")
res = run_grid(spec)
st = res.stats

print(f"\nbucket plan (N_pad, U_pad, windows): {st['plan']}")
print(f"{st['chunks']} chunks on {st['devices']} devices in "
      f"{st['seconds']:.1f}s "
      f"({N_VARIANTS * N_SEEDS * len(OFFLINE_POLICIES) / st['seconds']:.0f} "
      "policy-windows/s)")
print(f"peak memory: {st['peak_chunk_in_bytes'] / 1e6:.1f} MB live per "
      f"chunk (a one-shot dispatch would pin "
      f"{st['grid_in_bytes'] / 1e6:.1f} MB of inputs); "
      f"process high-water "
      f"{resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e6:.2f} GB")

met = {p: np.asarray([[res.results[p][b][s][2]["avg_precision"]
                       for s in range(N_SEEDS)]
                      for b in range(N_VARIANTS)])
       for p in OFFLINE_POLICIES}
summary = improvement_ratio(met)
print("\ngrid-mean served precision per policy:")
for p in OFFLINE_POLICIES:
    print(f"  {p:8s}  {summary['means'][p]:.3f}")
print(f"\nCoCaR vs best baseline ({summary['best_baseline']}): "
      f"{summary['ratio']:.2f}x")
