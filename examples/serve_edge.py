"""End-to-end edge serving driver (the paper's kind of system, live).

A 3-pod edge cluster serves batched requests over two model types while the
CoCaR-OL control plane adapts which *submodels* are resident: demand shifts
mid-run, a pod fails and recovers, and every served request is real token
generation with the cached (truncated) parameters.

Run:  PYTHONPATH=src python examples/serve_edge.py
"""
import numpy as np

from repro import configs
from repro.models import partition
from repro.serving import EdgeCluster, Request, WeightStore

rng = np.random.default_rng(0)

MODELS = {"qwen-edge": configs.get_smoke("qwen1.5-0.5b"),
          "mix-edge": configs.get_smoke("mixtral-8x7b")}
store = WeightStore(MODELS, seed=0)
full_bytes = {m: partition.submodel_bytes(c, c.n_exits - 1)
              for m, c in MODELS.items()}
CAP = int(1.2 * max(full_bytes.values()))          # can't fit both in full
cluster = EdgeCluster(store, n_pods=3, capacity_bytes=CAP,
                      bandwidth_Bps=2e8)
print(f"capacity/pod {CAP/1e6:.1f} MB; full sizes "
      f"{ {m: round(b/1e6, 1) for m, b in full_bytes.items()} } MB")

# initial CoCaR-style placement: diversity across pods, small submodels
cluster.apply_caching({0: {"qwen-edge": 2}, 1: {"mix-edge": 1},
                       2: {"qwen-edge": 0, "mix-edge": 0}})
cluster.tick(5.0)

popularity = {"qwen-edge": 0.8, "mix-edge": 0.2}
stats = {"served": 0, "missed": 0, "precision": 0.0}

for slot in range(12):
    # --- demand shift + failure injection -------------------------------
    if slot == 4:
        popularity = {"qwen-edge": 0.2, "mix-edge": 0.8}
        print("== demand shift: mix-edge becomes popular ==")
        # control plane reacts: upgrade mix-edge via Δ-loads, shrink qwen
        cluster.pods[2].cache.request_load("mix-edge", 1, cluster.now)
        ev = cluster.pods[1].cache.request_load("mix-edge", 2, cluster.now)
        if ev:
            print(f"   pod1 Δ-upgrade mix-edge h2->h3: {ev.bytes/1e6:.1f} MB "
                  f"in {ev.seconds:.2f}s")
    if slot == 7:
        print("== pod0 FAILS ==")
        cluster.fail_pod(0)
    if slot == 10:
        print("== pod0 recovers ==")
        cluster.recover_pod(0)

    # --- requests ---------------------------------------------------------
    reqs = []
    for i in range(6):
        model = rng.choice(list(popularity), p=list(popularity.values()))
        reqs.append(Request(
            rid=slot * 10 + i, model=model,
            tokens=list(rng.integers(1, 200, size=4)), max_new=4,
            home=int(rng.integers(3)), deadline=cluster.now + 30.0))
    served = cluster.submit(reqs)
    for r in reqs:
        stats["served" if r.done else "missed"] += 1
        stats["precision"] += r.precision
    res = {p.idx: dict(p.cache.resident) for p in cluster.pods}
    print(f"slot {slot:2d}: served {served}/{len(reqs)}  resident={res}")
    cluster.tick(1.0)

total = stats["served"] + stats["missed"]
print(f"\nserved {stats['served']}/{total} "
      f"avg precision {stats['precision']/total:.3f}")
print("event log:", cluster.log)
